"""Cross-language metadata golden: rust/tests/fixtures/meta_sim_default.json
is the ``meta.json`` the AOT path exports for the sim-default architecture.
``rust/tests/meta_fixture.rs`` asserts the rust parse equals
``ArtifactMeta::sim_default()``; this module asserts the same file from the
exporter's side, so a drift in either language's constants fails one of the
two CI jobs.

The corpus-level checks are hermetic (``compile.corpus`` needs only numpy);
the full ``build_meta`` equality additionally needs jax (``compile.model``
imports it at module scope) and skips itself in hermetic CI like the other
jax-dependent tests.
"""

import json
import os

import pytest

from compile import corpus

FIXTURE = os.path.join(
    os.path.dirname(__file__), "..", "..",
    "rust", "tests", "fixtures", "meta_sim_default.json")


@pytest.fixture(scope="module")
def golden():
    with open(FIXTURE, "r", encoding="utf-8") as f:
        return json.load(f)


def test_golden_corpus_matches_python_constants(golden):
    ccfg = corpus.CorpusConfig()
    c = golden["corpus"]
    assert c["min_steps"] == ccfg.min_steps
    assert c["max_steps"] == ccfg.max_steps
    assert c["max_lookback"] == ccfg.max_lookback
    assert c["specials"] == {
        "pad": corpus.PAD, "bos": corpus.BOS, "eos": corpus.EOS,
        "q": corpus.Q, "eq": corpus.EQ, "sep": corpus.SEP,
        "step": corpus.STEP, "ans": corpus.ANS, "dot": corpus.DOT,
        "plus": corpus.PLUS, "minus": corpus.MINUS, "times": corpus.TIMES,
        "dig0": corpus.DIG0, "idx0": corpus.IDX0, "n_idx": corpus.N_IDX,
    }
    assert c["vocab_names"] == {str(k): v for k, v in corpus.TOKEN_NAMES.items()}
    assert golden["model"]["vocab"] == corpus.VOCAB_SIZE
    assert golden["page_size"] == 16
    assert golden["trained"] is False


def test_golden_equals_build_meta_export(golden):
    pytest.importorskip("jax", reason="jax not installed (hermetic CI)")
    from compile.aot import build_meta
    from compile.model import ModelConfig

    exported = build_meta(
        ModelConfig(), golden["files"],
        golden["capacities"], golden["prefill_sizes"], trained=False)
    assert exported == golden
