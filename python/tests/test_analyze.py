"""Figure-3 analyzer: map classifiers on synthetic attention maps."""

import numpy as np
import pytest

# analyze_attention imports jax at module scope
pytest.importorskip("jax", reason="jax not installed (hermetic CI)")
from compile.analyze_attention import classify_map


def _blank(T):
    # uniform-ish causal map
    m = np.zeros((T, T), np.float32)
    for t in range(T):
        m[t, : t + 1] = 1.0 / (t + 1)
    return m


def test_lazy_map_detected():
    T, plen = 60, 20
    m = np.zeros((T, T), np.float32)
    for t in range(T):
        m[t, 0] = 0.5  # sink
        lo = max(0, t - 3)
        m[t, lo:t + 1] = 0.5 / (t + 1 - lo)  # local band
    labels = classify_map(m, plen)
    assert "lazy" in labels


def test_milestone_map_detected():
    T, plen = 80, 20
    m = _blank(T)
    c = 30  # milestone column (decode region)
    # bright for decode steps 12..20, then dark forever
    for t in range(plen + 12, plen + 21):
        m[t, c] = 0.5
    for t in range(plen + 21, T):
        m[t, c] = 0.001
    labels = classify_map(m, plen, fade=10)
    assert "milestone" in labels


def test_phoenix_map_detected():
    T, plen = 90, 20
    m = _blank(T)
    c = 5  # prompt column
    m[plen + 2, c] = 0.5
    for t in range(plen + 3, plen + 60):
        m[t, c] = 0.0001
    m[plen + 62, c] = 0.5
    labels = classify_map(m, plen, gap=24)
    assert "phoenix" in labels


def test_blank_map_unlabelled():
    labels = classify_map(_blank(60), 20)
    assert "milestone" not in labels
    assert "phoenix" not in labels
