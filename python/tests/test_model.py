"""L2 correctness: dense/training form vs serving decomposition."""

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed (hermetic CI)")
import jax
import jax.numpy as jnp

from compile import corpus
from compile.model import (ModelConfig, embed_tok, forward_train, init_params,
                           layer_attn_mlp, layer_qkv, lm_head, prefill)

CFG = ModelConfig()
PARAMS = init_params(jax.random.PRNGKey(0), CFG)


def test_forward_shapes():
    toks = jnp.zeros((2, 17), jnp.int32)
    logits = forward_train(PARAMS, CFG, toks)
    assert logits.shape == (2, 17, CFG.vocab)


def test_causality():
    """Changing a future token must not change past logits."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, CFG.vocab, size=(1, 24)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 20] = (toks2[0, 20] + 1) % CFG.vocab
    a = forward_train(PARAMS, CFG, jnp.asarray(toks))
    b = forward_train(PARAMS, CFG, jnp.asarray(toks2))
    np.testing.assert_allclose(a[0, :20], b[0, :20], rtol=1e-5, atol=1e-5)
    assert not np.allclose(a[0, 20:], b[0, 20:])


def test_attention_maps_shape_and_rowsum():
    toks = jnp.zeros((1, 12), jnp.int32)
    _, maps = forward_train(PARAMS, CFG, toks, return_attn=True)
    assert maps.shape == (CFG.n_layers, 1, CFG.n_heads, 12, 12)
    np.testing.assert_allclose(np.asarray(maps).sum(-1), 1.0, rtol=1e-4, atol=1e-4)


def test_prefill_matches_dense_forward():
    """prefill logits == forward_train logits at the last prompt position."""
    rng = np.random.default_rng(1)
    plen = 13
    toks = rng.integers(3, CFG.vocab, size=(plen,)).astype(np.int32)
    P = 32
    padded = np.full((P,), corpus.PAD, np.int32)
    padded[:plen] = toks
    k_c, v_c, logits = prefill(PARAMS, CFG, jnp.asarray(padded), jnp.asarray(plen))
    assert k_c.shape == (CFG.n_layers, P, CFG.n_kv_heads, CFG.head_dim)
    dense = forward_train(PARAMS, CFG, jnp.asarray(toks[None]))
    np.testing.assert_allclose(logits, dense[0, plen - 1], rtol=2e-4, atol=2e-4)
    # zeroed beyond length
    assert float(jnp.abs(k_c[:, plen:]).max()) == 0.0


def test_serving_decode_matches_dense():
    """One full greedy decode step via the serving decomposition must equal
    the dense forward's next-token logits."""
    rng = np.random.default_rng(2)
    plen = 11
    toks = rng.integers(3, CFG.vocab, size=(plen,)).astype(np.int32)
    P = 16
    padded = np.full((P,), corpus.PAD, np.int32)
    padded[:plen] = toks
    k_c, v_c, logits_p = prefill(PARAMS, CFG, jnp.asarray(padded), jnp.asarray(plen))
    next_tok = int(jnp.argmax(logits_p))

    # serving step for next_tok at position plen over the prefill cache
    L = 64
    k_buf = np.zeros((CFG.n_layers, L, CFG.n_kv_heads, CFG.head_dim), np.float32)
    v_buf = np.zeros_like(k_buf)
    k_buf[:, :plen] = np.asarray(k_c[:, :plen])
    v_buf[:, :plen] = np.asarray(v_c[:, :plen])
    h = embed_tok(PARAMS, CFG, jnp.asarray([next_tok], jnp.int32))
    pos = jnp.asarray([plen], jnp.float32)
    for l in range(CFG.n_layers):
        q, k, v = layer_qkv(PARAMS, CFG, l, h, pos)
        kb, vb = k_buf[l].copy(), v_buf[l].copy()
        kb[plen], vb[plen] = np.asarray(k), np.asarray(v)  # self KV visible
        valid = np.zeros((L,), np.float32)
        valid[: plen + 1] = 1.0
        h = layer_attn_mlp(PARAMS, CFG, l, h, q, jnp.asarray(kb), jnp.asarray(vb),
                           jnp.asarray(valid))
    logits_s = lm_head(PARAMS, CFG, h)

    dense = forward_train(
        PARAMS, CFG, jnp.asarray(np.concatenate([toks, [next_tok]])[None]))
    np.testing.assert_allclose(logits_s, dense[0, plen], rtol=5e-4, atol=5e-4)


def test_kernel_vs_ref_inside_layer():
    """layer_attn_mlp(use_kernel=True) == layer_attn_mlp(use_kernel=False)."""
    rng = np.random.default_rng(3)
    L = 64
    h = jnp.asarray(rng.normal(size=(CFG.d_model,)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(CFG.n_heads, CFG.head_dim)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(L, CFG.n_kv_heads, CFG.head_dim)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(L, CFG.n_kv_heads, CFG.head_dim)).astype(np.float32))
    valid = jnp.asarray((rng.random(L) < 0.5).astype(np.float32)).at[0].set(1.0)
    a = layer_attn_mlp(PARAMS, CFG, 0, h, q, k, v, valid, use_kernel=True)
    b = layer_attn_mlp(PARAMS, CFG, 0, h, q, k, v, valid, use_kernel=False)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_param_count_reasonable():
    n = CFG.param_count(PARAMS)
    assert 3e5 < n < 3e6
