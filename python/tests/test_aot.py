"""AOT path: HLO-text export sanity (random weights, quick ladder)."""

import json
import os
import tempfile

import pytest

pytest.importorskip("jax", reason="jax not installed (hermetic CI)")
import jax

from compile import aot
from compile.model import ModelConfig, init_params

CFG = ModelConfig()


@pytest.fixture(scope="module")
def exported():
    params = init_params(jax.random.PRNGKey(0), CFG)
    d = tempfile.mkdtemp(prefix="raas_aot_")
    files = aot.export_all(params, CFG, d, capacities=[64], prefill_sizes=[64],
                           verbose=False)
    return d, files


def test_export_writes_all_modules(exported):
    d, files = exported
    assert os.path.exists(os.path.join(d, files["embed"]))
    assert os.path.exists(os.path.join(d, files["lm_head"]))
    assert len(files["qkv"]) == CFG.n_layers
    assert len(files["attn_mlp"]["64"]) == CFG.n_layers
    for name in files["qkv"] + files["attn_mlp"]["64"]:
        assert os.path.getsize(os.path.join(d, name)) > 100


def test_hlo_is_text_not_proto(exported):
    d, files = exported
    with open(os.path.join(d, files["embed"])) as f:
        head = f.read(200)
    assert "HloModule" in head  # text interchange format (see DESIGN.md)


def test_attn_mlp_entry_has_expected_params(exported):
    d, files = exported
    with open(os.path.join(d, files["attn_mlp"]["64"][0])) as f:
        text = f.read()
    assert "ENTRY" in text
    # 5 runtime inputs: h, q, K, V, valid (weights are constants).  Count
    # parameters in the ENTRY computation only — nested computations (e.g.
    # the pallas while-loop body) declare their own.
    entry = text[text.index("ENTRY"):]
    assert entry.count("parameter(") == 5


def test_meta_roundtrip(exported):
    d, files = exported
    meta = aot.build_meta(CFG, files, [64], [64], trained=False)
    s = json.dumps(meta)
    back = json.loads(s)
    assert back["model"]["n_layers"] == CFG.n_layers
    assert back["page_size"] == 16
    assert back["corpus"]["specials"]["dig0"] == 12
