"""Corpus semantics: the synthetic reasoning task used across both layers."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (hermetic CI)")
from hypothesis import given, settings, strategies as st

from compile import corpus


def test_apply_op():
    assert corpus.apply_op(7, corpus.PLUS, 5) == 2
    assert corpus.apply_op(3, corpus.MINUS, 7) == 6
    assert corpus.apply_op(4, corpus.TIMES, 4) == 6


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_problem_values_consistent(seed):
    rng = np.random.default_rng(seed)
    cfg = corpus.CorpusConfig()
    p = corpus.sample_problem(rng, cfg)
    assert p.values[0] == p.a
    for i, (r, op, b) in enumerate(p.steps, start=1):
        assert 0 <= r < i
        assert i - r <= cfg.max_lookback
        assert p.values[i] == corpus.apply_op(p.values[r], op, b)
    assert 0 <= p.answer <= 9


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_encode_lengths_match_config(seed):
    rng = np.random.default_rng(seed)
    cfg = corpus.CorpusConfig()
    p = corpus.sample_problem(rng, cfg, k=cfg.max_steps)
    assert len(corpus.encode_prompt(p)) == cfg.prompt_len
    assert len(corpus.encode_decode(p)) == cfg.decode_len


def test_parse_answer_roundtrip():
    rng = np.random.default_rng(0)
    cfg = corpus.CorpusConfig()
    for _ in range(20):
        p = corpus.sample_problem(rng, cfg)
        dec = corpus.encode_decode(p)
        assert corpus.parse_answer(dec) == p.answer


def test_parse_answer_garbage_is_none():
    assert corpus.parse_answer([corpus.STEP, corpus.SEP, corpus.EOS]) is None
    assert corpus.parse_answer([]) is None
    # ANS not followed by a digit
    assert corpus.parse_answer([corpus.ANS, corpus.SEP]) is None


def test_milestone_positions_point_at_values():
    rng = np.random.default_rng(1)
    cfg = corpus.CorpusConfig()
    p = corpus.sample_problem(rng, cfg)
    full, plen = corpus.encode_full(p)
    for i, pos in corpus.milestone_positions(p, plen).items():
        assert full[pos] == corpus.DIG0 + p.values[i]


def test_phoenix_positions_point_at_operands():
    rng = np.random.default_rng(2)
    cfg = corpus.CorpusConfig()
    p = corpus.sample_problem(rng, cfg)
    full, _ = corpus.encode_full(p)
    for i, pos in corpus.phoenix_positions(p).items():
        r, op, b = p.steps[i - 1]
        assert full[pos] == corpus.DIG0 + b


def test_training_batch_masks_only_decode():
    rng = np.random.default_rng(3)
    cfg = corpus.CorpusConfig()
    toks, mask = corpus.training_batch(rng, cfg, 4)
    assert toks.shape == mask.shape == (4, cfg.seq_len)
    # mask never set on pure-pad tail beyond sequence end
    for b in range(4):
        n = int((toks[b] != corpus.PAD).sum())
        assert mask[b, n:].sum() == 0
        assert mask[b].sum() > 0


def test_detok_readable():
    rng = np.random.default_rng(4)
    p = corpus.sample_problem(rng, corpus.CorpusConfig(), k=2)
    s = corpus.detok(corpus.encode_full(p)[0])
    assert "Q" in s and "=" in s and "A" in s
