"""L1 correctness: Pallas kernels vs pure-jnp oracle (the CORE signal).

Hypothesis sweeps shapes / GQA ratios / mask patterns; assert_allclose
against ref.py.  All kernels run interpret=True (CPU image)."""

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed (hermetic CI)")
pytest.importorskip("hypothesis", reason="hypothesis not installed (hermetic CI)")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.paged_attn import paged_attention, vmem_bytes
from compile.kernels.rep_score import rep_score

RTOL, ATOL = 2e-5, 2e-5


def _mk(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([(8, 4, 16), (8, 8, 16), (4, 2, 32), (8, 2, 8), (2, 1, 64)]),
    st.sampled_from([64, 128, 256]),
    st.floats(min_value=0.05, max_value=1.0),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_paged_attention_matches_ref(heads_kv_hd, L, density, seed):
    nh, nkv, hd = heads_kv_hd
    rng = np.random.default_rng(seed)
    q = _mk(rng, nh, hd)
    k = _mk(rng, L, nkv, hd)
    v = _mk(rng, L, nkv, hd)
    valid = (rng.random(L) < density).astype(np.float32)
    valid[rng.integers(0, L)] = 1.0  # at least one valid slot
    valid = jnp.asarray(valid)
    out = paged_attention(q, k, v, valid)
    want = ref.paged_attention_ref(q, k, v, valid)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_paged_attention_all_valid():
    rng = np.random.default_rng(0)
    q, k, v = _mk(rng, 8, 16), _mk(rng, 128, 4, 16), _mk(rng, 128, 4, 16)
    valid = jnp.ones((128,), jnp.float32)
    np.testing.assert_allclose(
        paged_attention(q, k, v, valid),
        ref.paged_attention_ref(q, k, v, valid), rtol=RTOL, atol=ATOL)


def test_paged_attention_single_valid_slot_returns_that_value():
    """With exactly one valid slot, output == that slot's value (per group)."""
    rng = np.random.default_rng(1)
    nh, nkv, hd, L = 8, 4, 16, 64
    q, k, v = _mk(rng, nh, hd), _mk(rng, L, nkv, hd), _mk(rng, L, nkv, hd)
    valid = np.zeros(L, np.float32)
    valid[17] = 1.0
    out = paged_attention(q, k, v, jnp.asarray(valid))
    group = nh // nkv
    for h in range(nh):
        np.testing.assert_allclose(out[h], v[17, h // group], rtol=RTOL, atol=ATOL)


def test_paged_attention_block_sizes_agree():
    rng = np.random.default_rng(2)
    q, k, v = _mk(rng, 8, 16), _mk(rng, 256, 4, 16), _mk(rng, 256, 4, 16)
    valid = jnp.asarray((rng.random(256) < 0.5).astype(np.float32))
    a = paged_attention(q, k, v, valid, block_l=64)
    b = paged_attention(q, k, v, valid, block_l=256)
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_paged_attention_invalid_slots_are_ignored():
    """Garbage in invalid slots must not perturb the output."""
    rng = np.random.default_rng(3)
    q = _mk(rng, 8, 16)
    k = np.asarray(_mk(rng, 128, 4, 16))
    v = np.asarray(_mk(rng, 128, 4, 16))
    valid = (rng.random(128) < 0.5).astype(np.float32)
    valid[0] = 1.0
    k2, v2 = k.copy(), v.copy()
    k2[valid < 0.5] = 1e6  # poison
    v2[valid < 0.5] = -1e6
    a = paged_attention(q, jnp.asarray(k), jnp.asarray(v), jnp.asarray(valid))
    b = paged_attention(q, jnp.asarray(k2), jnp.asarray(v2), jnp.asarray(valid))
    np.testing.assert_allclose(a, b, rtol=RTOL, atol=ATOL)


def test_paged_attention_rejects_ragged_L():
    rng = np.random.default_rng(4)
    with pytest.raises(AssertionError):
        paged_attention(_mk(rng, 8, 16), _mk(rng, 96, 4, 16), _mk(rng, 96, 4, 16),
                        jnp.ones((96,)), block_l=64)


def test_vmem_estimate_monotone_in_block():
    assert vmem_bytes(8192, 4, 16, 8, block_l=128) > vmem_bytes(8192, 4, 16, 8, block_l=64)
    # must fit a ~16 MB VMEM budget comfortably
    assert vmem_bytes(8192, 4, 16, 8, block_l=128) < 16 * 2**20


# ---------------------------------------------------------------------------
# rep_score
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([(8, 4, 16), (4, 4, 32), (8, 2, 16)]),
    st.integers(min_value=1, max_value=64),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_rep_score_matches_ref(heads_kv_hd, P, seed):
    nh, nkv, hd = heads_kv_hd
    rng = np.random.default_rng(seed)
    q = _mk(rng, nh, hd)
    kmin = _mk(rng, P, nkv, hd)
    kmax = jnp.asarray(np.asarray(kmin) + np.abs(rng.normal(size=(P, nkv, hd))).astype(np.float32))
    valid = jnp.asarray((rng.random(P) < 0.8).astype(np.float32))
    out = rep_score(q, kmin, kmax, valid)
    want = ref.rep_score_ref(q, kmin, kmax, valid)
    np.testing.assert_allclose(out, want, rtol=RTOL, atol=ATOL)


def test_rep_score_is_upper_bound():
    """The Quest bound must dominate the true q·k of every key in the page."""
    rng = np.random.default_rng(5)
    nh, nkv, hd, page = 8, 4, 16, 16
    q = _mk(rng, nh, hd)
    keys = rng.normal(size=(page, nkv, hd)).astype(np.float32)
    kmin = jnp.asarray(keys.min(axis=0, keepdims=True))  # [1, nkv, hd]
    kmax = jnp.asarray(keys.max(axis=0, keepdims=True))
    score = np.asarray(rep_score(q, kmin, kmax, jnp.ones((1,), jnp.float32)))
    group = nh // nkv
    for h in range(nh):
        true = keys[:, h // group, :] @ np.asarray(q[h])
        assert score[h, 0] >= true.max() - 1e-4


def test_page_probs_sum_to_one():
    rng = np.random.default_rng(6)
    scores = _mk(rng, 8, 32)
    valid = jnp.asarray((rng.random(32) < 0.6).astype(np.float32))
    p = ref.page_probs_ref(scores, valid, 16)
    assert abs(float(jnp.sum(p)) - 1.0) < 1e-5
    assert float(jnp.max(jnp.where(valid > 0.5, 0.0, p))) == 0.0
