"""Training smoke: loss decreases, weights round-trip through npz."""

import os
import tempfile

import numpy as np
import pytest

pytest.importorskip("jax", reason="jax not installed (hermetic CI)")
import jax
import jax.numpy as jnp

from compile import corpus
from compile.model import ModelConfig, init_params
from compile.train import (adam_init, adam_update, flatten_params, load_weights,
                           loss_fn, save_weights, unflatten_params)

CFG = ModelConfig()


def test_loss_decreases_over_a_few_steps():
    params = init_params(jax.random.PRNGKey(0), CFG)
    opt = adam_init(params)
    rng = np.random.default_rng(0)
    ccfg = corpus.CorpusConfig(max_steps=6)

    @jax.jit
    def step(params, opt, t, m, lr):
        loss, g = jax.value_and_grad(loss_fn)(params, CFG, t, m)
        params, opt = adam_update(params, g, opt, lr)
        return params, opt, loss

    lr = jnp.asarray(1e-3, jnp.float32)
    losses = []
    for _ in range(8):
        t, m = corpus.training_batch(rng, ccfg, 8)
        params, opt, loss = step(params, opt, jnp.asarray(t), jnp.asarray(m), lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_weights_roundtrip():
    params = init_params(jax.random.PRNGKey(1), CFG)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.npz")
        save_weights(path, params)
        back = load_weights(path, CFG.n_layers)
    np.testing.assert_array_equal(params["embed"], back["embed"])
    for a, b in zip(params["layers"], back["layers"]):
        assert set(a.keys()) == set(b.keys())
        np.testing.assert_array_equal(a["wq"], b["wq"])


def test_flatten_unflatten_inverse():
    params = init_params(jax.random.PRNGKey(2), CFG)
    flat = flatten_params(params)
    back = unflatten_params(flat, CFG.n_layers)
    np.testing.assert_array_equal(params["layers"][2]["wd"], back["layers"][2]["wd"])
