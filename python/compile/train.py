"""Train the tiny reasoning model on the synthetic chain-arithmetic corpus.

Build-time only.  Produces ``artifacts/weights.npz`` (flat param dict) and
``artifacts/train_log.json`` (loss curve + eval accuracy, recorded in
EXPERIMENTS.md as the end-to-end training validation run).

Usage: python -m compile.train [--steps 800] [--batch 24] [--out ../artifacts]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .model import ModelConfig, forward_train, generate_dense, init_params


def flatten_params(params) -> dict:
    flat = {"embed": params["embed"], "ln_f": params["ln_f"]}
    for i, layer in enumerate(params["layers"]):
        for k, v in layer.items():
            flat[f"layers.{i}.{k}"] = v
    return flat


def unflatten_params(flat, n_layers: int) -> dict:
    params = {"embed": jnp.asarray(flat["embed"]), "ln_f": jnp.asarray(flat["ln_f"]),
              "layers": []}
    for i in range(n_layers):
        prefix = f"layers.{i}."
        params["layers"].append({
            k[len(prefix):]: jnp.asarray(v) for k, v in flat.items()
            if k.startswith(prefix)
        })
    return params


def save_weights(path: str, params) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in flatten_params(params).items()})


def load_weights(path: str, n_layers: int) -> dict:
    with np.load(path) as z:
        return unflatten_params(dict(z), n_layers)


def loss_fn(params, cfg, tokens, mask):
    logits = forward_train(params, cfg, tokens)  # [B,T,V]
    # next-token CE at masked positions
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, :-1]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.float32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.98, eps=1e-9):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def eval_exact_match(params, cfg, ccfg, n: int = 12, seed: int = 123) -> float:
    """Greedy-generate n problems end-to-end; exact-match on the final answer."""
    rng = np.random.default_rng(seed)
    good = 0
    for _ in range(n):
        p = corpus.sample_problem(rng, ccfg)
        prompt = corpus.encode_prompt(p)
        out = generate_dense(params, cfg, prompt, max_new=cfg_max_new(ccfg), eos=corpus.EOS)
        if corpus.parse_answer(out) == p.answer:
            good += 1
    return good / n


def cfg_max_new(ccfg) -> int:
    return ccfg.decode_len + 8


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=int(os.environ.get("RAAS_TRAIN_STEPS", 800)))
    ap.add_argument("--batch", type=int, default=24)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--eval-every", type=int, default=200)
    args = ap.parse_args()

    cfg = ModelConfig()
    ccfg = corpus.CorpusConfig()
    os.makedirs(args.out, exist_ok=True)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    print(f"model params: {cfg.param_count(params):,}")
    opt = adam_init(params)

    @jax.jit
    def train_step(params, opt, tokens, mask, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, tokens, mask)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    rng = np.random.default_rng(args.seed)
    log = {"loss": [], "eval": [], "config": cfg.to_dict(),
           "corpus": {"min_steps": ccfg.min_steps, "max_steps": ccfg.max_steps,
                      "max_lookback": ccfg.max_lookback}}
    t0 = time.time()
    for step in range(1, args.steps + 1):
        # Curriculum over chain length: the two-hop lookup circuit emerges
        # far more reliably when short chains dominate early training.
        cur_max = min(ccfg.max_steps, 4 + step // 100)
        cur_cfg = dataclasses.replace(ccfg, max_steps=cur_max)
        tokens, mask = corpus.training_batch(rng, cur_cfg, args.batch,
                                             seq_len=ccfg.seq_len)
        # lr must be a traced array: a fresh python float would trigger a jit
        # recompile every warmup step.
        lr = jnp.asarray(args.lr * min(1.0, step / max(args.warmup, 1)), jnp.float32)
        params, opt, loss = train_step(params, opt, jnp.asarray(tokens),
                                       jnp.asarray(mask), lr)
        if step % 20 == 0 or step == 1:
            l = float(loss)
            log["loss"].append([step, l])
            print(f"step {step:5d} loss {l:.4f} ({time.time()-t0:.0f}s)", flush=True)
        if step % args.eval_every == 0 or step == args.steps:
            acc = eval_exact_match(params, cfg, ccfg)
            log["eval"].append([step, acc])
            print(f"step {step:5d} eval exact-match {acc:.3f}", flush=True)
            save_weights(os.path.join(args.out, "weights.npz"), params)
            with open(os.path.join(args.out, "train_log.json"), "w") as f:
                json.dump(log, f)
    save_weights(os.path.join(args.out, "weights.npz"), params)
    with open(os.path.join(args.out, "train_log.json"), "w") as f:
        json.dump(log, f)
    print("training done")


if __name__ == "__main__":
    main()
