"""L2: the served model — a small GQA transformer in JAX.

Two forms of the same network, numerically identical (tested):

  * **training/dense form** (`forward_train`): batched causal attention over
    full sequences, used by `train.py` and the Figure-3 attention analyzer.
  * **serving form**: the decomposition the rust coordinator drives per decode
    step — `embed_tok`, per-layer `layer_qkv` / `layer_attn_mlp` (which calls
    the L1 Pallas paged-attention kernel over gathered slots), `lm_head`,
    plus `prefill` which emits the post-RoPE KV cache for the prompt.

Weights are baked into the AOT artifacts as HLO constants by `aot.py`, so the
rust runtime never handles parameters.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.paged_attn import paged_attention
from .kernels import ref as kref

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # Sized for the single-core CPU budget of this environment (see
    # DESIGN.md §3): ~0.6M params trains to >95% exact-match on the
    # synthetic reasoning task in a few thousand Adam steps.
    vocab: int = 48
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    head_dim: int = 16
    d_ff: int = 256
    rope_theta: float = 10000.0
    rms_eps: float = 1e-5

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self, params=None) -> int:
        if params is None:
            params = init_params(jax.random.PRNGKey(0), self)
        return sum(int(np.prod(v.shape)) for v in jax.tree_util.tree_leaves(params))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def init_params(key, cfg: ModelConfig) -> dict:
    """Initialise parameters (normal 0.02 projections, unit norms)."""
    def dense(key, shape, scale=0.02):
        return scale * jax.random.normal(key, shape, jnp.float32)

    keys = jax.random.split(key, 2 + cfg.n_layers)
    params = {
        "embed": dense(keys[0], (cfg.vocab, cfg.d_model)),
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [],
    }
    for l in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + l], 7)
        params["layers"].append({
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "wq": dense(ks[0], (cfg.d_model, cfg.q_dim)),
            "wk": dense(ks[1], (cfg.d_model, cfg.kv_dim)),
            "wv": dense(ks[2], (cfg.d_model, cfg.kv_dim)),
            "wo": dense(ks[3], (cfg.q_dim, cfg.d_model)),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
            "wg": dense(ks[4], (cfg.d_model, cfg.d_ff)),
            "wu": dense(ks[5], (cfg.d_model, cfg.d_ff)),
            "wd": dense(ks[6], (cfg.d_ff, cfg.d_model)),
        })
    return params


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-5):
    return x * w / jnp.sqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)


def rope_freqs(cfg: ModelConfig):
    half = cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return inv  # [hd/2]


def apply_rope(x, pos, cfg: ModelConfig):
    """Rotate-half RoPE.  x: [..., head_dim], pos broadcastable to x[..., 0]."""
    half = cfg.head_dim // 2
    inv = rope_freqs(cfg)
    ang = pos[..., None] * inv  # [..., hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, layer):
    return (jax.nn.silu(x @ layer["wg"]) * (x @ layer["wu"])) @ layer["wd"]


# ---------------------------------------------------------------------------
# Training / dense form
# ---------------------------------------------------------------------------

def forward_train(params, cfg: ModelConfig, tokens, return_attn: bool = False):
    """Batched dense causal forward.  tokens: [B, T] int32 → logits [B, T, V].

    With ``return_attn`` also returns per-layer attention probabilities
    [n_layers, B, n_heads, T, T] (used by the Figure-3 analyzer — memory
    heavy, only call on short sequences).
    """
    B, T = tokens.shape
    group = cfg.n_heads // cfg.n_kv_heads
    pos = jnp.arange(T, dtype=jnp.float32)
    h = params["embed"][tokens]  # [B, T, d]
    causal = jnp.tril(jnp.ones((T, T), bool))
    attn_maps = []
    for layer in params["layers"]:
        x = rms_norm(h, layer["ln1"], cfg.rms_eps)
        q = (x @ layer["wq"]).reshape(B, T, cfg.n_heads, cfg.head_dim)
        k = (x @ layer["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = (x @ layer["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, pos[None, :, None], cfg)
        k = apply_rope(k, pos[None, :, None], cfg)
        kh = jnp.repeat(k, group, axis=2)
        vh = jnp.repeat(v, group, axis=2)
        scores = jnp.einsum("bthd,bshd->bhts", q, kh) / jnp.sqrt(
            jnp.asarray(cfg.head_dim, jnp.float32))
        scores = jnp.where(causal[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        if return_attn:
            attn_maps.append(probs)
        attn = jnp.einsum("bhts,bshd->bthd", probs, vh).reshape(B, T, cfg.q_dim)
        h = h + attn @ layer["wo"]
        x = rms_norm(h, layer["ln2"], cfg.rms_eps)
        h = h + swiglu(x, layer)
    logits = rms_norm(h, params["ln_f"], cfg.rms_eps) @ params["embed"].T
    if return_attn:
        return logits, jnp.stack(attn_maps)
    return logits


# ---------------------------------------------------------------------------
# Serving form (what aot.py lowers, what the rust engine drives)
# ---------------------------------------------------------------------------

def embed_tok(params, cfg: ModelConfig, token):
    """token: i32[1] → hidden f32[d]."""
    return params["embed"][token[0]]


def layer_qkv(params, cfg: ModelConfig, layer_idx: int, h, pos):
    """h: f32[d], pos: f32[1] → (q [nh,hd] RoPE'd, k [nkv,hd] RoPE'd, v)."""
    layer = params["layers"][layer_idx]
    x = rms_norm(h, layer["ln1"], cfg.rms_eps)
    q = (x @ layer["wq"]).reshape(cfg.n_heads, cfg.head_dim)
    k = (x @ layer["wk"]).reshape(cfg.n_kv_heads, cfg.head_dim)
    v = (x @ layer["wv"]).reshape(cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, jnp.broadcast_to(pos, (cfg.n_heads,)), cfg)
    k = apply_rope(k, jnp.broadcast_to(pos, (cfg.n_kv_heads,)), cfg)
    return q, k, v


def layer_attn_mlp(params, cfg: ModelConfig, layer_idx: int, h, q, k_sel, v_sel,
                   valid, interpret: bool = True, use_kernel: bool = True):
    """Post-QKV half of a decode layer over gathered slots.

    h: f32[d] residual input; q: [nh,hd]; k_sel/v_sel: [L,nkv,hd]; valid: [L].
    Returns hidden f32[d].
    """
    layer = params["layers"][layer_idx]
    if use_kernel:
        attn = paged_attention(q, k_sel, v_sel, valid, interpret=interpret)
    else:
        attn = kref.paged_attention_ref(q, k_sel, v_sel, valid)
    h = h + attn.reshape(cfg.q_dim) @ layer["wo"]
    x = rms_norm(h, layer["ln2"], cfg.rms_eps)
    return h + swiglu(x, layer)


def lm_head(params, cfg: ModelConfig, h):
    """h: f32[d] → logits f32[V]."""
    return rms_norm(h, params["ln_f"], cfg.rms_eps) @ params["embed"].T


def prefill(params, cfg: ModelConfig, tokens, length):
    """Dense prefill emitting the serving-form KV cache.

    tokens: i32[P] (padded), length: i32[] actual prompt length.
    Returns (k_cache [n_layers,P,nkv,hd] post-RoPE, v_cache same shape,
    logits f32[V] at position length-1).  Entries at positions >= length are
    zeroed; the rust engine only consumes the first ``length`` slots.
    """
    P = tokens.shape[0]
    group = cfg.n_heads // cfg.n_kv_heads
    pos = jnp.arange(P, dtype=jnp.float32)
    idx = jnp.arange(P)
    in_range = idx < length  # [P]
    h = params["embed"][tokens]  # [P, d]
    causal = (idx[:, None] >= idx[None, :]) & in_range[None, :]
    ks, vs = [], []
    for layer in params["layers"]:
        x = rms_norm(h, layer["ln1"], cfg.rms_eps)
        q = (x @ layer["wq"]).reshape(P, cfg.n_heads, cfg.head_dim)
        k = (x @ layer["wk"]).reshape(P, cfg.n_kv_heads, cfg.head_dim)
        v = (x @ layer["wv"]).reshape(P, cfg.n_kv_heads, cfg.head_dim)
        q = apply_rope(q, pos[:, None], cfg)
        k = apply_rope(k, pos[:, None], cfg)
        ks.append(jnp.where(in_range[:, None, None], k, 0.0))
        vs.append(jnp.where(in_range[:, None, None], v, 0.0))
        kh = jnp.repeat(k, group, axis=1)
        vh = jnp.repeat(v, group, axis=1)
        scores = jnp.einsum("thd,shd->hts", q, kh) / jnp.sqrt(
            jnp.asarray(cfg.head_dim, jnp.float32))
        scores = jnp.where(causal[None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("hts,shd->thd", probs, vh).reshape(P, cfg.q_dim)
        h = h + attn @ layer["wo"]
        x = rms_norm(h, layer["ln2"], cfg.rms_eps)
        h = h + swiglu(x, layer)
    logits_all = rms_norm(h, params["ln_f"], cfg.rms_eps) @ params["embed"].T
    logits = logits_all[jnp.maximum(length - 1, 0)]
    return jnp.stack(ks), jnp.stack(vs), logits


_GEN_CACHE = {}


def generate_dense(params, cfg: ModelConfig, prompt_tokens, max_new: int, eos: int,
                   pad: int = 0):
    """Reference greedy generation (dense, python loop) — used by train-time
    eval and by tests as the oracle for the rust serving path.

    Uses a fixed-size token buffer so the jitted forward compiles once per
    (model, buffer-length) pair instead of once per sequence length.
    """
    toks = [int(t) for t in prompt_tokens]
    T = len(toks) + max_new
    # round buffer up to a multiple of 64 to bound recompiles
    T = ((T + 63) // 64) * 64
    key = (id(params), T)
    if key not in _GEN_CACHE:
        _GEN_CACHE[key] = jax.jit(lambda t: forward_train(params, cfg, t))
    fwd = _GEN_CACHE[key]
    buf = np.full((1, T), pad, dtype=np.int32)
    buf[0, : len(toks)] = toks
    out = []
    n = len(toks)
    for _ in range(max_new):
        logits = fwd(jnp.asarray(buf))
        nxt = int(jnp.argmax(logits[0, n - 1]))
        buf[0, n] = nxt
        n += 1
        out.append(nxt)
        if nxt == eos:
            break
    return out
