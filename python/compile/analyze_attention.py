"""Figure-3 data: classify the trained model's attention maps.

Runs the dense model over sampled problems with attention capture, then
classifies every (layer, head) map the way the paper's §3.1 manual
inspection does:

  * **milestone map** — has a decode column that is bright (above `hi`)
    while consumed and then fades for good (the waterfall);
  * **phoenix map** — has a column that goes quiet for >= `gap` decode steps
    and then re-lights (paper uses 128; scaled by --gap to this model's
    shorter chains);
  * **lazy map** — attention mass concentrated on the sink + local band
    (StreamingLLM pattern).

Writes ``artifacts/fig3_attention_stats.json`` which `raas fig3` renders
next to the paper's 20-25 % / 1-2 % / >70 % figures.

Usage: python -m compile.analyze_attention [--out ../artifacts] [--problems 12]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import numpy as np

from . import corpus
from .model import ModelConfig, forward_train
from .train import load_weights


def classify_map(attn, prompt_len: int, hi=0.2, lo=0.02, gap=24, fade=12):
    """Classify one [T, T] attention map.  Returns set of labels."""
    T = attn.shape[0]
    labels = set()
    # lazy: fraction of each decode row's mass on sink (first 2 cols) + local
    # (previous 4 positions)
    rows = range(prompt_len, T)
    lazy_mass = []
    for t in rows:
        sink = attn[t, :2].sum()
        local = attn[t, max(0, t - 4):t + 1].sum()
        lazy_mass.append(min(1.0, sink + local))
    if lazy_mass and float(np.mean(lazy_mass)) > 0.80:
        labels.add("lazy")

    # column analysis over decode steps
    cols = attn[prompt_len:, :]  # [D, T] rows=decode steps
    D = cols.shape[0]
    for c in range(T):
        series = cols[:, c]
        hot = np.where(series >= hi)[0]
        if len(hot) == 0:
            continue
        # ignore trivial self/local columns
        if c >= prompt_len and (hot + prompt_len - c <= 2).all():
            continue
        # phoenix: two hots separated by a quiet gap
        if len(hot) >= 2:
            gaps = np.diff(hot)
            if gaps.max() >= gap and series[hot[0] + 1:hot[-1]].max() < hi:
                labels.add("phoenix")
                continue
        last = hot[-1]
        tail = series[last + 1:]
        if len(tail) >= fade and (tail < lo).all():
            labels.add("milestone")
    return labels


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--problems", type=int, default=12)
    ap.add_argument("--gap", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ModelConfig()
    wpath = os.path.join(args.out, "weights.npz")
    params = load_weights(wpath, cfg.n_layers)
    ccfg = corpus.CorpusConfig()
    rng = np.random.default_rng(args.seed)

    counts = {"milestone": 0, "phoenix": 0, "lazy": 0}
    n_maps = 0
    fwd = jax.jit(lambda t: forward_train(params, cfg, t, return_attn=True))
    for _ in range(args.problems):
        p = corpus.sample_problem(rng, ccfg, k=ccfg.max_steps)
        full, plen = corpus.encode_full(p)
        toks = np.asarray([full], np.int32)
        _, maps = fwd(toks)  # [L, 1, H, T, T]
        maps = np.asarray(maps)
        for l in range(cfg.n_layers):
            for h in range(cfg.n_heads):
                labels = classify_map(maps[l, 0, h], plen, gap=args.gap)
                for lab in labels:
                    counts[lab] += 1
                n_maps += 1

    stats = {
        "n_maps": n_maps,
        "milestone_frac": counts["milestone"] / n_maps,
        "phoenix_frac": counts["phoenix"] / n_maps,
        "lazy_frac": counts["lazy"] / n_maps,
        "problems": args.problems,
    }
    out_path = os.path.join(args.out, "fig3_attention_stats.json")
    with open(out_path, "w") as f:
        json.dump(stats, f, indent=1)
    print(json.dumps(stats, indent=1))
    print(f"wrote {out_path}")


if __name__ == "__main__":
    main()
