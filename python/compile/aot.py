"""AOT compile path: bake trained weights into HLO-text executables.

Emits HLO **text**, not a serialized ``HloModuleProto`` — jax >= 0.5 writes
protos with 64-bit instruction ids which the image's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser on the rust side
(`HloModuleProto::from_text_file`) reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

Artifact set (all shapes static; weights are HLO constants):

  embed.hlo.txt                  (token i32[1])                  -> (h f32[d],)
  qkv_l{i}.hlo.txt               (h f32[d], pos f32[1])          -> (q, k, v)
  attn_mlp_l{i}_c{C}.hlo.txt     (h, q, K[C], V[C], valid[C])    -> (h',)
  lm_head.hlo.txt                (h f32[d])                      -> (logits,)
  prefill_p{P}.hlo.txt           (tokens i32[P], len i32[])      -> (K, V, logits)

``C`` ranges over the slot-capacity ladder: the engine picks the smallest
capacity >= the slot count a policy selected, padding with invalid slots.
``meta.json`` describes everything the rust runtime needs.

Usage: python -m compile.aot [--out ../artifacts] [--quick]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from .model import (ModelConfig, embed_tok, init_params, layer_attn_mlp,
                    layer_qkv, lm_head, prefill)
from .train import load_weights

CAPACITIES = [64, 128, 256, 512, 1024, 2048, 4096, 8192]
PREFILL_SIZES = [256, 2048]
QUICK_CAPACITIES = [64, 256]
QUICK_PREFILL_SIZES = [256]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True is REQUIRED: the default elides weight
    # tensors as `constant({...})`, which the rust-side HLO text parser reads
    # back as zeros — every baked weight would silently vanish.
    return comp.as_hlo_text(print_large_constants=True)


def _write(out_dir: str, name: str, lowered) -> str:
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    return name


def export_all(params, cfg: ModelConfig, out_dir: str,
               capacities=None, prefill_sizes=None, verbose=True) -> dict:
    capacities = capacities or CAPACITIES
    prefill_sizes = prefill_sizes or PREFILL_SIZES
    os.makedirs(out_dir, exist_ok=True)
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    f32, i32 = jnp.float32, jnp.int32
    spec = jax.ShapeDtypeStruct
    files = {}

    def log(msg):
        if verbose:
            print(msg, flush=True)

    t0 = time.time()
    lowered = jax.jit(lambda t: (embed_tok(params, cfg, t),)).lower(spec((1,), i32))
    files["embed"] = _write(out_dir, "embed.hlo.txt", lowered)
    log(f"embed done ({time.time()-t0:.1f}s)")

    lowered = jax.jit(lambda h: (lm_head(params, cfg, h),)).lower(spec((d,), f32))
    files["lm_head"] = _write(out_dir, "lm_head.hlo.txt", lowered)

    files["qkv"] = []
    for l in range(cfg.n_layers):
        lowered = jax.jit(
            lambda h, pos, l=l: layer_qkv(params, cfg, l, h, pos)
        ).lower(spec((d,), f32), spec((1,), f32))
        files["qkv"].append(_write(out_dir, f"qkv_l{l}.hlo.txt", lowered))
    log(f"qkv done ({time.time()-t0:.1f}s)")

    files["attn_mlp"] = {}
    for C in capacities:
        per_layer = []
        for l in range(cfg.n_layers):
            lowered = jax.jit(
                lambda h, q, k, v, valid, l=l: (
                    layer_attn_mlp(params, cfg, l, h, q, k, v, valid),)
            ).lower(spec((d,), f32), spec((nh, hd), f32),
                    spec((C, nkv, hd), f32), spec((C, nkv, hd), f32),
                    spec((C,), f32))
            per_layer.append(_write(out_dir, f"attn_mlp_l{l}_c{C}.hlo.txt", lowered))
        files["attn_mlp"][str(C)] = per_layer
        log(f"attn_mlp C={C} done ({time.time()-t0:.1f}s)")

    files["prefill"] = {}
    for P in prefill_sizes:
        lowered = jax.jit(
            lambda toks, ln: prefill(params, cfg, toks, ln)
        ).lower(spec((P,), i32), spec((), i32))
        files["prefill"][str(P)] = _write(out_dir, f"prefill_p{P}.hlo.txt", lowered)
        log(f"prefill P={P} done ({time.time()-t0:.1f}s)")

    return files


def build_meta(cfg: ModelConfig, files: dict, capacities, prefill_sizes,
               trained: bool) -> dict:
    ccfg = corpus.CorpusConfig()
    return {
        "model": cfg.to_dict(),
        "trained": trained,
        "capacities": capacities,
        "prefill_sizes": prefill_sizes,
        "files": files,
        "page_size": 16,
        "corpus": {
            "min_steps": ccfg.min_steps,
            "max_steps": ccfg.max_steps,
            "max_lookback": ccfg.max_lookback,
            "vocab_names": {str(k): v for k, v in corpus.TOKEN_NAMES.items()},
            "specials": {
                "pad": corpus.PAD, "bos": corpus.BOS, "eos": corpus.EOS,
                "q": corpus.Q, "eq": corpus.EQ, "sep": corpus.SEP,
                "step": corpus.STEP, "ans": corpus.ANS, "dot": corpus.DOT,
                "plus": corpus.PLUS, "minus": corpus.MINUS,
                "times": corpus.TIMES, "dig0": corpus.DIG0,
                "idx0": corpus.IDX0, "n_idx": corpus.N_IDX,
            },
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", type=str, default="../artifacts")
    ap.add_argument("--weights", type=str, default=None,
                    help="weights.npz (default: <out>/weights.npz; random init if absent)")
    ap.add_argument("--quick", action="store_true",
                    help="small capacity ladder (CI / tests)")
    args = ap.parse_args()

    cfg = ModelConfig()
    wpath = args.weights or os.path.join(args.out, "weights.npz")
    trained = os.path.exists(wpath)
    if trained:
        params = load_weights(wpath, cfg.n_layers)
        print(f"loaded trained weights from {wpath}")
    else:
        print(f"WARNING: {wpath} missing — exporting randomly initialised weights")
        params = init_params(jax.random.PRNGKey(0), cfg)

    capacities = QUICK_CAPACITIES if args.quick else CAPACITIES
    prefill_sizes = QUICK_PREFILL_SIZES if args.quick else PREFILL_SIZES
    files = export_all(params, cfg, args.out, capacities, prefill_sizes)
    meta = build_meta(cfg, files, capacities, prefill_sizes, trained)
    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    write_consistency(params, cfg, args.out)
    print(f"wrote {args.out}/meta.json")


def write_consistency(params, cfg: ModelConfig, out_dir: str, n: int = 4) -> None:
    """Greedy dense-oracle token streams for fixed prompts: the rust
    integration suite replays these through the serving decomposition and
    asserts exact agreement (cross-language numerics check)."""
    from .model import generate_dense

    rng = np.random.default_rng(1234)
    cases = []
    ccfg = corpus.CorpusConfig()
    for _ in range(n):
        p = corpus.sample_problem(rng, ccfg, k=int(rng.integers(2, 7)))
        prompt = corpus.encode_prompt(p)
        toks = generate_dense(params, cfg, prompt, max_new=24, eos=corpus.EOS)
        cases.append({"prompt": prompt, "dense_tokens": toks})
    with open(os.path.join(out_dir, "consistency.json"), "w") as f:
        json.dump({"cases": cases}, f)


if __name__ == "__main__":
    main()
