"""Pure-jnp oracles for the Pallas kernels (the L1 correctness signal).

These are deliberately written in the most obvious way possible; the pytest
suite asserts the Pallas kernels match them across shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k, v, valid):
    """Single-query attention over gathered KV slots with a validity mask.

    Args:
      q:     [n_heads, head_dim]           query (RoPE already applied)
      k:     [L, n_kv_heads, head_dim]     gathered keys (RoPE'd at cache time)
      v:     [L, n_kv_heads, head_dim]     gathered values
      valid: [L] float32 {0,1}             slot validity (padding mask)

    Returns:
      out:   [n_heads, head_dim]
    """
    n_heads, head_dim = q.shape
    n_kv = k.shape[1]
    group = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, dtype=q.dtype))
    kh = jnp.repeat(k, group, axis=1)  # [L, n_heads, hd]
    scores = jnp.einsum("hd,lhd->hl", q, kh) * scale
    scores = jnp.where(valid[None, :] > 0.5, scores, NEG_INF)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs * (valid[None, :] > 0.5)
    denom = jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    probs = probs / denom
    vh = jnp.repeat(v, group, axis=1)  # [L, n_heads, hd]
    return jnp.einsum("hl,lhd->hd", probs, vh)


def rep_score_ref(q, kmin, kmax, valid):
    """Quest-style representative page scores (upper bound on q·k).

    Args:
      q:     [n_heads, head_dim]
      kmin:  [P, n_kv_heads, head_dim]  channelwise min of keys in each page
      kmax:  [P, n_kv_heads, head_dim]  channelwise max of keys in each page
      valid: [P] float32 {0,1}

    Returns:
      scores: [n_heads, P] — sum_c max(q_c*kmin_c, q_c*kmax_c), NEG_INF on
              invalid pages.  (Quest's criticality estimate.)
    """
    n_heads = q.shape[0]
    n_kv = kmin.shape[1]
    group = n_heads // n_kv
    kminh = jnp.repeat(kmin, group, axis=1)  # [P, n_heads, hd]
    kmaxh = jnp.repeat(kmax, group, axis=1)
    prod_min = q[None, :, :] * kminh  # [P, n_heads, hd]
    prod_max = q[None, :, :] * kmaxh
    ub = jnp.sum(jnp.maximum(prod_min, prod_max), axis=-1).T  # [n_heads, P]
    return jnp.where(valid[None, :] > 0.5, ub, NEG_INF)


def page_probs_ref(scores, valid, head_dim):
    """Softmax over valid pages of the per-page upper-bound scores.

    Group-max over query heads first (GQA pages are shared), then a softmax
    that mirrors what the rust coordinator computes to threshold against the
    paper's alpha.  Returns [P].
    """
    s = jnp.max(scores, axis=0) / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    s = jnp.where(valid > 0.5, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s))
    p = p * (valid > 0.5)
    return p / jnp.maximum(jnp.sum(p), 1e-30)
