"""L1 Pallas kernel: single-query paged sparse attention (GQA, masked slots).

This is the decode hot-spot of the serving stack: one query token attending
to the L KV slots the coordinator gathered for it (the selected pages under
Quest/RaaS, or the full resident cache under Dense/Sink/H2O), padded to a
static slot capacity with ``valid == 0`` entries.

TPU mapping (see DESIGN.md §8): the CUDA original streams KV pages through
shared memory with warp-level softmax; here the HBM→VMEM schedule is the
BlockSpec + the ``block_l`` inner loop (flash-style online softmax over slot
blocks), and the per-block score/weighted-sum contractions are MXU-shaped
matmuls.  ``interpret=True`` is mandatory on this CPU-PJRT image — real TPU
lowering emits Mosaic custom-calls the CPU plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, *, block_l: int, group: int):
    h = pl.program_id(0)
    g = h // group
    head_dim = q_ref.shape[-1]
    L = k_ref.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(head_dim, jnp.float32))
    q = q_ref[h, :]  # [hd]

    n_blocks = L // block_l

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        kblk = k_ref[pl.ds(i * block_l, block_l), g, :]  # [bl, hd]
        vblk = v_ref[pl.ds(i * block_l, block_l), g, :]  # [bl, hd]
        vld = valid_ref[pl.ds(i * block_l, block_l)]  # [bl]
        s = jnp.dot(kblk, q) * scale  # [bl]  (MXU contraction)
        s = jnp.where(vld > 0.5, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s))
        # online-softmax rescale of the running accumulator
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new) * (vld > 0.5)  # [bl]
        l_new = l_prev * alpha + jnp.sum(p)
        acc_new = acc_prev * alpha + jnp.dot(p, vblk)  # [hd]
        return m_new, l_new, acc_new

    init = (jnp.asarray(NEG_INF, jnp.float32), jnp.asarray(0.0, jnp.float32),
            jnp.zeros((head_dim,), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, n_blocks, body, init)
    o_ref[h, :] = acc / jnp.maximum(l, 1e-30)


def paged_attention(q, k, v, valid, *, block_l: int = 128, interpret: bool = True):
    """Single-query attention over gathered KV slots.

    Args:
      q:     [n_heads, head_dim] float32
      k, v:  [L, n_kv_heads, head_dim] float32; ``L`` must be a multiple of
             the effective block size (capacities are powers of two >= 64).
      valid: [L] float32 {0, 1}
      block_l: inner slot-block size (the VMEM tile along the L axis).

    Returns: [n_heads, head_dim] float32.
    """
    n_heads, head_dim = q.shape
    L, n_kv, _ = k.shape
    assert n_heads % n_kv == 0, (n_heads, n_kv)
    bl = min(block_l, L)
    assert L % bl == 0, f"L={L} not a multiple of block_l={bl}"
    kernel = functools.partial(_attn_kernel, block_l=bl, group=n_heads // n_kv)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_heads, head_dim), jnp.float32),
        grid=(n_heads,),
        interpret=interpret,
    )(q, k, v, valid)


def vmem_bytes(L: int, n_kv: int, head_dim: int, n_heads: int, block_l: int = 128) -> int:
    """Static VMEM footprint estimate for one program instance (fp32).

    Counted: q row, one K block, one V block, valid block, accumulator.
    Used by the §Perf roofline notes in EXPERIMENTS.md.
    """
    bl = min(block_l, L)
    return 4 * (head_dim + 2 * bl * head_dim + bl + head_dim)
