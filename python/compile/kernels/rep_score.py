"""L1 Pallas kernel: Quest-style representative page scoring.

For each resident KV page the cache keeps channelwise min/max bounds of its
keys; the upper bound on any q·k inside the page is
``sum_c max(q_c * kmin_c, q_c * kmax_c)``.  Quest selects the top-L pages by
this bound; RaaS turns the bound (softmaxed, see ``ref.page_probs_ref``) into
its timestamp-refresh test against alpha.

The rust coordinator recomputes this same quantity on its side for policy
decisions (it owns the page metadata); this kernel exists so the L2 graph can
also emit the per-page score tensor that the engine logs for Figure 3, and so
the estimate itself is covered by the kernel-vs-ref test sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _rep_kernel(q_ref, kmin_ref, kmax_ref, valid_ref, o_ref, *, group: int):
    h = pl.program_id(0)
    g = h // group
    q = q_ref[h, :]  # [hd]
    kmin = kmin_ref[:, g, :]  # [P, hd]
    kmax = kmax_ref[:, g, :]
    vld = valid_ref[:]  # [P]
    ub = jnp.sum(jnp.maximum(q[None, :] * kmin, q[None, :] * kmax), axis=-1)  # [P]
    o_ref[h, :] = jnp.where(vld > 0.5, ub, NEG_INF)


def rep_score(q, kmin, kmax, valid, *, interpret: bool = True):
    """Per-page criticality upper bounds.

    Args:
      q:          [n_heads, head_dim] float32
      kmin, kmax: [P, n_kv_heads, head_dim] float32 page key bounds
      valid:      [P] float32 {0, 1}

    Returns: [n_heads, P] float32 (NEG_INF on invalid pages).
    """
    n_heads, _ = q.shape
    P, n_kv, _ = kmin.shape
    assert n_heads % n_kv == 0
    kernel = functools.partial(_rep_kernel, group=n_heads // n_kv)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n_heads, P), jnp.float32),
        grid=(n_heads,),
        interpret=interpret,
    )(q, kmin, kmax, valid)
