"""Synthetic chain-arithmetic reasoning corpus.

This is the substitute for GSM8k/MATH500/AIME (see DESIGN.md §3): a task that
*provably* contains the two phenomena RaaS exploits:

  * **milestone tokens** — each reasoning step emits an intermediate value
    ``v_i`` that a later step (possibly many steps later) consumes and that is
    never needed again afterwards;
  * **phoenix tokens** — the per-step instructions ``(r_i, op_i, b_i)`` live
    in the (short) prefill prompt and are consumed mid-decode, long after any
    recency window would have evicted them.

Task
----
The prompt specifies ``k`` reasoning steps over single-digit values (mod 10):

    prompt  = BOS Q a [ IDX_i IDX_r op b ] * k  EQ
    decode  = [ STEP IDX_i IDX_r v_r op b IDX_i v_i SEP ] * k  ANS v_k DOT EOS

where step ``i`` (1-based) computes ``v_i = v_{r_i} op_i b_i (mod 10)`` with
``v_0 = a`` and ``r_i`` drawn from the last ``max_lookback`` steps.  Step
indices are *single dedicated tokens* ``IDX_0 … IDX_19`` and the decode is a
fully decomposed chain of thought — every prediction is one induction hop or
a local table lookup, the structures a tiny model learns reliably:

  * ``IDX_r``, ``op``, ``b``: copied out of the prompt group opened by
    ``IDX_i`` — **phoenix** accesses long after prefill;
  * ``v_r``: the input token *is* ``IDX_r``; every earlier occurrence of
    ``IDX_r`` followed by a digit carries ``v_r`` (step ``r`` re-emits
    ``IDX_r v_r`` before its SEP), so this is a +1 induction copy — the
    **milestone** access, up to ``9 * max_lookback`` tokens back;
  * ``v_i``: local arithmetic over the just-emitted ``v_r op b``.

The vocabulary, framing and constants are mirrored in
``rust/src/runtime/tokenizer.rs`` and exported via ``artifacts/meta.json``;
keep the two in sync.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# Vocabulary (mirrored in rust/src/runtime/tokenizer.rs)
# ---------------------------------------------------------------------------
PAD, BOS, EOS, Q, EQ, SEP, STEP, ANS, DOT, PLUS, MINUS, TIMES = range(12)
DIG0 = 12  # digits 0..9 are token ids 12..21
IDX0 = 22  # step-index tokens IDX_0..IDX_19 are ids 22..41
N_IDX = 20
VOCAB_SIZE = 48  # rounded up for nice MXU-friendly shapes

TOKEN_NAMES = {
    PAD: "<pad>", BOS: "<bos>", EOS: "<eos>", Q: "Q", EQ: "=", SEP: ";",
    STEP: "s", ANS: "A", DOT: ".", PLUS: "+", MINUS: "-", TIMES: "*",
}
for _d in range(10):
    TOKEN_NAMES[DIG0 + _d] = str(_d)
for _i in range(N_IDX):
    TOKEN_NAMES[IDX0 + _i] = f"#{_i}"

OPS = (PLUS, MINUS, TIMES)


def apply_op(x: int, op: int, y: int) -> int:
    if op == PLUS:
        return (x + y) % 10
    if op == MINUS:
        return (x - y) % 10
    if op == TIMES:
        return (x * y) % 10
    raise ValueError(f"not an op token: {op}")


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    """Distribution of synthetic reasoning problems."""

    min_steps: int = 2
    max_steps: int = 16
    max_lookback: int = 6  # r_i >= i - max_lookback
    seed: int = 0

    # Fixed framing sizes (tokens).
    @property
    def prompt_len(self) -> int:  # for max_steps
        return 3 + 4 * self.max_steps + 1

    @property
    def decode_len(self) -> int:  # for max_steps
        return 9 * self.max_steps + 4

    @property
    def seq_len(self) -> int:
        return self.prompt_len + self.decode_len


@dataclasses.dataclass
class Problem:
    a: int
    steps: list  # list of (r, op, b) with r 0-based index of consumed value
    values: list  # v_0..v_k

    @property
    def answer(self) -> int:
        return self.values[-1]


def sample_problem(rng: np.random.Generator, cfg: CorpusConfig, k: int | None = None) -> Problem:
    if k is None:
        k = int(rng.integers(cfg.min_steps, cfg.max_steps + 1))
    a = int(rng.integers(0, 10))
    values = [a]
    steps = []
    for i in range(1, k + 1):
        lo = max(0, i - cfg.max_lookback)
        r = int(rng.integers(lo, i))  # consume v_r, r in [lo, i-1]
        op = OPS[int(rng.integers(0, len(OPS)))]
        b = int(rng.integers(0, 10))
        steps.append((r, op, b))
        values.append(apply_op(values[r], op, b))
    return Problem(a=a, steps=steps, values=values)


def encode_prompt(p: Problem) -> list:
    toks = [BOS, Q, DIG0 + p.a]
    for i, (r, op, b) in enumerate(p.steps, start=1):
        toks += [IDX0 + i, IDX0 + r, op, DIG0 + b]
    toks.append(EQ)
    return toks


def encode_decode(p: Problem) -> list:
    toks = []
    for i in range(1, len(p.steps) + 1):
        r, op, b = p.steps[i - 1]
        toks += [STEP, IDX0 + i, IDX0 + r, DIG0 + p.values[r], op, DIG0 + b,
                 IDX0 + i, DIG0 + p.values[i], SEP]
    toks += [ANS, DIG0 + p.answer, DOT, EOS]
    return toks


def encode_full(p: Problem) -> tuple:
    """Returns (tokens, prompt_len)."""
    pr = encode_prompt(p)
    return pr + encode_decode(p), len(pr)


def detok(tokens) -> str:
    return " ".join(TOKEN_NAMES.get(int(t), f"<{int(t)}>") for t in tokens)


def training_batch(rng: np.random.Generator, cfg: CorpusConfig, batch: int,
                   seq_len: int | None = None):
    """Padded token batch + loss mask (decode positions only, next-token).

    ``seq_len`` fixes the padded width independently of ``cfg`` (used by the
    curriculum so the jitted train step compiles once)."""
    T = seq_len or cfg.seq_len
    toks = np.full((batch, T), PAD, dtype=np.int32)
    # loss_mask[b, t] == 1 iff position t+1 is a decode token to be predicted.
    loss_mask = np.zeros((batch, T), dtype=np.float32)
    for b in range(batch):
        full, plen = encode_full(sample_problem(rng, cfg))
        n = min(len(full), T)
        toks[b, :n] = full[:n]
        # predict tokens plen..n-1 from positions plen-1..n-2
        loss_mask[b, plen - 1 : n - 1] = 1.0
    return toks, loss_mask


def parse_answer(decoded_tokens) -> int | None:
    """Extract the final answer digit from a decoded token stream."""
    toks = [int(t) for t in decoded_tokens]
    for i, t in enumerate(toks):
        if t == ANS and i + 1 < len(toks) and DIG0 <= toks[i + 1] <= DIG0 + 9:
            return toks[i + 1] - DIG0
    return None


def milestone_positions(p: Problem, prompt_len: int) -> dict:
    """Absolute position of each emitted value v_i (i>=1) in the full stream.

    Decode step i occupies positions prompt_len + 9*(i-1) .. +8 and the
    (re-emitted) value token sits at offset 7.  Used by tests and by the
    attention analyzer.
    """
    return {i: prompt_len + 9 * (i - 1) + 7 for i in range(1, len(p.steps) + 1)}


def phoenix_positions(p: Problem) -> dict:
    """Absolute position of each prompt operand b_i, keyed by step i."""
    return {i + 1: 3 + 4 * i + 3 for i in range(len(p.steps))}
