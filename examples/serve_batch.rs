//! End-to-end serving driver (the DESIGN.md validation workload): spin up
//! engine replicas behind the router, push a batch of reasoning requests
//! through continuous batching, and report accuracy + latency/throughput.
//! Results land in results/serve_batch.json and EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_batch -- [--requests 32] [--replicas 2]

use std::time::Instant;

use anyhow::Result;

use raas::config::EngineConfig;
use raas::coordinator::batcher::BatcherConfig;
use raas::coordinator::request::{Request, Response};
use raas::coordinator::router::{RoutePolicy, Router};
use raas::coordinator::server::EngineServer;
use raas::util::cli::Args;
use raas::util::json::Json;
use raas::util::rng::Rng;
use raas::util::stats::Summary;
use raas::workload::{parse_answer, Problem};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n_requests = args.usize_or("requests", 24);
    let replicas = args.usize_or("replicas", 2);
    let max_batch = args.usize_or("max-batch", 4);
    let cfg = EngineConfig::from_args(&args)?;

    println!(
        "spawning {replicas} replicas (backend={}, policy={}, budget={})…",
        cfg.backend, cfg.policy, cfg.budget
    );
    let servers: Vec<EngineServer> = (0..replicas)
        .map(|i| {
            EngineServer::spawn(
                format!("r{i}"),
                cfg.clone(),
                BatcherConfig { max_batch, ..Default::default() },
                Some(vec![64, 128, 256, 512]),
            )
        })
        .collect::<Result<_>>()?;
    let meta = cfg.resolve_meta()?;
    let spec = meta.corpus.clone();
    let mut router = Router::new(servers, RoutePolicy::LeastLoaded);

    let mut rng = Rng::new(args.u64_or("seed", 11));
    let (tx, rx) = std::sync::mpsc::channel::<Response>();
    let mut answers = Vec::new();
    let t0 = Instant::now();
    for id in 0..n_requests as u64 {
        let p = Problem::sample(&mut rng, &spec, None);
        answers.push(p.answer());
        let req = Request::new(
            id,
            p.encode_prompt(&spec),
            spec.max_decode_tokens(spec.max_steps),
            tx.clone(),
        );
        if let Err(se) = router.route(req) {
            anyhow::bail!("request {} not routed: {}", se.req.id, se.reason);
        }
    }
    drop(tx);

    let mut jct = Summary::new();
    let mut ttft = Summary::new();
    let (mut tokens, mut correct, mut errors) = (0usize, 0usize, 0usize);
    for resp in rx.iter() {
        match &resp.error {
            Some(e) => {
                eprintln!("request {} failed: {e}", resp.id);
                errors += 1;
            }
            None => {
                jct.add(resp.jct_secs);
                ttft.add(resp.ttft_secs);
                tokens += resp.tokens.len();
                if parse_answer(&spec, &resp.tokens) == Some(answers[resp.id as usize]) {
                    correct += 1;
                }
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let done = jct.count();
    let report = Json::obj(vec![
        ("requests", Json::from(n_requests)),
        ("completed", Json::from(done)),
        ("errors", Json::from(errors)),
        ("replicas", Json::from(replicas)),
        ("policy", Json::str(cfg.policy.name())),
        ("budget", Json::from(cfg.budget)),
        ("wall_secs", Json::from(wall)),
        ("req_per_sec", Json::from(done as f64 / wall)),
        ("tok_per_sec", Json::from(tokens as f64 / wall)),
        ("accuracy", Json::from(correct as f64 / done.max(1) as f64)),
        ("jct_p50_s", Json::from(jct.percentile(50.0))),
        ("jct_p99_s", Json::from(jct.percentile(99.0))),
        ("ttft_p50_s", Json::from(ttft.percentile(50.0))),
    ]);
    std::fs::create_dir_all("results")?;
    std::fs::write("results/serve_batch.json", report.to_string())?;
    println!("\n== serve_batch report ==");
    println!("served {done}/{n_requests} in {wall:.1}s on {replicas} replicas");
    println!("throughput {:.2} req/s, {:.1} tok/s", done as f64 / wall, tokens as f64 / wall);
    println!("JCT p50 {:.2}s p99 {:.2}s | TTFT p50 {:.0}ms", jct.percentile(50.0),
             jct.percentile(99.0), 1e3 * ttft.percentile(50.0));
    println!("accuracy {:.2} | errors {errors}", correct as f64 / done.max(1) as f64);
    println!("wrote results/serve_batch.json");
    for r in router.into_replicas() {
        r.shutdown();
    }
    Ok(())
}
