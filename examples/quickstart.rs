//! Quickstart: decode one reasoning problem with the RaaS policy and print
//! everything a first-time user wants to see.  Runs on the default `sim`
//! backend — deterministic, pure Rust, no artifacts needed:
//!
//!     cargo run --release --example quickstart
//!
//! To drive the PJRT/HLO path instead, build with `--features backend-xla`,
//! run `make artifacts`, and set `backend: BackendKind::Xla` below.

use anyhow::Result;

use raas::config::EngineConfig;
use raas::engine::{Engine, GenOptions};
use raas::util::rng::Rng;
use raas::workload::Problem;

fn main() -> Result<()> {
    // 1. Configure: sim backend, RaaS policy, 256-token KV budget, alpha = 1e-4.
    let cfg = EngineConfig {
        budget: 256,
        alpha: 1e-4,
        ..Default::default()
    };

    // 2. Load the engine (instant on the sim backend; the xla backend
    //    compiles the HLO artifacts once, ~seconds).
    let mut engine = Engine::new_with_capacities(cfg, &[64, 128, 256, 512])?;
    println!("loaded: {:?}", engine.model());

    // 3. Sample a reasoning problem from the synthetic benchmark.
    let spec = engine.meta.corpus.clone();
    let mut rng = Rng::new(7);
    let problem = Problem::sample(&mut rng, &spec, Some(10));
    let prompt = problem.encode_prompt(&spec);
    println!("\nprompt:  {}", engine.tokenizer.decode(&prompt));

    // 4. Generate.
    let out = engine.generate(&prompt, &GenOptions { max_new: 96, ..Default::default() })?;
    println!("decoded: {}", engine.tokenizer.decode(&out.tokens));

    // 5. Check the answer and report serving stats.
    let got = engine.tokenizer.parse_answer(&out.tokens);
    println!("\nanswer: got {:?}, expected {}", got, problem.answer());
    println!(
        "prefill {:.1} ms | decode {:.1} ms ({:.2} ms/token) | peak resident KV {} B",
        1e3 * out.prefill_secs,
        1e3 * out.decode_secs,
        1e3 * out.decode_secs / out.tokens.len().max(1) as f64,
        out.peak_resident_bytes
    );
    Ok(())
}
