//! Budget sweep: accuracy of every policy at several cache budgets — the
//! end-to-end validation of the Figure-6 orderings (the full grid runs in
//! the trace simulator; this example shows the same ordering emerges from
//! the serving stack).  Absolute accuracies need the trained model
//! (`--features backend-xla` build + `--backend xla`); the default sim
//! surrogate exercises the full path but cannot solve the task.
//!
//!     cargo run --release --example budget_sweep -- [--problems 25]

use anyhow::Result;

use raas::config::{EngineConfig, PolicyKind};
use raas::engine::{Engine, GenOptions};
use raas::figures::common::{print_table, write_csv};
use raas::util::cli::Args;
use raas::util::rng::Rng;
use raas::workload::Problem;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let n = args.usize_or("problems", 25);
    let budgets = args.usize_list_or("budgets", &[64, 96, 128, 256]);
    // parse once: per-cell configs are clones with policy/budget overridden
    let base_cfg = EngineConfig::from_args(&args)?;
    let backend = base_cfg.backend;

    let mut tbl = Vec::new();
    let mut rows = Vec::new();
    for kind in PolicyKind::all() {
        let mut line = vec![kind.name().to_string()];
        for &budget in &budgets {
            let mut cfg = base_cfg.clone();
            cfg.policy = kind;
            cfg.budget = budget;
            let mut engine = Engine::new_with_capacities(cfg, &[64, 128, 256, 512])?;
            let spec = engine.meta.corpus.clone();
            let mut rng = Rng::new(args.u64_or("seed", 42));
            let mut correct = 0;
            for _ in 0..n {
                // long-ish chains stress the budget while staying inside the
                // tiny model's compounding-accuracy range (k=16 chains have
                // a dense ceiling near zero: ~0.97^(2*16) per-token)
                let p = Problem::sample(&mut rng, &spec, Some(12));
                let out = engine.generate(
                    &p.encode_prompt(&spec),
                    &GenOptions { max_new: spec.max_decode_tokens(spec.max_steps), ..Default::default() },
                )?;
                if engine.tokenizer.parse_answer(&out.tokens) == Some(p.answer()) {
                    correct += 1;
                }
            }
            let acc = correct as f64 / n as f64;
            line.push(format!("{acc:.2}"));
            rows.push(vec![kind.name().into(), budget.to_string(), format!("{acc:.3}")]);
            println!("{} @ {budget}: {acc:.2}", kind.name());
        }
        tbl.push(line);
    }
    std::fs::create_dir_all("results")?;
    let csv = format!("results/budget_sweep_{}.csv", backend.name());
    write_csv(std::path::Path::new(&csv), &["policy", "budget", "accuracy"], &rows)?;
    println!("\n`{backend}` backend accuracy by policy × budget ({n} problems, longest chains):");
    let mut headers = vec!["policy"];
    let bs: Vec<String> = budgets.iter().map(|b| b.to_string()).collect();
    headers.extend(bs.iter().map(|s| s.as_str()));
    print_table(&headers, &tbl);
    println!("expected ordering (paper Fig. 6): dense ≈ quest ≈ raas > h2o ≈ sink at\n\
              tight budgets, converging as the budget covers the full context.");
    Ok(())
}
