//! Visualise the waterfall attention pattern (paper Figure 3) on the real
//! model: run a dense decode with page-score logging and print each page's
//! estimated-attention time series as an ASCII heat strip.
//!
//!     cargo run --release --example waterfall_trace -- [--steps 14]

use anyhow::Result;

use raas::config::{EngineConfig, PolicyKind};
use raas::engine::{Engine, GenOptions};
use raas::figures::fig3::{ColumnKind, Detector};
use raas::util::cli::Args;
use raas::util::rng::Rng;
use raas::workload::Problem;

fn shade(p: f32) -> char {
    match p {
        x if x >= 0.30 => '#',
        x if x >= 0.10 => '+',
        x if x >= 0.03 => ':',
        x if x >= 0.005 => '.',
        _ => ' ',
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.usize_or("steps", 14);
    let mut cfg = EngineConfig::from_args(&args)?;
    cfg.policy = PolicyKind::Dense;
    let mut engine = Engine::new_with_capacities(cfg, &[256, 2048])?;
    let spec = engine.meta.corpus.clone();
    let mut rng = Rng::new(args.u64_or("seed", 5));
    let p = Problem::sample(&mut rng, &spec, Some(steps));
    let prompt = p.encode_prompt(&spec);
    let out = engine.generate(
        &prompt,
        &GenOptions { max_new: steps * 5 + 16, log_scores: true, ..Default::default() },
    )?;
    println!("prompt:  {}", engine.tokenizer.decode(&prompt));
    println!("decoded: {}\n", engine.tokenizer.decode(&out.tokens));

    // pivot: page -> series
    let mut pages: std::collections::BTreeMap<usize, Vec<f32>> = Default::default();
    for (i, (_, entries)) in out.score_log.iter().enumerate() {
        for &(start, prob) in entries {
            let s = pages.entry(start).or_default();
            while s.len() < i {
                s.push(0.0);
            }
            s.push(prob);
        }
    }
    let det = Detector { fade_window: 10, ..Default::default() };
    println!("page-level estimated attention over decode steps (layer 0):");
    println!("rows = KV pages (by start position), cols = decode steps\n");
    for (start, series) in &pages {
        let kind = match det.classify(series) {
            ColumnKind::Milestone => "milestone",
            ColumnKind::Phoenix => "phoenix",
            ColumnKind::Background => "",
        };
        let strip: String = series.iter().map(|&p| shade(p)).collect();
        let region = if *start < prompt.len() { "prompt" } else { "decode" };
        println!("page@{start:>4} {region} |{strip}| {kind}");
    }
    println!("\nlegend: '#' ≥0.30, '+' ≥0.10, ':' ≥0.03, '.' ≥0.005 — a milestone page");
    println!("shows a bright column that fades and never re-lights (the waterfall).");
    Ok(())
}
