//! Cross-language metadata golden (DESIGN.md §4): the committed fixture
//! `tests/fixtures/meta_sim_default.json` is the `meta.json` the python AOT
//! path (`python/compile/aot.py::build_meta`) exports for the sim-default
//! architecture.  This suite asserts the rust parse of that golden equals
//! [`ArtifactMeta::sim_default`]; `python/tests/test_meta_fixture.py`
//! asserts the same file from the exporter's side, so a drift in either
//! language's constants fails one of the two CI jobs.

use std::path::Path;

use raas::config::ArtifactMeta;
use raas::util::json::Json;

fn fixture() -> (ArtifactMeta, Json) {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/meta_sim_default.json");
    let text = std::fs::read_to_string(&path).expect("read golden meta fixture");
    let j = Json::parse(&text).expect("golden meta fixture must be valid JSON");
    let meta = ArtifactMeta::from_json(path.parent().unwrap(), &j).expect("parse golden meta");
    (meta, j)
}

#[test]
fn golden_meta_json_parses_to_sim_default() {
    let (meta, _) = fixture();
    let sim = ArtifactMeta::sim_default();
    // `dir` is where the file was loaded from (display-only) — everything
    // else must agree field for field.
    assert_eq!(meta.model, sim.model, "ModelSpec drifted from python ModelConfig");
    assert_eq!(meta.corpus, sim.corpus, "CorpusSpec drifted from python corpus constants");
    assert_eq!(meta.trained, sim.trained);
    assert_eq!(meta.capacities, sim.capacities, "capacity ladder drifted");
    assert_eq!(meta.prefill_sizes, sim.prefill_sizes, "prefill paddings drifted");
    assert_eq!(meta.page_size, sim.page_size, "KV page size drifted");
}

#[test]
fn golden_meta_json_vocab_names_cover_the_sim_vocab() {
    // The exporter writes a name for every non-padding token id below
    // idx0 + n_idx; the golden must carry all of them (the tokenizer's
    // display path relies on this map when artifacts are loaded).
    let (meta, j) = fixture();
    let names = j.path("corpus.vocab_names").expect("vocab_names present");
    let last = meta.corpus.idx0 + meta.corpus.n_idx;
    for id in 0..last {
        let name = names.get(&id.to_string());
        assert!(name.is_some(), "vocab_names missing token id {id}");
    }
    assert!((last as usize) <= meta.model.vocab, "named ids exceed vocab");
}
