//! Serving-layer integration over the `SimBackend`: continuous batcher +
//! `EngineBackend` + RaaS under pool pressure — `milestone_lifecycle` at the
//! serving layer.  Admits N sequences, forces long decodes, and asserts that
//! RaaS evicts the oldest-stamp unpinned pages while pinned prefill pages
//! stay resident and per-layer residency respects the budget.

use std::sync::mpsc::channel;

use anyhow::Result;
use raas::config::{EngineConfig, PolicyKind};
use raas::coordinator::batcher::{Batcher, BatcherConfig, StepBackend};
use raas::coordinator::request::{Request, Response};
use raas::coordinator::server::EngineBackend;
use raas::engine::Engine;
use raas::kvcache::page::PageMeta;
use raas::kvcache::SeqCache;
use raas::util::rng::Rng;
use raas::workload::Problem;

/// Wraps the real `EngineBackend` and checks layer-0 page-table invariants
/// around every decode step.
struct Instrumented {
    inner: EngineBackend,
    budget: usize,
    page_size: usize,
    /// When true, assert strict oldest-stamp (FIFO-under-frozen-stamps)
    /// eviction order — sound only when alpha > 1 freezes non-active stamps.
    strict_order: bool,
    evictions: usize,
    max_resident_l0: usize,
}

impl Instrumented {
    fn new(engine: Engine, pages_per_seq_estimate: usize, strict_order: bool) -> Self {
        let budget = engine.cfg.budget;
        let page_size = engine.meta.page_size;
        Instrumented {
            inner: EngineBackend::new(engine).with_page_estimate(pages_per_seq_estimate),
            budget,
            page_size,
            strict_order,
            evictions: 0,
            max_resident_l0: 0,
        }
    }

    fn check_step(&mut self, before: &[PageMeta], after: &[PageMeta]) {
        // 1. pinned prefill pages survive every step
        for p in before.iter().filter(|p| p.pinned) {
            assert!(
                after.iter().any(|q| q.pinned && q.start_pos == p.start_pos),
                "pinned prefill page @{} was evicted",
                p.start_pos
            );
        }
        // 2. evicted pages (identified by start_pos: positions are never
        //    reused) are unpinned and never the active page
        let active_start = before.last().map(|p| p.start_pos);
        let evicted: Vec<&PageMeta> = before
            .iter()
            .filter(|p| !after.iter().any(|q| q.start_pos == p.start_pos))
            .collect();
        for ev in &evicted {
            assert!(!ev.pinned, "evicted a pinned page @{}", ev.start_pos);
            assert_ne!(Some(ev.start_pos), active_start, "evicted the active page");
        }
        // 3. strict mode: the evicted set must be exactly the oldest-stamp
        //    (and, by monotonicity, oldest-position) unpinned pages
        if self.strict_order && !evicted.is_empty() {
            let min_surviving = after
                .iter()
                .filter(|q| !q.pinned)
                .map(|q| q.start_pos)
                .min()
                .unwrap_or(usize::MAX);
            let min_surviving_stamp = after
                .iter()
                .filter(|q| !q.pinned && before.iter().any(|p| p.start_pos == q.start_pos))
                .map(|q| q.last_stamp)
                .min()
                .unwrap_or(u64::MAX);
            for ev in &evicted {
                assert!(
                    ev.start_pos < min_surviving,
                    "evicted @{} but older unpinned page @{} survived",
                    ev.start_pos,
                    min_surviving
                );
                assert!(
                    ev.last_stamp <= min_surviving_stamp,
                    "evicted stamp {} newer than surviving stamp {}",
                    ev.last_stamp,
                    min_surviving_stamp
                );
            }
        }
        self.evictions += evicted.len();
        // 4. budget respected (one page of slack for the active page)
        let resident: usize = after.iter().map(|p| p.len).sum();
        assert!(
            resident <= self.budget + self.page_size,
            "layer-0 resident {resident} exceeds budget {} + page", self.budget
        );
        self.max_resident_l0 = self.max_resident_l0.max(resident);
    }
}

impl StepBackend for Instrumented {
    type Seq = SeqCache;

    fn begin(&mut self, prompt: &[u32]) -> Result<(SeqCache, u32)> {
        self.inner.begin(prompt)
    }

    fn step(&mut self, seq: &mut SeqCache, token: u32, now: u64) -> Result<u32> {
        let before: Vec<PageMeta> = seq.layers[0].table.clone();
        let tok = self.inner.step(seq, token, now)?;
        let after: Vec<PageMeta> = seq.layers[0].table.clone();
        self.check_step(&before, &after);
        Ok(tok)
    }

    fn finish(&mut self, seq: SeqCache) {
        self.inner.finish(seq)
    }

    fn is_eos(&self, _token: u32) -> bool {
        false // force full-length decodes so pool pressure builds
    }

    fn has_capacity(&self, active: usize) -> bool {
        self.inner.has_capacity(active)
    }
}

fn mk_engine(alpha: f64, budget: usize, pool_pages: usize) -> Engine {
    let cfg = EngineConfig {
        policy: PolicyKind::Raas,
        alpha,
        budget,
        pool_pages,
        ..Default::default()
    };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

fn submit_problems(b: &mut Batcher<Instrumented>, n: u64, max_new: usize,
                   tx: &std::sync::mpsc::Sender<Response>) {
    let spec = b.backend.inner.engine.meta.corpus.clone();
    let mut rng = Rng::new(17);
    for id in 0..n {
        let p = Problem::sample(&mut rng, &spec, Some(8));
        b.submit(Request::new(id, p.encode_prompt(&spec), max_new, tx.clone()));
    }
}

#[test]
fn raas_serving_evicts_oldest_stamp_first() {
    // alpha > 1 freezes every non-active stamp (estimated probabilities are
    // <= 1), so eviction order is exactly oldest-stamp == oldest-position;
    // the strict per-step checks in `Instrumented` verify it.
    let engine = mk_engine(2.0, 96, 512);
    let mut b = Batcher::new(
        Instrumented::new(engine, 16, true),
        BatcherConfig { max_batch: 1, ..Default::default() },
    );
    let (tx, rx) = channel::<Response>();
    submit_problems(&mut b, 1, 160, &tx);
    b.run_to_completion();
    drop(tx);

    let resp: Vec<Response> = rx.iter().collect();
    assert_eq!(resp.len(), 1);
    assert!(resp[0].error.is_none(), "decode failed: {:?}", resp[0].error);
    assert_eq!(resp[0].tokens.len(), 160);
    assert!(
        b.backend.evictions > 0,
        "160 decode tokens against a 96-token budget must evict"
    );
    // everything returned to the pool once the sequence finished
    assert_eq!(b.backend.inner.engine.pool().allocated_pages(), 0);
}

#[test]
fn pool_pressure_batch_keeps_prefill_resident_and_bounded() {
    // N concurrent sequences share one pool under the default RaaS alpha:
    // prefill pages stay pinned+resident, per-layer residency respects the
    // budget, and the batcher conserves requests.
    let n_seqs = 4u64;
    let engine = mk_engine(1e-4, 96, 192); // tight: ~48 pages/seq steady state
    let mut b = Batcher::new(
        Instrumented::new(engine, 40, false),
        BatcherConfig { max_batch: n_seqs as usize, ..Default::default() },
    );
    let (tx, rx) = channel::<Response>();
    submit_problems(&mut b, n_seqs, 120, &tx);
    b.run_to_completion();
    drop(tx);

    let mut resp: Vec<Response> = rx.iter().collect();
    resp.sort_by_key(|r| r.id);
    assert_eq!(resp.len(), n_seqs as usize, "all requests answered");
    for r in &resp {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(r.tokens.len(), 120);
    }
    assert!(b.backend.evictions > 0, "pool pressure must force evictions");
    assert!(
        b.backend.max_resident_l0 <= 96 + 16,
        "residency blew the budget: {}",
        b.backend.max_resident_l0
    );
    let pool = b.backend.inner.engine.pool();
    assert_eq!(pool.allocated_pages(), 0, "sequences must release their pages");
    assert!(
        pool.high_water_pages() > 0 && pool.high_water_pages() <= 192,
        "high water {} outside pool bounds",
        pool.high_water_pages()
    );
}

#[test]
fn chunked_admission_matches_monolithic_and_records_prefill_metrics() {
    // The same requests under prefill-first and prefill-token-budgeted
    // admission must decode identical token streams (chunked prefill is
    // bit-identical; batch composition never changes per-sequence decode),
    // and every admitted request must leave exactly one
    // `admit.prefill_secs` sample in the engine metrics registry.
    let n_reqs = 4u64;
    let run = |budget: Option<usize>| -> (Vec<Vec<u32>>, usize) {
        let engine = mk_engine(1e-4, 96, 512);
        let mut b = Batcher::new(
            EngineBackend::new(engine).with_page_estimate(40),
            BatcherConfig { max_batch: 2, prefill_token_budget: budget, ..Default::default() },
        );
        let (tx, rx) = channel::<Response>();
        let spec = b.backend.engine.meta.corpus.clone();
        let mut rng = Rng::new(23);
        for id in 0..n_reqs {
            let p = Problem::sample(&mut rng, &spec, Some(8));
            b.submit(Request::new(id, p.encode_prompt(&spec), 48, tx.clone()));
        }
        b.run_to_completion();
        drop(tx);
        let mut resp: Vec<Response> = rx.iter().collect();
        resp.sort_by_key(|r| r.id);
        assert_eq!(resp.len(), n_reqs as usize, "all requests answered");
        for r in &resp {
            assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
            assert!(r.ttft_secs >= 0.0);
        }
        assert_eq!(b.backend.engine.pool().allocated_pages(), 0, "pool drained");
        let samples = b
            .backend
            .engine
            .metrics
            .timer("admit.prefill_secs")
            .map(|t| t.count())
            .unwrap_or(0);
        (resp.into_iter().map(|r| r.tokens).collect(), samples)
    };

    let (mono_tokens, mono_samples) = run(None);
    let (chunked_tokens, chunked_samples) = run(Some(8));
    assert_eq!(mono_tokens, chunked_tokens,
               "budgeted admission must not change decoded tokens");
    assert_eq!(mono_samples, n_reqs as usize,
               "one admit.prefill_secs sample per request (prefill-first)");
    assert_eq!(chunked_samples, n_reqs as usize,
               "one admit.prefill_secs sample per request (chunked)");
}
