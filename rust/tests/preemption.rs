//! Preempt → resume bit-identity (ISSUE 8 tentpole): a sequence parked
//! under pool pressure and later resumed must decode the exact token
//! stream — and emit the exact Figure-3 score log — of an uninterrupted
//! run, in BOTH preemption modes (recompute: drop pages + replay history;
//! restore: swap pages to a host buffer and back) across the full policy
//! zoo (`PolicyKind::all`).  Two layers:
//!
//!  * engine-level: manual decode with score logging, preempted mid-run;
//!  * serving-level: `Batcher` + `EngineBackend` with a deterministic
//!    injected `PoolExhausted` fault forcing a real preemption, compared
//!    against a fault-free control run of the same requests.

use std::sync::mpsc::channel;

use anyhow::Result;
use raas::config::{EngineConfig, PolicyKind, PreemptMode};
use raas::coordinator::batcher::{Batcher, BatcherConfig, StepBackend, StepItem};
use raas::coordinator::request::{Outcome, Request, RequestId, Response};
use raas::coordinator::server::EngineBackend;
use raas::engine::{Engine, GenOptions};
use raas::kvcache::SeqCache;
use raas::runtime::{FaultOp, FaultSchedule, StepFaultInjector};

const MODES: [PreemptMode; 2] = [PreemptMode::Recompute, PreemptMode::Restore];

fn mk_engine(policy: PolicyKind) -> Engine {
    let cfg = EngineConfig { policy, budget: 96, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

#[test]
fn engine_level_preempt_resume_is_bit_identical() {
    // Decode 12 steps; preempt after step 4 (both modes), resume, finish.
    // Tokens AND per-step Figure-3 score logs must match an uninterrupted
    // reference run — stamps, H2O accumulators and page tables all rebuild.
    let prompt: Vec<u32> = (0..20u32).map(|i| 1 + i % 40).collect();
    let steps = 12usize;
    for policy in PolicyKind::all() {
        let opts = GenOptions {
            max_new: steps,
            force_len: Some(steps),
            log_scores: true,
            ..Default::default()
        };
        let mut plain = mk_engine(policy);
        let reference = plain.generate(&prompt, &opts).expect("reference run");

        for mode in MODES {
            let mut e = mk_engine(policy);
            let mut seq = e.new_seq();
            let mut tok = e.prefill_seq(&mut seq, &prompt).expect("prefill");
            let mut tokens = vec![tok];
            let mut log = Vec::new();
            // the decode-step inputs applied so far — the `produced`
            // history the scheduler would hand to `StepBackend::resume`
            let mut fed = Vec::new();
            for step in 1..=4u64 {
                fed.push(tok);
                tok = e.decode_step(&mut seq, tok, step, Some(&mut log)).expect("step");
                tokens.push(tok);
            }
            match mode {
                PreemptMode::Restore => {
                    // park: bytes go host-side; churn the freed ranges so
                    // swap-in really remaps physical pages
                    let handle = e.swap_out_seq(&mut seq);
                    let mut filler = e.new_seq();
                    e.prefill_seq(&mut filler, &prompt).expect("filler prefill");
                    e.release_seq(&mut filler);
                    e.swap_in_seq(&mut seq, &handle).expect("swap in");
                }
                PreemptMode::Recompute => {
                    // park: drop everything; resume re-prefills and replays
                    // the fed tokens with their original step counters
                    // (exactly what `EngineBackend::resume` does)
                    e.release_seq(&mut seq);
                    seq = e.new_seq();
                    let first = e.prefill_seq(&mut seq, &prompt).expect("re-prefill");
                    assert_eq!(first, tokens[0], "re-prefill must decode the same token");
                    for (i, &t) in fed.iter().enumerate() {
                        e.decode_step(&mut seq, t, (i + 1) as u64, None).expect("replay");
                    }
                }
            }
            for step in 5..=steps as u64 {
                tok = e.decode_step(&mut seq, tok, step, Some(&mut log)).expect("step");
                tokens.push(tok);
            }
            // generate() pushes before decoding, so compare its window
            tokens.truncate(reference.tokens.len());
            assert_eq!(tokens, reference.tokens,
                       "{policy:?}/{mode}: preempted decode diverged");
            assert_eq!(log, reference.score_log,
                       "{policy:?}/{mode}: Figure-3 log diverged");
            e.release_seq(&mut seq);
            assert_eq!(e.pool().allocated_pages(), 0, "{policy:?}/{mode}: pages leaked");
        }
    }
}

/// `EngineBackend` that never sees EOS, so every request decodes exactly
/// `max_new` tokens — the run length (and thus the fault schedule's
/// alignment) is deterministic across policies.
struct NoEos(EngineBackend);

impl StepBackend for NoEos {
    type Seq = SeqCache;
    fn begin(&mut self, prompt: &[u32]) -> Result<(SeqCache, u32)> {
        self.0.begin(prompt)
    }
    fn step(&mut self, seq: &mut SeqCache, token: u32, now: u64) -> Result<u32> {
        self.0.step(seq, token, now)
    }
    fn step_batch(&mut self, items: &mut [StepItem<'_, SeqCache>]) -> Vec<Result<u32>> {
        self.0.step_batch(items)
    }
    fn preempt(&mut self, id: RequestId, seq: SeqCache, mode: PreemptMode) -> Result<()> {
        self.0.preempt(id, seq, mode)
    }
    fn resume(&mut self, id: RequestId, prompt: &[u32], produced: &[u32]) -> Result<SeqCache> {
        self.0.resume(id, prompt, produced)
    }
    fn record_counter(&mut self, name: &'static str, delta: u64) {
        self.0.record_counter(name, delta);
    }
    fn finish(&mut self, seq: SeqCache) {
        self.0.finish(seq);
    }
    fn is_eos(&self, _token: u32) -> bool {
        false
    }
    fn has_capacity(&self, active: usize) -> bool {
        self.0.has_capacity(active)
    }
}

/// Serve 3 fixed requests under `schedule`; returns the per-request token
/// streams (id order) plus the batcher after the run (for counters/pool).
fn serve(policy: PolicyKind, mode: PreemptMode, schedule: FaultSchedule)
         -> (Vec<Vec<u32>>, Batcher<StepFaultInjector<NoEos>>) {
    let backend = StepFaultInjector::new(
        NoEos(EngineBackend::new(mk_engine(policy)).with_page_estimate(8)),
        schedule,
    );
    let mut b = Batcher::new(
        backend,
        BatcherConfig { max_batch: 3, preempt_mode: mode, ..Default::default() },
    );
    let (tx, rx) = channel::<Response>();
    for id in 0..3u64 {
        let prompt: Vec<u32> = (0..16).map(|i| 1 + ((i + id as usize) % 40) as u32).collect();
        b.submit(Request::new(id, prompt, 20, tx.clone()));
    }
    b.run_to_completion();
    drop(tx);
    let mut resp: Vec<Response> = rx.iter().collect();
    resp.sort_by_key(|r| r.id);
    assert_eq!(resp.len(), 3, "all requests answered");
    for r in &resp {
        assert_eq!(r.outcome, Outcome::Done, "request {} ended {:?}: {:?}",
                   r.id, r.outcome, r.error);
        assert_eq!(r.tokens.len(), 20);
    }
    (resp.into_iter().map(|r| r.tokens).collect(), b)
}

#[test]
fn serving_preempt_resume_is_bit_identical_across_policies_and_modes() {
    // The injected Alloc fault fires on the 2nd decode-step draw of the
    // first batched tick — while 3 sequences are active — so the batcher
    // must rewind the stalled step, preempt a victim (mode under test),
    // resume it, and still answer every request with exactly the tokens a
    // fault-free run decodes.
    for policy in PolicyKind::all() {
        for mode in MODES {
            let (control, cb) = serve(policy, mode, FaultSchedule::new(0));
            assert_eq!(cb.preemptions, 0, "control run must not preempt");

            let schedule = FaultSchedule::new(0).fail_nth(FaultOp::Alloc, 2);
            let (chaos, b) = serve(policy, mode, schedule);
            assert_eq!(chaos, control,
                       "{policy:?}/{mode}: preempt/resume changed decoded tokens");
            assert!(b.preemptions >= 1, "{policy:?}/{mode}: the fault must preempt");
            assert_eq!(b.backend.schedule.injected(), 1, "exactly the targeted fault fired");

            let m = &b.backend.inner.0.engine.metrics;
            assert_eq!(m.counter("preempt.count"), b.preemptions,
                       "metrics mirror the batcher counter");
            match mode {
                PreemptMode::Restore => assert!(
                    m.counter("preempt.restore_bytes") > 0,
                    "{policy:?}: restore mode must swap bytes host-side"
                ),
                PreemptMode::Recompute => assert!(
                    m.counter("preempt.recompute_tokens") > 0,
                    "{policy:?}: recompute mode must replay tokens"
                ),
            }
            assert_eq!(b.backend.inner.0.engine.pool().allocated_pages(), 0,
                       "{policy:?}/{mode}: pool must drain");
        }
    }
}
