//! Seeded accuracy-cliff regression (Lil harness, milestone-sparse, 8k
//! decode): the paper-ordering claims the full grid in
//! `benches/accuracy_cliff.rs` visualises, pinned as a deterministic test
//! at one grid cell — at a 256-token budget RaaS's stamp-driven retention
//! holds every era anchor (the re-read refreshes its stamp every step,
//! while cold pages go tens of tokens between spurious flares), matching
//! the dense coin count exactly, while H2O's pin-blind lifetime
//! accumulator sheds phoenix prompt pages and fresh anchors, and Quest's
//! O(N) selection drowns in resident-set flares — both collapse to zero.
//!
//! Every policy replays the SAME pre-generated traces with the SAME
//! answer coins (see `LilTrace`), so the unbudgeted dense reference is
//! *exactly* the coin count — any drift is a simulator regression, not
//! noise — and cross-policy comparisons are paired.

use raas::config::{EngineConfig, PolicyKind};
use raas::kvcache::policy::make_policy;
use raas::sim::{
    gen_lil_trace, run_lil_trials, LilAggregate, LilTrace, SimParams, LIL_SCENARIOS, MODELS,
};
use raas::util::rng::Rng;

/// Nominal decode length (tokens): the short end of the Lil grid.
const TARGET: usize = 8192;
/// The smallest budget at which RaaS holds every era anchor (the cliff
/// cell: both baselines have already collapsed here — see the bench grid).
const BUDGET: usize = 256;
const TRIALS: usize = 16;

fn traces() -> Vec<LilTrace> {
    let sc = &LIL_SCENARIOS[1]; // milestone-sparse
    let mut rng = Rng::new(0xC11FF);
    (0..TRIALS).map(|_| gen_lil_trace(sc, &MODELS[2], TARGET, &mut rng)).collect()
}

fn cell(kind: PolicyKind, budget: usize, traces: &[LilTrace]) -> LilAggregate {
    let sc = &LIL_SCENARIOS[1];
    let cfg = EngineConfig {
        policy: kind,
        budget,
        alpha: sc.raas_alpha,
        ..Default::default()
    };
    let policy = make_policy(&cfg);
    let params = SimParams {
        budget_tokens: budget,
        max_decode: TARGET + 4096,
        ..Default::default()
    };
    run_lil_trials(policy.as_ref(), &params, &MODELS[2], sc, traces)
}

#[test]
fn dense_reference_is_exact_and_raas_holds_the_cliff() {
    let sc = &LIL_SCENARIOS[1];
    let traces = traces();

    let dense = cell(PolicyKind::Dense, 1 << 24, &traces);
    let raas = cell(PolicyKind::Raas, BUDGET, &traces);
    let quest = cell(PolicyKind::Quest, BUDGET, &traces);
    let h2o = cell(PolicyKind::H2o, BUDGET, &traces);

    // dense = the shared answer coins, exactly: no misses, no derailments,
    // full token agreement
    let reference =
        traces.iter().filter(|t| t.answer_u < sc.base_acc).count() as f64 / TRIALS as f64;
    assert!((dense.accuracy - reference).abs() < 1e-12,
            "dense {} must equal the coin count {reference}", dense.accuracy);
    assert!((dense.token_agreement - 1.0).abs() < 1e-12,
            "dense agreement {}", dense.token_agreement);
    assert_eq!(dense.milestone_miss_rate, 0.0);
    assert_eq!(dense.phoenix_miss_rate, 0.0);
    assert_eq!(dense.cap_rate, 0.0);

    // raas tracks the dense ceiling at the cliff budget (the port of this
    // cell measures exact equality; two trials of slack absorb fp drift)
    assert!(raas.accuracy + 2.0 / TRIALS as f64 + 1e-9 >= dense.accuracy,
            "raas {} must track dense {} at budget {BUDGET}", raas.accuracy, dense.accuracy);

    // the paper ordering at the small budget: raas >= quest >= h2o (one
    // trial of slack on the quest/h2o tail, where both sit near zero)
    assert!(raas.accuracy + 1e-9 >= quest.accuracy,
            "raas {} must not trail quest {}", raas.accuracy, quest.accuracy);
    assert!(quest.accuracy + 1.0 / TRIALS as f64 + 1e-9 >= h2o.accuracy,
            "quest {} more than one trial under h2o {}", quest.accuracy, h2o.accuracy);
    // the cliff is real: stamp-driven retention clears eviction-by-history
    // by a wide margin at 8k decode
    assert!(raas.accuracy > h2o.accuracy + 0.15,
            "raas {} vs h2o {}: the 8k cliff should separate them",
            raas.accuracy, h2o.accuracy);
    assert!(raas.token_agreement + 1e-9 >= quest.token_agreement,
            "raas agreement {} vs quest {}", raas.token_agreement, quest.token_agreement);

    // the baselines actually lose milestones at this budget — otherwise the
    // cell is too easy to mean anything
    assert!(quest.milestone_miss_rate > 0.0, "quest must miss milestones at budget {BUDGET}");
    assert!(h2o.milestone_miss_rate > 0.0, "h2o must miss milestones at budget {BUDGET}");

    // memory: eviction-sparse raas stays near the budget, selection-sparse
    // quest retains the whole 8k+ trace
    assert!(raas.mean_peak_resident < (BUDGET + 160) as f64,
            "raas peak {}", raas.mean_peak_resident);
    assert!(quest.mean_peak_resident > 4.0 * raas.mean_peak_resident,
            "quest {} vs raas {}", quest.mean_peak_resident, raas.mean_peak_resident);
}
