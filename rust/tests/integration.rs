//! Integration tests over the full stack (backend + engine + policies).
//!
//! These run hermetically on the default `SimBackend` — no artifacts, no
//! native dependencies — so `cargo test` exercises the complete decode path
//! on a fresh clone.  The xla-backend variants (trained-weights accuracy,
//! python-oracle consistency) live in the feature-gated module at the
//! bottom and skip with a notice when artifacts are absent.

use raas::config::{EngineConfig, PolicyKind};
use raas::engine::{Engine, GenOptions};
use raas::util::rng::Rng;
use raas::workload::Problem;

fn engine(policy: PolicyKind, budget: usize) -> Engine {
    let cfg = EngineConfig {
        policy,
        budget,
        ..Default::default()
    };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("engine")
}

#[test]
fn dense_generation_is_wellformed_and_deterministic() {
    let mut e = engine(PolicyKind::Dense, 4096);
    let spec = e.meta.corpus.clone();
    let mut rng = Rng::new(1);
    let p = Problem::sample(&mut rng, &spec, Some(6));
    let prompt = p.encode_prompt(&spec);
    let opts = GenOptions { max_new: 64, ..Default::default() };
    let a = e.generate(&prompt, &opts).unwrap();
    let b = e.generate(&prompt, &opts).unwrap();
    assert_eq!(a.tokens, b.tokens, "greedy decoding must be deterministic");
    assert!(!a.tokens.is_empty());
    // never emits out-of-vocab ids
    assert!(a.tokens.iter().all(|&t| (t as usize) < e.meta.model.vocab));
}

#[test]
fn raas_memory_stays_bounded_dense_grows() {
    let budget = 128;
    let force = 320;
    let mut prompt_engine = engine(PolicyKind::Dense, budget);
    let spec = prompt_engine.meta.corpus.clone();
    let mut rng = Rng::new(3);
    let p = Problem::sample(&mut rng, &spec, Some(8));
    let prompt = p.encode_prompt(&spec);
    let opts = GenOptions { max_new: force, force_len: Some(force), ..Default::default() };

    let dense_out = prompt_engine.generate(&prompt, &opts).unwrap();
    let mut raas_engine = engine(PolicyKind::Raas, budget);
    let raas_out = raas_engine.generate(&prompt, &opts).unwrap();

    assert!(
        raas_out.peak_resident_tokens_l0 <= budget + raas_engine.meta.page_size,
        "raas layer-0 resident {} exceeds budget {budget}",
        raas_out.peak_resident_tokens_l0
    );
    assert!(
        dense_out.peak_resident_bytes > 2 * raas_out.peak_resident_bytes,
        "dense {} should dwarf raas {}",
        dense_out.peak_resident_bytes,
        raas_out.peak_resident_bytes
    );
}

#[test]
fn quest_retains_everything_but_attends_budget() {
    let budget = 128;
    let force = 256;
    let mut e = engine(PolicyKind::Quest, budget);
    let spec = e.meta.corpus.clone();
    let mut rng = Rng::new(4);
    let p = Problem::sample(&mut rng, &spec, Some(8));
    let out = e
        .generate(
            &p.encode_prompt(&spec),
            &GenOptions { max_new: force, force_len: Some(force), ..Default::default() },
        )
        .unwrap();
    // memory grows beyond the budget (O(N) memory)
    assert!(
        out.peak_resident_tokens_l0 > budget,
        "quest should retain more than the budget: {}",
        out.peak_resident_tokens_l0
    );
}

#[test]
fn policies_agree_when_budget_covers_context() {
    // With a budget far larger than the sequence, every policy degenerates
    // to dense attention and must produce identical greedy output — on the
    // SAME problem for every policy.
    let mut reference: Option<Vec<u32>> = None;
    for kind in PolicyKind::all() {
        let mut e = engine(kind, 512);
        let spec = e.meta.corpus.clone();
        let mut prng = Rng::new(5);
        let p = Problem::sample(&mut prng, &spec, Some(4));
        let out = e
            .generate(&p.encode_prompt(&spec),
                      &GenOptions { max_new: 40, force_len: Some(40), ..Default::default() })
            .unwrap();
        match &reference {
            None => reference = Some(out.tokens),
            Some(r) => assert_eq!(r, &out.tokens, "{kind:?} diverged under slack budget"),
        }
    }
}

#[test]
fn sink_budget_enforced_during_long_decode() {
    let budget = 96;
    let mut e = engine(PolicyKind::Sink, budget);
    let spec = e.meta.corpus.clone();
    let mut rng = Rng::new(6);
    let p = Problem::sample(&mut rng, &spec, Some(8));
    let out = e
        .generate(
            &p.encode_prompt(&spec),
            &GenOptions { max_new: 300, force_len: Some(300), ..Default::default() },
        )
        .unwrap();
    assert!(
        out.peak_resident_tokens_l0 <= budget + e.meta.page_size,
        "sink resident {} exceeds budget {budget}",
        out.peak_resident_tokens_l0
    );
}

#[test]
fn pool_exhaustion_is_reported_not_panicking() {
    let cfg = EngineConfig {
        policy: PolicyKind::Dense,
        budget: 1 << 20,
        pool_pages: 24, // tiny pool: 24 pages / 4 layers = 6 pages/layer ≈ 96 tokens
        ..Default::default()
    };
    let mut e = Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("engine");
    let spec = e.meta.corpus.clone();
    let mut rng = Rng::new(7);
    let p = Problem::sample(&mut rng, &spec, Some(spec.max_steps));
    let r = e.generate(
        &p.encode_prompt(&spec),
        &GenOptions { max_new: 400, force_len: Some(400), ..Default::default() },
    );
    assert!(r.is_err(), "dense decode into a tiny pool must fail gracefully");
    let msg = format!("{:#}", r.unwrap_err());
    assert!(msg.contains("pool exhausted"), "unexpected error: {msg}");
}

#[test]
fn score_log_records_waterfall_series() {
    let mut e = engine(PolicyKind::Dense, 4096);
    let spec = e.meta.corpus.clone();
    let mut rng = Rng::new(8);
    let p = Problem::sample(&mut rng, &spec, Some(8));
    let out = e
        .generate(
            &p.encode_prompt(&spec),
            &GenOptions {
                max_new: 48,
                force_len: Some(48),
                log_scores: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(out.score_log.len(), 48);
    for (_, entries) in &out.score_log {
        let sum: f32 = entries.iter().map(|(_, p)| *p).sum();
        assert!((sum - 1.0).abs() < 1e-3, "page probs must sum to 1, got {sum}");
    }
    // pages appear in position order and grow over time
    let first = out.score_log.first().unwrap().1.len();
    let last = out.score_log.last().unwrap().1.len();
    assert!(last >= first);
}

#[test]
fn seed_changes_sim_model() {
    // The surrogate is a family of models indexed by --seed: different
    // seeds must yield different generations for the same prompt.
    let mk = |seed: u64| {
        let cfg =
            EngineConfig { policy: PolicyKind::Dense, budget: 1024, seed, ..Default::default() };
        Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("engine")
    };
    let spec = mk(0).meta.corpus.clone();
    let mut rng = Rng::new(9);
    let p = Problem::sample(&mut rng, &spec, Some(6));
    let prompt = p.encode_prompt(&spec);
    let opts = GenOptions { max_new: 32, force_len: Some(32), ..Default::default() };
    let a = mk(1).generate(&prompt, &opts).unwrap();
    let b = mk(2).generate(&prompt, &opts).unwrap();
    assert_ne!(a.tokens, b.tokens, "different seeds should differ");
}

// ---------------------------------------------------------------------------
// xla-backend variants: need `--features backend-xla` + `make artifacts`
// ---------------------------------------------------------------------------

#[cfg(feature = "backend-xla")]
mod xla_backend {
    use super::*;
    use raas::config::BackendKind;

    fn artifacts_ready() -> bool {
        let ok = std::path::Path::new("artifacts/meta.json").exists();
        if !ok {
            eprintln!("SKIP: artifacts/meta.json missing (run `make artifacts`)");
        }
        ok
    }

    fn engine_xla(policy: PolicyKind, budget: usize) -> Engine {
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            policy,
            budget,
            ..Default::default()
        };
        Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("engine")
    }

    #[test]
    fn trained_model_solves_problems_dense() {
        if !artifacts_ready() {
            return;
        }
        let mut e = engine_xla(PolicyKind::Dense, 4096);
        if !e.meta.trained {
            eprintln!("SKIP: artifacts exported from untrained weights");
            return;
        }
        let spec = e.meta.corpus.clone();
        let mut rng = Rng::new(2);
        let n = 10;
        let mut correct = 0;
        for _ in 0..n {
            let p = Problem::sample(&mut rng, &spec, Some(6));
            let out = e
                .generate(&p.encode_prompt(&spec),
                          &GenOptions { max_new: 64, ..Default::default() })
                .unwrap();
            if e.tokenizer.parse_answer(&out.tokens) == Some(p.answer()) {
                correct += 1;
            }
        }
        assert!(correct * 2 >= n, "trained dense model solved only {correct}/{n} short chains");
    }

    #[test]
    fn serving_path_matches_python_dense_oracle() {
        if !artifacts_ready() {
            return;
        }
        let path = std::path::Path::new("artifacts/consistency.json");
        let Ok(text) = std::fs::read_to_string(path) else {
            eprintln!("SKIP: artifacts/consistency.json missing (re-run `make artifacts`)");
            return;
        };
        let j = raas::util::json::Json::parse(&text).unwrap();
        let mut e = engine_xla(PolicyKind::Dense, 1 << 14);
        for case in j.get("cases").unwrap().as_arr().unwrap() {
            let prompt: Vec<u32> = case
                .get("prompt").unwrap().as_arr().unwrap()
                .iter().map(|v| v.as_i64().unwrap() as u32).collect();
            let expect: Vec<u32> = case
                .get("dense_tokens").unwrap().as_arr().unwrap()
                .iter().map(|v| v.as_i64().unwrap() as u32).collect();
            let out = e
                .generate(&prompt, &GenOptions {
                    max_new: expect.len(),
                    force_len: Some(expect.len()),
                    ..Default::default()
                })
                .unwrap();
            assert_eq!(out.tokens, expect,
                       "rust serving path diverged from the python dense oracle");
        }
    }
}
