//! Property-based tests (hand-rolled driver over the in-tree PRNG —
//! proptest is unavailable offline).  Each property runs hundreds of
//! randomized cases; failures print the offending seed for replay.

use raas::config::{EngineConfig, PolicyKind};
use raas::coordinator::batcher::{Batcher, BatcherConfig, StepBackend};
use raas::coordinator::request::Request;
use raas::kvcache::page::{page_probs, PageMeta, RepBounds};
use raas::kvcache::policy::{make_policy, resident_tokens};
use raas::kvcache::{KvPool, SeqCache};
use raas::util::json::Json;
use raas::util::rng::Rng;

const CASES: u64 = 200;

/// Run `f` over `CASES` seeds, reporting the failing seed.
fn forall(name: &str, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed * 7919 + 13);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if result.is_err() {
            panic!("property '{name}' failed at seed {seed}");
        }
    }
}

// ---------------------------------------------------------------------------
// pool invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_never_over_allocates() {
    forall("pool_alloc", |rng| {
        let cap = rng.range(1, 32);
        let mut pool = KvPool::new(cap, 16, 8);
        let mut held = Vec::new();
        for _ in 0..200 {
            if rng.chance(0.6) {
                match pool.alloc() {
                    Ok(id) => held.push(id),
                    Err(_) => assert_eq!(pool.allocated_pages(), cap, "alloc fails only when full"),
                }
            } else if let Some(id) = held.pop() {
                pool.release(id);
            }
            assert!(pool.allocated_pages() <= cap);
            assert_eq!(pool.allocated_pages(), held.len());
            assert!(pool.high_water_pages() >= pool.allocated_pages());
        }
    });
}

// ---------------------------------------------------------------------------
// quantization codecs
// ---------------------------------------------------------------------------

#[test]
fn prop_quant_roundtrip_error_within_bound() {
    // Int8/Fp8E4M3 absolute reconstruction error stays within the dtype's
    // published `error_bound` on adversarial (inf/NaN-free) inputs: random
    // signs, magnitudes spanning ~60 decades, exact zeros, and pages whose
    // running range is pinned to a ±1e30 extreme — the large-dynamic-range
    // regime where a wrong scale or an overflowing affine would blow up.
    use raas::kvcache::KvDtype;
    forall("quant_roundtrip", |rng| {
        let n = rng.range(1, 65);
        let mut vals: Vec<f32> = (0..n)
            .map(|_| {
                if rng.chance(0.1) {
                    0.0
                } else {
                    let mag = 10f64.powf(rng.normal() * 10.0) as f32;
                    let s = if rng.chance(0.5) { -1.0 } else { 1.0 };
                    (s * mag).clamp(-1e30, 1e30)
                }
            })
            .collect();
        if rng.chance(0.3) {
            vals[0] = if rng.chance(0.5) { -1e30 } else { 1e30 };
        }
        for d in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let p = d.params(lo, hi);
            assert!(p.scale.is_finite(), "{d}: params must stay finite");
            let mut enc = vec![0u8; vals.len()];
            let mut dec = vec![0f32; vals.len()];
            d.encode_slice(&vals, p, &mut enc);
            d.decode_slice(&enc, p, &mut dec);
            for (i, (&x, &y)) in vals.iter().zip(&dec).enumerate() {
                assert!(y.is_finite(), "{d}: decode must stay finite");
                let bound = d.error_bound(x, p);
                assert!(
                    (x - y).abs() <= bound,
                    "{d} val[{i}]={x:e} decoded {y:e} err {:e} > bound {bound:e}",
                    (x - y).abs()
                );
            }
        }
    });
}

// ---------------------------------------------------------------------------
// sequence cache invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_seq_resident_accounting() {
    forall("seq_accounting", |rng| {
        let page_size = 4;
        let mut pool = KvPool::new(256, page_size, 6);
        let mut seq = SeqCache::new(2, page_size, 6);
        let mut appended = vec![0usize; 2];
        let mut evicted_tokens = vec![0usize; 2];
        for pos in 0..rng.range(1, 80) {
            for layer in 0..2 {
                seq.append(layer, &mut pool, pos, &[0.5; 6], &[0.1; 6], pos < 8, 0).unwrap();
                appended[layer] += 1;
            }
            if rng.chance(0.1) {
                let layer = rng.range(0, 2);
                if seq.layers[layer].table.len() > 1 {
                    let idx = rng.range(0, seq.layers[layer].table.len() - 1);
                    evicted_tokens[layer] += seq.layers[layer].table[idx].len;
                    seq.evict(layer, idx, &mut pool);
                }
            }
        }
        for layer in 0..2 {
            assert_eq!(seq.resident_tokens(layer), appended[layer] - evicted_tokens[layer]);
            // table ordered by start_pos, reps aligned
            let t = &seq.layers[layer].table;
            assert_eq!(t.len(), seq.layers[layer].reps.len());
            for w in t.windows(2) {
                assert!(w[0].start_pos < w[1].start_pos);
            }
        }
        seq.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    });
}

#[test]
fn prop_append_slots_matches_appends() {
    // Bulk page-granular `append_slots` must be bit-identical to N
    // sequential `append` calls — same page tables (pool ids included),
    // same slab bytes, same RepBounds — across random page sizes, kv dims,
    // run splits, and the pinned→unpinned prefill boundary.
    forall("append_slots", |rng| {
        let page_size = rng.range(2, 9);
        let kv_dim = rng.range(1, 5);
        let n = rng.range(1, 60);
        let pinned_prefix = rng.range(0, n + 1);
        let k: Vec<f32> = (0..n * kv_dim).map(|_| rng.normal() as f32).collect();
        let v: Vec<f32> = (0..n * kv_dim).map(|_| rng.normal() as f32).collect();

        // reference: token-by-token appends
        let mut pb = KvPool::new(64, page_size, kv_dim);
        let mut sb = SeqCache::new(1, page_size, kv_dim);
        for pos in 0..n {
            sb.append(0, &mut pb, pos, &k[pos * kv_dim..(pos + 1) * kv_dim],
                      &v[pos * kv_dim..(pos + 1) * kv_dim], pos < pinned_prefix, 7)
                .unwrap();
        }

        // bulk: random-length runs, split at the pinned boundary exactly
        // like the engine's prefill→decode transition
        let mut pa = KvPool::new(64, page_size, kv_dim);
        let mut sa = SeqCache::new(1, page_size, kv_dim);
        let mut pos = 0usize;
        while pos < n {
            let pinned = pos < pinned_prefix;
            let limit = if pinned { pinned_prefix } else { n };
            let run = rng.range(1, (limit - pos).min(13) + 1);
            sa.append_slots(0, &mut pa, pos, run, &k[pos * kv_dim..(pos + run) * kv_dim],
                            &v[pos * kv_dim..(pos + run) * kv_dim], pinned, 7)
                .unwrap();
            pos += run;
        }

        let (ta, tb) = (&sa.layers[0].table, &sb.layers[0].table);
        assert_eq!(ta.len(), tb.len(), "page counts diverged");
        for (a, b) in ta.iter().zip(tb.iter()) {
            assert_eq!((a.pool_id, a.start_pos, a.len, a.pinned, a.last_stamp),
                       (b.pool_id, b.start_pos, b.len, b.pinned, b.last_stamp));
            let eq_bits = |x: &[f32], y: &[f32]| {
                x.len() == y.len()
                    && x.iter().zip(y).all(|(p, q)| p.to_bits() == q.to_bits())
            };
            assert!(eq_bits(pa.page_k(a.pool_id, a.len), pb.page_k(b.pool_id, b.len)),
                    "key slab bytes diverged");
            assert!(eq_bits(pa.page_v(a.pool_id, a.len), pb.page_v(b.pool_id, b.len)),
                    "value slab bytes diverged");
        }
        for (ra, rb) in sa.layers[0].reps.iter().zip(&sb.layers[0].reps) {
            assert_eq!(ra.kmin, rb.kmin, "rep kmin diverged");
            assert_eq!(ra.kmax, rb.kmax, "rep kmax diverged");
        }
        sa.release_all(&mut pa);
        sb.release_all(&mut pb);
        assert_eq!(pa.allocated_pages(), 0);
        assert_eq!(pb.allocated_pages(), 0);
    });
}

#[test]
fn prop_gather_valid_matches_selection() {
    forall("gather", |rng| {
        let page_size = 4;
        let mut pool = KvPool::new(128, page_size, 3);
        let mut seq = SeqCache::new(1, page_size, 3);
        let n = rng.range(1, 60);
        for pos in 0..n {
            seq.append(0, &mut pool, pos, &[pos as f32; 3], &[0.0; 3], false, 0).unwrap();
        }
        let n_pages = seq.layers[0].table.len();
        let mut sel: Vec<usize> = (0..n_pages).filter(|_| rng.chance(0.5)).collect();
        if sel.is_empty() {
            sel.push(n_pages - 1);
        }
        let expect: usize = sel.iter().map(|&i| seq.layers[0].table[i].len).sum();
        let cap = expect.next_power_of_two().max(8);
        let (mut k, mut v, mut valid) = (Vec::new(), Vec::new(), Vec::new());
        let used = seq.gather(0, &pool, &sel, cap, &mut k, &mut v, &mut valid);
        assert_eq!(used, expect);
        assert_eq!(valid.iter().filter(|&&x| x > 0.5).count(), expect);
        assert!(valid[expect..].iter().all(|&x| x == 0.0));
    });
}

// ---------------------------------------------------------------------------
// policy invariants
// ---------------------------------------------------------------------------

fn random_table(rng: &mut Rng) -> (Vec<PageMeta>, Vec<f32>, Vec<f32>) {
    let n = rng.range(1, 40);
    let mut table = Vec::new();
    let mut pos = 0;
    for i in 0..n {
        let mut m = PageMeta::new(i as u32, pos, i < 3 && rng.chance(0.5), 0);
        m.len = rng.range(1, 17);
        m.last_stamp = rng.range(0, 50) as u64;
        m.acc_score = rng.f64() * 10.0;
        pos += m.len;
        table.push(m);
    }
    let scores: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 6.0 - 3.0).collect();
    let mut probs = Vec::new();
    page_probs(&scores, 16, &mut probs);
    (table, scores, probs)
}

#[test]
fn prop_policies_select_valid_indices_including_active() {
    forall("select_valid", |rng| {
        let (table, scores, _) = random_table(rng);
        for kind in PolicyKind::all() {
            let budget = rng.range(16, 2048);
            let cfg = EngineConfig { policy: kind, budget, ..Default::default() };
            let policy = make_policy(&cfg);
            let sel = policy.select(&table, &scores, budget, 16);
            assert!(!sel.is_empty());
            let mut seen = std::collections::BTreeSet::new();
            for &i in &sel {
                assert!(i < table.len(), "{kind:?} selected out of range");
                assert!(seen.insert(i), "{kind:?} duplicate selection");
            }
            assert!(sel.contains(&(table.len() - 1)), "{kind:?} must include active page");
        }
    });
}

#[test]
fn prop_eviction_respects_pins_and_active_page() {
    forall("evict_valid", |rng| {
        let (table, _, _) = random_table(rng);
        for kind in PolicyKind::all() {
            let cfg = EngineConfig { policy: kind, budget: 64, ..Default::default() };
            let policy = make_policy(&cfg);
            if let Some(victim) = policy.evict_candidate(&table) {
                assert!(victim < table.len() - 1, "{kind:?} evicted the active page");
                if matches!(kind, PolicyKind::Raas | PolicyKind::Rpc) {
                    assert!(!table[victim].pinned, "{kind:?} evicted pinned prefill");
                }
            } else {
                let len = table.len();
                let ok = match kind {
                    PolicyKind::Dense | PolicyKind::Quest | PolicyKind::LessIsMore => true,
                    _ if len <= 1 => true,
                    PolicyKind::Raas => table[..len - 1].iter().all(|p| p.pinned),
                    PolicyKind::Sink => {
                        table[..len - 1].iter().all(|p| p.start_pos < cfg.sink_tokens)
                    }
                    PolicyKind::Rpc => {
                        // mirror the policy's page-size inference: refusal is
                        // legitimate only when pins cover everything outside
                        // the protected recent tail
                        let ps = table.iter().map(|p| p.len).max().unwrap_or(16).max(1);
                        let protected = (cfg.rpc_period as usize / ps + 1).min(len - 1);
                        table[..len - protected].iter().all(|p| p.pinned)
                    }
                    PolicyKind::H2o => false,
                };
                assert!(ok, "{kind:?} refused eviction with evictable pages present");
            }
        }
    });
}

#[test]
fn prop_eviction_loop_reaches_budget_or_pins() {
    forall("evict_loop", |rng| {
        let (mut table, _, _) = random_table(rng);
        let budget = rng.range(16, 256);
        let cfg = EngineConfig { policy: PolicyKind::Raas, budget, ..Default::default() };
        let policy = make_policy(&cfg);
        loop {
            if resident_tokens(&table) <= budget {
                break;
            }
            match policy.evict_candidate(&table) {
                Some(i) => {
                    table.remove(i);
                }
                None => break,
            }
        }
        let resident = resident_tokens(&table);
        let pinned: usize =
            table.iter().filter(|p| p.pinned).map(|p| p.len).sum();
        let active = table.last().map(|p| p.len).unwrap_or(0);
        assert!(
            resident <= budget || resident <= pinned + active,
            "over budget with evictable pages left: resident={resident} budget={budget}"
        );
    });
}

#[test]
fn prop_raas_stamps_monotone() {
    forall("stamps_monotone", |rng| {
        let (mut table, _, probs) = random_table(rng);
        let cfg = EngineConfig { policy: PolicyKind::Raas, ..Default::default() };
        let policy = make_policy(&cfg);
        let before: Vec<u64> = table.iter().map(|p| p.last_stamp).collect();
        let now = 1000;
        policy.observe(&mut table, &probs, now);
        for (b, a) in before.iter().zip(&table) {
            assert!(a.last_stamp >= *b, "stamp moved backwards");
            assert!(a.last_stamp == *b || a.last_stamp == now);
        }
    });
}

#[test]
fn prop_quest_selection_is_top_k_by_score() {
    forall("quest_topk", |rng| {
        let (table, scores, _) = random_table(rng);
        let cfg = EngineConfig { policy: PolicyKind::Quest, budget: 64, ..Default::default() };
        let policy = make_policy(&cfg);
        let sel = policy.select(&table, &scores, 64, 16);
        let k = sel.len();
        // every non-selected, non-active page must score <= the minimum
        // selected non-active page
        let min_sel = sel
            .iter()
            .filter(|&&i| i != table.len() - 1)
            .map(|&i| scores[i])
            .fold(f32::INFINITY, f32::min);
        for i in 0..table.len() - 1 {
            if !sel.contains(&i) {
                assert!(
                    scores[i] <= min_sel + 1e-6,
                    "unselected page {i} outscores a selected one"
                );
            }
        }
        assert!(k <= (64 / 16).max(1) || k == table.len());
    });
}

#[test]
fn prop_policies_tolerate_non_finite_scores() {
    // Regression: Quest/RaaS/H2O sorted with `partial_cmp().unwrap()`, so a
    // single NaN score panicked the whole engine mid-decode.  Every policy
    // must now survive NaN/±inf scores and probs through the full
    // observe → select → evict_candidate cycle, with its invariants intact.
    forall("non_finite_scores", |rng| {
        let (table, mut scores, mut probs) = random_table(rng);
        for _ in 0..rng.range(1, 6) {
            let i = rng.range(0, scores.len());
            let bad = match rng.range(0, 3) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                _ => f32::NEG_INFINITY,
            };
            scores[i] = bad;
            probs[i] = bad;
        }
        for kind in PolicyKind::all() {
            let budget = rng.range(16, 512);
            let cfg = EngineConfig { policy: kind, budget, ..Default::default() };
            let policy = make_policy(&cfg);
            // several observes so H2O accumulators go NaN and stay NaN
            let mut t = table.clone();
            for now in 1..=3 {
                policy.observe(&mut t, &probs, now);
            }
            let sel = policy.select(&t, &scores, budget, 16);
            assert!(!sel.is_empty(), "{kind:?} empty selection under NaN");
            let mut seen = std::collections::BTreeSet::new();
            for &i in &sel {
                assert!(i < t.len(), "{kind:?} selected out of range under NaN");
                assert!(seen.insert(i), "{kind:?} duplicate selection under NaN");
            }
            assert!(sel.contains(&(t.len() - 1)), "{kind:?} dropped active page under NaN");
            if let Some(victim) = policy.evict_candidate(&t) {
                assert!(victim < t.len() - 1, "{kind:?} evicted active page under NaN");
                if matches!(kind, PolicyKind::Raas | PolicyKind::Rpc) {
                    assert!(!t[victim].pinned, "{kind:?} evicted pinned prefill under NaN");
                }
            }
        }
        // the RaaS top-r formulation sorts probs directly; exercise it too
        let cfg = EngineConfig {
            policy: PolicyKind::Raas,
            alpha: 0.0,
            stamp_fraction: 0.5,
            ..Default::default()
        };
        let policy = make_policy(&cfg);
        let mut t = table.clone();
        policy.observe(&mut t, &probs, 9);
        assert_eq!(t.last().unwrap().last_stamp, 9, "active page must still be stamped");
    });
}

#[test]
fn prop_rep_bounds_dominate_member_keys() {
    forall("rep_bounds", |rng| {
        let kv_dim = 8; // 2 kv heads × hd 4
        let mut bounds = RepBounds::empty(kv_dim);
        let keys: Vec<Vec<f32>> = (0..rng.range(1, 16))
            .map(|_| (0..kv_dim).map(|_| rng.normal() as f32).collect())
            .collect();
        for k in &keys {
            bounds.update(k);
        }
        let q: Vec<f32> = (0..16).map(|_| rng.normal() as f32).collect(); // 4 heads × hd 4
        let bound = bounds.score(&q, 4, 2, 4);
        let group = 4 / 2;
        for k in &keys {
            for h in 0..4 {
                let g = h / group;
                let dot: f32 = (0..4).map(|c| q[h * 4 + c] * k[g * 4 + c]).sum();
                assert!(bound >= dot - 1e-4, "bound {bound} < member dot {dot}");
            }
        }
    });
}

#[test]
fn prop_page_probs_is_distribution() {
    forall("page_probs", |rng| {
        let n = rng.range(1, 64);
        let scores: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 20.0 - 10.0).collect();
        let mut probs = Vec::new();
        page_probs(&scores, 16, &mut probs);
        assert_eq!(probs.len(), n);
        assert!(probs.iter().all(|&p| (0.0..=1.0 + 1e-5).contains(&p)));
        let sum: f32 = probs.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "sum {sum}");
    });
}

// ---------------------------------------------------------------------------
// coordinator conservation
// ---------------------------------------------------------------------------

struct CountBackend {
    live: usize,
    peak: usize,
    cap: usize,
}

impl StepBackend for CountBackend {
    type Seq = u32;
    fn begin(&mut self, prompt: &[u32]) -> anyhow::Result<(u32, u32)> {
        self.live += 1;
        self.peak = self.peak.max(self.live);
        Ok((prompt[0], 1))
    }
    fn step(&mut self, seq: &mut u32, _t: u32, _n: u64) -> anyhow::Result<u32> {
        if *seq == 0 {
            return Ok(0);
        }
        *seq -= 1;
        Ok(if *seq == 0 { 0 } else { 5 })
    }
    fn finish(&mut self, _s: u32) {
        self.live -= 1;
    }
    fn is_eos(&self, t: u32) -> bool {
        t == 0
    }
    fn has_capacity(&self, active: usize) -> bool {
        active < self.cap
    }
}

#[test]
fn prop_batcher_conserves_requests_and_capacity() {
    forall("batcher_conservation", |rng| {
        let cap = rng.range(1, 6);
        let n = rng.range(1, 30);
        let (tx, rx) = std::sync::mpsc::channel();
        let mut b = Batcher::new(
            CountBackend { live: 0, peak: 0, cap },
            BatcherConfig { max_batch: rng.range(1, 8), ..Default::default() },
        );
        for id in 0..n as u64 {
            let prompt = vec![rng.range(1, 20) as u32];
            b.submit(Request::new(id, prompt, rng.range(1, 40), tx.clone()));
        }
        b.run_to_completion();
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..n as u64).collect::<Vec<_>>(), "requests lost or duplicated");
        assert_eq!(b.backend.live, 0, "sequences leaked");
        assert!(b.backend.peak <= cap, "admission exceeded pool capacity");
    });
}

// ---------------------------------------------------------------------------
// json roundtrip
// ---------------------------------------------------------------------------

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    if depth == 0 || rng.chance(0.4) {
        match rng.range(0, 4) {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            _ => Json::Str(format!("s{}-\"q\"\n☃", rng.range(0, 1000))),
        }
    } else if rng.chance(0.5) {
        Json::Arr((0..rng.range(0, 5)).map(|_| random_json(rng, depth - 1)).collect())
    } else {
        Json::Obj(
            (0..rng.range(0, 5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        )
    }
}

#[test]
fn prop_json_roundtrip() {
    forall("json_roundtrip", |rng| {
        let v = random_json(rng, 4);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("parse '{text}': {e}"));
        assert_eq!(v, back);
    });
}
