//! Shared test/bench support: `SimBackend` with the paged entry points
//! masked off (`supports_paged` stays at the trait default, false), so the
//! engine falls back to the gather route — the reference side of every
//! paged-vs-gathered comparison.  Everything else, including the native
//! batched gathered implementations, delegates, keeping the comparison
//! route-for-route on otherwise identical code.
//!
//! Included via `#[path]` from `rust/tests/paged_attention.rs` and
//! `rust/benches/decode_throughput.rs` (files in `tests/` subdirectories
//! are not compiled as standalone test targets), so there is exactly one
//! copy to keep in sync with the `Backend` trait.

use anyhow::Result;
use raas::config::ModelSpec;
use raas::runtime::{AttnBatchItem, Backend, PrefillOut, Qkv, QkvBatchItem, SimBackend};

#[derive(Debug)]
pub struct GatheredSim(pub SimBackend);

impl Backend for GatheredSim {
    fn name(&self) -> &'static str {
        "sim-gathered"
    }
    fn spec(&self) -> &ModelSpec {
        self.0.spec()
    }
    fn capacities(&self) -> Vec<usize> {
        self.0.capacities()
    }
    fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        self.0.capacity_for(n_slots)
    }
    fn embed_tok(&self, token: u32) -> Result<Vec<f32>> {
        self.0.embed_tok(token)
    }
    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv> {
        self.0.layer_qkv(layer, h, pos)
    }
    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>> {
        self.0.layer_attn_mlp(layer, capacity, h, q, k_sel, v_sel, valid)
    }
    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>> {
        self.0.lm_head(h)
    }
    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut> {
        self.0.prefill(tokens)
    }
    fn embed_tok_batch(&self, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        self.0.embed_tok_batch(tokens)
    }
    fn layer_qkv_batch(&self, layer: usize, items: &[QkvBatchItem<'_>]) -> Result<Vec<Qkv>> {
        self.0.layer_qkv_batch(layer, items)
    }
    fn layer_attn_mlp_batch(&self, layer: usize, items: &[AttnBatchItem<'_>])
                            -> Result<Vec<Vec<f32>>> {
        self.0.layer_attn_mlp_batch(layer, items)
    }
    fn lm_head_batch(&self, hs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.0.lm_head_batch(hs)
    }
}
