//! Batched-decode equivalence suite (DESIGN.md §2, batched dataflow).
//!
//! The crate's core invariant: a batch of N prompts decoded through
//! `Engine::decode_batch` produces bit-identical tokens (and Figure-3
//! score logs) to N sequential `Engine::generate` calls on the sim
//! backend.  Every sharing shortcut in the batched path (feature memo,
//! attention-weight reuse, lm-head dedup) is only admissible because this
//! suite pins it.

use std::sync::mpsc::channel;

use raas::config::{EngineConfig, PolicyKind};
use raas::coordinator::batcher::{Batcher, BatcherConfig};
use raas::coordinator::request::{Request, Response};
use raas::coordinator::server::EngineBackend;
use raas::engine::{BatchEntry, Engine, GenOptions};
use raas::kvcache::SeqCache;
use raas::util::rng::Rng;
use raas::workload::Problem;

fn engine(policy: PolicyKind, budget: usize) -> Engine {
    let cfg = EngineConfig { policy, budget, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

/// Mixed workload: different lengths, plus an exact duplicate of prompt 0
/// (exercising the duplicate-request sharing paths).
fn prompts(seed: u64) -> Vec<Vec<u32>> {
    let spec = engine(PolicyKind::Raas, 128).meta.corpus.clone();
    let mut rng = Rng::new(seed);
    let mut ps: Vec<Vec<u32>> = [4usize, 6, 8]
        .iter()
        .map(|&steps| Problem::sample(&mut rng, &spec, Some(steps)).encode_prompt(&spec))
        .collect();
    ps.push(ps[0].clone());
    ps
}

/// Drive `decode_batch` for `steps` iterations, mirroring `generate`'s
/// token bookkeeping (per-seq step counter as the policy timestamp).
fn decode_batched(e: &mut Engine, prompts: &[Vec<u32>], steps: usize)
                  -> (Vec<Vec<u32>>, Vec<Vec<(u64, Vec<(usize, f32)>)>>) {
    let n = prompts.len();
    let mut seqs: Vec<SeqCache> = Vec::with_capacity(n);
    let mut tokens: Vec<u32> = Vec::with_capacity(n);
    for p in prompts {
        let mut seq = e.new_seq();
        tokens.push(e.prefill_seq(&mut seq, p).expect("prefill"));
        seqs.push(seq);
    }
    let mut produced: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut logs: Vec<Vec<(u64, Vec<(usize, f32)>)>> = vec![Vec::new(); n];
    for step in 1..=steps {
        for (out, &tok) in produced.iter_mut().zip(&tokens) {
            out.push(tok);
        }
        let mut entries: Vec<BatchEntry<'_>> = seqs
            .iter_mut()
            .zip(logs.iter_mut())
            .enumerate()
            .map(|(i, (seq, log))| BatchEntry {
                seq,
                token: tokens[i],
                now: step as u64,
                log: Some(log),
            })
            .collect();
        let results = e.decode_batch(&mut entries);
        drop(entries);
        for (tok, r) in tokens.iter_mut().zip(results) {
            *tok = r.expect("batched decode step");
        }
    }
    for mut seq in seqs {
        e.release_seq(&mut seq);
    }
    (produced, logs)
}

#[test]
fn decode_batch_matches_sequential_generate_bitwise() {
    let steps = 96;
    for policy in PolicyKind::all() {
        let ps = prompts(11);
        // sequential reference: one generate() per prompt
        let mut seq_engine = engine(policy, 128);
        let opts = GenOptions {
            max_new: steps,
            force_len: Some(steps),
            log_scores: true,
            ..Default::default()
        };
        let reference: Vec<_> = ps
            .iter()
            .map(|p| seq_engine.generate(p, &opts).expect("sequential generate"))
            .collect();
        // batched: same config, one decode_batch iteration per step
        let mut batch_engine = engine(policy, 128);
        let (tokens, logs) = decode_batched(&mut batch_engine, &ps, steps);
        for (i, r) in reference.iter().enumerate() {
            assert_eq!(
                r.tokens, tokens[i],
                "{policy:?} prompt {i}: batched tokens diverged from sequential"
            );
            assert_eq!(
                r.score_log, logs[i],
                "{policy:?} prompt {i}: batched score log diverged from sequential"
            );
        }
        // the duplicate prompt pair must agree with itself, too
        assert_eq!(tokens[0], tokens[3], "duplicate prompts must decode identically");
    }
}

#[test]
fn score_log_is_pinned_per_step_and_page_ordered() {
    // Figure-3 contract: one layer-0 entry per decode step, stamped with
    // the step counter, pages in strictly increasing start_pos order, and
    // probabilities forming a distribution at capture time.
    let steps = 48;
    let mut e = engine(PolicyKind::Raas, 128);
    let ps = prompts(23);
    let opts = GenOptions {
        max_new: steps,
        force_len: Some(steps),
        log_scores: true,
        ..Default::default()
    };
    let out = e.generate(&ps[1], &opts).expect("generate");
    assert_eq!(out.score_log.len(), steps, "one log entry per decode step");
    for (i, (now, entry)) in out.score_log.iter().enumerate() {
        assert_eq!(*now, (i + 1) as u64, "entries stamped with the step counter");
        assert!(!entry.is_empty());
        for w in entry.windows(2) {
            assert!(
                w[0].0 < w[1].0,
                "pages must be ordered by start_pos: {} !< {}",
                w[0].0,
                w[1].0
            );
        }
        let sum: f32 = entry.iter().map(|&(_, p)| p).sum();
        assert!((sum - 1.0).abs() < 1e-3, "layer-0 probs must sum to ~1, got {sum}");
    }
    // the batched path pins the identical contract (checked entry-by-entry
    // against the sequential log in the equivalence test above; here we
    // re-assert the shape directly)
    let mut be = engine(PolicyKind::Raas, 128);
    let (_, logs) = decode_batched(&mut be, &ps[1..2], steps);
    assert_eq!(logs[0].len(), steps);
    assert_eq!(logs[0], out.score_log);
}

#[test]
fn forked_sequences_decode_batched_bitwise_with_rep_score_sharing() {
    // `decode_batch` dedups Quest/RaaS rep-score work across sequences
    // whose logical page tables resolve to the same physical pool pages (a
    // fork family holding refcounted prefill pages).  The cache must be
    // invisible: a parent and its fork decoded in ONE batch produce
    // bit-identical tokens and Figure-3 logs to an independent
    // single-sequence decode, for every policy, while the
    // `decode.rep_score_shared` counter proves the dedup engaged.
    let steps = 8u64;
    let prompt: Vec<u32> = (0..70).map(|i| 1 + (i % 40) as u32).collect();
    let to_bits = |log: &Vec<(u64, Vec<(usize, f32)>)>| -> Vec<(u64, Vec<(usize, u32)>)> {
        log.iter()
            .map(|(now, e)| (*now, e.iter().map(|&(p, pr)| (p, pr.to_bits())).collect()))
            .collect()
    };
    for policy in PolicyKind::all() {
        // independent single-sequence reference
        let mut ind = engine(policy, 96);
        let mut iseq = ind.new_seq();
        let ifirst = ind.prefill_seq(&mut iseq, &prompt).expect("prefill");
        let mut ilog: Vec<(u64, Vec<(usize, f32)>)> = Vec::new();
        let mut itokens = vec![ifirst];
        let mut tok = ifirst;
        for step in 1..=steps {
            tok = ind.decode_step(&mut iseq, tok, step, Some(&mut ilog)).expect("decode");
            itokens.push(tok);
        }
        ind.release_seq(&mut iseq);
        assert_eq!(ind.pool().allocated_pages(), 0);

        // parent + fork decoded together in one batch
        let mut e = engine(policy, 96);
        let mut parent = e.new_seq();
        let first = e.prefill_seq(&mut parent, &prompt).expect("prefill");
        assert_eq!(first, ifirst, "{policy:?}: first token diverged");
        let fork = e.fork_seq(&parent);
        let mut seqs = vec![parent, fork];
        let mut tokens = vec![first; 2];
        let mut produced: Vec<Vec<u32>> = vec![vec![first]; 2];
        let mut logs: Vec<Vec<(u64, Vec<(usize, f32)>)>> = vec![Vec::new(); 2];
        for step in 1..=steps {
            let mut entries: Vec<BatchEntry<'_>> = seqs
                .iter_mut()
                .zip(logs.iter_mut())
                .enumerate()
                .map(|(i, (seq, log))| BatchEntry {
                    seq,
                    token: tokens[i],
                    now: step,
                    log: Some(log),
                })
                .collect();
            let results = e.decode_batch(&mut entries);
            drop(entries);
            for (i, r) in results.into_iter().enumerate() {
                tokens[i] = r.expect("batched decode step");
                produced[i].push(tokens[i]);
            }
        }
        for (i, who) in ["parent", "fork"].iter().enumerate() {
            assert_eq!(produced[i], itokens, "{policy:?}: {who} tokens diverged in batch");
            assert_eq!(to_bits(&logs[i]), to_bits(&ilog), "{policy:?}: {who} log diverged");
        }
        assert!(
            e.metrics.counter("decode.rep_score_shared") > 0,
            "{policy:?}: shared physical pages + identical queries must hit the score cache"
        );
        for mut seq in seqs {
            e.release_seq(&mut seq);
        }
        assert_eq!(e.pool().allocated_pages(), 0, "shared pool must drain");
    }
}

#[test]
fn batched_serving_path_matches_sequential_generate() {
    // End to end through the coordinator: Batcher -> EngineBackend ->
    // step_batch -> decode_batch must answer exactly what per-request
    // generate() answers.
    let max_new = 72;
    let ps = prompts(31);
    let mut ref_engine = engine(PolicyKind::Raas, 96);
    let opts = GenOptions { max_new, ..Default::default() };
    let expect: Vec<Vec<u32>> = ps
        .iter()
        .map(|p| ref_engine.generate(p, &opts).expect("reference").tokens)
        .collect();

    let backend = EngineBackend::new(engine(PolicyKind::Raas, 96)).with_page_estimate(16);
    let mut b = Batcher::new(backend, BatcherConfig { max_batch: ps.len(), ..Default::default() });
    let (tx, rx) = channel::<Response>();
    for (id, p) in ps.iter().enumerate() {
        b.submit(Request::new(id as u64, p.clone(), max_new, tx.clone()));
    }
    b.run_to_completion();
    drop(tx);
    let mut resp: Vec<Response> = rx.iter().collect();
    resp.sort_by_key(|r| r.id);
    assert_eq!(resp.len(), ps.len());
    for r in &resp {
        assert!(r.error.is_none(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(
            r.tokens, expect[r.id as usize],
            "served tokens diverged from sequential generate for request {}",
            r.id
        );
    }
    assert_eq!(b.backend.engine.pool().allocated_pages(), 0, "pool must drain");
}
