//! Supervised serving chaos suite (ISSUE 9): seeded replica crash/hang
//! faults against the full supervisor + router + replica stack, at 2/4/8
//! replicas across every attention policy, on the deterministic sim
//! clock.  Invariants:
//!
//!  * conservation: every submitted request resolves to EXACTLY ONE
//!    outcome — recovery never drops a request, the shadow registry never
//!    double-answers one;
//!  * determinism: survivors' (and recovered requests') tokens are
//!    bit-identical to a fault-free control run — re-dispatch re-prefills
//!    from the original prompt and per-sequence decode is
//!    batch-composition-invariant;
//!  * hygiene: zero leaked KV pages on every surviving replica;
//!  * liveness: the driver loop is bounded — a supervision bug deadlocks
//!    the test, not CI (the chaos job carries a hang-guard timeout).
//!
//! The fault seed comes from `CHAOS_SEED` (CI runs a 5-seed matrix).

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::time::Duration;

use raas::config::{EngineConfig, PolicyKind};
use raas::coordinator::batcher::BatcherConfig;
use raas::coordinator::request::{Outcome, Request, Response};
use raas::coordinator::router::RoutePolicy;
use raas::coordinator::supervisor::{Supervisor, SupervisorConfig};
use raas::engine::{Engine, GenOptions};
use raas::runtime::FaultSchedule;
use raas::util::clock::SimClock;
use raas::util::rng::Rng;

const POLICIES: [PolicyKind; 7] = PolicyKind::all();

fn chaos_seed() -> u64 {
    std::env::var("CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn prompt_for(id: u64) -> Vec<u32> {
    (0..16).map(|i| 1 + ((i + 3 * id as usize) % 40) as u32).collect()
}

struct CellOut {
    tokens: BTreeMap<u64, Vec<u32>>,
    outcomes: BTreeMap<u64, Outcome>,
    crashes: u64,
    hangs: u64,
    redispatched: u64,
}

/// One supervised cell: `n_reqs` requests against `n` replicas under the
/// given per-replica fault schedules, driven on a sim clock.  Panics on
/// any conservation/hygiene violation; returns outcomes + counters.
fn run_cell(
    policy: PolicyKind,
    n: usize,
    faults: Vec<Option<FaultSchedule>>,
    n_reqs: u64,
) -> CellOut {
    let sim = SimClock::new();
    let cfg = EngineConfig { policy, budget: 96, seed: 7, ..Default::default() };
    let pool_pages = cfg.pool_pages;
    let mut sup = Supervisor::spawn(
        n,
        cfg,
        BatcherConfig { max_batch: 3, ..Default::default() },
        Some(vec![64, 128, 256, 512]),
        RoutePolicy::Scored,
        SupervisorConfig { hang_timeout_ms: 400, redispatch_retries: 4 },
        sim.clone(),
        faults,
    )
    .expect("spawn supervisor");
    let (tx, rx) = channel::<Response>();
    for id in 0..n_reqs {
        let req = Request::new(id, prompt_for(id), 12, tx.clone());
        if let Err(se) = sup.submit(req) {
            // replica already dead at submit time: answer directly, as a
            // serving driver would
            let _ = se.req.reply.send(Response::err(se.req.id, se.req.submitted, se.reason));
        }
    }
    drop(tx);
    let mut polls = 0u64;
    while !sup.poll() {
        sim.advance(10);
        std::thread::sleep(Duration::from_micros(200));
        polls += 1;
        assert!(polls < 100_000, "supervised fleet must converge, not deadlock");
    }
    // let the survivors' final gauge publication land before the leak check
    std::thread::sleep(Duration::from_millis(5));
    for (i, r) in sup.router().replicas().iter().enumerate() {
        if sup.is_dead(i) {
            continue;
        }
        assert_eq!(r.status.load.load(Ordering::Relaxed), 0, "replica {i} still loaded");
        assert_eq!(
            r.status.free_pages.load(Ordering::Relaxed),
            pool_pages,
            "leaked KV pages on surviving replica {i}"
        );
    }
    let (crashes, hangs, redispatched) = (sup.crashes, sup.hangs, sup.redispatched);
    sup.shutdown();
    let mut tokens = BTreeMap::new();
    let mut outcomes = BTreeMap::new();
    for resp in rx.iter() {
        assert!(
            tokens.insert(resp.id, resp.tokens.clone()).is_none(),
            "request {} answered more than once",
            resp.id
        );
        outcomes.insert(resp.id, resp.outcome);
    }
    CellOut { tokens, outcomes, crashes, hangs, redispatched }
}

fn assert_all_done(out: &CellOut, n_reqs: u64, what: &str) {
    assert_eq!(out.outcomes.len() as u64, n_reqs, "{what}: one outcome per request");
    for id in 0..n_reqs {
        assert_eq!(
            out.outcomes.get(&id),
            Some(&Outcome::Done),
            "{what}: request {id} must complete (got {:?})",
            out.outcomes.get(&id)
        );
    }
}

/// The ISSUE-9 acceptance matrix: 2/4/8 replicas × all seven policies ×
/// {control, crash, hang}.  Faulted cells must recover every request with
/// tokens bit-identical to the fault-free control.
#[test]
fn replica_crash_and_hang_recovery_is_lossless_and_bit_identical() {
    for &policy in &POLICIES {
        for &n in &[2usize, 4, 8] {
            let n_reqs = 3 * n as u64;
            let control = run_cell(policy, n, Vec::new(), n_reqs);
            assert_all_done(&control, n_reqs, "control");
            assert_eq!(control.crashes + control.hangs, 0, "control must be fault-free");

            let crash = run_cell(
                policy,
                n,
                vec![Some(FaultSchedule::new(chaos_seed()).crash_at_tick(4))],
                n_reqs,
            );
            assert_all_done(&crash, n_reqs, "crash cell");
            assert_eq!(crash.crashes, 1, "{policy:?}/{n}: the injected crash must fire");
            assert!(crash.redispatched >= 1, "{policy:?}/{n}: crash must strand requests");
            assert_eq!(
                crash.tokens, control.tokens,
                "{policy:?}/{n}: crash-recovered tokens must be bit-identical to control"
            );

            let hang = run_cell(
                policy,
                n,
                vec![Some(FaultSchedule::new(chaos_seed()).hang_at_tick(4))],
                n_reqs,
            );
            assert_all_done(&hang, n_reqs, "hang cell");
            assert!(hang.hangs >= 1, "{policy:?}/{n}: the watchdog must catch the hang");
            assert!(hang.redispatched >= 1, "{policy:?}/{n}: hang must strand requests");
            assert_eq!(
                hang.tokens, control.tokens,
                "{policy:?}/{n}: hang-recovered tokens must be bit-identical to control"
            );
        }
    }
}

/// Property test (seeded by `CHAOS_SEED`): random fleets under random
/// crash/hang schedules — possibly killing every replica — never lose,
/// duplicate, or deadlock a request.  An all-dead fleet fails its
/// leftovers; nothing is ever shed (no deadlines in play).
#[test]
fn seeded_fault_sequences_never_lose_or_duplicate_requests() {
    let seed = chaos_seed();
    let mut rng = Rng::new(seed.wrapping_mul(0x9e37_79b9_7f4a_7c15).wrapping_add(1));
    for case in 0..4u64 {
        let n = 2 + rng.range(0, 3); // 2..=4 replicas
        let mut faults: Vec<Option<FaultSchedule>> = Vec::with_capacity(n);
        for i in 0..n as u64 {
            let sched = FaultSchedule::new(seed ^ (case << 8) ^ i);
            let tick = rng.range(0, 8) as u64;
            faults.push(if rng.chance(0.4) {
                Some(sched.crash_at_tick(tick))
            } else if rng.chance(0.5) {
                Some(sched.hang_at_tick(tick))
            } else {
                None
            });
        }
        let n_reqs = 2 * n as u64;
        let out = run_cell(PolicyKind::Raas, n, faults, n_reqs);
        assert_eq!(out.outcomes.len() as u64, n_reqs, "case {case}: one outcome per request");
        for (id, o) in &out.outcomes {
            assert!(
                matches!(o, Outcome::Done | Outcome::Failed),
                "case {case}: request {id} must be Done or Failed, got {o:?}"
            );
        }
    }
}

/// The determinism foundation recovery rests on: an engine whose state was
/// "warmed" by unrelated sequences decodes a fresh prompt with tokens AND
/// Figure-3 score logs bit-identical to a factory-fresh engine, across all
/// seven policies.  (This is why a re-prefilled recovered request matches
/// the fault-free control exactly.)
#[test]
fn warm_engine_matches_fresh_engine_tokens_and_figure3_logs() {
    for &policy in &POLICIES {
        let mk = || {
            let cfg = EngineConfig { policy, budget: 96, seed: 7, ..Default::default() };
            Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
        };
        let test_prompt = prompt_for(0);
        let opts = GenOptions { max_new: 12, log_scores: true, ..Default::default() };

        let mut fresh = mk();
        let want = fresh.generate(&test_prompt, &opts).expect("fresh decode");

        let mut warm = mk();
        for s in 0..2u64 {
            // offsets chosen so the warm prompts share no page-aligned
            // prefix with the test prompt (prefix-cache-neutral warmup)
            let warm_prompt: Vec<u32> =
                (0..16).map(|i| 1 + ((i + 7 * (s as usize + 1)) % 40) as u32).collect();
            warm.generate(&warm_prompt, &GenOptions { max_new: 8, ..Default::default() })
                .expect("warm decode");
        }
        let got = warm.generate(&test_prompt, &opts).expect("warm decode of test prompt");
        assert_eq!(got.tokens, want.tokens, "{policy:?}: warm tokens must match fresh");
        assert_eq!(
            got.score_log, want.score_log,
            "{policy:?}: warm Figure-3 score log must match fresh"
        );
    }
}
