//! Paged-vs-gathered equivalence suite (DESIGN.md §2, paged route).
//!
//! The zero-copy paged attention path (in-place pool-slab views through
//! `Backend::layer_attn_mlp_paged`) must decode bit-identically to the
//! classic gather path (copy selected slots into capacity-padded scratch):
//! same tokens, same Figure-3 score logs, across every policy, on both the
//! sequential (`decode_step`) and batched (`decode_batch`) engine paths.
//! The gathered reference engine is the same `SimBackend` with its paged
//! entry points masked off, so the only difference under test is the route.

use raas::config::{ArtifactMeta, EngineConfig, PolicyKind};
use raas::engine::{BatchEntry, Engine, GenOptions};
use raas::kvcache::{KvPool, SeqCache};
use raas::runtime::{Backend, SimBackend};
use raas::util::rng::Rng;
use raas::workload::Problem;

#[path = "support/gathered_sim.rs"]
mod gathered_sim;
use gathered_sim::GatheredSim;

const CAPS: [usize; 4] = [64, 128, 256, 512];

fn paged_engine(policy: PolicyKind, budget: usize) -> Engine {
    let cfg = EngineConfig { policy, budget, ..Default::default() };
    Engine::new_with_capacities(cfg, &CAPS).expect("sim engine")
}

fn gathered_engine(policy: PolicyKind, budget: usize) -> Engine {
    let cfg = EngineConfig { policy, budget, ..Default::default() };
    let meta = ArtifactMeta::sim_default();
    let model = Box::new(GatheredSim(SimBackend::with_capacities(&meta, cfg.seed, &CAPS)));
    Engine::with_backend(cfg, meta, model).expect("gathered engine")
}

/// Mixed workload: different lengths, plus an exact duplicate of prompt 0.
fn prompts(seed: u64) -> Vec<Vec<u32>> {
    let spec = ArtifactMeta::sim_default().corpus;
    let mut rng = Rng::new(seed);
    let mut ps: Vec<Vec<u32>> = [4usize, 6, 8]
        .iter()
        .map(|&steps| Problem::sample(&mut rng, &spec, Some(steps)).encode_prompt(&spec))
        .collect();
    ps.push(ps[0].clone());
    ps
}

/// Drive `decode_batch` for `steps` iterations (same bookkeeping as
/// `rust/tests/batched_decode.rs`).
fn decode_batched(e: &mut Engine, prompts: &[Vec<u32>], steps: usize)
                  -> (Vec<Vec<u32>>, Vec<Vec<(u64, Vec<(usize, f32)>)>>) {
    let n = prompts.len();
    let mut seqs: Vec<SeqCache> = Vec::with_capacity(n);
    let mut tokens: Vec<u32> = Vec::with_capacity(n);
    for p in prompts {
        let mut seq = e.new_seq();
        tokens.push(e.prefill_seq(&mut seq, p).expect("prefill"));
        seqs.push(seq);
    }
    let mut produced: Vec<Vec<u32>> = vec![Vec::new(); n];
    let mut logs: Vec<Vec<(u64, Vec<(usize, f32)>)>> = vec![Vec::new(); n];
    for step in 1..=steps {
        for (out, &tok) in produced.iter_mut().zip(&tokens) {
            out.push(tok);
        }
        let mut entries: Vec<BatchEntry<'_>> = seqs
            .iter_mut()
            .zip(logs.iter_mut())
            .enumerate()
            .map(|(i, (seq, log))| BatchEntry {
                seq,
                token: tokens[i],
                now: step as u64,
                log: Some(log),
            })
            .collect();
        let results = e.decode_batch(&mut entries);
        drop(entries);
        for (tok, r) in tokens.iter_mut().zip(results) {
            *tok = r.expect("batched decode step");
        }
    }
    for mut seq in seqs {
        e.release_seq(&mut seq);
    }
    (produced, logs)
}

#[test]
fn engine_routes_paged_on_sim_and_gathered_on_wrapper() {
    let e = paged_engine(PolicyKind::Raas, 128);
    assert!(e.model().supports_paged(), "sim backend must advertise the paged route");
    let g = gathered_engine(PolicyKind::Raas, 128);
    assert!(!g.model().supports_paged(), "wrapper must stay on the gather route");
}

#[test]
fn paged_and_gathered_decode_step_bitwise_identical() {
    // Sequential path (`generate` -> `decode_step`), all seven policies:
    // tokens and Figure-3 score logs must match bit for bit.
    let steps = 72;
    for policy in PolicyKind::all() {
        let ps = prompts(17);
        let opts = GenOptions {
            max_new: steps,
            force_len: Some(steps),
            log_scores: true,
            ..Default::default()
        };
        let mut pe = paged_engine(policy, 128);
        let mut ge = gathered_engine(policy, 128);
        for (i, p) in ps.iter().enumerate() {
            let a = pe.generate(p, &opts).expect("paged generate");
            let b = ge.generate(p, &opts).expect("gathered generate");
            assert_eq!(a.tokens, b.tokens,
                       "{policy:?} prompt {i}: paged tokens diverged from gathered");
            assert_eq!(a.score_log, b.score_log,
                       "{policy:?} prompt {i}: paged score log diverged from gathered");
            assert_eq!(a.tokens.len(), steps);
        }
        assert_eq!(pe.pool().allocated_pages(), 0, "paged pool must drain");
        assert_eq!(ge.pool().allocated_pages(), 0, "gathered pool must drain");
    }
}

#[test]
fn paged_and_gathered_decode_batch_bitwise_identical() {
    // Batched path (`decode_batch`), all seven policies — covers the
    // flattened-view assembly and `layer_attn_mlp_paged_batch`'s
    // cross-item weight reuse (the duplicate prompt pair).
    let steps = 72;
    for policy in PolicyKind::all() {
        let ps = prompts(29);
        let mut pe = paged_engine(policy, 128);
        let mut ge = gathered_engine(policy, 128);
        let (pt, pl) = decode_batched(&mut pe, &ps, steps);
        let (gt, gl) = decode_batched(&mut ge, &ps, steps);
        for i in 0..ps.len() {
            assert_eq!(pt[i], gt[i],
                       "{policy:?} prompt {i}: batched paged tokens diverged from gathered");
            assert_eq!(pl[i], gl[i],
                       "{policy:?} prompt {i}: batched paged score log diverged from gathered");
        }
        assert_eq!(pt[0], pt[3], "duplicate prompts must decode identically");
    }
}

#[test]
fn forked_sequences_decode_identically_on_both_routes() {
    // A fork shares physical pages with its parent; the zero-copy paged
    // route reads those pages in place, the gather route copies them out.
    // Both routes must decode a fork (and, afterwards, its parent) exactly
    // like an independently prefilled sequence — tokens and Figure-3 logs
    // — across every policy, COW included (`pin_prefill: false` leaves a
    // shared partial tail page that the first decode append detaches).
    let steps = 24usize;
    for policy in PolicyKind::all() {
        let prompt = prompts(41).remove(1);
        let mk = |paged: bool| -> Engine {
            // budget comfortably above prompt+decode residency: COW and
            // shared reads are under test here, not eviction (shared-page
            // eviction semantics intentionally differ from independent
            // RaaS eviction — see SparsityPolicy::evict_candidate)
            let cfg = EngineConfig {
                policy,
                budget: 256,
                pin_prefill: false,
                ..Default::default()
            };
            if paged {
                Engine::new_with_capacities(cfg, &CAPS).expect("sim engine")
            } else {
                let meta = ArtifactMeta::sim_default();
                let model =
                    Box::new(GatheredSim(SimBackend::with_capacities(&meta, cfg.seed, &CAPS)));
                Engine::with_backend(cfg, meta, model).expect("gathered engine")
            }
        };
        let decode = |e: &mut Engine, seq: &mut SeqCache, first: u32| {
            let mut log = Vec::new();
            let mut tokens = vec![first];
            let mut tok = first;
            for step in 1..=steps as u64 {
                tok = e.decode_step(seq, tok, step, Some(&mut log)).expect("decode");
                tokens.push(tok);
            }
            (tokens, log)
        };
        let mut outputs = Vec::new();
        for paged in [true, false] {
            let mut e = mk(paged);
            // independent reference
            let mut ind = e.new_seq();
            let ifirst = e.prefill_seq(&mut ind, &prompt).expect("prefill");
            let (itokens, ilog) = decode(&mut e, &mut ind, ifirst);
            e.release_seq(&mut ind);
            // fork, then parent, over the same shared pages
            let mut parent = e.new_seq();
            let first = e.prefill_seq(&mut parent, &prompt).expect("prefill");
            assert_eq!(first, ifirst);
            let mut fork = e.fork_seq(&parent);
            let (ftokens, flog) = decode(&mut e, &mut fork, first);
            let (ptokens, plog) = decode(&mut e, &mut parent, first);
            let route = if paged { "paged" } else { "gathered" };
            assert_eq!(ftokens, itokens, "{policy:?}/{route}: fork tokens diverged");
            assert_eq!(flog, ilog, "{policy:?}/{route}: fork score log diverged");
            assert_eq!(ptokens, itokens, "{policy:?}/{route}: parent tokens diverged");
            assert_eq!(plog, ilog, "{policy:?}/{route}: parent score log diverged");
            e.release_seq(&mut fork);
            e.release_seq(&mut parent);
            assert_eq!(e.pool().allocated_pages(), 0, "{policy:?}/{route}: pool must drain");
            outputs.push((itokens, ilog));
        }
        assert_eq!(outputs[0], outputs[1],
                   "{policy:?}: paged and gathered forks diverged from each other");
    }
}

#[test]
fn prop_page_views_match_read_page() {
    // Property: for random pool geometries and write patterns, the
    // zero-copy `page_k`/`page_v` views read exactly what `read_page`
    // gathers, at every prefix length.
    for seed in 0..200u64 {
        let mut rng = Rng::new(seed * 7919 + 13);
        let page_size = rng.range(1, 9);
        let kv_dim = rng.range(1, 9);
        let cap = rng.range(1, 17);
        let mut pool = KvPool::new(cap, page_size, kv_dim);
        let n_pages = rng.range(1, cap + 1);
        let ids: Vec<_> = (0..n_pages).map(|_| pool.alloc().unwrap()).collect();
        for _ in 0..rng.range(1, 120) {
            let id = ids[rng.range(0, ids.len())];
            let slot = rng.range(0, page_size);
            let k: Vec<f32> = (0..kv_dim).map(|_| rng.normal() as f32).collect();
            let v: Vec<f32> = (0..kv_dim).map(|_| rng.normal() as f32).collect();
            pool.write_slot(id, slot, &k, &v);
        }
        for &id in &ids {
            for len in 0..=page_size {
                let mut k = vec![0.0f32; len * kv_dim];
                let mut v = vec![0.0f32; len * kv_dim];
                pool.read_page(id, len, &mut k, &mut v);
                assert_eq!(pool.page_k(id, len), &k[..], "seed {seed}: page_k mismatch");
                assert_eq!(pool.page_v(id, len), &v[..], "seed {seed}: page_v mismatch");
            }
        }
    }
}
