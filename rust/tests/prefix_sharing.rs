//! Prefix sharing + copy-on-write KV pages at engine level (PR 6
//! acceptance).  Two sharing mechanisms ride the same refcounted pool:
//!
//!  * `Engine::fork_seq` — copy the logical page tables only; the first
//!    divergent append copy-on-writes just the touched page.  A fork (and
//!    the parent it forked from) must decode bit-identically to an
//!    independently prefilled sequence: same tokens, same Figure-3 score
//!    logs, same slab bytes / page tables (pool ids excepted), across all
//!    seven policies.
//!  * the pool-level prefix index (`prefix_cache: true`) — a repeated
//!    prompt attaches its already-resident full prefix pages instead of
//!    re-running prefill over them.  The warm sequence must be
//!    bit-identical to the cold one, and to a `prefix_cache: false`
//!    engine's, across all seven policies — including prompts that exceed
//!    the budget so post-prefill trims evict index-retained (shared) pages.
//!
//! Plus the shared-page lifecycle edges the satellites name: eviction of a
//! refcount>1 page frees nothing, the pool drains to zero after releasing
//! every sequence and clearing the index (no leak, no double free), and
//! decode feeds shared pages' RaaS stamps into the pool-level aggregate.

use raas::config::{EngineConfig, PolicyKind};
use raas::engine::Engine;
use raas::kvcache::SeqCache;

const PAGE: usize = 16; // sim-default page size

fn mk_engine(cfg: EngineConfig) -> Engine {
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

fn mk_prompt(len: usize) -> Vec<u32> {
    (0..len).map(|i| 1 + (i % 40) as u32).collect()
}

/// Bit patterns of a float slice (strict equality: distinguishes -0.0,
/// never equates NaN — "bit-identical" taken literally).
fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Everything observable about one resident page EXCEPT its pool id —
/// shared/forked sequences legitimately map different physical pages than
/// an independent prefill, so identity is everything-but-the-id.
#[derive(Debug, PartialEq, Eq)]
struct PageSnap {
    start_pos: usize,
    len: usize,
    pinned: bool,
    last_stamp: u64,
    k: Vec<u32>,
    v: Vec<u32>,
    kmin: Vec<u32>,
    kmax: Vec<u32>,
}

fn snapshot(e: &Engine, seq: &SeqCache) -> Vec<Vec<PageSnap>> {
    let pool = e.pool();
    seq.layers
        .iter()
        .map(|lc| {
            lc.table
                .iter()
                .zip(&lc.reps)
                .map(|(p, r)| PageSnap {
                    start_pos: p.start_pos,
                    len: p.len,
                    pinned: p.pinned,
                    last_stamp: p.last_stamp,
                    k: bits(pool.page_k(p.pool_id, p.len)),
                    v: bits(pool.page_v(p.pool_id, p.len)),
                    kmin: bits(&r.kmin),
                    kmax: bits(&r.kmax),
                })
                .collect()
        })
        .collect()
}

type ScoreLog = Vec<(u64, Vec<(usize, f32)>)>;

fn log_bits(log: ScoreLog) -> Vec<(u64, Vec<(usize, u32)>)> {
    log.into_iter()
        .map(|(now, e)| (now, e.into_iter().map(|(p, pr)| (p, pr.to_bits())).collect()))
        .collect()
}

/// Decode `steps` tokens from `first` with score logging.
fn decode(e: &mut Engine, seq: &mut SeqCache, first: u32, steps: u64) -> (Vec<u32>, ScoreLog) {
    let mut log = Vec::new();
    let mut tokens = vec![first];
    let mut tok = first;
    for step in 1..=steps {
        tok = e.decode_step(seq, tok, step, Some(&mut log)).expect("decode");
        tokens.push(tok);
    }
    (tokens, log)
}

#[test]
fn forked_and_parent_sequences_decode_like_independent_prefills() {
    // Default config pins prefill, so a post-prefill fork shares only
    // pinned pages and decode opens fresh unpinned pages — no COW, pure
    // shared-read decode.  Fork first, then parent, each against an
    // independent reference; prompt 120 exceeds the 96-token budget so
    // trims run over shared (refcount-2) pages too.
    for kind in PolicyKind::all() {
        for &plen in &[70usize, 120] {
            let prompt = mk_prompt(plen);
            let cfg = EngineConfig { policy: kind, budget: 96, ..Default::default() };

            let mut ind = mk_engine(cfg.clone());
            let mut iseq = ind.new_seq();
            let ifirst = ind.prefill_seq(&mut iseq, &prompt).expect("prefill");
            let (itokens, ilog) = decode(&mut ind, &mut iseq, ifirst, 8);
            let isnap = snapshot(&ind, &iseq);

            let mut e = mk_engine(cfg);
            let mut parent = e.new_seq();
            let first = e.prefill_seq(&mut parent, &prompt).expect("prefill");
            assert_eq!(first, ifirst, "{kind:?}/p{plen}: first token diverged");
            let mut fork = e.fork_seq(&parent);
            let (ftokens, flog) = decode(&mut e, &mut fork, first, 8);
            assert_eq!(ftokens, itokens, "{kind:?}/p{plen}: fork tokens diverged");
            assert_eq!(log_bits(flog), log_bits(ilog.clone()),
                       "{kind:?}/p{plen}: fork score log diverged");
            assert_eq!(snapshot(&e, &fork), isnap,
                       "{kind:?}/p{plen}: fork pages / slabs / RepBounds diverged");
            // the parent decodes identically AFTER its fork already did —
            // sharing must never let one sequence observe the other
            let (ptokens, plog) = decode(&mut e, &mut parent, first, 8);
            assert_eq!(ptokens, itokens, "{kind:?}/p{plen}: parent tokens diverged");
            assert_eq!(log_bits(plog), log_bits(ilog),
                       "{kind:?}/p{plen}: parent score log diverged");
            assert_eq!(snapshot(&e, &parent), isnap,
                       "{kind:?}/p{plen}: parent pages / slabs / RepBounds diverged");

            ind.release_seq(&mut iseq);
            e.release_seq(&mut fork);
            e.release_seq(&mut parent);
            assert_eq!(ind.pool().allocated_pages(), 0, "independent pool must drain");
            assert_eq!(e.pool().allocated_pages(), 0, "shared pool must drain");
        }
    }
}

#[test]
fn divergent_append_copy_on_writes_the_shared_tail_page() {
    // With `pin_prefill: false` the 70-token prompt leaves a partial
    // (6/16) unpinned tail page; the fork's first decode append lands in
    // it and must COW.  Budget 96 > 70 + 8 keeps eviction out of the
    // picture, so forked ≡ independent still holds bitwise for every
    // policy — now across an actual copy-on-write.
    for kind in PolicyKind::all() {
        let prompt = mk_prompt(70);
        let cfg = EngineConfig {
            policy: kind,
            budget: 96,
            pin_prefill: false,
            ..Default::default()
        };

        let mut ind = mk_engine(cfg.clone());
        let mut iseq = ind.new_seq();
        let ifirst = ind.prefill_seq(&mut iseq, &prompt).expect("prefill");
        let (itokens, ilog) = decode(&mut ind, &mut iseq, ifirst, 8);
        let isnap = snapshot(&ind, &iseq);

        let mut e = mk_engine(cfg);
        let mut parent = e.new_seq();
        let first = e.prefill_seq(&mut parent, &prompt).expect("prefill");
        let mut fork = e.fork_seq(&parent);
        let tail = |s: &SeqCache| s.layers[0].table.last().unwrap().pool_id;
        let head = |s: &SeqCache| s.layers[0].table[0].pool_id;
        assert_eq!(tail(&fork), tail(&parent), "pre-COW: tail page shared");
        let (ftokens, flog) = decode(&mut e, &mut fork, first, 8);
        assert_ne!(tail(&fork), tail(&parent), "{kind:?}: divergent append must COW");
        assert_eq!(head(&fork), head(&parent), "{kind:?}: untouched full page stays shared");
        assert_eq!(ftokens, itokens, "{kind:?}: fork tokens diverged across COW");
        assert_eq!(log_bits(flog), log_bits(ilog.clone()), "{kind:?}: fork log diverged");
        assert_eq!(snapshot(&e, &fork), isnap, "{kind:?}: fork state diverged across COW");
        // the parent's original tail (exclusive again after the COW)
        // decodes in place, bit-identically
        let (ptokens, plog) = decode(&mut e, &mut parent, first, 8);
        assert_eq!(ptokens, itokens, "{kind:?}: parent tokens diverged");
        assert_eq!(log_bits(plog), log_bits(ilog), "{kind:?}: parent log diverged");
        assert_eq!(snapshot(&e, &parent), isnap, "{kind:?}: parent state diverged");

        ind.release_seq(&mut iseq);
        e.release_seq(&mut fork);
        e.release_seq(&mut parent);
        assert_eq!(e.pool().allocated_pages(), 0, "pool must drain after COW + releases");
        assert_eq!(ind.pool().allocated_pages(), 0);
    }
}

#[test]
fn decode_feeds_shared_page_stamps_into_the_pool_aggregate() {
    // RaaS re-stamps pages it attends; while a page is shared, decode must
    // publish the fresh stamp into `KvPool::stamp_max` so OTHER sharers'
    // eviction sees the page as hot (the shared-page-safe eviction rule).
    let prompt = mk_prompt(70);
    let cfg = EngineConfig {
        policy: PolicyKind::Raas,
        budget: 96,
        pin_prefill: false,
        ..Default::default()
    };
    let mut e = mk_engine(cfg);
    let mut parent = e.new_seq();
    let first = e.prefill_seq(&mut parent, &prompt).expect("prefill");
    let mut fork = e.fork_seq(&parent);
    let (_, _) = decode(&mut e, &mut fork, first, 4);
    let mut saw_restamp = false;
    for (p, f) in parent.layers[0].table.iter().zip(&fork.layers[0].table) {
        if p.pool_id != f.pool_id {
            continue; // COWed tail — no longer shared
        }
        assert_eq!(e.pool().stamp_max(p.pool_id), f.last_stamp,
                   "pool aggregate must track the sharer's freshest stamp");
        saw_restamp |= f.last_stamp > p.last_stamp;
    }
    assert!(saw_restamp, "decode must have re-stamped at least one shared page");
    e.release_seq(&mut fork);
    e.release_seq(&mut parent);
    assert_eq!(e.pool().allocated_pages(), 0);
}

#[test]
fn warm_prefix_hit_is_bit_identical_to_cold_across_policies() {
    // Same prompt three ways: a `prefix_cache: false` engine (the
    // pre-existing behavior), the first run on a `prefix_cache: true`
    // engine (cold — the index is empty), and the second run on that
    // engine (warm — full prefix pages attach from the index).  All three
    // must agree on tokens, Figure-3 logs, and page state minus pool ids.
    // Prompt 120 exceeds the 96-token budget: post-prefill trims then
    // evict index-retained (shared) pages, which must not free them.
    for kind in PolicyKind::all() {
        for &plen in &[70usize, 120] {
            let prompt = mk_prompt(plen);
            let base = EngineConfig { policy: kind, budget: 96, ..Default::default() };

            let mut off = mk_engine(base.clone());
            let mut oseq = off.new_seq();
            let ofirst = off.prefill_seq(&mut oseq, &prompt).expect("prefill");
            let (otokens, olog) = decode(&mut off, &mut oseq, ofirst, 8);
            let osnap = snapshot(&off, &oseq);
            off.release_seq(&mut oseq);

            let cfg = EngineConfig { prefix_cache: true, ..base };
            let mut e = mk_engine(cfg);
            let runs: Vec<_> = (0..2)
                .map(|_| {
                    let mut seq = e.new_seq();
                    let first = e.prefill_seq(&mut seq, &prompt).expect("prefill");
                    let (tokens, log) = decode(&mut e, &mut seq, first, 8);
                    let snap = snapshot(&e, &seq);
                    let cached = seq.prefix_cached_tokens;
                    e.release_seq(&mut seq);
                    (tokens, log_bits(log), snap, cached)
                })
                .collect();
            let full_pages = (plen - 1) / PAGE; // final token never attaches
            for (i, (tokens, log, snap, cached)) in runs.iter().enumerate() {
                assert_eq!(*tokens, otokens, "{kind:?}/p{plen}/run{i}: tokens diverged");
                assert_eq!(*log, log_bits(olog.clone()),
                           "{kind:?}/p{plen}/run{i}: score log diverged");
                assert_eq!(*snap, osnap,
                           "{kind:?}/p{plen}/run{i}: pages / slabs / RepBounds diverged");
                let want = if i == 0 { 0 } else { full_pages * PAGE };
                assert_eq!(*cached, want, "{kind:?}/p{plen}/run{i}: cached-token count");
            }
            assert_eq!(e.metrics.counter("prefix.hit_pages"), full_pages as u64,
                       "{kind:?}/p{plen}: warm run must hit every full prefix page");
            assert_eq!(e.metrics.counter("prefix.hit_requests"), 1);
            assert!(e.prefix_len() > 0, "index must hold the prompt's prefix");
            // teardown: the index is the last owner; clearing it drains
            // the pool completely — no leak, no double free
            e.prefix_clear();
            assert_eq!(e.prefix_len(), 0);
            assert_eq!(e.pool().allocated_pages(), 0,
                       "{kind:?}/p{plen}: pool must drain after prefix_clear");
            assert_eq!(off.pool().allocated_pages(), 0);
        }
    }
}

#[test]
fn prefix_cache_off_keeps_the_index_empty() {
    // The default config must not cache anything: repeated prompts stay
    // pool-id-exact cold prefills (what every pre-existing suite pins).
    let prompt = mk_prompt(70);
    let mut e = mk_engine(EngineConfig { budget: 96, ..Default::default() });
    assert!(!e.cfg.prefix_cache, "prefix cache must default off");
    for _ in 0..2 {
        let mut seq = e.new_seq();
        e.prefill_seq(&mut seq, &prompt).expect("prefill");
        assert_eq!(seq.prefix_cached_tokens, 0);
        e.release_seq(&mut seq);
    }
    assert_eq!(e.prefix_len(), 0);
    assert_eq!(e.metrics.counter("prefix.hit_pages"), 0);
    assert_eq!(e.pool().allocated_pages(), 0);
}

#[test]
fn short_prompts_never_attach_their_final_token() {
    // A prompt that is exactly one page (or shorter) has no cacheable
    // prefix — the final chunk must always execute to produce the
    // first-token logits, so the warm run still prefills everything.
    let cfg = EngineConfig { budget: 96, prefix_cache: true, ..Default::default() };
    let mut e = mk_engine(cfg);
    for plen in [3usize, PAGE] {
        let prompt = mk_prompt(plen);
        for run in 0..2 {
            let mut seq = e.new_seq();
            e.prefill_seq(&mut seq, &prompt).expect("prefill");
            assert_eq!(seq.prefix_cached_tokens, 0, "p{plen}/run{run}: nothing to attach");
            e.release_seq(&mut seq);
        }
    }
    assert_eq!(e.prefix_len(), 0, "page-or-shorter prompts cache nothing");
    e.prefix_clear();
    assert_eq!(e.pool().allocated_pages(), 0);
}
