//! Policy-conformance suite: the cross-policy trait contract every member
//! of the zoo (`PolicyKind::all`) must honour, regardless of what it
//! ranks.  The engine's decode paths, the eviction loop, the trace
//! simulator, and the bit-identity matrices all assume these properties;
//! a new policy that passes this file can be dropped into any of them.
//!
//! Pinned per policy:
//!  * selection is a sorted, duplicate-free subset of the live table that
//!    always includes the active page;
//!  * selection-sparse policies respect the page budget, identity
//!    policies select everything;
//!  * `select_into` is pure: dirty out-params and warm internal scratch
//!    never change the result;
//!  * fully tied scores resolve deterministically (earliest index);
//!  * NaN/±inf scores and probs never panic, and `observe` never touches
//!    table *structure* (ids, positions, lengths, pins);
//!  * eviction candidates are live non-active pages, prefill-pinning
//!    policies never evict pins, and the eviction loop terminates;
//!  * `bounds_memory` matches eviction behaviour (never-evicting
//!    policies report O(N), evicting policies report O(L));
//!  * pool-level stamp aggregation (`note_stamp`/`stamp_max`) is
//!    monotone and survives retain/COW — the shared-page machinery the
//!    engine layers on top of sharing-oblivious policies.

use raas::config::{EngineConfig, PolicyKind};
use raas::kvcache::page::PageMeta;
use raas::kvcache::policy::{make_policy, resident_tokens, SparsityPolicy};
use raas::kvcache::KvPool;
use raas::util::rng::Rng;

const SEEDS: u64 = 60;

/// Random live table: mixed page lengths, a pinned prefix, randomized
/// policy statistics (stamps, accumulators, RPC windows).
fn random_table(rng: &mut Rng) -> (Vec<PageMeta>, Vec<f32>) {
    let n = rng.range(2, 40);
    let mut table = Vec::new();
    let mut pos = 0;
    for i in 0..n {
        let mut m = PageMeta::new(i as u32, pos, i < 3 && rng.chance(0.5), 0);
        m.len = rng.range(1, 17);
        m.last_stamp = rng.range(0, 50) as u64;
        m.acc_score = rng.f64() * 10.0;
        m.win_score = rng.f64() * 4.0;
        pos += m.len;
        table.push(m);
    }
    let scores: Vec<f32> = (0..n).map(|_| rng.f64() as f32 * 6.0 - 3.0).collect();
    (table, scores)
}

fn policy_for(kind: PolicyKind, budget: usize) -> Box<dyn SparsityPolicy> {
    let cfg = EngineConfig { policy: kind, budget, ..Default::default() };
    make_policy(&cfg)
}

/// Policies whose selection is a strict subset under pressure (everything
/// else selects the full resident set and sparsifies via eviction).
fn selection_sparse(kind: PolicyKind) -> bool {
    matches!(kind, PolicyKind::Quest | PolicyKind::LessIsMore)
}

#[test]
fn selection_is_sorted_subset_including_active() {
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed * 31 + 5);
        let (table, scores) = random_table(&mut rng);
        for kind in PolicyKind::all() {
            let budget = rng.range(16, 2048);
            let policy = policy_for(kind, budget);
            let sel = policy.select(&table, &scores, budget, 16);
            assert!(!sel.is_empty(), "{kind:?} empty selection");
            assert!(sel.windows(2).all(|w| w[0] < w[1]),
                    "{kind:?} selection not sorted/duplicate-free: {sel:?}");
            assert!(*sel.last().unwrap() < table.len(), "{kind:?} out of range");
            assert!(sel.contains(&(table.len() - 1)), "{kind:?} dropped active page");
            let budget_pages = (budget / 16).max(1);
            if selection_sparse(kind) && table.len() > budget_pages {
                assert!(sel.len() <= budget_pages,
                        "{kind:?} over page budget: {} > {budget_pages}", sel.len());
            }
            if !selection_sparse(kind) {
                assert_eq!(sel, (0..table.len()).collect::<Vec<_>>(),
                           "{kind:?} must select the full resident set");
            }
        }
    }
}

#[test]
fn select_into_is_pure_across_scratch_reuse() {
    // A dirty out-param and warm internal scratch (LessIsMore's aggregation
    // buffer, any future policy caches) must not change the selection; the
    // out-param form must equal the allocating wrapper.
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed * 67 + 9);
        let (table, scores) = random_table(&mut rng);
        for kind in PolicyKind::all() {
            let budget = rng.range(16, 1024);
            let policy = policy_for(kind, budget);
            let fresh = policy.select(&table, &scores, budget, 16);
            let mut dirty = vec![usize::MAX; rng.range(1, 9)];
            policy.select_into(&table, &scores, budget, 16, &mut dirty);
            assert_eq!(dirty, fresh, "{kind:?} first reuse diverged");
            policy.select_into(&table, &scores, budget, 16, &mut dirty);
            assert_eq!(dirty, fresh, "{kind:?} second reuse diverged");
        }
    }
}

#[test]
fn tied_scores_resolve_to_earliest_pages() {
    // All-tied scores are the degenerate case every comparator must handle
    // identically on every platform: `total_cmp` + index tie-break means
    // the earliest pages win, with the active page always appended.
    let mut table = Vec::new();
    for i in 0..8 {
        let mut m = PageMeta::new(i as u32, i * 16, false, 0);
        m.len = 16;
        table.push(m);
    }
    let scores = [0.5f32; 8];
    for kind in PolicyKind::all() {
        let policy = policy_for(kind, 64);
        let sel = policy.select(&table, &scores, 64, 16); // 4-page budget
        if selection_sparse(kind) {
            // Quest: 3 earliest ties + active.  LessIsMore: 3 earliest by
            // (uniform) aggregated share + 1-page recent window.
            assert_eq!(sel, vec![0, 1, 2, 7], "{kind:?}");
        } else {
            assert_eq!(sel, (0..8).collect::<Vec<_>>(), "{kind:?}");
        }
    }
}

#[test]
fn non_finite_scores_never_panic_and_observe_preserves_structure() {
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed * 101 + 3);
        let (table, mut scores) = random_table(&mut rng);
        let mut probs: Vec<f32> = scores.iter().map(|s| s.abs() / 10.0).collect();
        for _ in 0..rng.range(1, 6) {
            let i = rng.range(0, scores.len());
            let bad = match rng.range(0, 3) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                _ => f32::NEG_INFINITY,
            };
            scores[i] = bad;
            probs[i] = bad;
        }
        for kind in PolicyKind::all() {
            let policy = policy_for(kind, 128);
            let mut t = table.clone();
            let shape: Vec<_> =
                t.iter().map(|p| (p.pool_id, p.start_pos, p.len, p.pinned)).collect();
            for now in 1..=3 {
                policy.observe(&mut t, &probs, now);
            }
            let after: Vec<_> =
                t.iter().map(|p| (p.pool_id, p.start_pos, p.len, p.pinned)).collect();
            assert_eq!(shape, after, "{kind:?} observe mutated table structure");
            let sel = policy.select(&t, &scores, 128, 16);
            assert!(!sel.is_empty(), "{kind:?} empty under NaN");
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "{kind:?} malformed under NaN");
            assert!(sel.contains(&(t.len() - 1)), "{kind:?} dropped active under NaN");
            if let Some(v) = policy.evict_candidate(&t) {
                assert!(v < t.len() - 1, "{kind:?} evicted active under NaN");
            }
        }
    }
}

#[test]
fn eviction_candidates_are_live_non_active_and_respect_pins() {
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed * 131 + 7);
        let (table, _) = random_table(&mut rng);
        for kind in PolicyKind::all() {
            let policy = policy_for(kind, 64);
            if let Some(v) = policy.evict_candidate(&table) {
                assert!(v < table.len() - 1, "{kind:?} evicted the active page");
                if matches!(kind, PolicyKind::Raas | PolicyKind::Rpc) {
                    assert!(!table[v].pinned, "{kind:?} evicted pinned prefill");
                }
            }
        }
    }
}

#[test]
fn eviction_loop_terminates_within_table_len_steps() {
    // The engine's budget-enforcement loop must never spin: each candidate
    // shrinks the table, and a `None` must be sticky enough to break on.
    for seed in 0..SEEDS {
        let mut rng = Rng::new(seed * 151 + 11);
        let (table, _) = random_table(&mut rng);
        let budget = rng.range(16, 128);
        for kind in PolicyKind::all() {
            let policy = policy_for(kind, budget);
            let mut t = table.clone();
            let mut iters = 0;
            while resident_tokens(&t) > budget {
                match policy.evict_candidate(&t) {
                    Some(v) => {
                        t.remove(v);
                    }
                    None => break,
                }
                iters += 1;
                assert!(iters <= table.len(), "{kind:?} eviction loop did not terminate");
            }
            if kind == PolicyKind::H2o {
                // the one policy with no pin/sink exemptions always reaches
                // the budget or a single page
                assert!(resident_tokens(&t) <= budget || t.len() <= 1, "{kind:?} over budget");
            }
        }
    }
}

#[test]
fn bounds_memory_flag_matches_eviction_behaviour() {
    let mut table = Vec::new();
    for i in 0..12 {
        let mut m = PageMeta::new(i as u32, i * 16, false, 0);
        m.len = 16;
        m.acc_score = i as f64;
        table.push(m);
    }
    for kind in PolicyKind::all() {
        let policy = policy_for(kind, 64);
        let bounded = matches!(
            kind,
            PolicyKind::Sink | PolicyKind::H2o | PolicyKind::Raas | PolicyKind::Rpc
        );
        assert_eq!(policy.bounds_memory(), bounded, "{kind:?}");
        if !bounded {
            assert_eq!(policy.evict_candidate(&table), None,
                       "{kind:?} claims O(N) memory but evicts");
        } else {
            assert!(policy.evict_candidate(&table).is_some(),
                    "{kind:?} claims O(L) memory but never evicts");
        }
    }
}

#[test]
fn pool_stamp_aggregation_is_monotone_under_sharing_and_cow() {
    // Shared-page stamps: `note_stamp` is a monotone max, `stamp_max`
    // starts at zero on alloc, retain does not disturb it, and a COW
    // detach inherits the source's aggregate (same tokens, same heat).
    let mut pool = KvPool::new(8, 16, 4);
    let id = pool.alloc().unwrap();
    assert_eq!(pool.stamp_max(id), 0);
    let mut high = 0;
    for stamp in [5u64, 3, 9, 2, 9, 11] {
        pool.note_stamp(id, stamp);
        high = high.max(stamp);
        assert_eq!(pool.stamp_max(id), high, "stamp aggregate must be a running max");
    }
    // exclusive page: COW is the identity and stamps are untouched
    assert_eq!(pool.cow_page(id, 4).unwrap(), id);
    assert_eq!(pool.stamp_max(id), 11);
    // shared page: detach inherits the aggregate, both copies stay monotone
    pool.retain(id);
    assert!(pool.is_shared(id));
    let detached = pool.cow_page(id, 4).unwrap();
    assert_ne!(detached, id, "shared page must detach");
    assert_eq!(pool.stamp_max(detached), 11, "COW copy inherits the stamp aggregate");
    assert_eq!(pool.stamp_max(id), 11);
    pool.note_stamp(detached, 4);
    assert_eq!(pool.stamp_max(detached), 11, "stale sharer stamp cannot lower the max");
    pool.note_stamp(detached, 20);
    assert_eq!(pool.stamp_max(detached), 20);
    assert_eq!(pool.stamp_max(id), 11, "copies aggregate independently after detach");
    pool.release(id);
    pool.release(detached);
    assert_eq!(pool.allocated_pages(), 0);
}
