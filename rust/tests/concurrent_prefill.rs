//! Concurrent multi-sequence chunked prefill vs the sequential chunked
//! path: bit-identical end to end (ISSUE 5 acceptance).  For every policy,
//! a spread of chunk sizes and 1/2/4-way prompt interleavings, driving the
//! SAME admission schedule through `Engine::prefill_batch` (one batched
//! backend call per round) and through per-entry
//! `Engine::prefill_seq_partial` calls must produce exactly:
//!
//!  * the same first decoded token per prompt,
//!  * the same KV slab contents of every resident page,
//!  * the same page tables (pool ids included — backend calls never touch
//!    the pool, and the batched driver appends per sequence in entry
//!    order, so allocation order is schedule-invariant),
//!  * the same Quest-style RepBounds,
//!  * and the same decode continuation (tokens + Figure-3 score logs).
//!
//! Plus: the non-streaming-backend fallback reaches the same state, and
//! the serving loop produces identical token streams under prefill-first,
//! sequential-chunked and concurrent-chunked admission.

use std::sync::mpsc::channel;

use anyhow::Result;

use raas::config::{ArtifactMeta, EngineConfig, ModelSpec, PolicyKind};
use raas::coordinator::batcher::{Batcher, BatcherConfig};
use raas::coordinator::request::{Request, Response};
use raas::coordinator::server::EngineBackend;
use raas::engine::{Engine, PrefillEntry};
use raas::kvcache::SeqCache;
use raas::runtime::{Backend, PrefillOut, Qkv, SimBackend};

fn mk_engine(kind: PolicyKind) -> Engine {
    let cfg = EngineConfig { policy: kind, budget: 96, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

/// Distinct prompts: varied lengths and contents, vocab-safe.
fn mk_prompts() -> Vec<Vec<u32>> {
    [70usize, 45, 120, 33]
        .iter()
        .enumerate()
        .map(|(p, &len)| (0..len).map(|i| 1 + ((i + 3 * p) % 40) as u32).collect())
        .collect()
}

/// Bit patterns of a float slice (strict equality: distinguishes -0.0,
/// never equates NaN — "bit-identical" taken literally).
fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Figure-3 score log with probabilities as bit patterns.
fn log_bits(log: Vec<(u64, Vec<(usize, f32)>)>) -> Vec<(u64, Vec<(usize, u32)>)> {
    log.into_iter()
        .map(|(now, e)| (now, e.into_iter().map(|(p, pr)| (p, pr.to_bits())).collect()))
        .collect()
}

/// Everything observable about one resident page after prefill.
#[derive(Debug, PartialEq, Eq)]
struct PageSnap {
    pool_id: u32,
    start_pos: usize,
    len: usize,
    pinned: bool,
    last_stamp: u64,
    k: Vec<u32>,
    v: Vec<u32>,
    kmin: Vec<u32>,
    kmax: Vec<u32>,
}

fn snapshot(e: &Engine, seq: &SeqCache) -> Vec<Vec<PageSnap>> {
    let pool = e.pool();
    seq.layers
        .iter()
        .map(|lc| {
            lc.table
                .iter()
                .zip(&lc.reps)
                .map(|(p, r)| PageSnap {
                    pool_id: p.pool_id,
                    start_pos: p.start_pos,
                    len: p.len,
                    pinned: p.pinned,
                    last_stamp: p.last_stamp,
                    k: bits(pool.page_k(p.pool_id, p.len)),
                    v: bits(pool.page_v(p.pool_id, p.len)),
                    kmin: bits(&r.kmin),
                    kmax: bits(&r.kmax),
                })
                .collect()
        })
        .collect()
}

/// The shared admission schedule: a FIFO co-admission window of `ways`
/// prompts; each round advances every live window member by one
/// `chunk`-token step, in window order, a freed slot admitting the next
/// prompt.  `batched` routes rounds through `Engine::prefill_batch`
/// (concurrent path); otherwise each round is per-entry
/// `prefill_seq_partial` calls (the PR-4 sequential path) — the two MUST
/// see identical schedules for the pool-id comparison to be meaningful.
fn run_prefills(e: &mut Engine, prompts: &[Vec<u32>], chunk: usize, ways: usize,
                batched: bool) -> (Vec<SeqCache>, Vec<u32>) {
    let n = prompts.len();
    let mut seqs: Vec<SeqCache> = (0..n).map(|_| e.new_seq()).collect();
    let mut firsts: Vec<Option<u32>> = vec![None; n];
    let mut live: Vec<usize> = Vec::new();
    let mut admitted = 0usize;
    let mut rounds = 0usize;
    while firsts.iter().any(Option::is_none) {
        while live.len() < ways && admitted < n {
            live.push(admitted);
            admitted += 1;
        }
        if batched {
            // `live` is ascending, so the filter preserves window order
            let mut entries: Vec<PrefillEntry<'_>> = seqs
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| live.contains(i))
                .map(|(i, seq)| PrefillEntry { seq, prompt: &prompts[i], max_tokens: chunk })
                .collect();
            let results = e.prefill_batch(&mut entries);
            for (&i, r) in live.iter().zip(results) {
                firsts[i] = r.expect("batched prefill chunk");
            }
        } else {
            for &i in &live {
                firsts[i] =
                    e.prefill_seq_partial(&mut seqs[i], &prompts[i], chunk).expect("chunk");
            }
        }
        live.retain(|&i| firsts[i].is_none());
        rounds += 1;
        assert!(rounds <= 1000, "prefill failed to make progress");
    }
    (seqs, firsts.into_iter().map(Option::unwrap).collect())
}

#[test]
fn concurrent_prefill_is_bit_identical_across_policies_chunks_and_ways() {
    for kind in PolicyKind::all() {
        let prompts = mk_prompts();
        for &chunk in &[5usize, 16, 37] {
            for &ways in &[1usize, 2, 4] {
                let mut seq_e = mk_engine(kind);
                let (mut ref_seqs, ref_firsts) =
                    run_prefills(&mut seq_e, &prompts, chunk, ways, false);
                let mut conc_e = mk_engine(kind);
                let (mut conc_seqs, conc_firsts) =
                    run_prefills(&mut conc_e, &prompts, chunk, ways, true);

                assert_eq!(conc_firsts, ref_firsts,
                           "{kind:?}/c{chunk}/w{ways}: first tokens diverged");
                for (i, (rs, cs)) in ref_seqs.iter().zip(&conc_seqs).enumerate() {
                    assert_eq!(snapshot(&conc_e, cs), snapshot(&seq_e, rs),
                               "{kind:?}/c{chunk}/w{ways}/seq{i}: page tables / KV slabs \
                                / RepBounds diverged");
                }

                // decode continuation: 6 steps per sequence, same order on
                // both engines, with Figure-3 score logs
                for i in 0..prompts.len() {
                    let mut ref_log = Vec::new();
                    let mut conc_log = Vec::new();
                    let mut rt = ref_firsts[i];
                    let mut ct = conc_firsts[i];
                    for step in 1..=6u64 {
                        rt = seq_e
                            .decode_step(&mut ref_seqs[i], rt, step, Some(&mut ref_log))
                            .expect("decode");
                        ct = conc_e
                            .decode_step(&mut conc_seqs[i], ct, step, Some(&mut conc_log))
                            .expect("decode");
                        assert_eq!(ct, rt,
                                   "{kind:?}/c{chunk}/w{ways}/seq{i}: decode step {step} \
                                    diverged");
                    }
                    assert_eq!(log_bits(conc_log), log_bits(ref_log),
                               "{kind:?}/c{chunk}/w{ways}/seq{i}: score log diverged");
                }
                for s in ref_seqs.iter_mut() {
                    seq_e.release_seq(s);
                }
                for s in conc_seqs.iter_mut() {
                    conc_e.release_seq(s);
                }
            }
        }
    }
}

#[test]
fn warm_batched_prefill_matches_warm_sequential() {
    // With `prefix_cache: true` and a pre-populated index, the batched
    // admission path must attach cached prefix pages per entry in entry
    // order — exactly like the sequential loop — so the two stay
    // bit-identical INCLUDING pool ids (both engines pre-populate the
    // index identically, so their free lists and attach order coincide).
    // Prompts deliberately share page-aligned prefixes with each other
    // (`mk_prompts` reuses token patterns) and with the warm-up pass.
    let mk_warm_engine = || {
        let cfg = EngineConfig {
            policy: PolicyKind::Raas,
            budget: 96,
            prefix_cache: true,
            ..Default::default()
        };
        Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
    };
    let prompts = mk_prompts();
    let warm_up = |e: &mut Engine| {
        for p in &prompts {
            let mut seq = e.new_seq();
            e.prefill_seq(&mut seq, p).expect("warm-up prefill");
            e.release_seq(&mut seq);
        }
    };
    for &chunk in &[5usize, 16, 37] {
        for &ways in &[1usize, 2, 4] {
            let mut seq_e = mk_warm_engine();
            warm_up(&mut seq_e);
            let (mut ref_seqs, ref_firsts) = run_prefills(&mut seq_e, &prompts, chunk, ways,
                                                          false);
            let mut conc_e = mk_warm_engine();
            warm_up(&mut conc_e);
            let (mut conc_seqs, conc_firsts) = run_prefills(&mut conc_e, &prompts, chunk, ways,
                                                            true);
            assert_eq!(conc_firsts, ref_firsts, "c{chunk}/w{ways}: first tokens diverged");
            for (i, (rs, cs)) in ref_seqs.iter().zip(&conc_seqs).enumerate() {
                assert!(cs.prefix_cached_tokens > 0 || prompts[i].len() <= 16,
                        "c{chunk}/w{ways}/seq{i}: warm run must hit the index");
                assert_eq!(cs.prefix_cached_tokens, rs.prefix_cached_tokens,
                           "c{chunk}/w{ways}/seq{i}: cached-token counts diverged");
                assert_eq!(snapshot(&conc_e, cs), snapshot(&seq_e, rs),
                           "c{chunk}/w{ways}/seq{i}: warm batched state diverged from \
                            warm sequential");
            }
            assert_eq!(conc_e.metrics.counter("prefix.hit_pages"),
                       seq_e.metrics.counter("prefix.hit_pages"),
                       "c{chunk}/w{ways}: hit counters diverged");
            for s in ref_seqs.iter_mut() {
                seq_e.release_seq(s);
            }
            for s in conc_seqs.iter_mut() {
                conc_e.release_seq(s);
            }
            seq_e.prefix_clear();
            conc_e.prefix_clear();
            assert_eq!(seq_e.pool().allocated_pages(), 0, "sequential pool must drain");
            assert_eq!(conc_e.pool().allocated_pages(), 0, "concurrent pool must drain");
        }
    }
}

/// `SimBackend` with its streaming-prefill entry points masked off: forces
/// `Engine::prefill_batch` onto the sequential monolithic-slicing fallback
/// (the AOT `ModelRuntime`'s shape).
#[derive(Debug)]
struct NoStreamSim(SimBackend);

impl Backend for NoStreamSim {
    fn name(&self) -> &'static str {
        "sim-nostream"
    }
    fn spec(&self) -> &ModelSpec {
        self.0.spec()
    }
    fn capacities(&self) -> Vec<usize> {
        self.0.capacities()
    }
    fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        self.0.capacity_for(n_slots)
    }
    fn embed_tok(&self, token: u32) -> Result<Vec<f32>> {
        self.0.embed_tok(token)
    }
    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv> {
        self.0.layer_qkv(layer, h, pos)
    }
    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>> {
        self.0.layer_attn_mlp(layer, capacity, h, q, k_sel, v_sel, valid)
    }
    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>> {
        self.0.lm_head(h)
    }
    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut> {
        self.0.prefill(tokens)
    }
    // supports_chunked_prefill / prefill_chunk / prefill_chunk_batch stay
    // on the trait defaults: whole-prompt prefill only.
}

#[test]
fn prefill_batch_fallback_matches_streamed_state() {
    // A backend without native streaming takes prefill_batch's sequential
    // fallback; the resulting cache state must still match the streamed
    // concurrent path bit for bit (chunked ≡ monolithic is the PR-4
    // invariant, concurrent ≡ sequential is this PR's).
    let prompts = mk_prompts();
    let cfg = EngineConfig { policy: PolicyKind::Raas, budget: 96, ..Default::default() };
    let meta = ArtifactMeta::sim_default();
    let masked = NoStreamSim(SimBackend::new(&meta, cfg.seed));
    let mut fb_e = Engine::with_backend(cfg.clone(), meta, Box::new(masked)).unwrap();
    assert!(!fb_e.model().supports_chunked_prefill());
    let (mut fb_seqs, fb_firsts) = run_prefills(&mut fb_e, &prompts, 16, 2, true);

    let mut st_e = mk_engine(PolicyKind::Raas);
    let (mut st_seqs, st_firsts) = run_prefills(&mut st_e, &prompts, 16, 2, true);

    assert_eq!(fb_firsts, st_firsts, "fallback first tokens diverged");
    for (i, (fs, ss)) in fb_seqs.iter().zip(&st_seqs).enumerate() {
        assert_eq!(snapshot(&fb_e, fs), snapshot(&st_e, ss),
                   "seq{i}: fallback prefill state diverged from streamed");
    }
    for s in fb_seqs.iter_mut() {
        fb_e.release_seq(s);
    }
    for s in st_seqs.iter_mut() {
        st_e.release_seq(s);
    }
}

#[test]
fn serving_concurrent_admission_matches_sequential_and_prefill_first() {
    // The same request set under prefill-first, sequential-chunked
    // (concurrency 1) and concurrent-chunked (concurrency 4) admission
    // must decode identical per-request token streams: admission mode
    // reorders work, never changes any sequence's bits.  Every admitted
    // request must also leave exactly one `admit.prefill_secs` sample.
    let lens = [40usize, 8, 64, 23, 88, 5];
    let run = |budget: Option<usize>, concurrency: usize| -> Vec<Vec<u32>> {
        let engine = mk_engine(PolicyKind::Raas);
        let mut b = Batcher::new(
            EngineBackend::new(engine).with_page_estimate(40),
            BatcherConfig {
                max_batch: 4,
                prefill_token_budget: budget,
                prefill_concurrency: concurrency,
                ..Default::default()
            },
        );
        let (tx, rx) = channel::<Response>();
        for (id, &len) in lens.iter().enumerate() {
            let prompt = (0..len).map(|i| 1 + ((i + id) % 40) as u32).collect();
            b.submit(Request::new(id as u64, prompt, 24, tx.clone()));
        }
        b.run_to_completion();
        drop(tx);
        let samples = b
            .backend
            .engine
            .metrics
            .timer("admit.prefill_secs")
            .map(|t| t.count())
            .unwrap_or(0);
        assert_eq!(samples, lens.len(), "one prefill_secs sample per admitted request");
        let mut resp: Vec<Response> = rx.iter().collect();
        assert_eq!(resp.len(), lens.len());
        assert!(resp.iter().all(|r| r.error.is_none()), "no request may fail");
        resp.sort_by_key(|r| r.id);
        resp.into_iter().map(|r| r.tokens).collect()
    };
    let prefill_first = run(None, 1);
    let sequential = run(Some(24), 1);
    let concurrent = run(Some(24), 4);
    assert_eq!(sequential, prefill_first,
               "sequential-chunked admission changed decoded tokens");
    assert_eq!(concurrent, prefill_first,
               "concurrent-chunked admission changed decoded tokens");
}
