//! Streaming chunked prefill vs monolithic prefill: bit-identical end to
//! end (ISSUE 4 acceptance).  For every policy and a spread of chunk sizes
//! — including chunk >= prompt (the degenerate whole-prompt case) and
//! chunk boundaries that fall mid-page — the chunked route must reproduce
//! the monolithic route exactly:
//!
//!  * the first decoded token,
//!  * the KV slab contents of every resident page,
//!  * the page tables (pool ids included — the page-run-major append order
//!    makes pool allocation chunking-invariant),
//!  * the Quest-style RepBounds,
//!  * and the decode continuation (tokens + Figure-3 score logs).
//!
//! Plus the RaaS pinned-prefill/page-alignment boundary: pinning stays
//! page-aligned across chunk boundaries, and the prefill→decode boundary
//! opens exactly one unpinned page.

use raas::config::{EngineConfig, PolicyKind};
use raas::engine::Engine;
use raas::kvcache::SeqCache;

const PAGE: usize = 16; // sim-default page size

fn mk_engine(kind: PolicyKind) -> Engine {
    let cfg = EngineConfig { policy: kind, budget: 96, ..Default::default() };
    Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine")
}

fn mk_prompt(len: usize) -> Vec<u32> {
    // digit/index tokens, vocab-safe, varied content
    (0..len).map(|i| 1 + (i % 40) as u32).collect()
}

/// Bit patterns of a float slice (strict equality: distinguishes -0.0,
/// never equates NaN — "bit-identical" taken literally).
fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

/// Everything observable about one resident page after prefill.
#[derive(Debug, PartialEq, Eq)]
struct PageSnap {
    pool_id: u32,
    start_pos: usize,
    len: usize,
    pinned: bool,
    last_stamp: u64,
    k: Vec<u32>,
    v: Vec<u32>,
    kmin: Vec<u32>,
    kmax: Vec<u32>,
}

fn snapshot(e: &Engine, seq: &SeqCache) -> Vec<Vec<PageSnap>> {
    let pool = e.pool();
    seq.layers
        .iter()
        .map(|lc| {
            lc.table
                .iter()
                .zip(&lc.reps)
                .map(|(p, r)| PageSnap {
                    pool_id: p.pool_id,
                    start_pos: p.start_pos,
                    len: p.len,
                    pinned: p.pinned,
                    last_stamp: p.last_stamp,
                    k: bits(pool.page_k(p.pool_id, p.len)),
                    v: bits(pool.page_v(p.pool_id, p.len)),
                    kmin: bits(&r.kmin),
                    kmax: bits(&r.kmax),
                })
                .collect()
        })
        .collect()
}

/// Prefill (monolithic when `chunk` is None, streamed otherwise), snapshot,
/// then decode 8 steps with score logging.
#[allow(clippy::type_complexity)]
fn run(kind: PolicyKind, prompt: &[u32], chunk: Option<usize>)
       -> (u32, Vec<Vec<PageSnap>>, Vec<u32>, Vec<(u64, Vec<(usize, u32)>)>) {
    let mut e = mk_engine(kind);
    let mut seq = e.new_seq();
    let first = match chunk {
        None => e.prefill_seq(&mut seq, prompt).expect("monolithic prefill"),
        Some(c) => {
            let mut first = None;
            let mut chunks = 0usize;
            while first.is_none() {
                first = e.prefill_seq_partial(&mut seq, prompt, c).expect("chunked prefill");
                chunks += 1;
                assert!(chunks <= prompt.len(), "chunked prefill failed to make progress");
            }
            assert_eq!(chunks, prompt.len().div_ceil(c), "unexpected chunk count");
            first.unwrap()
        }
    };
    assert_eq!(seq.n_tokens, prompt.len());
    assert_eq!(seq.prompt_len, prompt.len());
    let snap = snapshot(&e, &seq);
    let mut log = Vec::new();
    let mut tokens = vec![first];
    let mut tok = first;
    for step in 1..=8u64 {
        tok = e.decode_step(&mut seq, tok, step, Some(&mut log)).expect("decode");
        tokens.push(tok);
    }
    let log_bits: Vec<(u64, Vec<(usize, u32)>)> = log
        .into_iter()
        .map(|(now, entry)| (now, entry.into_iter().map(|(p, pr)| (p, pr.to_bits())).collect()))
        .collect();
    e.release_seq(&mut seq);
    (first, snap, tokens, log_bits)
}

#[test]
fn chunked_prefill_is_bit_identical_across_policies_and_chunk_sizes() {
    // prompt 70: non-page-multiple tail; prompt 120: exceeds the 96-token
    // budget so post-prefill enforcement (Sink/H2O trims) runs too.
    // chunks: 1 (every boundary mid-page), 5 (mid-page), 16 (page-aligned),
    // 37 (mid-page, multi-page runs), 200 (>= prompt — degenerates to the
    // monolithic path by construction).
    for kind in PolicyKind::all() {
        for &plen in &[70usize, 120] {
            let prompt = mk_prompt(plen);
            let (ref_first, ref_snap, ref_tokens, ref_log) = run(kind, &prompt, None);
            for &chunk in &[1usize, 5, 16, 37, 200] {
                let (first, snap, tokens, log) = run(kind, &prompt, Some(chunk));
                assert_eq!(first, ref_first,
                           "{kind:?}/p{plen}/c{chunk}: first token diverged");
                assert_eq!(snap, ref_snap,
                           "{kind:?}/p{plen}/c{chunk}: page tables / KV slabs / RepBounds \
                            diverged");
                assert_eq!(tokens, ref_tokens,
                           "{kind:?}/p{plen}/c{chunk}: decode continuation diverged");
                assert_eq!(log, ref_log,
                           "{kind:?}/p{plen}/c{chunk}: Figure-3 score log diverged");
            }
        }
    }
}

#[test]
fn warm_prefix_hit_prefill_is_bit_identical_to_cold() {
    // With `prefix_cache: true`, re-prefilling a prompt attaches its full
    // prefix pages from the index instead of recomputing them.  For every
    // policy and the same chunk-size spread as the cold suite, the warm
    // sequence must be bit-identical to the cold one — first token, page
    // tables (pool ids excepted: attached pages ARE the cold run's
    // physical pages), slab bytes, RepBounds, decode tokens and Figure-3
    // logs.  Prompt 120 exceeds the budget so post-prefill trims evict
    // index-retained (shared) pages along the way.
    let strip = |snap: Vec<Vec<PageSnap>>| -> Vec<Vec<PageSnap>> {
        snap.into_iter()
            .map(|l| l.into_iter().map(|mut p| { p.pool_id = 0; p }).collect())
            .collect()
    };
    for kind in PolicyKind::all() {
        for &plen in &[70usize, 120] {
            let prompt = mk_prompt(plen);
            let (ref_first, ref_snap, ref_tokens, ref_log) = run(kind, &prompt, None);
            let ref_snap = strip(ref_snap);
            for &chunk in &[5usize, 16, 37, 200] {
                let cfg = EngineConfig {
                    policy: kind,
                    budget: 96,
                    prefix_cache: true,
                    ..Default::default()
                };
                let mut e = Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).unwrap();
                // cold pass populates the index
                let mut cold = e.new_seq();
                e.prefill_seq(&mut cold, &prompt).expect("cold prefill");
                assert_eq!(cold.prefix_cached_tokens, 0);
                e.release_seq(&mut cold);
                // warm pass: first chunk attaches every cached full page
                let mut seq = e.new_seq();
                let mut first = None;
                while first.is_none() {
                    first = e.prefill_seq_partial(&mut seq, &prompt, chunk).expect("warm");
                }
                let first = first.unwrap();
                assert_eq!(seq.prefix_cached_tokens, (plen - 1) / PAGE * PAGE,
                           "{kind:?}/p{plen}/c{chunk}: warm run must attach every full \
                            prefix page");
                assert_eq!(first, ref_first, "{kind:?}/p{plen}/c{chunk}: first token");
                assert_eq!(strip(snapshot(&e, &seq)), ref_snap,
                           "{kind:?}/p{plen}/c{chunk}: warm page state diverged");
                let mut log = Vec::new();
                let mut tokens = vec![first];
                let mut tok = first;
                for step in 1..=8u64 {
                    tok = e.decode_step(&mut seq, tok, step, Some(&mut log)).expect("decode");
                    tokens.push(tok);
                }
                assert_eq!(tokens, ref_tokens,
                           "{kind:?}/p{plen}/c{chunk}: warm decode diverged");
                let log: Vec<(u64, Vec<(usize, u32)>)> = log
                    .into_iter()
                    .map(|(now, entry)| {
                        (now, entry.into_iter().map(|(p, pr)| (p, pr.to_bits())).collect())
                    })
                    .collect();
                assert_eq!(log, ref_log, "{kind:?}/p{plen}/c{chunk}: warm score log diverged");
                e.release_seq(&mut seq);
                e.prefix_clear();
                assert_eq!(e.pool().allocated_pages(), 0,
                           "{kind:?}/p{plen}/c{chunk}: pool must drain");
            }
        }
    }
}

#[test]
fn chunk_boundaries_respect_pinned_prefill_page_alignment() {
    // RaaS pins prefill pages; a 37-token chunk puts boundaries mid-page
    // (37, 70 % 16 != 0).  Pinning must stay page-aligned — chunk
    // boundaries never open a page — and the prefill→decode boundary must
    // open exactly one new unpinned page at prompt_len.
    let prompt = mk_prompt(70);
    let mut e = mk_engine(PolicyKind::Raas);
    assert!(e.cfg.pin_prefill, "default config pins prefill");
    let mut seq = e.new_seq();
    let mut first = None;
    while first.is_none() {
        first = e.prefill_seq_partial(&mut seq, &prompt, 37).expect("chunked prefill");
    }
    for (layer, lc) in seq.layers.iter().enumerate() {
        assert_eq!(lc.table.len(), 70usize.div_ceil(PAGE), "layer {layer} page count");
        for (i, p) in lc.table.iter().enumerate() {
            assert!(p.pinned, "layer {layer} prefill page {i} must be pinned");
            assert_eq!(p.start_pos, i * PAGE, "pages open only at page-aligned positions");
        }
        assert_eq!(lc.table.last().unwrap().len, 70 % PAGE, "partial tail page");
    }
    // one decode step: the unpinned boundary page opens at prompt_len
    let tok = first.unwrap();
    e.decode_step(&mut seq, tok, 1, None).expect("decode");
    for (layer, lc) in seq.layers.iter().enumerate() {
        let last = lc.table.last().unwrap();
        assert!(!last.pinned, "layer {layer} decode page must be unpinned");
        assert_eq!(last.start_pos, 70, "decode page opens at the prompt boundary");
        assert_eq!(last.len, 1);
        assert!(lc.table[lc.table.len() - 2].pinned, "prefill tail stays pinned");
    }
    e.release_seq(&mut seq);
}
