//! Chaos serving (ISSUE 8): seeded random faults across every policy ×
//! KV dtype, driven through the real `Engine` + `Batcher` stack by the
//! deterministic fault-injection harness.  Invariants under chaos:
//!
//!  * conservation: every submitted request resolves to EXACTLY ONE
//!    response, and that response is exactly one of Done / Failed / Shed;
//!  * hygiene: the KV pool drains to zero allocated pages after every
//!    cell, faults and preemptions included;
//!  * observability: the robustness counters (`preempt.count`,
//!    `shed.count`, mode-specific preempt counters) are non-zero and agree
//!    with the batcher's own accounting;
//!  * the router fails over around injected submit faults and trips its
//!    circuit breaker on a hung replica without losing a single request.
//!
//! The fault seed comes from `CHAOS_SEED` (CI runs a 3-seed matrix);
//! everything else is fixed, so any failure reproduces from the seed.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::sync::mpsc::channel;

use anyhow::Result;
use raas::config::{EngineConfig, PolicyKind, PreemptMode};
use raas::coordinator::batcher::{Batcher, BatcherConfig, StepBackend, StepItem};
use raas::coordinator::request::{Outcome, Request, RequestId, Response};
use raas::coordinator::router::{Replica, SubmitError};
use raas::coordinator::server::EngineBackend;
use raas::coordinator::{RoutePolicy, Router};
use raas::engine::Engine;
use raas::kvcache::{KvDtype, SeqCache};
use raas::runtime::{FaultOp, FaultSchedule, StepFaultInjector};

const POLICIES: [PolicyKind; 7] = PolicyKind::all();
const DTYPES: [KvDtype; 3] = [KvDtype::F32, KvDtype::Fp8E4M3, KvDtype::Int8];
const N_REQS: u64 = 12;

fn chaos_seed() -> u64 {
    std::env::var("CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// `EngineBackend` that never sees EOS, so every surviving request decodes
/// exactly `max_new` tokens — the tick structure (and thus the targeted
/// fault's alignment) is deterministic across policies and dtypes.
struct NoEos(EngineBackend);

impl StepBackend for NoEos {
    type Seq = SeqCache;
    fn begin(&mut self, prompt: &[u32]) -> Result<(SeqCache, u32)> {
        self.0.begin(prompt)
    }
    fn step(&mut self, seq: &mut SeqCache, token: u32, now: u64) -> Result<u32> {
        self.0.step(seq, token, now)
    }
    fn step_batch(&mut self, items: &mut [StepItem<'_, SeqCache>]) -> Vec<Result<u32>> {
        self.0.step_batch(items)
    }
    fn preempt(&mut self, id: RequestId, seq: SeqCache, mode: PreemptMode) -> Result<()> {
        self.0.preempt(id, seq, mode)
    }
    fn resume(&mut self, id: RequestId, prompt: &[u32], produced: &[u32]) -> Result<SeqCache> {
        self.0.resume(id, prompt, produced)
    }
    fn record_counter(&mut self, name: &'static str, delta: u64) {
        self.0.record_counter(name, delta);
    }
    fn finish(&mut self, seq: SeqCache) {
        self.0.finish(seq);
    }
    fn is_eos(&self, _token: u32) -> bool {
        false
    }
    fn has_capacity(&self, active: usize) -> bool {
        self.0.has_capacity(active)
    }
}

struct CellStats {
    done: usize,
    failed: usize,
    shed: usize,
    preemptions: u64,
}

/// One chaos cell: 12 requests against one engine under rate + targeted
/// faults.  Panics on any invariant violation; returns the outcome tally.
fn chaos_cell(policy: PolicyKind, dtype: KvDtype, mode: PreemptMode, seed: u64) -> CellStats {
    let cfg = EngineConfig { policy, kv_dtype: dtype, budget: 96, ..Default::default() };
    let engine = Engine::new_with_capacities(cfg, &[64, 128, 256, 512]).expect("sim engine");
    // Rates give broad random coverage; the targeted Alloc fault fires on
    // the 2nd decode-step draw of the first tick — 3 sequences are active
    // then, so every cell exercises preemption deterministically.
    let schedule = FaultSchedule::new(seed)
        .rate(FaultOp::Begin, 0.1)
        .rate(FaultOp::Step, 0.01)
        .rate(FaultOp::Alloc, 0.01)
        .fail_nth(FaultOp::Alloc, 2);
    let backend = StepFaultInjector::new(
        NoEos(EngineBackend::new(engine).with_page_estimate(8)),
        schedule,
    );
    let mut b = Batcher::new(
        backend,
        BatcherConfig {
            max_batch: 3,
            preempt_mode: mode,
            max_queue_depth: Some(8),
            ..Default::default()
        },
    );
    let (tx, rx) = channel::<Response>();
    for id in 0..N_REQS {
        let prompt: Vec<u32> = (0..16).map(|i| 1 + ((i + id as usize) % 40) as u32).collect();
        let mut req = Request::new(id, prompt, 20, tx.clone());
        if id % 6 == 0 {
            // already expired on arrival: must shed, never execute
            req = req.with_deadline_ms(0);
        }
        b.submit(req);
    }
    b.run_to_completion();
    drop(tx);

    // conservation: exactly one response per id, each a single outcome
    let mut seen: BTreeMap<u64, Outcome> = BTreeMap::new();
    let mut stats = CellStats { done: 0, failed: 0, shed: 0, preemptions: b.preemptions };
    for r in rx.iter() {
        assert!(seen.insert(r.id, r.outcome).is_none(),
                "{policy:?}/{dtype:?}: request {} answered twice", r.id);
        match r.outcome {
            Outcome::Done => {
                assert!(r.error.is_none(), "Done with error: {:?}", r.error);
                assert!(!r.tokens.is_empty(), "Done with no tokens");
                stats.done += 1;
            }
            Outcome::Failed => {
                assert!(r.error.is_some(), "Failed without a diagnostic");
                stats.failed += 1;
            }
            Outcome::Shed => {
                assert!(r.error.is_some(), "Shed without a reason");
                assert!(r.tokens.is_empty(), "Shed must not carry tokens");
                stats.shed += 1;
            }
        }
    }
    assert_eq!(seen.len(), N_REQS as usize,
               "{policy:?}/{dtype:?}: lost {} request(s)", N_REQS as usize - seen.len());

    // hygiene: no leaked pages, whatever the fault pattern did
    let engine = &b.backend.inner.0.engine;
    assert_eq!(engine.pool().allocated_pages(), 0,
               "{policy:?}/{dtype:?}: chaos leaked pool pages");

    // observability: counters mirror the batcher and are actually firing
    assert_eq!(engine.metrics.counter("shed.count"), b.sheds);
    assert_eq!(engine.metrics.counter("preempt.count"), b.preemptions);
    assert!(b.preemptions >= 1, "{policy:?}/{dtype:?}: targeted Alloc fault must preempt");
    match mode {
        PreemptMode::Restore => {
            assert!(engine.metrics.counter("preempt.restore_bytes") > 0)
        }
        PreemptMode::Recompute => {
            assert!(engine.metrics.counter("preempt.recompute_tokens") > 0)
        }
    }
    // the two expired requests + the four over-depth submissions shed
    assert!(stats.shed >= 6, "{policy:?}/{dtype:?}: expected >= 6 sheds, got {}", stats.shed);
    stats
}

#[test]
fn chaos_matrix_conserves_requests_and_pages() {
    let seed = chaos_seed();
    let mut total = CellStats { done: 0, failed: 0, shed: 0, preemptions: 0 };
    for (pi, &policy) in POLICIES.iter().enumerate() {
        for (di, &dtype) in DTYPES.iter().enumerate() {
            // both preemption modes across the matrix
            let mode = if (pi + di) % 2 == 0 {
                PreemptMode::Recompute
            } else {
                PreemptMode::Restore
            };
            // decorrelate cells while keeping the run reproducible
            let cell_seed = seed
                .wrapping_mul(1_000_003)
                .wrapping_add((pi * DTYPES.len() + di) as u64);
            let s = chaos_cell(policy, dtype, mode, cell_seed);
            total.done += s.done;
            total.failed += s.failed;
            total.shed += s.shed;
            total.preemptions += s.preemptions;
        }
    }
    let cells = POLICIES.len() * DTYPES.len();
    assert_eq!(total.done + total.failed + total.shed, cells * N_REQS as usize);
    assert!(total.done > 0, "chaos must not kill everything");
    assert!(total.failed > 0, "a 10% begin-fault rate over {cells} cells must fail some");
    assert!(total.preemptions as usize >= cells, "every cell preempts at least once");
}

/// A replica whose `submit` faults on a [`FaultSchedule`] — the
/// [`FaultOp::Submit`] consumer the backend wrappers leave to serving
/// harnesses.
struct FlakyReplica {
    schedule: RefCell<FaultSchedule>,
    accepted: Cell<usize>,
}

impl FlakyReplica {
    fn new(schedule: FaultSchedule) -> Self {
        FlakyReplica { schedule: RefCell::new(schedule), accepted: Cell::new(0) }
    }
}

impl Replica for FlakyReplica {
    fn submit(&self, req: Request) -> Result<(), SubmitError> {
        if self.schedule.borrow_mut().check(FaultOp::Submit, None) {
            return Err(SubmitError { req, reason: "injected submit fault".to_string() });
        }
        self.accepted.set(self.accepted.get() + 1);
        Ok(())
    }
    fn pending(&self) -> usize {
        0
    }
}

#[test]
fn router_chaos_fails_over_and_trips_the_breaker_without_losing_requests() {
    let seed = chaos_seed();
    // replica 0 dies (hangs) after 5 submits; replica 1 stays healthy
    let replicas = vec![
        FlakyReplica::new(FaultSchedule::new(seed).hang_after(5)),
        FlakyReplica::new(FaultSchedule::new(seed.wrapping_add(1))),
    ];
    let mut router = Router::with_seed(replicas, RoutePolicy::RoundRobin, seed);
    let mut accepted = 0usize;
    let mut returned = 0usize;
    for i in 0..60u64 {
        let (tx, rx) = channel();
        std::mem::forget(rx); // mock replicas never reply
        let req = Request::new(i, vec![1 + (i % 40) as u32], 1, tx).with_retries(1);
        match router.route(req) {
            Ok(_) => accepted += 1,
            Err(se) => {
                // the request must come back intact, never vanish
                assert_eq!(se.req.id, i);
                returned += 1;
            }
        }
    }
    assert_eq!(accepted + returned, 60, "conservation across router chaos");
    assert!(router.failovers > 0, "dead replica must force failovers");
    assert!(router.breaker_opens > 0, "repeated failures must trip the breaker");
    assert!(router.replicas()[1].accepted.get() > 0, "healthy replica carries the load");
    // with one healthy replica and a retry budget, nothing is ever lost
    assert_eq!(returned, 0, "failover to the healthy replica must absorb every request");
}
