//! Configuration system: model/artifact metadata (from `artifacts/meta.json`,
//! written by the AOT path) + engine/policy configuration (JSON file and/or
//! CLI overrides).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::kvcache::KvDtype;
use crate::util::cli::Args;
use crate::util::json::Json;

/// Architecture of the AOT-compiled model (mirrors python ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Vocabulary size (token ids are `0..vocab`).
    pub vocab: usize,
    /// Hidden width of the residual stream.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Query heads per layer.
    pub n_heads: usize,
    /// KV heads per layer (GQA: `n_heads` must be a multiple).
    pub n_kv_heads: usize,
    /// Per-head channel count.
    pub head_dim: usize,
    /// MLP hidden width.
    pub d_ff: usize,
}

impl ModelSpec {
    /// KV-cache bytes one token occupies in one layer (K + V, f32).
    pub fn kv_bytes_per_token_layer(&self) -> usize {
        // K + V, f32
        2 * self.n_kv_heads * self.head_dim * 4
    }
    /// KV-cache bytes one token occupies across all layers.
    pub fn kv_bytes_per_token(&self) -> usize {
        self.kv_bytes_per_token_layer() * self.n_layers
    }
    /// Query heads per KV head (the GQA group width).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

/// Everything the runtime needs to load and drive the artifacts.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Artifact directory (display-only `(built-in)` for the sim backend).
    pub dir: PathBuf,
    /// Architecture of the served model.
    pub model: ModelSpec,
    /// Whether the artifacts carry trained weights (sim: always false).
    pub trained: bool,
    /// Slot-capacity ladder of the compiled attention kernels.
    pub capacities: Vec<usize>,
    /// Prompt paddings of the compiled prefill executables.
    pub prefill_sizes: Vec<usize>,
    /// KV-cache page size in tokens.
    pub page_size: usize,
    /// Synthetic-corpus framing (token ids, step bounds).
    pub corpus: CorpusSpec,
}

/// Mirror of python CorpusConfig + token ids (kept in sync via meta.json;
/// the golden fixture `rust/tests/fixtures/meta_sim_default.json` pins the
/// agreement from both `cargo test` and `pytest python/tests`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CorpusSpec {
    /// Minimum reasoning-chain length in steps.
    pub min_steps: usize,
    /// Maximum reasoning-chain length in steps.
    pub max_steps: usize,
    /// Maximum lookback distance (in steps) of operand references.
    pub max_lookback: usize,
    /// Padding token id.
    pub pad: u32,
    /// Beginning-of-sequence token id.
    pub bos: u32,
    /// End-of-sequence token id.
    pub eos: u32,
    /// Question-marker token id.
    pub q: u32,
    /// Equals-sign token id.
    pub eq: u32,
    /// Separator token id.
    pub sep: u32,
    /// Step-marker token id.
    pub step: u32,
    /// Answer-marker token id.
    pub ans: u32,
    /// Terminator (full-stop) token id.
    pub dot: u32,
    /// `+` operator token id.
    pub plus: u32,
    /// `-` operator token id.
    pub minus: u32,
    /// Multiplication operator token id.
    pub times: u32,
    /// First of the ten digit tokens DIG_0..DIG_9.
    pub dig0: u32,
    /// First of the dedicated step-index tokens IDX_0..IDX_{n_idx-1}.
    pub idx0: u32,
    /// Number of step-index tokens.
    pub n_idx: u32,
}

impl CorpusSpec {
    /// Worst-case decode length for a problem of `k` steps (9 tokens per
    /// step + ANS v DOT EOS), plus slack for malformed tails.
    pub fn max_decode_tokens(&self, k: usize) -> usize {
        9 * k + 4 + 8
    }
}

impl ArtifactMeta {
    /// Built-in metadata for the simulated backend: mirrors the shape the
    /// AOT path exports (python ModelConfig/CorpusConfig defaults) so every
    /// harness runs hermetically with zero artifacts on disk.
    pub fn sim_default() -> ArtifactMeta {
        ArtifactMeta {
            // display-only sentinel: the sim backend never reads the disk
            dir: PathBuf::from("(built-in)"),
            model: ModelSpec {
                vocab: 48,
                d_model: 128,
                n_layers: 4,
                n_heads: 8,
                n_kv_heads: 4,
                head_dim: 16,
                d_ff: 256,
            },
            trained: false,
            capacities: vec![64, 128, 256, 512, 1024, 2048, 4096, 8192],
            prefill_sizes: vec![8192],
            page_size: 16,
            corpus: CorpusSpec {
                min_steps: 2,
                max_steps: 16,
                max_lookback: 6,
                pad: 0,
                bos: 1,
                eos: 2,
                q: 3,
                eq: 4,
                sep: 5,
                step: 6,
                ans: 7,
                dot: 8,
                plus: 9,
                minus: 10,
                times: 11,
                dig0: 12,
                idx0: 22,
                n_idx: 20,
            },
        }
    }

    /// Load `meta.json` from an artifact directory (the AOT path).
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {meta_path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("{meta_path:?}: {e}"))?;
        Self::from_json(dir, &j)
    }

    /// Parse artifact metadata from an already-loaded `meta.json` value.
    pub fn from_json(dir: &Path, j: &Json) -> Result<ArtifactMeta> {
        let need = |path: &str| -> Result<&Json> {
            j.path(path).ok_or_else(|| anyhow!("meta.json missing '{path}'"))
        };
        let model = ModelSpec {
            vocab: need("model.vocab")?.as_usize().unwrap(),
            d_model: need("model.d_model")?.as_usize().unwrap(),
            n_layers: need("model.n_layers")?.as_usize().unwrap(),
            n_heads: need("model.n_heads")?.as_usize().unwrap(),
            n_kv_heads: need("model.n_kv_heads")?.as_usize().unwrap(),
            head_dim: need("model.head_dim")?.as_usize().unwrap(),
            d_ff: need("model.d_ff")?.as_usize().unwrap(),
        };
        let caps: Vec<usize> = need("capacities")?
            .as_arr()
            .ok_or_else(|| anyhow!("capacities not an array"))?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let prefills: Vec<usize> = need("prefill_sizes")?
            .as_arr()
            .ok_or_else(|| anyhow!("prefill_sizes not an array"))?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        let sp = |name: &str| -> Result<u32> {
            Ok(need(&format!("corpus.specials.{name}"))?.as_i64().unwrap() as u32)
        };
        let corpus = CorpusSpec {
            min_steps: need("corpus.min_steps")?.as_usize().unwrap(),
            max_steps: need("corpus.max_steps")?.as_usize().unwrap(),
            max_lookback: need("corpus.max_lookback")?.as_usize().unwrap(),
            pad: sp("pad")?,
            bos: sp("bos")?,
            eos: sp("eos")?,
            q: sp("q")?,
            eq: sp("eq")?,
            sep: sp("sep")?,
            step: sp("step")?,
            ans: sp("ans")?,
            dot: sp("dot")?,
            plus: sp("plus")?,
            minus: sp("minus")?,
            times: sp("times")?,
            dig0: sp("dig0")?,
            idx0: sp("idx0")?,
            n_idx: sp("n_idx")?,
        };
        if model.n_heads % model.n_kv_heads != 0 {
            bail!("n_heads must be a multiple of n_kv_heads");
        }
        Ok(ArtifactMeta {
            dir: dir.to_path_buf(),
            model,
            trained: j.get("trained").and_then(|v| v.as_bool()).unwrap_or(false),
            capacities: caps,
            prefill_sizes: prefills,
            page_size: need("page_size")?.as_usize().unwrap(),
            corpus,
        })
    }
}

/// Which execution backend serves the model (DESIGN.md §4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Deterministic pure-Rust transformer surrogate (default; hermetic).
    Sim,
    /// PJRT/HLO-text runtime over AOT artifacts (`--features backend-xla`).
    Xla,
}

impl BackendKind {
    /// Parse a CLI backend name (`sim`/`surrogate`, `xla`/`pjrt`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "sim" | "surrogate" => BackendKind::Sim,
            "xla" | "pjrt" => BackendKind::Xla,
            other => bail!("unknown backend '{other}' (sim|xla)"),
        })
    }
    /// Canonical lowercase name (`sim`, `xla`).
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Sim => "sim",
            BackendKind::Xla => "xla",
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Which sparsity algorithm drives the KV cache (paper Figure 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Standard attention: O(N) time, O(N) memory.
    Dense,
    /// StreamingLLM: sink + recent window.  O(L)/O(L) but poor accuracy.
    Sink,
    /// Heavy-Hitter Oracle: accumulated scores.  O(L)/O(L) (theoretical).
    H2o,
    /// Query-aware page selection; retains ALL pages: O(L) time, O(N) memory.
    Quest,
    /// This paper: milestone timestamps + pinned prefill: O(L)/O(L).
    Raas,
    /// Reasoning Path Compression (arXiv:2505.13866): the trajectory is
    /// compressed every R steps from a recent-window importance score;
    /// between compressions the policy is O(1) per page per step.
    Rpc,
    /// LessIsMore (arXiv:2508.07101): one *unified* page set selected
    /// across heads; retains ALL pages like Quest: O(L) time, O(N) memory.
    LessIsMore,
}

impl PolicyKind {
    /// Parse a CLI policy name (`dense`, `sink`, `h2o`, `quest`, `raas`,
    /// `rpc`, `lessismore`).
    pub fn parse(s: &str) -> Result<PolicyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dense" | "full" => PolicyKind::Dense,
            "sink" | "streamingllm" | "streaming" => PolicyKind::Sink,
            "h2o" => PolicyKind::H2o,
            "quest" => PolicyKind::Quest,
            "raas" => PolicyKind::Raas,
            "rpc" | "reasoning-path-compression" => PolicyKind::Rpc,
            "lessismore" | "less-is-more" | "lim" => PolicyKind::LessIsMore,
            other => bail!("unknown policy '{other}' (dense|sink|h2o|quest|raas|rpc|lessismore)"),
        })
    }
    /// Canonical lowercase name (matches [`PolicyKind::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Dense => "dense",
            PolicyKind::Sink => "sink",
            PolicyKind::H2o => "h2o",
            PolicyKind::Quest => "quest",
            PolicyKind::Raas => "raas",
            PolicyKind::Rpc => "rpc",
            PolicyKind::LessIsMore => "lessismore",
        }
    }
    /// Every policy: the paper's Figure-2 columns in order, then the
    /// post-paper zoo (RPC, LessIsMore — ROADMAP item 4).
    pub const fn all() -> [PolicyKind; 7] {
        [
            PolicyKind::Dense,
            PolicyKind::Sink,
            PolicyKind::H2o,
            PolicyKind::Quest,
            PolicyKind::Raas,
            PolicyKind::Rpc,
            PolicyKind::LessIsMore,
        ]
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// What happens to a preempted sequence's KV pages until it resumes
/// (DESIGN.md §6): the recompute-vs-restore policy of ROADMAP item 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PreemptMode {
    /// Drop the pages outright; on resume, replay prompt + generated
    /// tokens through the prefill/decode paths.  Zero host memory, extra
    /// compute proportional to the victim's progress.
    Recompute,
    /// Copy the page bytes + quant params to a host-side swap buffer; on
    /// resume, swap them back in verbatim.  Host memory proportional to
    /// the victim's resident set, near-zero extra compute.
    Restore,
}

impl PreemptMode {
    /// Parse a CLI mode name (`recompute`, `restore`).
    pub fn parse(s: &str) -> Result<PreemptMode> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "recompute" | "replay" => PreemptMode::Recompute,
            "restore" | "swap" => PreemptMode::Restore,
            other => bail!("unknown preempt mode '{other}' (recompute|restore)"),
        })
    }
    /// Canonical lowercase name (matches [`PreemptMode::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            PreemptMode::Recompute => "recompute",
            PreemptMode::Restore => "restore",
        }
    }
}

impl std::fmt::Display for PreemptMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Engine + policy configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Execution backend serving the model.
    pub backend: BackendKind,
    /// Where the AOT artifacts live (xla backend only).
    pub artifacts_dir: PathBuf,
    /// Sparsity policy driving the KV cache.
    pub policy: PolicyKind,
    /// Cache budget in tokens (the paper's L).
    pub budget: usize,
    /// Timestamp-refresh threshold (paper's alpha).  <= 0 selects the
    /// top-`stamp_fraction` variant instead.
    pub alpha: f64,
    /// RaaS r parameter: fraction of pages stamped per step when alpha <= 0.
    pub stamp_fraction: f64,
    /// StreamingLLM sink size in tokens.
    pub sink_tokens: usize,
    /// H2O recent-window fraction of the budget.
    pub h2o_recent_fraction: f64,
    /// RPC compression cadence in decode steps (the paper's R): page
    /// importance is re-frozen every `rpc_period` steps; between freezes
    /// the eviction ranking is constant.
    pub rpc_period: u64,
    /// RPC selector window in decode steps: the e-folding length of the
    /// recent-window attention mass RPC freezes at each compression.
    pub rpc_window: f64,
    /// Pin prefill pages against eviction (RaaS idea #2; the ablation
    /// switch behind `raas ablate`).
    pub pin_prefill: bool,
    /// Hard cap on decode length (paper Fig. 8 uses 4k).
    pub max_decode: usize,
    /// Total KV pool size in pages (across sequences).
    pub pool_pages: usize,
    /// Element dtype of the pool's KV storage.  `F32` is the bit-exact
    /// reference; `Fp8E4M3`/`Int8` store quantized bytes plus per-page
    /// scale/zero-point and dequantize on read.
    pub kv_dtype: KvDtype,
    /// Share full prompt pages across sequences through the pool-level
    /// prefix index (refcount + copy-on-write).  Off by default: sharing
    /// changes pool-id allocation order, and the bit-identity suites pin
    /// pool ids exactly on the cold path.
    pub prefix_cache: bool,
    /// Seed for the sim backend's feature dictionaries.
    pub seed: u64,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            backend: BackendKind::Sim,
            artifacts_dir: PathBuf::from("artifacts"),
            policy: PolicyKind::Raas,
            budget: 256,
            alpha: 1e-4,
            stamp_fraction: 0.5,
            sink_tokens: 16,
            h2o_recent_fraction: 0.5,
            rpc_period: 64,
            rpc_window: 16.0,
            pin_prefill: true,
            max_decode: 4096,
            pool_pages: 16384,
            kv_dtype: KvDtype::from_env(),
            prefix_cache: false,
            seed: 0,
        }
    }
}

impl EngineConfig {
    /// Metadata for this configuration: loaded from `artifacts_dir` for the
    /// AOT backend, built-in for the simulated one (no disk access).
    pub fn resolve_meta(&self) -> Result<ArtifactMeta> {
        match self.backend {
            BackendKind::Sim => Ok(ArtifactMeta::sim_default()),
            BackendKind::Xla => ArtifactMeta::load(&self.artifacts_dir),
        }
    }

    /// CLI overrides: --backend --artifacts --policy --budget --alpha
    /// --rpc-period --rpc-window --max-decode --pool-pages --kv-dtype
    /// --seed.
    ///
    /// An explicit `--backend` wins; a bare `--artifacts DIR` implies the
    /// xla backend so pre-backend invocations keep driving the real model
    /// instead of silently falling back to the surrogate.
    pub fn from_args(args: &Args) -> Result<EngineConfig> {
        let mut c = EngineConfig::default();
        let backend_flag = args.str_opt("backend");
        let artifacts_flag = args.str_opt("artifacts");
        c.backend = match &backend_flag {
            Some(s) => BackendKind::parse(s)?,
            None if artifacts_flag.is_some() => BackendKind::Xla,
            None => BackendKind::Sim,
        };
        if c.backend == BackendKind::Sim && artifacts_flag.is_some() {
            eprintln!("warning: --artifacts is ignored by the sim backend (built-in metadata)");
        }
        c.artifacts_dir = PathBuf::from(artifacts_flag.unwrap_or_else(|| "artifacts".into()));
        c.policy = PolicyKind::parse(&args.str_or("policy", "raas"))?;
        c.budget = args.usize_or("budget", c.budget);
        c.alpha = args.f64_or("alpha", c.alpha);
        c.stamp_fraction = args.f64_or("stamp-fraction", c.stamp_fraction);
        c.sink_tokens = args.usize_or("sink-tokens", c.sink_tokens);
        c.rpc_period = args.u64_or("rpc-period", c.rpc_period);
        c.rpc_window = args.f64_or("rpc-window", c.rpc_window);
        if args.switch("no-pin-prefill") {
            c.pin_prefill = false;
        }
        c.max_decode = args.usize_or("max-decode", c.max_decode);
        c.pool_pages = args.usize_or("pool-pages", c.pool_pages);
        c.kv_dtype = KvDtype::parse(&args.str_or("kv-dtype", c.kv_dtype.name()))?;
        if args.switch("prefix-cache") {
            c.prefix_cache = true;
        }
        c.seed = args.u64_or("seed", c.seed);
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta_json() -> Json {
        Json::parse(
            r#"{
              "model": {"vocab":48,"d_model":128,"n_layers":4,"n_heads":8,
                        "n_kv_heads":4,"head_dim":16,"d_ff":256,
                        "rope_theta":10000.0,"rms_eps":1e-5},
              "trained": true,
              "capacities": [64,128],
              "prefill_sizes": [256],
              "page_size": 16,
              "files": {},
              "corpus": {"min_steps":2,"max_steps":16,"max_lookback":6,
                "specials":{"pad":0,"bos":1,"eos":2,"q":3,"eq":4,"sep":5,
                            "step":6,"ans":7,"dot":8,"plus":9,"minus":10,
                            "times":11,"dig0":12,"idx0":22,"n_idx":20}}
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn meta_parses() {
        let m = ArtifactMeta::from_json(Path::new("/tmp"), &meta_json()).unwrap();
        assert_eq!(m.model.n_layers, 4);
        assert_eq!(m.capacities, vec![64, 128]);
        assert_eq!(m.corpus.dig0, 12);
        assert!(m.trained);
        assert_eq!(m.model.kv_bytes_per_token(), 2 * 4 * 16 * 4 * 4);
    }

    #[test]
    fn policy_parse() {
        assert_eq!(PolicyKind::parse("RaaS").unwrap(), PolicyKind::Raas);
        assert_eq!(PolicyKind::parse("streamingllm").unwrap(), PolicyKind::Sink);
        assert_eq!(PolicyKind::parse("rpc").unwrap(), PolicyKind::Rpc);
        assert_eq!(PolicyKind::parse("LessIsMore").unwrap(), PolicyKind::LessIsMore);
        assert_eq!(PolicyKind::parse("lim").unwrap(), PolicyKind::LessIsMore);
        assert!(PolicyKind::parse("bogus").is_err());
        // the zoo helper and the parser must agree on every name
        for kind in PolicyKind::all() {
            assert_eq!(PolicyKind::parse(kind.name()).unwrap(), kind);
        }
    }

    #[test]
    fn preempt_mode_parse() {
        assert_eq!(PreemptMode::parse("recompute").unwrap(), PreemptMode::Recompute);
        assert_eq!(PreemptMode::parse("SWAP").unwrap(), PreemptMode::Restore);
        assert_eq!(PreemptMode::Restore.name(), "restore");
        assert!(PreemptMode::parse("discard").is_err());
    }

    #[test]
    fn backend_parse_and_default() {
        assert_eq!(BackendKind::parse("sim").unwrap(), BackendKind::Sim);
        assert_eq!(BackendKind::parse("PJRT").unwrap(), BackendKind::Xla);
        assert!(BackendKind::parse("tpu").is_err());
        assert_eq!(EngineConfig::default().backend, BackendKind::Sim);
    }

    #[test]
    fn bare_artifacts_flag_implies_xla() {
        let parse = |argv: &[&str]| {
            EngineConfig::from_args(
                &Args::parse(argv.iter().map(|s| s.to_string())).unwrap(),
            )
            .unwrap()
        };
        // pre-backend invocation: --artifacts alone selects the real model
        let c = parse(&["x", "--artifacts", "trained"]);
        assert_eq!(c.backend, BackendKind::Xla);
        assert_eq!(c.artifacts_dir, PathBuf::from("trained"));
        // explicit --backend always wins
        let c = parse(&["x", "--artifacts", "trained", "--backend", "sim"]);
        assert_eq!(c.backend, BackendKind::Sim);
        // no flags: hermetic default
        assert_eq!(parse(&["x"]).backend, BackendKind::Sim);
    }

    #[test]
    fn sim_default_meta_is_consistent() {
        let m = ArtifactMeta::sim_default();
        assert_eq!(m.model.n_heads % m.model.n_kv_heads, 0);
        assert_eq!(m.model.d_model, m.model.n_heads * m.model.head_dim);
        // vocab covers every special + digit + index token
        assert!(m.model.vocab as u32 >= m.corpus.idx0 + m.corpus.n_idx);
        // sim meta resolves without touching the filesystem
        let cfg = EngineConfig::default();
        assert_eq!(cfg.resolve_meta().unwrap().page_size, m.page_size);
    }

    #[test]
    fn engine_config_overrides() {
        let args = Args::parse(
            [
                "x", "--policy", "quest", "--budget", "512", "--alpha", "0.01", "--prefix-cache",
                "--kv-dtype", "int8",
            ]
            .iter()
            .map(|s| s.to_string()),
        )
        .unwrap();
        let c = EngineConfig::from_args(&args).unwrap();
        assert_eq!(c.policy, PolicyKind::Quest);
        assert_eq!(c.budget, 512);
        assert_eq!(c.alpha, 0.01);
        assert!(c.prefix_cache);
        assert_eq!(c.kv_dtype, KvDtype::Int8);
        assert!(!EngineConfig::default().prefix_cache, "prefix cache is opt-in");
        // no default-dtype assertion here: the CI matrix legs run the whole
        // suite under KV_DTYPE=fp8|int8, which EngineConfig::default() obeys
    }
}
