//! Request lifecycle types.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// Coordinator-wide request identifier.
pub type RequestId = u64;

/// A generation request submitted to the coordinator.
pub struct Request {
    /// Caller-chosen identifier, echoed in the [`Response`].
    pub id: RequestId,
    /// Prompt tokens.
    pub prompt: Vec<u32>,
    /// Decode-length cap (EOS may stop earlier).
    pub max_new: usize,
    /// Submission instant (the JCT/TTFT clock origin — a latency metric,
    /// so it stays on real time; deadline logic rides the injectable
    /// serving clock below).
    pub submitted: Instant,
    /// Deadline budget in serving-clock milliseconds, measured from
    /// [`Request::arrived_ms`]; the batcher sheds the request
    /// ([`Outcome::Shed`]) rather than admit it past the budget.
    /// `None` means no deadline.
    pub deadline_ms: Option<u64>,
    /// Serving-clock arrival stamp, set once by the first batcher that
    /// sees the request ([`Request::stamp_arrival`]).  It survives
    /// cross-replica re-dispatch, so a recovered request keeps its
    /// original deadline budget instead of resetting it.
    pub arrived_ms: Option<u64>,
    /// Router-level retry budget: how many more times a `submit` failure
    /// may fail over to another replica before the request is failed.
    pub retries_left: u32,
    /// Where the response is delivered.
    pub reply: Sender<Response>,
}

impl Request {
    /// Request with no deadline and no retry budget, submitted now.
    pub fn new(id: RequestId, prompt: Vec<u32>, max_new: usize, reply: Sender<Response>) -> Self {
        Request {
            id,
            prompt,
            max_new,
            submitted: Instant::now(),
            deadline_ms: None,
            arrived_ms: None,
            retries_left: 0,
            reply,
        }
    }

    /// Set a deadline budget of `ms` serving-clock milliseconds from
    /// arrival (0 = expired as soon as it arrives).
    pub fn with_deadline_ms(mut self, ms: u64) -> Self {
        self.deadline_ms = Some(ms);
        self
    }

    /// Set the router-level retry budget.
    pub fn with_retries(mut self, retries: u32) -> Self {
        self.retries_left = retries;
        self
    }

    /// Record the serving-clock arrival if not already stamped (first
    /// batcher wins; re-dispatch after a replica death keeps the stamp).
    pub fn stamp_arrival(&mut self, now_ms: u64) {
        if self.arrived_ms.is_none() {
            self.arrived_ms = Some(now_ms);
        }
    }

    /// Whether the deadline budget (if any) is exhausted at serving-clock
    /// time `now_ms`.  Never true before the arrival stamp exists.
    pub fn expired_at_ms(&self, now_ms: u64) -> bool {
        match (self.deadline_ms, self.arrived_ms) {
            (Some(d), Some(a)) => now_ms.saturating_sub(a) >= d,
            _ => false,
        }
    }
}

/// How a request's lifecycle ended — every submitted request resolves to
/// exactly one of these (the fault-tolerance trichotomy, DESIGN.md §6).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Outcome {
    /// Decode completed (EOS or `max_new`); `tokens` holds the output.
    Done,
    /// An execution error killed the request; `error` holds the
    /// diagnostic.
    Failed,
    /// Load shedding: the coordinator refused the work (deadline expired,
    /// queue too deep) before/while serving it; `error` holds the reason.
    Shed,
}

/// The completed response.
#[derive(Debug, Clone)]
pub struct Response {
    /// The request this answers.
    pub id: RequestId,
    /// Decoded tokens (empty unless [`Outcome::Done`]).
    pub tokens: Vec<u32>,
    /// Job completion time (paper metric): submission → full response.
    pub jct_secs: f64,
    /// Time to first token.
    pub ttft_secs: f64,
    /// How the request ended.
    pub outcome: Outcome,
    /// Failure/shed diagnostic; `None` on success.
    pub error: Option<String>,
}

impl Response {
    /// Failure response carrying the elapsed time as its JCT.
    pub fn err(id: RequestId, submitted: Instant, msg: String) -> Self {
        Response {
            id,
            tokens: Vec::new(),
            jct_secs: submitted.elapsed().as_secs_f64(),
            ttft_secs: 0.0,
            outcome: Outcome::Failed,
            error: Some(msg),
        }
    }

    /// Load-shed response: the request was refused, not executed.
    pub fn shed(id: RequestId, submitted: Instant, reason: String) -> Self {
        Response {
            id,
            tokens: Vec::new(),
            jct_secs: submitted.elapsed().as_secs_f64(),
            ttft_secs: 0.0,
            outcome: Outcome::Shed,
            error: Some(reason),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn request_roundtrip() {
        let (tx, rx) = channel();
        let req = Request::new(7, vec![1, 2], 4, tx);
        req.reply
            .send(Response {
                id: req.id,
                tokens: vec![9],
                jct_secs: 0.1,
                ttft_secs: 0.05,
                outcome: Outcome::Done,
                error: None,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.outcome, Outcome::Done);
        assert!(resp.error.is_none());
    }

    #[test]
    fn deadline_and_retry_builders() {
        let (tx, _rx) = channel();
        let mut req = Request::new(1, vec![3], 2, tx).with_deadline_ms(50).with_retries(2);
        assert_eq!(req.retries_left, 2);
        assert_eq!(req.deadline_ms, Some(50));
        // no arrival stamp yet: the budget hasn't started
        assert!(!req.expired_at_ms(1_000_000));
        req.stamp_arrival(100);
        req.stamp_arrival(9_999); // second stamp is ignored (first batcher wins)
        assert_eq!(req.arrived_ms, Some(100));
        assert!(!req.expired_at_ms(149));
        assert!(req.expired_at_ms(150));
        // a zero budget expires the moment it arrives
        let (tx2, _rx2) = channel();
        let mut zero = Request::new(2, vec![3], 2, tx2).with_deadline_ms(0);
        zero.stamp_arrival(7);
        assert!(zero.expired_at_ms(7));
        // no deadline never expires
        let (tx3, _rx3) = channel();
        let mut open = Request::new(3, vec![3], 2, tx3);
        open.stamp_arrival(0);
        assert!(!open.expired_at_ms(u64::MAX));
    }

    #[test]
    fn outcome_constructors_classify() {
        let t = Instant::now();
        let f = Response::err(4, t, "boom".into());
        assert_eq!(f.outcome, Outcome::Failed);
        assert!(f.error.is_some());
        let s = Response::shed(5, t, "deadline expired".into());
        assert_eq!(s.outcome, Outcome::Shed);
        assert!(s.tokens.is_empty());
    }
}
