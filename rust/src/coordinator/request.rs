//! Request lifecycle types.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// Coordinator-wide request identifier.
pub type RequestId = u64;

/// A generation request submitted to the coordinator.
pub struct Request {
    /// Caller-chosen identifier, echoed in the [`Response`].
    pub id: RequestId,
    /// Prompt tokens.
    pub prompt: Vec<u32>,
    /// Decode-length cap (EOS may stop earlier).
    pub max_new: usize,
    /// Submission instant (the JCT/TTFT clock origin).
    pub submitted: Instant,
    /// Where the response is delivered.
    pub reply: Sender<Response>,
}

/// The completed response.
#[derive(Debug, Clone)]
pub struct Response {
    /// The request this answers.
    pub id: RequestId,
    /// Decoded tokens (empty on error).
    pub tokens: Vec<u32>,
    /// Job completion time (paper metric): submission → full response.
    pub jct_secs: f64,
    /// Time to first token.
    pub ttft_secs: f64,
    /// Failure diagnostic; `None` on success.
    pub error: Option<String>,
}

impl Response {
    /// Failure response carrying the elapsed time as its JCT.
    pub fn err(id: RequestId, submitted: Instant, msg: String) -> Self {
        Response {
            id,
            tokens: Vec::new(),
            jct_secs: submitted.elapsed().as_secs_f64(),
            ttft_secs: 0.0,
            error: Some(msg),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn request_roundtrip() {
        let (tx, rx) = channel();
        let req = Request {
            id: 7,
            prompt: vec![1, 2],
            max_new: 4,
            submitted: Instant::now(),
            reply: tx,
        };
        req.reply
            .send(Response {
                id: req.id,
                tokens: vec![9],
                jct_secs: 0.1,
                ttft_secs: 0.05,
                error: None,
            })
            .unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.id, 7);
        assert!(resp.error.is_none());
    }
}
