//! Continuous batching: interleaves decode steps of all admitted sequences
//! (Orca-style iteration-level scheduling, prefill-first admission).
//!
//! The batcher is generic over a [`StepBackend`] so the scheduling logic is
//! testable without AOT artifacts; the real backend is [`crate::engine::Engine`]
//! via [`super::server::EngineBackend`].

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::request::{Request, Response};

/// One sequence's slot in a batched scheduler iteration
/// ([`StepBackend::step_batch`]).
pub struct StepItem<'a, S> {
    pub seq: &'a mut S,
    /// The token decoded this iteration (last step's output).
    pub token: u32,
    /// Per-sequence step counter.
    pub now: u64,
}

/// What the batcher needs from an inference engine.
pub trait StepBackend {
    type Seq;
    /// Prefill: build sequence state, return the first decoded token.
    fn begin(&mut self, prompt: &[u32]) -> Result<(Self::Seq, u32)>;
    /// One decode step; `now` is the per-sequence step counter.
    fn step(&mut self, seq: &mut Self::Seq, token: u32, now: u64) -> Result<u32>;
    /// One decode iteration across several sequences; returns one result
    /// per item, index-aligned.  The default decodes item by item;
    /// engines with a batched fast path override it
    /// (`EngineBackend::step_batch` → `Engine::decode_batch`), which is
    /// how the serving loop amortizes per-iteration dispatch across the
    /// whole batch.
    fn step_batch(&mut self, items: &mut [StepItem<'_, Self::Seq>]) -> Vec<Result<u32>> {
        items.iter_mut().map(|it| self.step(it.seq, it.token, it.now)).collect()
    }
    /// Release sequence resources.
    fn finish(&mut self, seq: Self::Seq);
    fn is_eos(&self, token: u32) -> bool;
    /// True when another sequence can be admitted (pool headroom).
    fn has_capacity(&self, active: usize) -> bool;
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Hard cap on concurrently decoding sequences.
    pub max_batch: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8 }
    }
}

struct Active<S> {
    req: Request,
    seq: S,
    token: u32,
    produced: Vec<u32>,
    step: u64,
    ttft_secs: f64,
}

/// Iteration-level scheduler over a [`StepBackend`].
pub struct Batcher<B: StepBackend> {
    pub backend: B,
    cfg: BatcherConfig,
    active: Vec<Active<B::Seq>>,
    /// FIFO admission queue.  `VecDeque`: admission pops the front every
    /// iteration, and a `Vec::remove(0)` here is O(n²) under queue
    /// pressure.
    queue: VecDeque<Request>,
    pub completed: u64,
}

impl<B: StepBackend> Batcher<B> {
    pub fn new(backend: B, cfg: BatcherConfig) -> Self {
        Batcher { backend, cfg, active: Vec::new(), queue: VecDeque::new(), completed: 0 }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Admit queued requests while capacity allows (prefill-first policy:
    /// admission runs before the decode sweep each iteration).
    fn admit(&mut self) {
        while !self.queue.is_empty()
            && self.active.len() < self.cfg.max_batch
            && self.backend.has_capacity(self.active.len())
        {
            let req = self.queue.pop_front().expect("queue non-empty");
            let t0 = Instant::now();
            match self.backend.begin(&req.prompt) {
                Ok((seq, token)) => {
                    let ttft = req.submitted.elapsed().as_secs_f64();
                    let _ = t0;
                    self.active.push(Active {
                        req,
                        seq,
                        token,
                        produced: Vec::new(),
                        step: 0,
                        ttft_secs: ttft,
                    });
                }
                Err(e) => {
                    let resp = Response::err(req.id, req.submitted, format!("prefill: {e:#}"));
                    let _ = req.reply.send(resp);
                }
            }
        }
    }

    /// One scheduler iteration: admit, retire finished sequences, then ONE
    /// batched decode call across every remaining active sequence
    /// ([`StepBackend::step_batch`] — the engine amortizes per-iteration
    /// dispatch across the batch).  Returns the number of decode steps
    /// taken.
    pub fn tick(&mut self) -> usize {
        self.admit();
        // deliver the tokens produced last iteration; retire sequences
        // that hit EOS or their length cap so they free their batch slot
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            a.produced.push(a.token);
            let done_eos = self.backend.is_eos(a.token);
            let done_len = a.produced.len() >= a.req.max_new;
            if done_eos || done_len {
                let a = self.active.remove(i);
                let resp = Response {
                    id: a.req.id,
                    tokens: a.produced,
                    jct_secs: a.req.submitted.elapsed().as_secs_f64(),
                    ttft_secs: a.ttft_secs,
                    error: None,
                };
                self.backend.finish(a.seq);
                let _ = a.req.reply.send(resp);
                self.completed += 1;
                continue; // i now points at the next sequence
            }
            a.step += 1;
            i += 1;
        }
        if self.active.is_empty() {
            return 0;
        }
        // one batched iteration over the survivors
        let mut items: Vec<StepItem<'_, B::Seq>> = self
            .active
            .iter_mut()
            .map(|a| StepItem { seq: &mut a.seq, token: a.token, now: a.step })
            .collect();
        let mut results = self.backend.step_batch(&mut items);
        drop(items);
        // Hard contract, not a debug_assert: a misbehaving backend must not
        // panic the replica thread (extra results) or stall sequences on a
        // stale token forever (missing results).
        let got = results.len();
        if got != self.active.len() {
            results.truncate(self.active.len());
            while results.len() < self.active.len() {
                results.push(Err(anyhow::anyhow!(
                    "step_batch returned {got} results for {} sequences",
                    self.active.len()
                )));
            }
        }
        let mut steps = 0;
        // apply back-to-front so error removals keep earlier indices valid
        for (idx, r) in results.into_iter().enumerate().rev() {
            match r {
                Ok(next) => {
                    self.active[idx].token = next;
                    steps += 1;
                }
                Err(e) => {
                    let a = self.active.remove(idx);
                    let resp =
                        Response::err(a.req.id, a.req.submitted, format!("decode: {e:#}"));
                    self.backend.finish(a.seq);
                    let _ = a.req.reply.send(resp);
                    self.completed += 1;
                }
            }
        }
        steps
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) {
        while self.pending() > 0 {
            self.tick();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    /// Scripted backend: echoes prompt[0], counts down, then EOS (token 0).
    struct MockBackend {
        capacity: usize,
        begun: usize,
        finished: usize,
    }

    impl StepBackend for MockBackend {
        type Seq = u32; // remaining tokens before EOS
        fn begin(&mut self, prompt: &[u32]) -> Result<(u32, u32)> {
            self.begun += 1;
            if prompt.is_empty() {
                anyhow::bail!("empty prompt");
            }
            Ok((prompt[0], 100 + prompt[0]))
        }
        fn step(&mut self, seq: &mut u32, _token: u32, _now: u64) -> Result<u32> {
            if *seq == 0 {
                return Ok(0);
            }
            *seq -= 1;
            Ok(if *seq == 0 { 0 } else { 100 + *seq })
        }
        fn finish(&mut self, _seq: u32) {
            self.finished += 1;
        }
        fn is_eos(&self, token: u32) -> bool {
            token == 0
        }
        fn has_capacity(&self, active: usize) -> bool {
            active < self.capacity
        }
    }

    fn mk_req(id: u64, first: u32, max_new: usize, tx: &std::sync::mpsc::Sender<Response>)
              -> Request {
        Request { id, prompt: vec![first], max_new, submitted: Instant::now(), reply: tx.clone() }
    }

    #[test]
    fn conservation_no_lost_or_duplicated_requests() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 3, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 3 },
        );
        for id in 0..10 {
            b.submit(mk_req(id, (id % 4) as u32 + 1, 64, &tx));
        }
        b.run_to_completion();
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(b.backend.begun, 10);
        assert_eq!(b.backend.finished, 10, "all sequences released");
        assert_eq!(b.completed, 10);
    }

    #[test]
    fn respects_max_new() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 50, 5, &tx)); // would emit 50 tokens, capped at 5
        b.run_to_completion();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.error.is_none());
    }

    #[test]
    fn eos_terminates_early() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 2, 64, &tx)); // 2 countdown steps then EOS
        b.run_to_completion();
        let resp = rx.recv().unwrap();
        assert_eq!(*resp.tokens.last().unwrap(), 0);
        assert!(resp.tokens.len() < 64);
    }

    #[test]
    fn admission_respects_capacity() {
        let (tx, _rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 2, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 8 },
        );
        for id in 0..5 {
            b.submit(mk_req(id, 30, 64, &tx));
        }
        b.tick();
        assert_eq!(b.backend.begun, 2, "only 2 admitted");
        assert_eq!(b.pending(), 5);
    }

    /// Records admission order; every sequence decodes one token then EOS,
    /// so slots churn and admission happens in many partial waves.
    struct OrderBackend {
        order: Vec<u64>,
        capacity: usize,
    }

    impl StepBackend for OrderBackend {
        type Seq = ();
        fn begin(&mut self, prompt: &[u32]) -> Result<((), u32)> {
            self.order.push(prompt[0] as u64);
            Ok(((), 1))
        }
        fn step(&mut self, _seq: &mut (), _token: u32, _now: u64) -> Result<u32> {
            Ok(0)
        }
        fn finish(&mut self, _seq: ()) {}
        fn is_eos(&self, token: u32) -> bool {
            token == 0
        }
        fn has_capacity(&self, active: usize) -> bool {
            active < self.capacity
        }
    }

    #[test]
    fn admission_is_fifo_under_repeated_partial_admission() {
        // 9 requests through 2 slots: ~5 admission waves, each popping the
        // queue front.  The begin order must equal the submission order
        // (the VecDeque queue preserves FIFO; a priority or LIFO regression
        // would reorder here).
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            OrderBackend { order: Vec::new(), capacity: 2 },
            BatcherConfig { max_batch: 8 },
        );
        for id in 0..9u64 {
            b.submit(mk_req(id, id as u32, 64, &tx));
        }
        b.run_to_completion();
        drop(tx);
        assert_eq!(b.backend.order, (0..9).collect::<Vec<u64>>(), "admission must be FIFO");
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn prefill_error_is_reported_not_fatal() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(Request { id: 1, prompt: vec![], max_new: 4, submitted: Instant::now(), reply: tx.clone() });
        b.submit(mk_req(2, 1, 8, &tx));
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert!(resps[0].error.is_some());
        assert!(resps[1].error.is_none());
    }
}
