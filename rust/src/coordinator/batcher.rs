//! Continuous batching: interleaves decode steps of all admitted sequences
//! (Orca-style iteration-level scheduling, prefill-first admission).
//!
//! The batcher is generic over a [`StepBackend`] so the scheduling logic is
//! testable without AOT artifacts; the real backend is [`crate::engine::Engine`]
//! via [`super::server::EngineBackend`].

use std::time::Instant;

use anyhow::Result;

use super::request::{Request, Response};

/// What the batcher needs from an inference engine.
pub trait StepBackend {
    type Seq;
    /// Prefill: build sequence state, return the first decoded token.
    fn begin(&mut self, prompt: &[u32]) -> Result<(Self::Seq, u32)>;
    /// One decode step; `now` is the per-sequence step counter.
    fn step(&mut self, seq: &mut Self::Seq, token: u32, now: u64) -> Result<u32>;
    /// Release sequence resources.
    fn finish(&mut self, seq: Self::Seq);
    fn is_eos(&self, token: u32) -> bool;
    /// True when another sequence can be admitted (pool headroom).
    fn has_capacity(&self, active: usize) -> bool;
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Hard cap on concurrently decoding sequences.
    pub max_batch: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8 }
    }
}

struct Active<S> {
    req: Request,
    seq: S,
    token: u32,
    produced: Vec<u32>,
    step: u64,
    ttft_secs: f64,
}

/// Iteration-level scheduler over a [`StepBackend`].
pub struct Batcher<B: StepBackend> {
    pub backend: B,
    cfg: BatcherConfig,
    active: Vec<Active<B::Seq>>,
    queue: Vec<Request>,
    pub completed: u64,
}

impl<B: StepBackend> Batcher<B> {
    pub fn new(backend: B, cfg: BatcherConfig) -> Self {
        Batcher { backend, cfg, active: Vec::new(), queue: Vec::new(), completed: 0 }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len() + self.active.len()
    }

    /// Admit queued requests while capacity allows (prefill-first policy:
    /// admission runs before the decode sweep each iteration).
    fn admit(&mut self) {
        while !self.queue.is_empty()
            && self.active.len() < self.cfg.max_batch
            && self.backend.has_capacity(self.active.len())
        {
            let req = self.queue.remove(0);
            let t0 = Instant::now();
            match self.backend.begin(&req.prompt) {
                Ok((seq, token)) => {
                    let ttft = req.submitted.elapsed().as_secs_f64();
                    let _ = t0;
                    self.active.push(Active {
                        req,
                        seq,
                        token,
                        produced: Vec::new(),
                        step: 0,
                        ttft_secs: ttft,
                    });
                }
                Err(e) => {
                    let resp = Response::err(req.id, req.submitted, format!("prefill: {e:#}"));
                    let _ = req.reply.send(resp);
                }
            }
        }
    }

    /// One scheduler iteration: admit, then one decode step per active
    /// sequence (round-robin).  Returns the number of decode steps taken.
    pub fn tick(&mut self) -> usize {
        self.admit();
        let mut steps = 0;
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            a.produced.push(a.token);
            let done_eos = self.backend.is_eos(a.token);
            let done_len = a.produced.len() >= a.req.max_new;
            if done_eos || done_len {
                let a = self.active.remove(i);
                let resp = Response {
                    id: a.req.id,
                    tokens: a.produced,
                    jct_secs: a.req.submitted.elapsed().as_secs_f64(),
                    ttft_secs: a.ttft_secs,
                    error: None,
                };
                self.backend.finish(a.seq);
                let _ = a.req.reply.send(resp);
                self.completed += 1;
                continue; // i now points at the next sequence
            }
            a.step += 1;
            match self.backend.step(&mut a.seq, a.token, a.step) {
                Ok(next) => {
                    a.token = next;
                    steps += 1;
                    i += 1;
                }
                Err(e) => {
                    let a = self.active.remove(i);
                    let resp =
                        Response::err(a.req.id, a.req.submitted, format!("decode: {e:#}"));
                    self.backend.finish(a.seq);
                    let _ = a.req.reply.send(resp);
                    self.completed += 1;
                }
            }
        }
        steps
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) {
        while self.pending() > 0 {
            self.tick();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    /// Scripted backend: echoes prompt[0], counts down, then EOS (token 0).
    struct MockBackend {
        capacity: usize,
        begun: usize,
        finished: usize,
    }

    impl StepBackend for MockBackend {
        type Seq = u32; // remaining tokens before EOS
        fn begin(&mut self, prompt: &[u32]) -> Result<(u32, u32)> {
            self.begun += 1;
            if prompt.is_empty() {
                anyhow::bail!("empty prompt");
            }
            Ok((prompt[0], 100 + prompt[0]))
        }
        fn step(&mut self, seq: &mut u32, _token: u32, _now: u64) -> Result<u32> {
            if *seq == 0 {
                return Ok(0);
            }
            *seq -= 1;
            Ok(if *seq == 0 { 0 } else { 100 + *seq })
        }
        fn finish(&mut self, _seq: u32) {
            self.finished += 1;
        }
        fn is_eos(&self, token: u32) -> bool {
            token == 0
        }
        fn has_capacity(&self, active: usize) -> bool {
            active < self.capacity
        }
    }

    fn mk_req(id: u64, first: u32, max_new: usize, tx: &std::sync::mpsc::Sender<Response>)
              -> Request {
        Request { id, prompt: vec![first], max_new, submitted: Instant::now(), reply: tx.clone() }
    }

    #[test]
    fn conservation_no_lost_or_duplicated_requests() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 3, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 3 },
        );
        for id in 0..10 {
            b.submit(mk_req(id, (id % 4) as u32 + 1, 64, &tx));
        }
        b.run_to_completion();
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(b.backend.begun, 10);
        assert_eq!(b.backend.finished, 10, "all sequences released");
        assert_eq!(b.completed, 10);
    }

    #[test]
    fn respects_max_new() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 50, 5, &tx)); // would emit 50 tokens, capped at 5
        b.run_to_completion();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.error.is_none());
    }

    #[test]
    fn eos_terminates_early() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 2, 64, &tx)); // 2 countdown steps then EOS
        b.run_to_completion();
        let resp = rx.recv().unwrap();
        assert_eq!(*resp.tokens.last().unwrap(), 0);
        assert!(resp.tokens.len() < 64);
    }

    #[test]
    fn admission_respects_capacity() {
        let (tx, _rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 2, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 8 },
        );
        for id in 0..5 {
            b.submit(mk_req(id, 30, 64, &tx));
        }
        b.tick();
        assert_eq!(b.backend.begun, 2, "only 2 admitted");
        assert_eq!(b.pending(), 5);
    }

    #[test]
    fn prefill_error_is_reported_not_fatal() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(Request { id: 1, prompt: vec![], max_new: 4, submitted: Instant::now(), reply: tx.clone() });
        b.submit(mk_req(2, 1, 8, &tx));
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert!(resps[0].error.is_some());
        assert!(resps[1].error.is_none());
    }
}
