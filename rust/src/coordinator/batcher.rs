//! Continuous batching: interleaves decode steps of all admitted sequences
//! (Orca-style iteration-level scheduling).  Admission is either
//! prefill-first (whole prompts, the legacy default) or — with
//! [`BatcherConfig::prefill_token_budget`] set — Sarathi-style chunked:
//! each tick spends at most the budget in prompt tokens, holding up to
//! [`BatcherConfig::prefill_concurrency`] partially-prefilled sequences in
//! an admission state and packing their next chunks into one batched
//! backend call, so long prompts interleave with the decode sweep (and
//! with each other) instead of stalling every co-scheduled decoder
//! (DESIGN.md §5, the Queued → Prefilling{n} → Active state machine).
//!
//! The batcher is generic over a [`StepBackend`] so the scheduling logic is
//! testable without AOT artifacts; the real backend is [`crate::engine::Engine`]
//! via [`super::server::EngineBackend`].

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use super::request::{Outcome, Request, RequestId, Response};
use crate::config::PreemptMode;
use crate::kvcache::PoolExhausted;
use crate::util::clock::{SharedClock, WallClock};

/// One sequence's slot in a batched scheduler iteration
/// ([`StepBackend::step_batch`]).
pub struct StepItem<'a, S> {
    /// The decoding sequence.
    pub seq: &'a mut S,
    /// The token decoded this iteration (last step's output).
    pub token: u32,
    /// Per-sequence step counter.
    pub now: u64,
}

/// Progress of one streaming-prefill chunk ([`StepBackend::prefill_chunk`]).
pub struct PrefillProgress {
    /// Prompt tokens consumed by this chunk (>= 1).
    pub consumed: usize,
    /// Of `consumed`, tokens satisfied from the pool-level prefix cache
    /// (attached, not computed).  Admission credits them back against the
    /// tick's prefill budget — the prefix-cache TTFT win — so only real
    /// backend work is paced.  0 on cold paths and non-first chunks.
    pub cached: usize,
    /// The first decoded token — present exactly when prefill completed.
    pub first_token: Option<u32>,
}

/// One prompt's slot in a batched admission tick
/// ([`StepBackend::prefill_chunk_batch`]): the same arguments
/// [`StepBackend::prefill_chunk`] takes, one entry per co-admitted prompt.
pub struct PrefillBatchItem<'a, S> {
    /// The sequence being prefilled.
    pub seq: &'a mut S,
    /// The full prompt.
    pub prompt: &'a [u32],
    /// Prompt tokens already consumed.
    pub done: usize,
    /// Consume at most this many more prompt tokens (>= 1).
    pub max_tokens: usize,
}

/// What the batcher needs from an inference engine.
pub trait StepBackend {
    /// Per-sequence state the backend threads through the scheduler.
    type Seq;
    /// Prefill: build sequence state, return the first decoded token.
    fn begin(&mut self, prompt: &[u32]) -> Result<(Self::Seq, u32)>;
    /// Start a streaming admission: an empty sequence that
    /// [`StepBackend::prefill_chunk`] fills chunk by chunk.  `None` (the
    /// default) means this backend admits whole prompts only — under a
    /// token budget the batcher then falls back to [`StepBackend::begin`],
    /// still budget-paced but at whole-prompt granularity.
    fn begin_chunked(&mut self) -> Option<Self::Seq> {
        None
    }
    /// Consume up to `max_tokens` more prompt tokens into `seq` (`done`
    /// already consumed), returning the progress — with `first_token` set
    /// once the prompt completes.  Only called when
    /// [`StepBackend::begin_chunked`] returned `Some`; implementers
    /// override the two together (the default errors).
    fn prefill_chunk(&mut self, _seq: &mut Self::Seq, _prompt: &[u32], _done: usize,
                     _max_tokens: usize) -> Result<PrefillProgress> {
        anyhow::bail!("backend does not stream prefill chunks")
    }
    /// One admission tick's prefill chunks across every co-admitted
    /// prompt; returns one progress per item, index-aligned.  The default
    /// streams item by item through [`StepBackend::prefill_chunk`]
    /// (mock/test backends need nothing extra); engines with a batched
    /// fast path override it (`EngineBackend::prefill_chunk_batch` →
    /// `Engine::prefill_batch`).  Overrides MUST stay bit-identical to
    /// the per-item loop — the scheduler-level face of the concurrent
    /// chunked-prefill invariant (`rust/tests/concurrent_prefill.rs`).
    /// Only called for sequences that came from
    /// [`StepBackend::begin_chunked`].
    fn prefill_chunk_batch(&mut self, items: &mut [PrefillBatchItem<'_, Self::Seq>])
                           -> Vec<Result<PrefillProgress>> {
        items
            .iter_mut()
            .map(|it| self.prefill_chunk(it.seq, it.prompt, it.done, it.max_tokens))
            .collect()
    }
    /// Record one request's total prefill wall seconds — called exactly
    /// once per successfully admitted request, when its prefill completes
    /// (summed across chunks under budgeted admission).  Default: no-op;
    /// `EngineBackend` feeds the engine metrics registry
    /// (`admit.prefill_secs`).
    fn record_prefill_secs(&mut self, _secs: f64) {}
    /// One decode step; `now` is the per-sequence step counter.
    fn step(&mut self, seq: &mut Self::Seq, token: u32, now: u64) -> Result<u32>;
    /// One decode iteration across several sequences; returns one result
    /// per item, index-aligned.  The default decodes item by item;
    /// engines with a batched fast path override it
    /// (`EngineBackend::step_batch` → `Engine::decode_batch`), which is
    /// how the serving loop amortizes per-iteration dispatch across the
    /// whole batch.
    fn step_batch(&mut self, items: &mut [StepItem<'_, Self::Seq>]) -> Vec<Result<u32>> {
        items.iter_mut().map(|it| self.step(it.seq, it.token, it.now)).collect()
    }
    /// Park an active sequence under pool pressure so its pages free up;
    /// the scheduler re-admits the request later through
    /// [`StepBackend::resume`].  `mode` picks recompute (drop the KV,
    /// replay on resume) vs restore (swap the pages to a host-side buffer,
    /// [`crate::kvcache::SwapHandle`]).  Default: drop the sequence —
    /// recompute semantics, correct for any deterministic backend.
    fn preempt(&mut self, _id: RequestId, seq: Self::Seq, _mode: PreemptMode) -> Result<()> {
        self.finish(seq);
        Ok(())
    }
    /// Rebuild the sequence of a preempted request from its token history:
    /// `prompt`, then the `produced` tokens already applied as decode
    /// steps, in order.  The returned sequence must be bit-identical to
    /// the state right after the last applied step — the preempt/resume
    /// identity pinned by `rust/tests/preemption.rs`.  Default: recompute
    /// via [`StepBackend::begin`] plus replaying `produced` through
    /// [`StepBackend::step`] with the original step counters.
    fn resume(&mut self, _id: RequestId, prompt: &[u32], produced: &[u32])
              -> Result<Self::Seq> {
        let (mut seq, _first) = self.begin(prompt)?;
        for (i, &t) in produced.iter().enumerate() {
            self.step(&mut seq, t, (i + 1) as u64)?;
        }
        Ok(seq)
    }
    /// Bump a named robustness counter (`preempt.count`, `shed.count`, …).
    /// Default: no-op; `EngineBackend` forwards to the engine metrics
    /// registry so chaos harnesses can assert on them.
    fn record_counter(&mut self, _name: &'static str, _delta: u64) {}
    /// Release sequence resources.
    fn finish(&mut self, seq: Self::Seq);
    /// Whether `token` terminates its sequence.
    fn is_eos(&self, token: u32) -> bool;
    /// True when another sequence can be admitted (pool headroom).
    fn has_capacity(&self, active: usize) -> bool;
    /// Free pages in the backing KV pool, when the backend has one — a
    /// live placement signal the replica publishes for scored routing
    /// (DESIGN.md §6).  `None` (the default) means unknown/no pool.
    fn free_pages(&self) -> Option<usize> {
        None
    }
}

/// Admission/scheduling knobs of the continuous batcher (DESIGN.md §5).
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Hard cap on concurrently decoding sequences.
    pub max_batch: usize,
    /// Per-tick prefill token budget (Sarathi-style chunked admission):
    /// each tick consumes at most this many prompt tokens before the
    /// decode sweep, holding partially-prefilled sequences in an
    /// admission state between ticks, so a long prompt no longer stalls
    /// co-scheduled decoders.  `None` = legacy prefill-first whole-prompt
    /// admission.  Admission stays FIFO either way.
    pub prefill_token_budget: Option<usize>,
    /// Streaming-admission slots: how many prompts may prefill
    /// concurrently under budgeted admission, their per-tick chunks
    /// packed into ONE batched [`StepBackend::prefill_chunk_batch`] call
    /// (DESIGN.md §5).  1 (the default) reproduces the one-at-a-time
    /// PR-4 state machine; ignored unless `prefill_token_budget` is set.
    pub prefill_concurrency: usize,
    /// How preempted sequences park their KV (DESIGN.md §6): recompute
    /// (drop the pages, replay the token history on resume) or restore
    /// (swap the page bytes to a host-side buffer and copy them back).
    pub preempt_mode: PreemptMode,
    /// Shed new submissions ([`Outcome::Shed`]) once the FIFO queue is
    /// this deep.  `None` (the default) never sheds on depth.
    pub max_queue_depth: Option<usize>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            prefill_token_budget: None,
            prefill_concurrency: 1,
            preempt_mode: PreemptMode::Recompute,
            max_queue_depth: None,
        }
    }
}

struct Active<S> {
    req: Request,
    seq: S,
    token: u32,
    produced: Vec<u32>,
    step: u64,
    ttft_secs: f64,
}

/// A preempted request awaiting re-admission (DESIGN.md §5, the
/// `Preempted` state): its sequence is parked with the backend (restore
/// mode keeps a swap buffer; recompute mode dropped the KV), and the
/// batcher keeps the exact token history needed to rebuild bit-identical
/// decode state through [`StepBackend::resume`].
struct Parked {
    req: Request,
    /// The pending token — the last step's output, not yet applied.
    token: u32,
    /// Tokens already applied as decode steps, in order.
    produced: Vec<u32>,
    ttft_secs: f64,
}

/// A partially-prefilled sequence (budgeted admission): popped from the
/// FIFO queue into an admission slot, held between ticks while its prompt
/// streams in.
struct Prefilling<S> {
    req: Request,
    seq: S,
    /// Prompt tokens consumed so far.
    done: usize,
    /// Prefill wall seconds accumulated across chunks.
    prefill_secs: f64,
    /// Deficit-round-robin entitlement: budget tokens granted but not yet
    /// spent.  Carries across rounds and ticks, so a slot the budget ran
    /// out before reaching catches up instead of starving.
    deficit: usize,
}

/// Iteration-level scheduler over a [`StepBackend`].
pub struct Batcher<B: StepBackend> {
    /// The inference engine being scheduled (public for tests/benches).
    pub backend: B,
    cfg: BatcherConfig,
    active: Vec<Active<B::Seq>>,
    /// Sequences mid-prefill (budgeted admission only), in FIFO admission
    /// order — at most [`BatcherConfig::prefill_concurrency`] at a time.
    /// Completions activate in slot order, so equal-progress prompts keep
    /// the submission order; a shorter later prompt may legitimately
    /// finish before a longer front (chunked admission exists precisely
    /// to remove that head-of-line blocking).
    prefilling: Vec<Prefilling<B::Seq>>,
    /// FIFO admission queue.  `VecDeque`: admission pops the front every
    /// iteration, and a `Vec::remove(0)` here is O(n²) under queue
    /// pressure.
    queue: VecDeque<Request>,
    /// Preempted requests in preemption order; re-admitted FIFO *ahead*
    /// of the queue (they already waited once and their pages/history are
    /// warm), as soon as a slot and pool headroom open up.
    preempted: VecDeque<Parked>,
    /// Deficit-round-robin cursor: the admission-slot index the next
    /// remainder token goes to, rotating so `budget < slots` serves every
    /// slot over successive rounds rather than only the FIFO front.
    drr_next: usize,
    /// Serving clock for deadline expiry (sim in tests, wall in `main` —
    /// DESIGN.md §6).  Perf metrics (TTFT/JCT) stay on `Instant`.
    clock: SharedClock,
    /// Requests answered so far (done, failed, or shed).
    pub completed: u64,
    /// Sequences preempted so far (mirrors the `preempt.count` counter).
    pub preemptions: u64,
    /// Requests shed so far (mirrors the `shed.count` counter).
    pub sheds: u64,
}

impl<B: StepBackend> Batcher<B> {
    /// Scheduler over `backend` with the given admission config, on the
    /// process wall clock.
    pub fn new(backend: B, cfg: BatcherConfig) -> Self {
        Self::with_clock(backend, cfg, WallClock::shared())
    }

    /// Scheduler with an explicit serving clock (sim clocks make deadline
    /// tests deterministic; supervised replicas share the supervisor's).
    pub fn with_clock(backend: B, cfg: BatcherConfig, clock: SharedClock) -> Self {
        Batcher {
            backend,
            cfg,
            active: Vec::new(),
            prefilling: Vec::new(),
            queue: VecDeque::new(),
            preempted: VecDeque::new(),
            drr_next: 0,
            clock,
            completed: 0,
            preemptions: 0,
            sheds: 0,
        }
    }

    /// Enqueue a request (FIFO; admission happens on the next tick).
    /// Stamps the serving-clock arrival (first batcher wins, so the
    /// deadline budget survives re-dispatch) and sheds immediately when
    /// the queue is at [`BatcherConfig::max_queue_depth`].
    pub fn submit(&mut self, mut req: Request) {
        req.stamp_arrival(self.clock.now_ms());
        if let Some(depth) = self.cfg.max_queue_depth {
            if self.queue.len() >= depth {
                self.shed(req, format!("queue depth at cap {depth}"));
                return;
            }
        }
        self.queue.push_back(req);
    }

    /// Requests not yet answered: queued, preempted, mid-prefill, or
    /// decoding.
    pub fn pending(&self) -> usize {
        self.queue.len() + self.preempted.len() + self.prefilling.len() + self.active.len()
    }

    /// Depth of the FIFO admission queue (a scored-placement signal).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Prompts currently mid-prefill — the prefill-budget occupancy
    /// signal scored placement reads.
    pub fn prefilling_len(&self) -> usize {
        self.prefilling.len()
    }

    /// Tear the scheduler down after a replica-level failure: every
    /// request the batcher still owns — decoding, mid-prefill, preempted,
    /// or queued — comes back intact (in that order) so a supervisor can
    /// re-dispatch it to another replica.  Sequence resources are released
    /// best-effort behind a panic guard: after a caught replica panic the
    /// backend may be mid-tick-inconsistent, and recovering the requests
    /// matters more than this replica's pages (it is being torn down with
    /// its pool).
    pub fn drain_requests(&mut self) -> Vec<Request> {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let mut out = Vec::new();
        for a in std::mem::take(&mut self.active) {
            let backend = &mut self.backend;
            let _ = catch_unwind(AssertUnwindSafe(move || backend.finish(a.seq)));
            out.push(a.req);
        }
        for p in std::mem::take(&mut self.prefilling) {
            let backend = &mut self.backend;
            let _ = catch_unwind(AssertUnwindSafe(move || backend.finish(p.seq)));
            out.push(p.req);
        }
        for p in std::mem::take(&mut self.preempted) {
            out.push(p.req);
        }
        out.extend(std::mem::take(&mut self.queue));
        out
    }

    /// Refuse `req` with [`Outcome::Shed`] and account for it.
    fn shed(&mut self, req: Request, reason: String) {
        self.backend.record_counter("shed.count", 1);
        self.sheds += 1;
        let resp = Response::shed(req.id, req.submitted, reason);
        let _ = req.reply.send(resp);
        self.completed += 1;
    }

    /// Deadline gate at admission: sheds an expired request, passes a
    /// live one through.
    fn shed_if_expired(&mut self, req: Request) -> Option<Request> {
        if req.expired_at_ms(self.clock.now_ms()) {
            self.shed(req, "deadline expired before admission".to_string());
            None
        } else {
            Some(req)
        }
    }

    /// Sequences holding a batch slot: decoding or mid-prefill.
    fn in_flight(&self) -> usize {
        self.active.len() + self.prefilling.len()
    }

    fn slot_available(&self) -> bool {
        self.in_flight() < self.cfg.max_batch && self.backend.has_capacity(self.in_flight())
    }

    /// Admit queued requests (runs before the decode sweep each
    /// iteration): preempted sequences resume first (FIFO, ahead of the
    /// queue), then prefill-first whole prompts or budget-paced chunks
    /// when [`BatcherConfig::prefill_token_budget`] is set.
    fn admit(&mut self) {
        self.resume_preempted();
        match self.cfg.prefill_token_budget {
            None => self.admit_prefill_first(),
            // a zero budget would make no progress and livelock the
            // serving loop; clamp to one token per tick
            Some(b) => self.admit_budgeted(b.max(1)),
        }
    }

    /// Re-admit preempted sequences FIFO while slots and pool headroom
    /// allow.  A typed [`PoolExhausted`] resume failure parks the request
    /// again (front of the line) and stops — unless nothing else is
    /// running or queued, in which case no pages will ever free and the
    /// request is failed rather than livelocked.
    fn resume_preempted(&mut self) {
        while !self.preempted.is_empty() && self.slot_available() {
            let p = self.preempted.pop_front().expect("preempted non-empty");
            // the deadline may have passed while parked
            if p.req.expired_at_ms(self.clock.now_ms()) {
                self.shed(p.req, "deadline expired while preempted".to_string());
                continue;
            }
            match self.backend.resume(p.req.id, &p.req.prompt, &p.produced) {
                Ok(seq) => {
                    let step = p.produced.len() as u64;
                    self.active.push(Active {
                        req: p.req,
                        seq,
                        token: p.token,
                        produced: p.produced,
                        step,
                        ttft_secs: p.ttft_secs,
                    });
                }
                Err(e) => {
                    let exhausted = e.downcast_ref::<PoolExhausted>().is_some();
                    if exhausted && (self.in_flight() > 0 || !self.queue.is_empty()) {
                        self.preempted.push_front(p);
                        break;
                    }
                    let resp =
                        Response::err(p.req.id, p.req.submitted, format!("resume: {e:#}"));
                    let _ = p.req.reply.send(resp);
                    self.completed += 1;
                }
            }
        }
    }

    /// Legacy admission: whole prompts, while capacity allows.
    fn admit_prefill_first(&mut self) {
        while !self.queue.is_empty() && self.slot_available() {
            let req = self.queue.pop_front().expect("queue non-empty");
            let Some(req) = self.shed_if_expired(req) else { continue };
            self.begin_whole(req);
        }
    }

    /// Move a fully-prefilled sequence into the decode batch — the shared
    /// tail of whole-prompt and budgeted admission (metrics, TTFT stamp,
    /// batch slot).
    fn activate(&mut self, req: Request, seq: B::Seq, token: u32, prefill_secs: f64) {
        self.backend.record_prefill_secs(prefill_secs);
        let ttft = req.submitted.elapsed().as_secs_f64();
        self.active.push(Active {
            req,
            seq,
            token,
            produced: Vec::new(),
            step: 0,
            ttft_secs: ttft,
        });
    }

    /// Whole-prompt admission of one request; returns true when admitted.
    fn begin_whole(&mut self, req: Request) -> bool {
        let t0 = Instant::now();
        match self.backend.begin(&req.prompt) {
            Ok((seq, token)) => {
                self.activate(req, seq, token, t0.elapsed().as_secs_f64());
                true
            }
            Err(e) => {
                let resp = Response::err(req.id, req.submitted, format!("prefill: {e:#}"));
                let _ = req.reply.send(resp);
                false
            }
        }
    }

    /// Sarathi-style budgeted admission: spend at most `budget` prompt
    /// tokens this tick, across up to
    /// [`BatcherConfig::prefill_concurrency`] in-flight prompts.  Each
    /// round fills free admission slots from the queue front (FIFO), then
    /// packs every in-flight prompt's next chunk into ONE batched
    /// [`StepBackend::prefill_chunk_batch`] call ([`Batcher::prefill_round`]).
    /// Backends without streaming prefill (`begin_chunked` = `None`)
    /// admit whole prompts, each charged against the budget, so pacing
    /// survives the fallback.
    fn admit_budgeted(&mut self, budget: usize) {
        let concurrency = self.cfg.prefill_concurrency.max(1);
        let mut left = budget;
        loop {
            // fill free admission slots from the queue front
            while left > 0
                && self.prefilling.len() < concurrency
                && !self.queue.is_empty()
                && self.slot_available()
            {
                let req = self.queue.pop_front().expect("queue non-empty");
                let Some(req) = self.shed_if_expired(req) else { continue };
                match self.backend.begin_chunked() {
                    Some(seq) => self.prefilling.push(Prefilling {
                        req,
                        seq,
                        done: 0,
                        prefill_secs: 0.0,
                        deficit: 0,
                    }),
                    None => {
                        let cost = req.prompt.len().max(1);
                        self.begin_whole(req);
                        left = left.saturating_sub(cost);
                    }
                }
            }
            if left == 0 || self.prefilling.is_empty() {
                break;
            }
            left = self.prefill_round(left);
        }
    }

    /// One batched prefill round over the in-flight admission slots:
    /// split `budget` deficit-round-robin — every slot's deficit grows by
    /// `budget / slots`, the remainder is handed out one token at a time
    /// from the rotating [`Batcher::drr_next`] cursor, and shares are then
    /// drawn FIFO as `min(deficit, left)`.  Equal entitlement means
    /// concurrency 1 still degenerates to the PR-4 whole-budget front and
    /// equal-length co-admitted prompts still activate in submission
    /// order; unlike the old front-biased `ceil(left / slots_left)` split,
    /// a budget smaller than the slot count rotates over the tail instead
    /// of starving it behind the FIFO front.  Issues ONE batched chunk
    /// call, applies per-prompt progress (consumed tokens repay deficit),
    /// activates completions in slot order and reports failures.  Returns
    /// the budget left — always strictly less than `budget` when any
    /// prompt participated (each drains at least one token), so the
    /// admission loop cannot livelock.
    fn prefill_round(&mut self, budget: usize) -> usize {
        let n = self.prefilling.len();
        let base = budget / n;
        let rem = budget % n;
        let start = self.drr_next % n;
        for (i, p) in self.prefilling.iter_mut().enumerate() {
            // slots start, start+1, … start+rem-1 (mod n) get one extra
            let extra = usize::from((i + n - start) % n < rem);
            p.deficit += base + extra;
        }
        self.drr_next = (start + rem) % n;
        let mut shares = Vec::with_capacity(n);
        {
            let mut left = budget;
            for p in &self.prefilling {
                let share = p.deficit.min(left);
                shares.push(share);
                left -= share;
            }
        }
        let mut idxs: Vec<usize> = Vec::with_capacity(n);
        let mut items: Vec<PrefillBatchItem<'_, B::Seq>> = Vec::with_capacity(n);
        for (i, p) in self.prefilling.iter_mut().enumerate() {
            if shares[i] == 0 {
                continue; // budget < live prompts: the tail waits its turn
            }
            idxs.push(i);
            items.push(PrefillBatchItem {
                seq: &mut p.seq,
                prompt: &p.req.prompt,
                done: p.done,
                max_tokens: shares[i],
            });
        }
        let t0 = Instant::now();
        let mut results = self.backend.prefill_chunk_batch(&mut items);
        let call_secs = t0.elapsed().as_secs_f64();
        drop(items);
        // Hard contract, like step_batch: a misbehaving backend returning
        // the wrong result count must not panic the replica thread or
        // stall prompts mid-prefill forever.
        let got = results.len();
        if got != idxs.len() {
            results.truncate(idxs.len());
            while results.len() < idxs.len() {
                results.push(Err(anyhow::anyhow!(
                    "prefill_chunk_batch returned {got} results for {} prompts",
                    idxs.len()
                )));
            }
        }
        enum RoundOutcome {
            Pending,
            Done(u32),
            Failed(String),
        }
        let mut outcomes: Vec<RoundOutcome> = (0..n).map(|_| RoundOutcome::Pending).collect();
        // time attribution weights by COMPUTED tokens: cached prefix
        // tokens attach without backend work, so they carry no wall time
        let consumed_total: usize = results
            .iter()
            .filter_map(|r| r.as_ref().ok().map(|p| p.consumed.saturating_sub(p.cached)))
            .sum();
        let mut spent = 0usize;
        for (&i, r) in idxs.iter().zip(results.into_iter()) {
            match r {
                Ok(prog) => {
                    let p = &mut self.prefilling[i];
                    p.done += prog.consumed;
                    // the batched call's wall time is attributed
                    // proportionally to tokens consumed (prefill cost is
                    // ~linear in tokens), keeping the per-request
                    // `admit.prefill_secs` semantics of PR 4
                    let computed = prog.consumed.saturating_sub(prog.cached);
                    p.prefill_secs += if consumed_total > 0 {
                        call_secs * computed as f64 / consumed_total as f64
                    } else {
                        call_secs / idxs.len().max(1) as f64
                    };
                    // only computed tokens drain the budget — cached prefix
                    // tokens are credited back (prefix-aware admission); a
                    // zero-compute chunk still drains one token, or a
                    // misbehaving backend livelocks the tick
                    spent += computed.max(1);
                    // consumed tokens repay the DRR entitlement too
                    p.deficit = p.deficit.saturating_sub(computed.max(1));
                    if let Some(first) = prog.first_token {
                        outcomes[i] = RoundOutcome::Done(first);
                    }
                }
                Err(e) => outcomes[i] = RoundOutcome::Failed(format!("prefill: {e:#}")),
            }
        }
        // apply front to back so completions activate in FIFO slot order
        // and survivors keep their order
        let old = std::mem::take(&mut self.prefilling);
        for (p, oc) in old.into_iter().zip(outcomes) {
            match oc {
                RoundOutcome::Pending => self.prefilling.push(p),
                RoundOutcome::Done(first) => self.activate(p.req, p.seq, first, p.prefill_secs),
                RoundOutcome::Failed(msg) => {
                    let resp = Response::err(p.req.id, p.req.submitted, msg);
                    self.backend.finish(p.seq);
                    let _ = p.req.reply.send(resp);
                }
            }
        }
        budget.saturating_sub(spent)
    }

    /// One scheduler iteration: admit, retire finished sequences, then ONE
    /// batched decode call across every remaining active sequence
    /// ([`StepBackend::step_batch`] — the engine amortizes per-iteration
    /// dispatch across the batch).  Returns the number of decode steps
    /// taken.
    pub fn tick(&mut self) -> usize {
        self.admit();
        // deliver the tokens produced last iteration; retire sequences
        // that hit EOS or their length cap so they free their batch slot
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            a.produced.push(a.token);
            let done_eos = self.backend.is_eos(a.token);
            let done_len = a.produced.len() >= a.req.max_new;
            if done_eos || done_len {
                let a = self.active.remove(i);
                let resp = Response {
                    id: a.req.id,
                    tokens: a.produced,
                    jct_secs: a.req.submitted.elapsed().as_secs_f64(),
                    ttft_secs: a.ttft_secs,
                    outcome: Outcome::Done,
                    error: None,
                };
                self.backend.finish(a.seq);
                let _ = a.req.reply.send(resp);
                self.completed += 1;
                continue; // i now points at the next sequence
            }
            a.step += 1;
            i += 1;
        }
        if self.active.is_empty() {
            return 0;
        }
        // one batched iteration over the survivors
        let mut items: Vec<StepItem<'_, B::Seq>> = self
            .active
            .iter_mut()
            .map(|a| StepItem { seq: &mut a.seq, token: a.token, now: a.step })
            .collect();
        let mut results = self.backend.step_batch(&mut items);
        drop(items);
        // Hard contract, not a debug_assert: a misbehaving backend must not
        // panic the replica thread (extra results) or stall sequences on a
        // stale token forever (missing results).
        let got = results.len();
        if got != self.active.len() {
            results.truncate(self.active.len());
            while results.len() < self.active.len() {
                results.push(Err(anyhow::anyhow!(
                    "step_batch returned {got} results for {} sequences",
                    self.active.len()
                )));
            }
        }
        let mut steps = 0;
        let mut stalled: Vec<RequestId> = Vec::new();
        // apply back-to-front so error removals keep earlier indices valid
        for (idx, r) in results.into_iter().enumerate().rev() {
            match r {
                Ok(next) => {
                    self.active[idx].token = next;
                    steps += 1;
                }
                // Pool pressure with a co-scheduled victim available: the
                // step failed *before* mutating the sequence (the engine's
                // pre-mutation exhaustion guard), so rewind this tick's
                // bookkeeping and retry after a preemption frees pages.
                Err(e)
                    if e.downcast_ref::<PoolExhausted>().is_some()
                        && self.active.len() > 1 =>
                {
                    let a = &mut self.active[idx];
                    let t = a.produced.pop().expect("token was pushed this tick");
                    debug_assert_eq!(t, a.token, "rewound token must be the pending one");
                    a.step = a.produced.len() as u64;
                    stalled.push(a.req.id);
                }
                Err(e) => {
                    let a = self.active.remove(idx);
                    let resp =
                        Response::err(a.req.id, a.req.submitted, format!("decode: {e:#}"));
                    self.backend.finish(a.seq);
                    let _ = a.req.reply.send(resp);
                    self.completed += 1;
                }
            }
        }
        if !stalled.is_empty() {
            self.preempt_one(&stalled);
        }
        steps
    }

    /// Preempt one victim so a pool-stalled sequence can progress next
    /// tick: the active sequence with the fewest produced tokens (least
    /// recompute/restore cost lost; ties break to the youngest slot),
    /// never the oldest stalled sequence itself — the one whose progress
    /// this preemption guarantees.  One victim per tick is enough;
    /// repeated pressure preempts again on the next tick.
    fn preempt_one(&mut self, stalled_ids: &[RequestId]) {
        let oldest = self
            .active
            .iter()
            .position(|a| stalled_ids.contains(&a.req.id))
            .expect("a stalled id is active");
        let victim = self
            .active
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != oldest)
            .min_by(|(ia, a), (ib, b)| {
                a.produced.len().cmp(&b.produced.len()).then(ib.cmp(ia))
            })
            .map(|(i, _)| i);
        let Some(vi) = victim else { return };
        let a = self.active.remove(vi);
        match self.backend.preempt(a.req.id, a.seq, self.cfg.preempt_mode) {
            Ok(()) => {
                self.backend.record_counter("preempt.count", 1);
                self.preemptions += 1;
                self.preempted.push_back(Parked {
                    req: a.req,
                    token: a.token,
                    produced: a.produced,
                    ttft_secs: a.ttft_secs,
                });
            }
            Err(e) => {
                // parking failed — the sequence state is gone; fail the
                // request rather than resume from corrupt history
                let resp =
                    Response::err(a.req.id, a.req.submitted, format!("preempt: {e:#}"));
                let _ = a.req.reply.send(resp);
                self.completed += 1;
            }
        }
    }

    /// Drive until all submitted work completes.
    pub fn run_to_completion(&mut self) {
        while self.pending() > 0 {
            self.tick();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    use crate::runtime::{FaultOp, FaultSchedule, StepFaultInjector};

    /// Scripted backend: echoes prompt[0], counts down, then EOS (token 0).
    struct MockBackend {
        capacity: usize,
        begun: usize,
        finished: usize,
    }

    impl StepBackend for MockBackend {
        type Seq = u32; // remaining tokens before EOS
        fn begin(&mut self, prompt: &[u32]) -> Result<(u32, u32)> {
            self.begun += 1;
            if prompt.is_empty() {
                anyhow::bail!("empty prompt");
            }
            Ok((prompt[0], 100 + prompt[0]))
        }
        fn step(&mut self, seq: &mut u32, _token: u32, _now: u64) -> Result<u32> {
            if *seq == 0 {
                return Ok(0);
            }
            *seq -= 1;
            Ok(if *seq == 0 { 0 } else { 100 + *seq })
        }
        fn finish(&mut self, _seq: u32) {
            self.finished += 1;
        }
        fn is_eos(&self, token: u32) -> bool {
            token == 0
        }
        fn has_capacity(&self, active: usize) -> bool {
            active < self.capacity
        }
    }

    fn mk_req(id: u64, first: u32, max_new: usize, tx: &std::sync::mpsc::Sender<Response>)
              -> Request {
        Request::new(id, vec![first], max_new, tx.clone())
    }

    #[test]
    fn conservation_no_lost_or_duplicated_requests() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 3, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 3, ..Default::default() },
        );
        for id in 0..10 {
            b.submit(mk_req(id, (id % 4) as u32 + 1, 64, &tx));
        }
        b.run_to_completion();
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        assert_eq!(b.backend.begun, 10);
        assert_eq!(b.backend.finished, 10, "all sequences released");
        assert_eq!(b.completed, 10);
    }

    #[test]
    fn respects_max_new() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 50, 5, &tx)); // would emit 50 tokens, capped at 5
        b.run_to_completion();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.tokens.len(), 5);
        assert!(resp.error.is_none());
    }

    #[test]
    fn eos_terminates_early() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 2, 64, &tx)); // 2 countdown steps then EOS
        b.run_to_completion();
        let resp = rx.recv().unwrap();
        assert_eq!(*resp.tokens.last().unwrap(), 0);
        assert!(resp.tokens.len() < 64);
    }

    #[test]
    fn admission_respects_capacity() {
        let (tx, _rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 2, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 8, ..Default::default() },
        );
        for id in 0..5 {
            b.submit(mk_req(id, 30, 64, &tx));
        }
        b.tick();
        assert_eq!(b.backend.begun, 2, "only 2 admitted");
        assert_eq!(b.pending(), 5);
    }

    /// Records admission order; every sequence decodes one token then EOS,
    /// so slots churn and admission happens in many partial waves.
    struct OrderBackend {
        order: Vec<u64>,
        capacity: usize,
    }

    impl StepBackend for OrderBackend {
        type Seq = ();
        fn begin(&mut self, prompt: &[u32]) -> Result<((), u32)> {
            self.order.push(prompt[0] as u64);
            Ok(((), 1))
        }
        fn step(&mut self, _seq: &mut (), _token: u32, _now: u64) -> Result<u32> {
            Ok(0)
        }
        fn finish(&mut self, _seq: ()) {}
        fn is_eos(&self, token: u32) -> bool {
            token == 0
        }
        fn has_capacity(&self, active: usize) -> bool {
            active < self.capacity
        }
    }

    #[test]
    fn admission_is_fifo_under_repeated_partial_admission() {
        // 9 requests through 2 slots: ~5 admission waves, each popping the
        // queue front.  The begin order must equal the submission order
        // (the VecDeque queue preserves FIFO; a priority or LIFO regression
        // would reorder here).
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            OrderBackend { order: Vec::new(), capacity: 2 },
            BatcherConfig { max_batch: 8, ..Default::default() },
        );
        for id in 0..9u64 {
            b.submit(mk_req(id, id as u32, 64, &tx));
        }
        b.run_to_completion();
        drop(tx);
        assert_eq!(b.backend.order, (0..9).collect::<Vec<u64>>(), "admission must be FIFO");
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn prefill_error_is_reported_not_fatal() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(Request::new(1, vec![], 4, tx.clone()));
        b.submit(mk_req(2, 1, 8, &tx));
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert!(resps[0].error.is_some());
        assert!(resps[1].error.is_none());
    }

    // -- chunked (prefill-token-budgeted) admission -----------------------

    #[derive(Debug, Clone, PartialEq, Eq)]
    enum Ev {
        /// (request tag, tokens consumed) — one streamed prefill chunk.
        Chunk(u64, usize),
        /// One batched prefill call covering this many co-admitted prompts.
        Batch(usize),
        /// Request tag activated (prefill complete, joins the decode batch).
        Activate(u64),
        /// Request tag took one decode step.
        Step(u64),
    }

    /// Scripted streaming backend: logs the interleaving of prefill chunks
    /// and decode steps; never emits EOS (max_new caps every sequence).
    struct ChunkedMock {
        events: Vec<Ev>,
        capacity: usize,
        finished: usize,
        /// `(tag, tokens)` — this tag's first chunk reports that many
        /// prompt tokens as prefix-cache hits (consumed for free).
        cached_prefix_of: Option<(u64, usize)>,
    }

    impl ChunkedMock {
        fn new(capacity: usize) -> Self {
            ChunkedMock { events: Vec::new(), capacity, finished: 0, cached_prefix_of: None }
        }
    }

    impl StepBackend for ChunkedMock {
        /// (request tag = prompt[0], prompt tokens consumed)
        type Seq = (u64, usize);
        fn begin(&mut self, prompt: &[u32]) -> Result<((u64, usize), u32)> {
            let id = prompt[0] as u64;
            self.events.push(Ev::Chunk(id, prompt.len()));
            self.events.push(Ev::Activate(id));
            Ok(((id, prompt.len()), 1))
        }
        fn begin_chunked(&mut self) -> Option<(u64, usize)> {
            Some((u64::MAX, 0))
        }
        fn prefill_chunk(&mut self, seq: &mut (u64, usize), prompt: &[u32], done: usize,
                         max_tokens: usize) -> Result<PrefillProgress> {
            let id = prompt[0] as u64;
            if seq.0 == u64::MAX {
                seq.0 = id;
            }
            // a scripted prefix-cache hit attaches free tokens on the
            // first chunk, like the engine's attach-then-compute path
            let cached = match self.cached_prefix_of {
                Some((tag, c)) if tag == id && done == 0 => {
                    c.min(prompt.len().saturating_sub(1))
                }
                _ => 0,
            };
            let take = (cached + max_tokens).min(prompt.len() - done);
            seq.1 = done + take;
            self.events.push(Ev::Chunk(id, take));
            let first_token = if seq.1 == prompt.len() {
                self.events.push(Ev::Activate(id));
                Some(1)
            } else {
                None
            };
            Ok(PrefillProgress { consumed: take, cached, first_token })
        }
        fn prefill_chunk_batch(&mut self, items: &mut [PrefillBatchItem<'_, (u64, usize)>])
                               -> Vec<Result<PrefillProgress>> {
            // log the batch width, then stream per item like the default
            self.events.push(Ev::Batch(items.len()));
            items
                .iter_mut()
                .map(|it| self.prefill_chunk(it.seq, it.prompt, it.done, it.max_tokens))
                .collect()
        }
        fn step(&mut self, seq: &mut (u64, usize), _token: u32, _now: u64) -> Result<u32> {
            self.events.push(Ev::Step(seq.0));
            Ok(1)
        }
        fn finish(&mut self, _seq: (u64, usize)) {
            self.finished += 1;
        }
        fn is_eos(&self, _token: u32) -> bool {
            false
        }
        fn has_capacity(&self, active: usize) -> bool {
            active < self.capacity
        }
    }

    fn mk_long_req(id: u64, prompt_len: usize, max_new: usize,
                   tx: &std::sync::mpsc::Sender<Response>) -> Request {
        Request::new(id, vec![id as u32; prompt_len.max(1)], max_new, tx.clone())
    }

    #[test]
    fn decoder_progresses_while_long_prompt_admits_chunked() {
        // A 40-token prompt under a 4-token/tick budget takes ~10 ticks to
        // admit; the co-scheduled decoder must take a decode step on every
        // one of those ticks instead of stalling behind the prefill — the
        // head-of-line-blocking fix the budget exists for.
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            ChunkedMock::new(8),
            BatcherConfig { max_batch: 8, prefill_token_budget: Some(4), ..Default::default() },
        );
        b.submit(mk_long_req(1, 1, 30, &tx)); // decoder: activates tick 1
        b.submit(mk_long_req(2, 40, 2, &tx)); // long prompt: ~10 ticks
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert!(resps.iter().all(|r| r.error.is_none()));

        let ev = &b.backend.events;
        let first_chunk = ev.iter().position(|e| matches!(e, Ev::Chunk(2, _))).unwrap();
        let activated = ev.iter().position(|e| *e == Ev::Activate(2)).unwrap();
        assert!(activated > first_chunk + 8, "long prompt admitted in too few chunks");
        let steps_between = ev[first_chunk..activated]
            .iter()
            .filter(|e| **e == Ev::Step(1))
            .count();
        assert!(
            steps_between >= 8,
            "decoder stalled during chunked admission: {steps_between} steps interleaved"
        );
        // chunk sizes respect the budget
        for e in ev {
            if let Ev::Chunk(_, n) = e {
                assert!(*n <= 4, "chunk of {n} tokens exceeded the 4-token budget");
            }
        }
    }

    #[test]
    fn chunked_admission_stays_fifo_under_partial_admission() {
        // 7 multi-chunk prompts through 2 slots: activation order must equal
        // submission order even though every prompt needs several ticks and
        // slots churn continuously.
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            ChunkedMock::new(2),
            BatcherConfig { max_batch: 2, prefill_token_budget: Some(5), ..Default::default() },
        );
        for id in 0..7u64 {
            b.submit(mk_long_req(id, 12, 2, &tx));
        }
        b.run_to_completion();
        drop(tx);
        let activations: Vec<u64> = b
            .backend
            .events
            .iter()
            .filter_map(|e| match e {
                Ev::Activate(id) => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(activations, (0..7).collect::<Vec<u64>>(), "activation must stay FIFO");
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..7).collect::<Vec<_>>());
        assert_eq!(b.backend.finished, 7, "all sequences released");
    }

    #[test]
    fn budgeted_admission_falls_back_to_whole_prompts() {
        // A backend without streaming prefill (`begin_chunked` = None) still
        // serves correctly under a token budget: whole-prompt admissions,
        // each charged against the tick budget.
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 8, prefill_token_budget: Some(2), ..Default::default() },
        );
        for id in 0..6 {
            b.submit(mk_req(id, (id % 3) as u32 + 1, 16, &tx));
        }
        // one tick admits at most 2 whole one-token prompts
        b.tick();
        assert_eq!(b.backend.begun, 2, "budget must pace whole-prompt admissions");
        b.run_to_completion();
        drop(tx);
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        assert_eq!(b.backend.finished, 6);
    }

    #[test]
    fn cached_prefix_tokens_are_not_charged_against_the_budget() {
        // Prompt 1 is 20 tokens with a 16-token prefix-cache hit: its
        // first chunk consumes all 20 but only 4 were computed, so an
        // 8-token tick budget has 4 left — enough to also admit and
        // complete prompt 2 (4 tokens) in the SAME tick.  Without the
        // cached-token credit, prompt 1 alone would drain the budget and
        // prompt 2 would wait a tick (a Step would land between the two
        // activations).
        let (tx, rx) = channel();
        let mut backend = ChunkedMock::new(8);
        backend.cached_prefix_of = Some((1, 16));
        let mut b = Batcher::new(
            backend,
            BatcherConfig { max_batch: 8, prefill_token_budget: Some(8), ..Default::default() },
        );
        b.submit(mk_long_req(1, 20, 2, &tx));
        b.submit(mk_long_req(2, 4, 2, &tx));
        b.run_to_completion();
        drop(tx);
        assert_eq!(rx.iter().filter(|r| r.error.is_none()).count(), 2);
        let ev = &b.backend.events;
        let act2 = ev.iter().position(|e| *e == Ev::Activate(2)).unwrap();
        assert!(
            ev[..act2].iter().all(|e| !matches!(e, Ev::Step(_))),
            "prompt 2 must activate in the same tick as the warm prompt 1: {ev:?}"
        );
    }

    // -- concurrent (multi-slot) chunked admission ------------------------

    #[test]
    fn concurrent_prefill_packs_chunks_into_one_batched_call() {
        // Two co-admitted 12-token prompts under a 6-token budget and 2
        // admission slots: every round issues ONE batched call covering
        // both prompts (width-2 Batch events), both progress every tick
        // (front-biased shares 3/3), and activation stays FIFO.
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            ChunkedMock::new(8),
            BatcherConfig {
                max_batch: 8,
                prefill_token_budget: Some(6),
                prefill_concurrency: 2,
            },
        );
        b.submit(mk_long_req(1, 12, 2, &tx));
        b.submit(mk_long_req(2, 12, 2, &tx));
        b.run_to_completion();
        drop(tx);
        assert_eq!(rx.iter().filter(|r| r.error.is_none()).count(), 2);

        let ev = &b.backend.events;
        let widths: Vec<usize> = ev
            .iter()
            .filter_map(|e| match e {
                Ev::Batch(n) => Some(*n),
                _ => None,
            })
            .collect();
        assert_eq!(widths, vec![2, 2, 2, 2], "both prompts pack into every round");
        // front-biased even split: 3 tokens each per round
        for e in ev {
            if let Ev::Chunk(_, n) = e {
                assert_eq!(*n, 3, "6-token budget splits 3/3 across 2 prompts");
            }
        }
        let activations: Vec<u64> = ev
            .iter()
            .filter_map(|e| match e {
                Ev::Activate(id) => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(activations, vec![1, 2], "equal-length prompts activate FIFO");
    }

    #[test]
    fn concurrent_prefill_removes_prefill_head_of_line_blocking() {
        // A 40-token prompt ahead of a 4-token prompt: with one admission
        // slot the short prompt waits ~10 ticks behind the long one; with
        // two slots it co-prefills and activates long before — the
        // head-of-line-blocking fix concurrency exists for.
        let order_with = |concurrency: usize| -> Vec<u64> {
            let (tx, _rx) = channel();
            let mut b = Batcher::new(
                ChunkedMock::new(8),
                BatcherConfig {
                    max_batch: 8,
                    prefill_token_budget: Some(4),
                    prefill_concurrency: concurrency,
                },
            );
            b.submit(mk_long_req(1, 40, 1, &tx));
            b.submit(mk_long_req(2, 4, 1, &tx));
            b.run_to_completion();
            b.backend
                .events
                .iter()
                .filter_map(|e| match e {
                    Ev::Activate(id) => Some(*id),
                    _ => None,
                })
                .collect()
        };
        assert_eq!(order_with(1), vec![1, 2], "one slot: short prompt blocked");
        assert_eq!(order_with(2), vec![2, 1], "two slots: short prompt overtakes");
    }

    #[test]
    fn concurrent_prefill_preserves_fifo_for_equal_prompts() {
        // 6 equal 10-token prompts through 3 admission slots: activation
        // order must equal submission order (front-biased shares mean the
        // front never falls behind a later slot), and every request is
        // answered and released.
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            ChunkedMock::new(8),
            BatcherConfig {
                max_batch: 8,
                prefill_token_budget: Some(6),
                prefill_concurrency: 3,
            },
        );
        for id in 0..6u64 {
            b.submit(mk_long_req(id, 10, 2, &tx));
        }
        b.run_to_completion();
        drop(tx);
        let activations: Vec<u64> = b
            .backend
            .events
            .iter()
            .filter_map(|e| match e {
                Ev::Activate(id) => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(activations, (0..6).collect::<Vec<u64>>(), "activation must stay FIFO");
        let mut ids: Vec<u64> = rx.iter().map(|r| r.id).collect();
        ids.sort();
        assert_eq!(ids, (0..6).collect::<Vec<_>>());
        assert_eq!(b.backend.finished, 6, "all sequences released");
    }

    #[test]
    fn drr_shares_rotate_over_slots_when_budget_is_smaller_than_slot_count() {
        // 4 co-admitted 6-token prompts under a 2-token/tick budget: the
        // old front-biased split (`ceil(left / slots_left)`) gave tokens
        // to slots 0 and 1 every tick and starved slots 2 and 3 until the
        // front pair finished.  Deficit round-robin hands the remainder
        // out from a rotating cursor, so every prompt must receive a
        // chunk within the first two ticks.
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            ChunkedMock::new(8),
            BatcherConfig {
                max_batch: 8,
                prefill_token_budget: Some(2),
                prefill_concurrency: 4,
            },
        );
        for id in 0..4u64 {
            b.submit(mk_long_req(id, 6, 1, &tx));
        }
        b.tick();
        b.tick();
        let served: std::collections::BTreeSet<u64> = b
            .backend
            .events
            .iter()
            .filter_map(|e| match e {
                Ev::Chunk(id, _) => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(
            served,
            (0..4u64).collect::<std::collections::BTreeSet<u64>>(),
            "2 ticks × 2-token budget must touch all 4 slots, not just the front"
        );
        // chunks never exceed the per-tick budget
        for e in &b.backend.events {
            if let Ev::Chunk(_, n) = e {
                assert!(*n <= 2, "chunk of {n} tokens exceeded the 2-token budget");
            }
        }
        b.run_to_completion();
        drop(tx);
        assert_eq!(rx.iter().filter(|r| r.error.is_none()).count(), 4);
        // equal entitlement keeps equal-length prompts activating FIFO
        let activations: Vec<u64> = b
            .backend
            .events
            .iter()
            .filter_map(|e| match e {
                Ev::Activate(id) => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(activations, (0..4).collect::<Vec<u64>>());
        assert_eq!(b.backend.finished, 4, "all sequences released");
    }

    #[test]
    fn concurrent_prefill_error_is_isolated_to_the_failing_prompt() {
        // Two co-prefilling prompts, one of which errors on its second
        // chunk (scheduled through the fault injector, keyed by the
        // prompt tag): the failure must be reported for that request
        // only, its sequence released, and its neighbor must keep
        // streaming to completion.
        let (tx, rx) = channel();
        let schedule = FaultSchedule::new(0).fail_nth_for(FaultOp::Chunk, 3, 2);
        let mut b = Batcher::new(
            StepFaultInjector::new(ChunkedMock::new(8), schedule),
            BatcherConfig {
                max_batch: 8,
                prefill_token_budget: Some(8),
                prefill_concurrency: 2,
                ..Default::default()
            },
        );
        b.submit(mk_long_req(3, 12, 2, &tx)); // fails on its second chunk
        b.submit(mk_long_req(4, 12, 2, &tx));
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert_eq!(resps[0].outcome, Outcome::Failed);
        assert!(resps[0].error.as_deref().unwrap_or("").contains("prefill"));
        assert!(resps[1].error.is_none());
        assert_eq!(b.backend.schedule.injected(), 1);
        assert_eq!(b.backend.inner.finished, 2, "failed partial + finished neighbor released");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn chunked_prefill_error_releases_the_sequence() {
        let (tx, rx) = channel();
        let schedule = FaultSchedule::new(0).fail_nth_for(FaultOp::Chunk, 3, 2);
        let mut b = Batcher::new(
            StepFaultInjector::new(ChunkedMock::new(8), schedule),
            BatcherConfig { max_batch: 8, prefill_token_budget: Some(4), ..Default::default() },
        );
        b.submit(mk_long_req(3, 12, 4, &tx)); // fails on its second chunk
        b.submit(mk_long_req(4, 3, 2, &tx));
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps.len(), 2);
        assert!(resps[0].error.as_deref().unwrap_or("").contains("prefill"));
        assert!(resps[1].error.is_none());
        // the failed partial sequence AND the finished one were released
        assert_eq!(b.backend.inner.finished, 2);
        assert_eq!(b.pending(), 0);
    }

    // -- preemption, deadlines, shedding ----------------------------------

    #[test]
    fn pool_pressure_preempts_a_victim_and_resumes_bit_identically() {
        // Two decoding sequences; the third alloc draw (request 1's step
        // on tick 2) injects a typed PoolExhausted.  The batcher must
        // preempt the co-scheduled victim (request 2, fewest produced),
        // retry the stalled step, resume the victim FIFO, and the final
        // token streams must equal an uninterrupted control run's.
        let run = |faults: bool| -> (Vec<Response>, u64, usize) {
            let (tx, rx) = channel();
            let schedule = if faults {
                FaultSchedule::new(0).fail_nth(FaultOp::Alloc, 3)
            } else {
                FaultSchedule::new(0)
            };
            let inner = MockBackend { capacity: 8, begun: 0, finished: 0 };
            let mut b = Batcher::new(
                StepFaultInjector::new(inner, schedule),
                BatcherConfig { max_batch: 8, ..Default::default() },
            );
            b.submit(mk_req(1, 6, 16, &tx));
            b.submit(mk_req(2, 5, 16, &tx));
            b.run_to_completion();
            drop(tx);
            let mut resps: Vec<Response> = rx.iter().collect();
            resps.sort_by_key(|r| r.id);
            (resps, b.preemptions, b.backend.inner.finished)
        };
        let (control, p0, _) = run(false);
        let (chaos, p1, finished) = run(true);
        assert_eq!(p0, 0);
        assert_eq!(p1, 1, "the alloc fault must trigger exactly one preemption");
        // releases: the preempted sequence at park time, then both
        // sequences (one rebuilt by resume) at retirement
        assert_eq!(finished, 3);
        for (c, f) in control.iter().zip(&chaos) {
            assert_eq!(c.id, f.id);
            assert_eq!(f.outcome, Outcome::Done, "preemption must be invisible: {:?}", f.error);
            assert_eq!(c.tokens, f.tokens, "request {} tokens diverged after preemption", c.id);
        }
    }

    #[test]
    fn preempted_requests_readmit_ahead_of_the_queue() {
        // A and B decode (max_batch 2), C waits queued.  When B is
        // preempted under injected pool pressure, the freed slot must go
        // back to B (FIFO ahead of the queue), not to C.
        let (tx, rx) = channel();
        let schedule = FaultSchedule::new(0).fail_nth(FaultOp::Alloc, 3);
        let mut b = Batcher::new(
            StepFaultInjector::new(ChunkedMock::new(8), schedule),
            BatcherConfig { max_batch: 2, ..Default::default() },
        );
        b.submit(mk_long_req(1, 1, 6, &tx));
        b.submit(mk_long_req(2, 1, 6, &tx));
        b.submit(mk_long_req(3, 1, 2, &tx));
        b.run_to_completion();
        drop(tx);
        assert_eq!(b.preemptions, 1);
        assert_eq!(rx.iter().filter(|r| r.outcome == Outcome::Done).count(), 3);
        let activations: Vec<u64> = b
            .backend
            .inner
            .events
            .iter()
            .filter_map(|e| match e {
                Ev::Activate(id) => Some(*id),
                _ => None,
            })
            .collect();
        assert_eq!(
            activations,
            vec![1, 2, 2, 3],
            "the preempted request must resume before the queued one admits"
        );
    }

    #[test]
    fn expired_requests_are_shed_at_admission() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 8, begun: 0, finished: 0 },
            BatcherConfig::default(),
        );
        b.submit(mk_req(1, 3, 8, &tx).with_deadline_ms(0)); // expired on arrival
        b.submit(mk_req(2, 3, 8, &tx));
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].outcome, Outcome::Shed);
        assert!(resps[0].error.as_deref().unwrap_or("").contains("deadline"));
        assert!(resps[0].tokens.is_empty());
        assert_eq!(resps[1].outcome, Outcome::Done);
        assert_eq!(b.sheds, 1);
        assert_eq!(b.backend.begun, 1, "shed requests never reach the backend");
    }

    #[test]
    fn queue_depth_cap_sheds_excess_submissions() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 1, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 1, max_queue_depth: Some(2), ..Default::default() },
        );
        for id in 0..5 {
            b.submit(mk_req(id, 3, 4, &tx));
        }
        assert_eq!(b.sheds, 3, "queue holds 2, the rest shed at submit");
        b.run_to_completion();
        drop(tx);
        let resps: Vec<Response> = rx.iter().collect();
        assert_eq!(resps.len(), 5, "every request gets exactly one response");
        assert_eq!(resps.iter().filter(|r| r.outcome == Outcome::Shed).count(), 3);
        assert_eq!(resps.iter().filter(|r| r.outcome == Outcome::Done).count(), 2);
    }

    #[test]
    fn deadline_expiry_follows_the_injected_clock_not_real_time() {
        // PR 8's deadline tests could only express "expired immediately"
        // (deadline 0) without sleeping; with the injectable clock the
        // budget elapses exactly when the test says so.
        let sim = crate::util::clock::SimClock::new();
        let (tx, rx) = channel();
        let mut b = Batcher::with_clock(
            MockBackend { capacity: 1, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 1, ..Default::default() },
            sim.clone(),
        );
        b.submit(mk_req(1, 30, 40, &tx).with_deadline_ms(50)); // will hold the slot
        b.submit(mk_req(2, 3, 8, &tx).with_deadline_ms(50)); // waits in queue
        b.tick(); // admits 1 only (capacity 1); 2 still queued, clock at 0
        assert_eq!(b.backend.begun, 1);
        sim.advance(60); // past request 2's budget while it queues
        b.run_to_completion();
        drop(tx);
        let mut resps: Vec<Response> = rx.iter().collect();
        resps.sort_by_key(|r| r.id);
        assert_eq!(resps[0].outcome, Outcome::Done, "admitted before expiry, runs to done");
        assert_eq!(resps[1].outcome, Outcome::Shed, "expired on the sim clock while queued");
        assert_eq!(b.backend.begun, 1, "the expired request never reached the backend");
    }

    #[test]
    fn drain_requests_returns_every_owned_request_in_order() {
        let (tx, rx) = channel();
        let mut b = Batcher::new(
            MockBackend { capacity: 2, begun: 0, finished: 0 },
            BatcherConfig { max_batch: 2, ..Default::default() },
        );
        for id in 0..5 {
            b.submit(mk_req(id, 30, 40, &tx));
        }
        b.tick(); // 0 and 1 decoding, 2..4 queued
        assert_eq!(b.pending(), 5);
        let drained = b.drain_requests();
        let ids: Vec<u64> = drained.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4], "active first, then the FIFO queue");
        assert_eq!(b.pending(), 0, "the batcher owns nothing after a drain");
        assert_eq!(b.backend.finished, 2, "active sequences were released");
        drop(tx);
        assert_eq!(rx.iter().count(), 0, "drained requests are not answered here");
        for r in &drained {
            assert!(r.arrived_ms.is_some(), "arrival stamps survive the drain");
        }
    }
}
