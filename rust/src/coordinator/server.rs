//! Engine replica server: an [`Engine`] + [`Batcher`] living on a dedicated
//! thread, fed through an mpsc mailbox.  Under supervision
//! ([`EngineServer::spawn_supervised`]) the thread additionally publishes
//! lock-free liveness/occupancy signals ([`ReplicaStatus`]), runs its tick
//! loop behind a panic guard, drains every owned request on a crash, and
//! draws seeded replica-level faults from a
//! [`crate::runtime::FaultSchedule`] (DESIGN.md §6).

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig, PrefillBatchItem, PrefillProgress, StepBackend,
                     StepItem};
use super::request::{Request, RequestId, Response};
use super::router::{Replica, ReplicaSignals, SubmitError};
use crate::config::{EngineConfig, PreemptMode};
use crate::engine::{BatchEntry, Engine, PrefillEntry};
use crate::kvcache::{SeqCache, SwapHandle};
use crate::runtime::{FaultSchedule, ReplicaFault};
use crate::util::clock::{Clock, SharedClock, WallClock};
use crate::util::threadpool::spawn_named;

/// A restore-mode preempted sequence: the page-table skeleton (its
/// `pool_id`s are stale until swap-in remaps them) plus the host-side
/// swap buffer holding the page bytes.
struct ParkedSeq {
    seq: SeqCache,
    handle: SwapHandle,
}

/// [`StepBackend`] implementation over the real engine.
pub struct EngineBackend {
    /// The engine this replica schedules onto.
    pub engine: Engine,
    /// Reserve this many free pool pages per admitted sequence.
    pub pages_per_seq_estimate: usize,
    /// Restore-mode preempted sequences by request id (recompute mode
    /// parks nothing — the batcher's token history is enough).
    parked: HashMap<RequestId, ParkedSeq>,
}

impl EngineBackend {
    /// Backend over `engine` with the default per-sequence page reserve.
    pub fn new(engine: Engine) -> Self {
        EngineBackend { engine, pages_per_seq_estimate: 64, parked: HashMap::new() }
    }

    /// Override the per-sequence page reserve `has_capacity` checks.
    pub fn with_page_estimate(mut self, pages: usize) -> Self {
        self.pages_per_seq_estimate = pages;
        self
    }
}

impl StepBackend for EngineBackend {
    type Seq = SeqCache;

    fn begin(&mut self, prompt: &[u32]) -> Result<(SeqCache, u32)> {
        let mut seq = self.engine.new_seq();
        match self.engine.prefill_seq(&mut seq, prompt) {
            Ok(tok) => Ok((seq, tok)),
            Err(e) => {
                // a failed prefill (e.g. pool exhaustion mid-prompt) must
                // not leak its partially-appended pages
                self.engine.release_seq(&mut seq);
                Err(e)
            }
        }
    }

    /// Streaming admission: the engine's chunked prefill drives
    /// budget-paced admission (`BatcherConfig::prefill_token_budget`) —
    /// but only when the backend prefills chunks natively.  Otherwise the
    /// trait-default `Backend::prefill_chunk` re-runs the whole prefix per
    /// chunk (O(N²/C) for the AOT `ModelRuntime`), so we return `None` and
    /// the batcher's budget-paced whole-prompt fallback takes over.
    fn begin_chunked(&mut self) -> Option<SeqCache> {
        if self.engine.model().supports_chunked_prefill() {
            Some(self.engine.new_seq())
        } else {
            None
        }
    }

    fn prefill_chunk(&mut self, seq: &mut SeqCache, prompt: &[u32], done: usize,
                     max_tokens: usize) -> Result<PrefillProgress> {
        debug_assert_eq!(seq.n_tokens, done, "prefill progress out of sync");
        let first_token = self.engine.prefill_seq_partial(seq, prompt, max_tokens)?;
        // Prefix-cache hits only happen on the first chunk of a fresh
        // sequence; report them so the batcher's token budget charges
        // computed tokens, not attached ones.
        let cached = if done == 0 { seq.prefix_cached_tokens } else { 0 };
        Ok(PrefillProgress { consumed: seq.n_tokens - done, cached, first_token })
    }

    /// The batched admission fast path: one `Engine::prefill_batch` call
    /// per round covering every co-admitted prompt, instead of one
    /// streaming call per prompt — bit-identical to the per-item loop
    /// (the engine pins that invariant end to end).
    fn prefill_chunk_batch(&mut self, items: &mut [PrefillBatchItem<'_, SeqCache>])
                           -> Vec<Result<PrefillProgress>> {
        let dones: Vec<usize> = items.iter().map(|it| it.done).collect();
        let mut entries: Vec<PrefillEntry<'_>> = items
            .iter_mut()
            .map(|it| {
                debug_assert_eq!(it.seq.n_tokens, it.done, "prefill progress out of sync");
                PrefillEntry { seq: &mut *it.seq, prompt: it.prompt, max_tokens: it.max_tokens }
            })
            .collect();
        let results = self.engine.prefill_batch(&mut entries);
        drop(entries);
        results
            .into_iter()
            .zip(items.iter())
            .zip(dones)
            .map(|((r, it), done)| {
                let cached = if done == 0 { it.seq.prefix_cached_tokens } else { 0 };
                r.map(|first| PrefillProgress { consumed: it.seq.n_tokens - done,
                                                cached,
                                                first_token: first })
            })
            .collect()
    }

    fn record_prefill_secs(&mut self, secs: f64) {
        self.engine.metrics.record_secs("admit.prefill_secs", secs);
    }

    fn step(&mut self, seq: &mut SeqCache, token: u32, now: u64) -> Result<u32> {
        self.engine.decode_step(seq, token, now, None)
    }

    /// The batched fast path: one `Engine::decode_batch` iteration per
    /// scheduler tick instead of one full engine pass per sequence.
    fn step_batch(&mut self, items: &mut [StepItem<'_, SeqCache>]) -> Vec<Result<u32>> {
        let mut entries: Vec<BatchEntry<'_>> = items
            .iter_mut()
            .map(|it| BatchEntry::new(&mut *it.seq, it.token, it.now))
            .collect();
        self.engine.decode_batch(&mut entries)
    }

    fn preempt(&mut self, id: RequestId, mut seq: SeqCache, mode: PreemptMode) -> Result<()> {
        match mode {
            PreemptMode::Restore => {
                // Page bytes (and quant params) move to a host-side swap
                // buffer; the page-table skeleton is parked for swap-in.
                let handle = self.engine.swap_out_seq(&mut seq);
                self.parked.insert(id, ParkedSeq { seq, handle });
            }
            PreemptMode::Recompute => {
                // Drop everything; resume replays prompt + produced tokens.
                self.engine.release_seq(&mut seq);
            }
        }
        Ok(())
    }

    fn resume(&mut self, id: RequestId, prompt: &[u32], produced: &[u32]) -> Result<SeqCache> {
        if let Some(parked) = self.parked.get_mut(&id) {
            // Restore: all-or-nothing swap-in.  On pool pressure the entry
            // stays parked (untouched) and the typed error tells the
            // batcher to retry on a later tick.
            self.engine.swap_in_seq(&mut parked.seq, &parked.handle)?;
            let parked = self.parked.remove(&id).expect("entry present");
            return Ok(parked.seq);
        }
        // Recompute: fresh prefill, then replay the generated tokens with
        // their original step counters so stamps and per-page policy state
        // (H2O accumulators, Figure-3 logs) rebuild bit-identically.
        let mut seq = self.engine.new_seq();
        let replay = |engine: &mut Engine, seq: &mut SeqCache| -> Result<()> {
            engine.prefill_seq(seq, prompt)?;
            for (i, &tok) in produced.iter().enumerate() {
                engine.decode_step(seq, tok, (i + 1) as u64, None)?;
            }
            Ok(())
        };
        match replay(&mut self.engine, &mut seq) {
            Ok(()) => {
                self.engine
                    .metrics
                    .add("preempt.recompute_tokens", (prompt.len() + produced.len()) as u64);
                Ok(seq)
            }
            Err(e) => {
                self.engine.release_seq(&mut seq);
                Err(e)
            }
        }
    }

    fn record_counter(&mut self, name: &'static str, delta: u64) {
        self.engine.metrics.add(name, delta);
    }

    fn finish(&mut self, mut seq: SeqCache) {
        self.engine.release_seq(&mut seq);
    }

    fn is_eos(&self, token: u32) -> bool {
        self.engine.tokenizer.is_eos(token)
    }

    fn has_capacity(&self, _active: usize) -> bool {
        self.engine.pool().free_pages() >= self.pages_per_seq_estimate
    }

    fn free_pages(&self) -> Option<usize> {
        Some(self.engine.pool().free_pages())
    }
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Replica lifecycle states, published lock-free in [`ReplicaStatus`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaState {
    /// Spawned; the engine is still constructing on the replica thread.
    Starting,
    /// Serving its tick loop.
    Running,
    /// The watchdog declared it hung (stale heartbeat with pending work
    /// and no tick progress); it no longer accepts work and dies at its
    /// next kill-flag check.
    Hung,
    /// The replica thread panicked; its owned requests were drained.
    Crashed,
    /// Clean exit after a shutdown.
    Stopped,
}

impl ReplicaState {
    fn from_u8(v: u8) -> ReplicaState {
        match v {
            0 => ReplicaState::Starting,
            1 => ReplicaState::Running,
            2 => ReplicaState::Hung,
            3 => ReplicaState::Crashed,
            _ => ReplicaState::Stopped,
        }
    }

    fn as_u8(self) -> u8 {
        match self {
            ReplicaState::Starting => 0,
            ReplicaState::Running => 1,
            ReplicaState::Hung => 2,
            ReplicaState::Crashed => 3,
            ReplicaState::Stopped => 4,
        }
    }

    /// Lowercase name for diagnostics.
    pub fn name(self) -> &'static str {
        match self {
            ReplicaState::Starting => "starting",
            ReplicaState::Running => "running",
            ReplicaState::Hung => "hung",
            ReplicaState::Crashed => "crashed",
            ReplicaState::Stopped => "stopped",
        }
    }
}

/// Live, lock-free signals one replica publishes: the watchdog heartbeat
/// the supervisor polls, and the load/pool/queue occupancy gauges scored
/// placement reads ([`ReplicaSignals`]).  All fields are written by the
/// replica thread (except `state`/`kill`, which the supervisor also
/// writes) and read from anywhere; `Relaxed` ordering is enough because
/// every consumer tolerates a stale-by-one-tick reading.
#[derive(Debug, Default)]
pub struct ReplicaStatus {
    /// Requests accepted but not yet answered.
    pub load: AtomicUsize,
    /// Serving-clock ms of the last tick-loop heartbeat.
    pub heartbeat_ms: AtomicU64,
    /// Tick-loop passes completed — the watchdog's progress witness (an
    /// OS-descheduled replica still ticks between two polls; a hung one
    /// does not).
    pub ticks: AtomicU64,
    /// Free pages in this replica's KV pool.
    pub free_pages: AtomicUsize,
    /// Depth of the batcher's FIFO admission queue.
    pub queue_depth: AtomicUsize,
    /// Prompts mid-prefill (prefill-budget occupancy).
    pub prefilling: AtomicUsize,
    /// [`ReplicaState`] as its `u8` tag.
    pub state: AtomicU8,
    /// Cooperative kill flag: the tick loop (and the injected-hang park
    /// loop) exit at their next check, keeping the thread joinable.
    pub kill: AtomicBool,
}

impl ReplicaStatus {
    /// Current lifecycle state.
    pub fn state(&self) -> ReplicaState {
        ReplicaState::from_u8(self.state.load(Ordering::Relaxed))
    }

    fn set_state(&self, st: ReplicaState) {
        self.state.store(st.as_u8(), Ordering::Relaxed);
    }

    /// Whether the replica can accept new work.
    pub fn accepting(&self) -> bool {
        !self.kill.load(Ordering::Relaxed)
            && matches!(self.state(), ReplicaState::Starting | ReplicaState::Running)
    }
}

/// Lifecycle events a supervised replica reports on
/// [`SpawnOpts::events`].
pub enum ReplicaEvent {
    /// The replica thread caught a panic.  Every request it still owned —
    /// in the batcher (decoding, mid-prefill, preempted, queued) or
    /// sitting unread in its mailbox — rides along for re-dispatch.
    Crashed {
        /// [`SpawnOpts::index`] of the dead replica.
        replica: usize,
        /// The drained requests, intact and in scheduling order.
        requests: Vec<Request>,
        /// Captured panic payload, for diagnostics.
        panic_msg: String,
    },
    /// Clean exit after a `Shutdown` message.
    Stopped {
        /// [`SpawnOpts::index`] of the replica.
        replica: usize,
    },
}

/// Supervision hooks for [`EngineServer::spawn_supervised`].
pub struct SpawnOpts {
    /// Replica index echoed in [`ReplicaEvent`]s.
    pub index: usize,
    /// Serving clock heartbeats are stamped from (must be the clock the
    /// supervisor's watchdog reads).
    pub clock: SharedClock,
    /// Replica-level fault plan: `crash_at_tick`/`hang_at_tick` schedules
    /// for chaos testing.  `None` = no injected replica faults.
    pub faults: Option<FaultSchedule>,
    /// Where lifecycle events go.  `None` = standalone mode: a crash
    /// fails its drained requests straight back to their callers instead
    /// of handing them to a supervisor.
    pub events: Option<Sender<ReplicaEvent>>,
}

impl Default for SpawnOpts {
    fn default() -> Self {
        SpawnOpts { index: 0, clock: WallClock::shared(), faults: None, events: None }
    }
}

/// Why the replica loop returned (vs panicking out of it).
enum LoopExit {
    /// Shutdown or mailbox disconnect: the loop drained its work.
    Clean,
    /// The kill flag fired (watchdog verdict); in-flight work is
    /// unrecoverable from here — the supervisor already owns the shadow
    /// copies.
    Killed,
}

/// Handle to a replica thread.
pub struct EngineServer {
    tx: Sender<Msg>,
    /// Live signals: watchdog heartbeat, lifecycle state, placement
    /// occupancy gauges.
    pub status: Arc<ReplicaStatus>,
    clock: SharedClock,
    handle: Option<JoinHandle<()>>,
    /// Replica name (thread name suffix, log prefix).
    pub name: String,
}

impl EngineServer {
    /// Spawn an unsupervised replica (wall clock, no fault plan, crash
    /// drains fail straight back to callers).  Engine construction
    /// happens on the replica thread (PJRT clients are not Send-safe to
    /// move casually).
    pub fn spawn(name: String, cfg: EngineConfig, bcfg: BatcherConfig,
                 caps: Option<Vec<usize>>) -> Result<EngineServer> {
        Self::spawn_supervised(name, cfg, bcfg, caps, SpawnOpts::default())
    }

    /// Spawn a supervised replica: heartbeats on `opts.clock`, panic
    /// capture with request drain, optional seeded replica faults.  NOTE:
    /// an injected hang leaves the thread parked until something sets
    /// [`ReplicaStatus::kill`] (the supervisor's watchdog does; standalone
    /// callers injecting hangs must kill before drop, or drop joins a
    /// parked thread forever).
    pub fn spawn_supervised(name: String, cfg: EngineConfig, bcfg: BatcherConfig,
                            caps: Option<Vec<usize>>, opts: SpawnOpts) -> Result<EngineServer> {
        let (tx, rx) = channel::<Msg>();
        let status = Arc::new(ReplicaStatus::default());
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread_name = name.clone();
        let SpawnOpts { index, clock, mut faults, events } = opts;
        let status2 = Arc::clone(&status);
        let clock2 = Arc::clone(&clock);
        let handle = spawn_named(format!("raas-replica-{name}"), move || {
            let engine = match caps {
                Some(c) => Engine::new_with_capacities(cfg, &c),
                None => Engine::new(cfg),
            };
            let engine = match engine {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let backend = EngineBackend::new(engine);
            let mut batcher = Batcher::with_clock(backend, bcfg, Arc::clone(&clock2));
            status2.set_state(ReplicaState::Running);
            // a fresh replica advertises its full pool before any work
            publish_signals(&batcher, &status2);
            // The batcher lives OUTSIDE the unwind boundary so a caught
            // panic can still drain the requests it owns.
            let result = catch_unwind(AssertUnwindSafe(|| {
                replica_loop(&mut batcher, &rx, &status2, &*clock2, faults.as_mut())
            }));
            match result {
                Ok(LoopExit::Clean) => {
                    status2.set_state(ReplicaState::Stopped);
                    status2.load.store(0, Ordering::Relaxed);
                    if let Some(ev) = &events {
                        let _ = ev.send(ReplicaEvent::Stopped { replica: index });
                    }
                }
                Ok(LoopExit::Killed) => {
                    // watchdog kill: the supervisor recovers from its
                    // shadow registry; nothing to drain here (the batcher
                    // state is suspect — it was declared hung mid-tick)
                    status2.load.store(0, Ordering::Relaxed);
                }
                Err(panic) => {
                    status2.set_state(ReplicaState::Crashed);
                    let panic_msg = panic_text(panic.as_ref());
                    // Drain everything the batcher still owns, plus any
                    // requests sitting unread in the mailbox — they must
                    // reach the supervisor (or their callers), not die
                    // with the thread.  The drain itself runs behind a
                    // guard: post-panic backend state may be inconsistent.
                    let mut requests =
                        catch_unwind(AssertUnwindSafe(|| batcher.drain_requests()))
                            .unwrap_or_default();
                    while let Ok(Msg::Req(r)) = rx.try_recv() {
                        requests.push(r);
                    }
                    status2.load.store(0, Ordering::Relaxed);
                    match &events {
                        Some(ev) => {
                            let _ = ev.send(ReplicaEvent::Crashed {
                                replica: index,
                                requests,
                                panic_msg,
                            });
                        }
                        None => {
                            for r in requests {
                                let resp = Response::err(
                                    r.id,
                                    r.submitted,
                                    format!("replica crashed: {panic_msg}"),
                                );
                                let _ = r.reply.send(resp);
                            }
                        }
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica {thread_name} died during startup"))??;
        Ok(EngineServer { tx, status, clock, handle: Some(handle), name: thread_name })
    }

    /// Enqueue one request into the replica mailbox.  A dead (or dying)
    /// replica hands the request back inside the error so the caller can
    /// fail over instead of losing it.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        if !self.status.accepting() {
            return Err(SubmitError {
                req,
                reason: format!("replica {} is {}", self.name, self.status.state().name()),
            });
        }
        match self.tx.send(Msg::Req(req)) {
            Ok(()) => {
                self.status.load.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                let req = match e.0 {
                    Msg::Req(r) => r,
                    Msg::Shutdown => unreachable!("submit only sends Req"),
                };
                Err(SubmitError { req, reason: format!("replica {} is down", self.name) })
            }
        }
    }

    /// Requests accepted but not yet answered.
    pub fn pending(&self) -> usize {
        self.status.load.load(Ordering::Relaxed)
    }

    /// Watchdog verdict: stop accepting work, ask the (possibly wedged)
    /// thread to die at its next kill check, and unpark it in case it is
    /// sitting in the injected-hang park loop.
    pub fn mark_hung(&self) {
        self.status.set_state(ReplicaState::Hung);
        self.status.kill.store(true, Ordering::Relaxed);
        if let Some(h) = &self.handle {
            h.thread().unpark();
        }
    }

    /// Drain remaining work, then stop and join the replica thread.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Replica for EngineServer {
    fn submit(&self, req: Request) -> Result<(), SubmitError> {
        EngineServer::submit(self, req)
    }

    fn pending(&self) -> usize {
        EngineServer::pending(self)
    }

    fn signals(&self) -> ReplicaSignals {
        let s = &self.status;
        ReplicaSignals {
            alive: s.accepting(),
            heartbeat_age_ms: self
                .clock
                .now_ms()
                .saturating_sub(s.heartbeat_ms.load(Ordering::Relaxed)),
            free_pages: s.free_pages.load(Ordering::Relaxed),
            queue_depth: s.queue_depth.load(Ordering::Relaxed),
            prefilling: s.prefilling.load(Ordering::Relaxed),
            pending: s.load.load(Ordering::Relaxed),
        }
    }
}

/// The supervised tick loop: kill check → heartbeat → mailbox drain →
/// injected replica fault point → `Batcher::tick` → signal publication.
fn replica_loop(batcher: &mut Batcher<EngineBackend>, rx: &Receiver<Msg>,
                status: &ReplicaStatus, clock: &dyn Clock,
                mut faults: Option<&mut FaultSchedule>) -> LoopExit {
    loop {
        if status.kill.load(Ordering::Relaxed) {
            return LoopExit::Killed;
        }
        status.heartbeat_ms.store(clock.now_ms(), Ordering::Relaxed);
        // Drain the mailbox without blocking while work is active; block
        // when idle (an idle replica's stale heartbeat is harmless — the
        // watchdog exempts replicas with no pending work).
        let msg = if batcher.pending() == 0 {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => return LoopExit::Clean,
            }
        } else {
            match rx.try_recv() {
                Ok(m) => Some(m),
                Err(TryRecvError::Empty) => None,
                Err(TryRecvError::Disconnected) => return LoopExit::Clean,
            }
        };
        match msg {
            Some(Msg::Req(r)) => {
                batcher.submit(r);
                publish_signals(batcher, status);
                continue; // keep draining before stepping
            }
            Some(Msg::Shutdown) => {
                batcher.run_to_completion();
                publish_signals(batcher, status);
                return LoopExit::Clean;
            }
            None => {}
        }
        // the replica-level fault point, between mailbox drain and tick
        if let Some(f) = faults.as_deref_mut() {
            match f.check_tick() {
                Some(ReplicaFault::Crash) => panic!("injected replica crash"),
                Some(ReplicaFault::Hang) => {
                    // Freeze: no heartbeats, no ticks, mailbox unread —
                    // exactly what a wedged engine call looks like from
                    // outside.  The park loop honors the kill flag so the
                    // thread stays joinable once the watchdog fires.
                    while !status.kill.load(Ordering::Relaxed) {
                        std::thread::park_timeout(Duration::from_millis(1));
                    }
                    return LoopExit::Killed;
                }
                None => {}
            }
        }
        batcher.tick();
        status.ticks.fetch_add(1, Ordering::Relaxed);
        publish_signals(batcher, status);
    }
}

/// Publish the occupancy gauges scored placement reads.
fn publish_signals(batcher: &Batcher<EngineBackend>, status: &ReplicaStatus) {
    status.load.store(batcher.pending(), Ordering::Relaxed);
    status.queue_depth.store(batcher.queue_depth(), Ordering::Relaxed);
    status.prefilling.store(batcher.prefilling_len(), Ordering::Relaxed);
    if let Some(fp) = batcher.backend.free_pages() {
        status.free_pages.store(fp, Ordering::Relaxed);
    }
}

/// Best-effort text of a captured panic payload.
fn panic_text(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}
