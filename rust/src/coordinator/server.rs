//! Engine replica server: an [`Engine`] + [`Batcher`] living on a dedicated
//! thread, fed through an mpsc mailbox.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use super::batcher::{Batcher, BatcherConfig, PrefillBatchItem, PrefillProgress, StepBackend,
                     StepItem};
use super::request::{Request, RequestId};
use super::router::SubmitError;
use crate::config::{EngineConfig, PreemptMode};
use crate::engine::{BatchEntry, Engine, PrefillEntry};
use crate::kvcache::{SeqCache, SwapHandle};

/// A restore-mode preempted sequence: the page-table skeleton (its
/// `pool_id`s are stale until swap-in remaps them) plus the host-side
/// swap buffer holding the page bytes.
struct ParkedSeq {
    seq: SeqCache,
    handle: SwapHandle,
}

/// [`StepBackend`] implementation over the real engine.
pub struct EngineBackend {
    /// The engine this replica schedules onto.
    pub engine: Engine,
    /// Reserve this many free pool pages per admitted sequence.
    pub pages_per_seq_estimate: usize,
    /// Restore-mode preempted sequences by request id (recompute mode
    /// parks nothing — the batcher's token history is enough).
    parked: HashMap<RequestId, ParkedSeq>,
}

impl EngineBackend {
    /// Backend over `engine` with the default per-sequence page reserve.
    pub fn new(engine: Engine) -> Self {
        EngineBackend { engine, pages_per_seq_estimate: 64, parked: HashMap::new() }
    }

    /// Override the per-sequence page reserve `has_capacity` checks.
    pub fn with_page_estimate(mut self, pages: usize) -> Self {
        self.pages_per_seq_estimate = pages;
        self
    }
}

impl StepBackend for EngineBackend {
    type Seq = SeqCache;

    fn begin(&mut self, prompt: &[u32]) -> Result<(SeqCache, u32)> {
        let mut seq = self.engine.new_seq();
        match self.engine.prefill_seq(&mut seq, prompt) {
            Ok(tok) => Ok((seq, tok)),
            Err(e) => {
                // a failed prefill (e.g. pool exhaustion mid-prompt) must
                // not leak its partially-appended pages
                self.engine.release_seq(&mut seq);
                Err(e)
            }
        }
    }

    /// Streaming admission: the engine's chunked prefill drives
    /// budget-paced admission (`BatcherConfig::prefill_token_budget`) —
    /// but only when the backend prefills chunks natively.  Otherwise the
    /// trait-default `Backend::prefill_chunk` re-runs the whole prefix per
    /// chunk (O(N²/C) for the AOT `ModelRuntime`), so we return `None` and
    /// the batcher's budget-paced whole-prompt fallback takes over.
    fn begin_chunked(&mut self) -> Option<SeqCache> {
        if self.engine.model().supports_chunked_prefill() {
            Some(self.engine.new_seq())
        } else {
            None
        }
    }

    fn prefill_chunk(&mut self, seq: &mut SeqCache, prompt: &[u32], done: usize,
                     max_tokens: usize) -> Result<PrefillProgress> {
        debug_assert_eq!(seq.n_tokens, done, "prefill progress out of sync");
        let first_token = self.engine.prefill_seq_partial(seq, prompt, max_tokens)?;
        // Prefix-cache hits only happen on the first chunk of a fresh
        // sequence; report them so the batcher's token budget charges
        // computed tokens, not attached ones.
        let cached = if done == 0 { seq.prefix_cached_tokens } else { 0 };
        Ok(PrefillProgress { consumed: seq.n_tokens - done, cached, first_token })
    }

    /// The batched admission fast path: one `Engine::prefill_batch` call
    /// per round covering every co-admitted prompt, instead of one
    /// streaming call per prompt — bit-identical to the per-item loop
    /// (the engine pins that invariant end to end).
    fn prefill_chunk_batch(&mut self, items: &mut [PrefillBatchItem<'_, SeqCache>])
                           -> Vec<Result<PrefillProgress>> {
        let dones: Vec<usize> = items.iter().map(|it| it.done).collect();
        let mut entries: Vec<PrefillEntry<'_>> = items
            .iter_mut()
            .map(|it| {
                debug_assert_eq!(it.seq.n_tokens, it.done, "prefill progress out of sync");
                PrefillEntry { seq: &mut *it.seq, prompt: it.prompt, max_tokens: it.max_tokens }
            })
            .collect();
        let results = self.engine.prefill_batch(&mut entries);
        drop(entries);
        results
            .into_iter()
            .zip(items.iter())
            .zip(dones)
            .map(|((r, it), done)| {
                let cached = if done == 0 { it.seq.prefix_cached_tokens } else { 0 };
                r.map(|first| PrefillProgress { consumed: it.seq.n_tokens - done,
                                                cached,
                                                first_token: first })
            })
            .collect()
    }

    fn record_prefill_secs(&mut self, secs: f64) {
        self.engine.metrics.record_secs("admit.prefill_secs", secs);
    }

    fn step(&mut self, seq: &mut SeqCache, token: u32, now: u64) -> Result<u32> {
        self.engine.decode_step(seq, token, now, None)
    }

    /// The batched fast path: one `Engine::decode_batch` iteration per
    /// scheduler tick instead of one full engine pass per sequence.
    fn step_batch(&mut self, items: &mut [StepItem<'_, SeqCache>]) -> Vec<Result<u32>> {
        let mut entries: Vec<BatchEntry<'_>> = items
            .iter_mut()
            .map(|it| BatchEntry::new(&mut *it.seq, it.token, it.now))
            .collect();
        self.engine.decode_batch(&mut entries)
    }

    fn preempt(&mut self, id: RequestId, mut seq: SeqCache, mode: PreemptMode) -> Result<()> {
        match mode {
            PreemptMode::Restore => {
                // Page bytes (and quant params) move to a host-side swap
                // buffer; the page-table skeleton is parked for swap-in.
                let handle = self.engine.swap_out_seq(&mut seq);
                self.parked.insert(id, ParkedSeq { seq, handle });
            }
            PreemptMode::Recompute => {
                // Drop everything; resume replays prompt + produced tokens.
                self.engine.release_seq(&mut seq);
            }
        }
        Ok(())
    }

    fn resume(&mut self, id: RequestId, prompt: &[u32], produced: &[u32]) -> Result<SeqCache> {
        if let Some(parked) = self.parked.get_mut(&id) {
            // Restore: all-or-nothing swap-in.  On pool pressure the entry
            // stays parked (untouched) and the typed error tells the
            // batcher to retry on a later tick.
            self.engine.swap_in_seq(&mut parked.seq, &parked.handle)?;
            let parked = self.parked.remove(&id).expect("entry present");
            return Ok(parked.seq);
        }
        // Recompute: fresh prefill, then replay the generated tokens with
        // their original step counters so stamps and per-page policy state
        // (H2O accumulators, Figure-3 logs) rebuild bit-identically.
        let mut seq = self.engine.new_seq();
        let replay = |engine: &mut Engine, seq: &mut SeqCache| -> Result<()> {
            engine.prefill_seq(seq, prompt)?;
            for (i, &tok) in produced.iter().enumerate() {
                engine.decode_step(seq, tok, (i + 1) as u64, None)?;
            }
            Ok(())
        };
        match replay(&mut self.engine, &mut seq) {
            Ok(()) => {
                self.engine
                    .metrics
                    .add("preempt.recompute_tokens", (prompt.len() + produced.len()) as u64);
                Ok(seq)
            }
            Err(e) => {
                self.engine.release_seq(&mut seq);
                Err(e)
            }
        }
    }

    fn record_counter(&mut self, name: &'static str, delta: u64) {
        self.engine.metrics.add(name, delta);
    }

    fn finish(&mut self, mut seq: SeqCache) {
        self.engine.release_seq(&mut seq);
    }

    fn is_eos(&self, token: u32) -> bool {
        self.engine.tokenizer.is_eos(token)
    }

    fn has_capacity(&self, _active: usize) -> bool {
        self.engine.pool().free_pages() >= self.pages_per_seq_estimate
    }
}

enum Msg {
    Req(Request),
    Shutdown,
}

/// Handle to a replica thread.
pub struct EngineServer {
    tx: Sender<Msg>,
    /// Pending-request gauge the router's least-loaded policy reads.
    pub load: Arc<AtomicUsize>,
    handle: Option<JoinHandle<()>>,
    /// Replica name (thread name suffix, log prefix).
    pub name: String,
}

impl EngineServer {
    /// Spawn a replica.  Engine construction happens on the replica thread
    /// (PJRT clients are not Send-safe to move casually).
    pub fn spawn(name: String, cfg: EngineConfig, bcfg: BatcherConfig,
                 caps: Option<Vec<usize>>) -> Result<EngineServer> {
        let (tx, rx) = channel::<Msg>();
        let load = Arc::new(AtomicUsize::new(0));
        let load2 = Arc::clone(&load);
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let thread_name = name.clone();
        let handle = std::thread::Builder::new()
            .name(format!("raas-replica-{name}"))
            .spawn(move || {
                let engine = match caps {
                    Some(c) => Engine::new_with_capacities(cfg, &c),
                    None => Engine::new(cfg),
                };
                let engine = match engine {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let backend = EngineBackend::new(engine);
                let mut batcher = Batcher::new(backend, bcfg);
                loop {
                    // Drain the mailbox without blocking while work is active;
                    // block when idle.
                    let msg = if batcher.pending() == 0 {
                        match rx.recv() {
                            Ok(m) => Some(m),
                            Err(_) => break,
                        }
                    } else {
                        match rx.try_recv() {
                            Ok(m) => Some(m),
                            Err(TryRecvError::Empty) => None,
                            Err(TryRecvError::Disconnected) => break,
                        }
                    };
                    match msg {
                        Some(Msg::Req(r)) => {
                            batcher.submit(r);
                            continue; // keep draining before stepping
                        }
                        Some(Msg::Shutdown) => {
                            batcher.run_to_completion();
                            break;
                        }
                        None => {}
                    }
                    batcher.tick();
                    load2.store(batcher.pending(), Ordering::Relaxed);
                }
                load2.store(0, Ordering::Relaxed);
            })
            .expect("spawn replica");
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("replica {thread_name} died during startup"))??;
        Ok(EngineServer { tx, load, handle: Some(handle), name: thread_name })
    }

    /// Enqueue one request into the replica mailbox.  On a dead replica
    /// the request is handed back inside the error so the caller can
    /// fail over instead of losing it.
    pub fn submit(&self, req: Request) -> Result<(), SubmitError> {
        match self.tx.send(Msg::Req(req)) {
            Ok(()) => {
                self.load.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                let req = match e.0 {
                    Msg::Req(r) => r,
                    Msg::Shutdown => unreachable!("submit only sends Req"),
                };
                Err(SubmitError { req, reason: format!("replica {} is down", self.name) })
            }
        }
    }

    /// Requests accepted but not yet answered.
    pub fn pending(&self) -> usize {
        self.load.load(Ordering::Relaxed)
    }

    /// Drain remaining work, then stop and join the replica thread.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for EngineServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
