//! Layer-3 serving coordinator: request lifecycle, continuous batching,
//! multi-replica routing and replica supervision.  Threads + mpsc
//! mailboxes stand in for the async runtime (tokio is unavailable
//! offline; DESIGN.md §3).

pub mod batcher;
pub mod request;
pub mod router;
pub mod server;
pub mod supervisor;

pub use batcher::{Batcher, BatcherConfig, PrefillBatchItem, PrefillProgress, StepBackend,
                  StepItem};
pub use request::{Outcome, Request, RequestId, Response};
pub use router::{Replica, ReplicaSignals, Router, RoutePolicy, SubmitError};
pub use server::{EngineServer, ReplicaEvent, ReplicaState, ReplicaStatus, SpawnOpts};
pub use supervisor::{Supervisor, SupervisorConfig};
