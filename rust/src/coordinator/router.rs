//! Multi-replica request router (vllm-project/router-style): dispatches
//! requests across engine replicas by round-robin, least-loaded, or
//! session-affinity hashing.

use anyhow::Result;

use super::request::Request;

/// How the router picks a replica for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in order.
    RoundRobin,
    /// Pick the replica with the fewest pending requests.
    LeastLoaded,
    /// Hash the prompt prefix (session affinity: same session hits the same
    /// replica, maximising KV-cache locality in prefix-caching setups).
    Affinity,
}

impl RoutePolicy {
    /// Parse a CLI route-policy name (`rr`, `least`, `affinity`).
    pub fn parse(s: &str) -> Result<RoutePolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => RoutePolicy::RoundRobin,
            "least" | "leastloaded" | "least-loaded" => RoutePolicy::LeastLoaded,
            "affinity" | "hash" => RoutePolicy::Affinity,
            other => anyhow::bail!("unknown route policy '{other}'"),
        })
    }
}

/// What the router needs from a replica (implemented by `EngineServer`;
/// mocked in tests).
pub trait Replica {
    /// Hand one request to this replica's mailbox.
    fn submit(&self, req: Request) -> Result<()>;
    /// Requests this replica has accepted but not yet answered.
    fn pending(&self) -> usize;
}

impl Replica for super::server::EngineServer {
    fn submit(&self, req: Request) -> Result<()> {
        // inherent method (mailbox send) — inherent methods take precedence,
        // so this does not recurse.
        EngineServer::submit(self, req)
    }
    fn pending(&self) -> usize {
        EngineServer::pending(self)
    }
}

use super::server::EngineServer;

/// Dispatches requests across engine replicas (DESIGN.md §5).
pub struct Router<R: Replica> {
    replicas: Vec<R>,
    policy: RoutePolicy,
    next_rr: usize,
    /// Requests routed so far.
    pub routed: u64,
}

impl<R: Replica> Router<R> {
    /// Router over at least one replica.
    pub fn new(replicas: Vec<R>, policy: RoutePolicy) -> Self {
        assert!(!replicas.is_empty());
        Router { replicas, policy, next_rr: 0, routed: 0 }
    }

    /// The replica set, in submission-index order.
    pub fn replicas(&self) -> &[R] {
        &self.replicas
    }

    /// Consume the router, returning its replicas (for shutdown).
    pub fn into_replicas(self) -> Vec<R> {
        self.replicas
    }

    fn pick(&mut self, req: &Request) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.replicas.len();
                i
            }
            RoutePolicy::LeastLoaded => self
                .replicas
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.pending())
                .map(|(i, _)| i)
                .unwrap(),
            RoutePolicy::Affinity => {
                // FNV-1a over the first 8 prompt tokens + avalanche finaliser
                // (low-entropy token ids need the final mix to spread mod n)
                let mut h: u64 = 0xcbf29ce484222325;
                for &t in req.prompt.iter().take(8) {
                    h ^= t as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
                h = (h ^ (h >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
                h = (h ^ (h >> 27)).wrapping_mul(0x94d049bb133111eb);
                h ^= h >> 31;
                (h % self.replicas.len() as u64) as usize
            }
        }
    }

    /// Route one request; returns the chosen replica index.
    pub fn route(&mut self, req: Request) -> Result<usize> {
        let i = self.pick(&req);
        self.replicas[i].submit(req)?;
        self.routed += 1;
        Ok(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    struct MockReplica {
        sent: Cell<usize>,
        load: usize,
    }
    impl Replica for MockReplica {
        fn submit(&self, _req: Request) -> Result<()> {
            self.sent.set(self.sent.get() + 1);
            Ok(())
        }
        fn pending(&self) -> usize {
            self.load
        }
    }

    fn req(prompt: Vec<u32>) -> Request {
        let (tx, _rx) = channel();
        // leak the receiver side: mock never replies
        std::mem::forget(_rx);
        Request { id: 0, prompt, max_new: 1, submitted: Instant::now(), reply: tx }
    }

    fn mocks(loads: &[usize]) -> Vec<MockReplica> {
        loads.iter().map(|&l| MockReplica { sent: Cell::new(0), load: l }).collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(mocks(&[0, 0, 0]), RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(req(vec![1])).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.routed, 6);
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let mut r = Router::new(mocks(&[5, 0, 9]), RoutePolicy::LeastLoaded);
        assert_eq!(r.route(req(vec![1])).unwrap(), 1);
    }

    #[test]
    fn affinity_is_deterministic_and_spreads() {
        let mut r = Router::new(mocks(&[0, 0, 0, 0]), RoutePolicy::Affinity);
        let a1 = r.route(req(vec![1, 2, 3])).unwrap();
        let a2 = r.route(req(vec![1, 2, 3])).unwrap();
        assert_eq!(a1, a2, "same session, same replica");
        let mut hit = std::collections::BTreeSet::new();
        for seed in 0..32u32 {
            hit.insert(r.route(req(vec![seed, seed + 1])).unwrap());
        }
        assert!(hit.len() >= 3, "hashing should spread sessions: {hit:?}");
    }

    #[test]
    fn policy_parse() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert!(RoutePolicy::parse("nope").is_err());
    }
}
