//! Multi-replica request router (vllm-project/router-style): dispatches
//! requests across engine replicas by round-robin, least-loaded, or
//! session-affinity hashing — with per-replica health tracking
//! (consecutive-failure circuit breaker, seeded half-open probes) and
//! failover: a failed `submit` returns the request to the router, which
//! retries it on the next healthy replica while the request's retry
//! budget lasts (DESIGN.md §6).

use crate::util::rng::Rng;

use super::request::Request;

/// Consecutive submit failures that trip a replica's circuit breaker.
const FAILURE_THRESHOLD: u32 = 3;
/// Breaker hold-off after the first trip, in router ticks (one tick per
/// [`Router::route`] call); doubles per consecutive trip.
const BASE_BACKOFF: u64 = 4;
/// Backoff growth cap, in ticks (plus up to 50% seeded jitter).
const MAX_BACKOFF: u64 = 64;

/// How the router picks a replica for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in order.
    RoundRobin,
    /// Pick the replica with the fewest pending requests.
    LeastLoaded,
    /// Hash the prompt prefix (session affinity: same session hits the same
    /// replica, maximising KV-cache locality in prefix-caching setups).
    Affinity,
}

impl RoutePolicy {
    /// Parse a CLI route-policy name (`rr`, `least`, `affinity`).
    pub fn parse(s: &str) -> anyhow::Result<RoutePolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => RoutePolicy::RoundRobin,
            "least" | "leastloaded" | "least-loaded" => RoutePolicy::LeastLoaded,
            "affinity" | "hash" => RoutePolicy::Affinity,
            other => anyhow::bail!("unknown route policy '{other}'"),
        })
    }
}

/// A failed hand-off that returns the request to the caller — the router
/// (for failover) or the submitter (to reply/retry) — instead of dropping
/// it on the floor.  Not an `anyhow::Error`: the request's reply channel
/// is `Send` but not `Sync`, and losing the request to an opaque error was
/// exactly the bug this type fixes.
pub struct SubmitError {
    /// The request, intact, so the caller can retry or answer it.
    pub req: Request,
    /// Why the hand-off failed.
    pub reason: String,
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SubmitError")
            .field("req_id", &self.req.id)
            .field("reason", &self.reason)
            .finish()
    }
}

/// What the router needs from a replica (implemented by `EngineServer`;
/// mocked in tests).
pub trait Replica {
    /// Hand one request to this replica's mailbox; on failure the request
    /// comes back in the [`SubmitError`].
    fn submit(&self, req: Request) -> Result<(), SubmitError>;
    /// Requests this replica has accepted but not yet answered.
    fn pending(&self) -> usize;
}

impl Replica for super::server::EngineServer {
    fn submit(&self, req: Request) -> Result<(), SubmitError> {
        // inherent method (mailbox send) — inherent methods take precedence,
        // so this does not recurse.
        EngineServer::submit(self, req)
    }
    fn pending(&self) -> usize {
        EngineServer::pending(self)
    }
}

use super::server::EngineServer;

/// Per-replica breaker state (logical router ticks, one per route call).
#[derive(Debug, Clone, Default)]
struct Health {
    /// Submit failures since the last success (resets on success/trip).
    consecutive_failures: u32,
    /// No traffic until this tick; 0 = closed.
    open_until: u64,
    /// Consecutive breaker trips (exponential-backoff exponent); resets
    /// on the first successful probe.
    trips: u32,
}

/// Dispatches requests across engine replicas (DESIGN.md §5), failing
/// over around unhealthy ones (DESIGN.md §6).
pub struct Router<R: Replica> {
    replicas: Vec<R>,
    health: Vec<Health>,
    policy: RoutePolicy,
    next_rr: usize,
    /// Jitter stream for half-open backoff (deterministic per seed).
    rng: Rng,
    /// Logical clock: one tick per [`Router::route`] call.
    now: u64,
    /// Requests routed so far.
    pub routed: u64,
    /// Submits retried on another replica after a failure.
    pub failovers: u64,
    /// Circuit-breaker trips (a replica taken out of rotation).
    pub breaker_opens: u64,
}

impl<R: Replica> Router<R> {
    /// Router over at least one replica (jitter seed 0; see
    /// [`Router::with_seed`]).
    pub fn new(replicas: Vec<R>, policy: RoutePolicy) -> Self {
        Self::with_seed(replicas, policy, 0)
    }

    /// Router with an explicit backoff-jitter seed.
    pub fn with_seed(replicas: Vec<R>, policy: RoutePolicy, seed: u64) -> Self {
        assert!(!replicas.is_empty());
        let health = replicas.iter().map(|_| Health::default()).collect();
        Router {
            replicas,
            health,
            policy,
            next_rr: 0,
            rng: Rng::new(seed),
            now: 0,
            routed: 0,
            failovers: 0,
            breaker_opens: 0,
        }
    }

    /// The replica set, in submission-index order.
    pub fn replicas(&self) -> &[R] {
        &self.replicas
    }

    /// Consume the router, returning its replicas (for shutdown).
    pub fn into_replicas(self) -> Vec<R> {
        self.replicas
    }

    /// Whether replica `i`'s breaker admits traffic at the current tick
    /// (closed, or open long enough to half-open probe).
    pub fn is_healthy(&self, i: usize) -> bool {
        self.health[i].open_until <= self.now
    }

    /// Replica indices the breaker currently admits.
    fn available(&self) -> Vec<usize> {
        (0..self.replicas.len()).filter(|&i| self.is_healthy(i)).collect()
    }

    /// Apply the route policy over the available set, returning a
    /// position *within* `avail`.
    fn pick(&mut self, req: &Request, avail: &[usize]) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let p = self.next_rr % avail.len();
                self.next_rr = (self.next_rr + 1) % avail.len();
                p
            }
            RoutePolicy::LeastLoaded => avail
                .iter()
                .enumerate()
                .min_by_key(|(_, &i)| self.replicas[i].pending())
                .map(|(p, _)| p)
                .unwrap(),
            RoutePolicy::Affinity => {
                // FNV-1a over the first 8 prompt tokens + avalanche finaliser
                // (low-entropy token ids need the final mix to spread mod n)
                let mut h: u64 = 0xcbf29ce484222325;
                for &t in req.prompt.iter().take(8) {
                    h ^= t as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
                h = (h ^ (h >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
                h = (h ^ (h >> 27)).wrapping_mul(0x94d049bb133111eb);
                h ^= h >> 31;
                (h % avail.len() as u64) as usize
            }
        }
    }

    fn on_success(&mut self, i: usize) {
        let h = &mut self.health[i];
        h.consecutive_failures = 0;
        h.open_until = 0;
        h.trips = 0;
    }

    fn on_failure(&mut self, i: usize) {
        let half_open = {
            let h = &self.health[i];
            h.trips > 0 && h.open_until <= self.now
        };
        let trip = {
            let h = &mut self.health[i];
            h.consecutive_failures += 1;
            half_open || h.consecutive_failures >= FAILURE_THRESHOLD
        };
        if trip {
            let h = &mut self.health[i];
            h.trips += 1;
            h.consecutive_failures = 0;
            let backoff = (BASE_BACKOFF << (h.trips - 1).min(4)).min(MAX_BACKOFF);
            let base_until = self.now + backoff;
            let jitter = self.rng.range(0, backoff as usize / 2 + 1) as u64;
            self.health[i].open_until = base_until + jitter;
            self.breaker_opens += 1;
        }
    }

    /// Route one request: pick a replica by policy among the healthy set,
    /// and on a failed `submit` fail over to the next healthy replica
    /// while the request's retry budget lasts.  Returns the replica index
    /// that accepted the request, or the request itself (in the
    /// [`SubmitError`]) when every attempt failed — never loses it.
    pub fn route(&mut self, req: Request) -> Result<usize, SubmitError> {
        self.now += 1;
        let mut avail = self.available();
        if avail.is_empty() {
            // every breaker is open: force-probe the soonest to recover
            // rather than deadlock the fleet
            let i = (0..self.replicas.len())
                .min_by_key(|&i| self.health[i].open_until)
                .expect("router has at least one replica");
            avail.push(i);
        }
        let start = self.pick(&req, &avail);
        let mut req = req;
        let mut last_reason = String::new();
        for attempt in 0..avail.len() {
            if attempt > 0 {
                if req.retries_left == 0 {
                    break;
                }
                req.retries_left -= 1;
                self.failovers += 1;
            }
            let i = avail[(start + attempt) % avail.len()];
            match self.replicas[i].submit(req) {
                Ok(()) => {
                    self.on_success(i);
                    self.routed += 1;
                    return Ok(i);
                }
                Err(se) => {
                    req = se.req;
                    last_reason = se.reason;
                    self.on_failure(i);
                }
            }
        }
        Err(SubmitError { req, reason: format!("no replica accepted: {last_reason}") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;
    use std::sync::mpsc::channel;

    struct MockReplica {
        sent: Cell<usize>,
        load: usize,
        /// When set, every submit fails and hands the request back.
        failing: Cell<bool>,
    }
    impl Replica for MockReplica {
        fn submit(&self, req: Request) -> Result<(), SubmitError> {
            if self.failing.get() {
                return Err(SubmitError { req, reason: "mock replica down".to_string() });
            }
            self.sent.set(self.sent.get() + 1);
            Ok(())
        }
        fn pending(&self) -> usize {
            self.load
        }
    }

    fn req(prompt: Vec<u32>) -> Request {
        let (tx, _rx) = channel();
        // leak the receiver side: mock never replies
        std::mem::forget(_rx);
        Request::new(0, prompt, 1, tx)
    }

    fn mocks(loads: &[usize]) -> Vec<MockReplica> {
        loads
            .iter()
            .map(|&l| MockReplica { sent: Cell::new(0), load: l, failing: Cell::new(false) })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(mocks(&[0, 0, 0]), RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(req(vec![1])).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.routed, 6);
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let mut r = Router::new(mocks(&[5, 0, 9]), RoutePolicy::LeastLoaded);
        assert_eq!(r.route(req(vec![1])).unwrap(), 1);
    }

    #[test]
    fn affinity_is_deterministic_and_spreads() {
        let mut r = Router::new(mocks(&[0, 0, 0, 0]), RoutePolicy::Affinity);
        let a1 = r.route(req(vec![1, 2, 3])).unwrap();
        let a2 = r.route(req(vec![1, 2, 3])).unwrap();
        assert_eq!(a1, a2, "same session, same replica");
        let mut hit = std::collections::BTreeSet::new();
        for seed in 0..32u32 {
            hit.insert(r.route(req(vec![seed, seed + 1])).unwrap());
        }
        assert!(hit.len() >= 3, "hashing should spread sessions: {hit:?}");
    }

    #[test]
    fn policy_parse() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert!(RoutePolicy::parse("nope").is_err());
    }

    #[test]
    fn failed_submit_returns_the_request_to_the_caller() {
        // The regression this PR fixes: a failed submit used to discard
        // the request (reply channel and all); now it comes back intact.
        let reps = mocks(&[0]);
        reps[0].failing.set(true);
        let mut r = Router::new(reps, RoutePolicy::RoundRobin);
        let original = req(vec![7, 8, 9]);
        let id = original.id;
        let err = r.route(original).unwrap_err();
        assert_eq!(err.req.id, id);
        assert_eq!(err.req.prompt, vec![7, 8, 9], "request must come back intact");
        assert!(err.reason.contains("mock replica down"));
        assert_eq!(r.routed, 0);
    }

    #[test]
    fn failover_retries_on_the_next_healthy_replica() {
        let reps = mocks(&[0, 0]);
        reps[0].failing.set(true);
        let mut r = Router::new(reps, RoutePolicy::RoundRobin);
        let i = r.route(req(vec![1]).with_retries(1)).unwrap();
        assert_eq!(i, 1, "must fail over from replica 0");
        assert_eq!(r.failovers, 1);
        assert_eq!(r.replicas()[1].sent.get(), 1);
    }

    #[test]
    fn no_retry_budget_means_no_failover() {
        let reps = mocks(&[0, 0]);
        reps[0].failing.set(true);
        let mut r = Router::new(reps, RoutePolicy::RoundRobin);
        let err = r.route(req(vec![1])).unwrap_err();
        assert_eq!(err.req.retries_left, 0);
        assert_eq!(r.failovers, 0);
        assert_eq!(r.replicas()[1].sent.get(), 0, "no budget, no second attempt");
    }

    #[test]
    fn breaker_opens_after_consecutive_failures_and_reprobes() {
        let reps = mocks(&[0, 0]);
        reps[0].failing.set(true);
        let mut r = Router::with_seed(reps, RoutePolicy::RoundRobin, 7);
        // round-robin alternates the first attempt, so every other route
        // hits replica 0 (and fails over to 1); the third failure trips it
        for _ in 0..6 {
            assert_eq!(r.route(req(vec![1]).with_retries(1)).unwrap(), 1);
        }
        assert_eq!(r.breaker_opens, 1, "threshold consecutive failures trip the breaker");
        assert!(!r.is_healthy(0));
        // while open, traffic routes straight to 1 with no failover
        let failovers_before = r.failovers;
        for _ in 0..2 {
            assert_eq!(r.route(req(vec![1]).with_retries(1)).unwrap(), 1);
        }
        assert_eq!(r.failovers, failovers_before, "open breaker removes 0 from rotation");
        // replica recovers; after the hold-off a half-open probe succeeds
        // and the breaker closes
        r.replicas()[0].failing.set(false);
        for _ in 0..(MAX_BACKOFF + MAX_BACKOFF / 2) {
            let _ = r.route(req(vec![1]).with_retries(1)).unwrap();
        }
        assert!(r.is_healthy(0), "successful probe must close the breaker");
        assert!(r.replicas()[0].sent.get() > 0, "replica 0 rejoined the rotation");
    }
}
