//! Multi-replica request router (vllm-project/router-style): dispatches
//! requests across engine replicas by round-robin, least-loaded,
//! session-affinity hashing, or health/KV-aware scoring — with
//! per-replica health tracking (consecutive-failure circuit breaker on
//! the injectable serving clock, seeded half-open probes, supervisor
//! quarantine) and failover: a failed `submit` returns the request to
//! the router, which retries it on the next healthy replica while the
//! request's retry budget lasts (DESIGN.md §6).

use std::collections::HashMap;

use crate::kvcache::prefix_hashes;
use crate::util::clock::{SharedClock, WallClock};
use crate::util::rng::Rng;

use super::request::Request;

/// Consecutive submit failures that trip a replica's circuit breaker.
const FAILURE_THRESHOLD: u32 = 3;
/// Breaker hold-off after the first trip, in serving-clock milliseconds;
/// doubles per consecutive trip.
const BASE_BACKOFF_MS: u64 = 50;
/// Backoff growth cap in milliseconds (plus up to 50% seeded jitter).
const MAX_BACKOFF_MS: u64 = 800;
/// Affinity-map entries before the router forgets everything (bounds
/// memory on long-lived servers; cold restarts only cost prefix-cache
/// misses, not correctness).
const AFFINITY_CAP: usize = 8192;

/// How the router picks a replica for each request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas in order.
    RoundRobin,
    /// Pick the replica with the fewest pending requests.
    LeastLoaded,
    /// Hash the prompt prefix (session affinity: same session hits the same
    /// replica, maximising KV-cache locality in prefix-caching setups).
    Affinity,
    /// Health/KV-aware scoring over live [`ReplicaSignals`] (free pool
    /// pages, queue depth, prefill occupancy, heartbeat age), with
    /// prefix-affinity: a prompt whose first `PrefixIndex` page hash was
    /// last served by a live replica routes back to it.
    Scored,
}

impl RoutePolicy {
    /// Parse a CLI route-policy name (`rr`, `least`, `affinity`, `scored`).
    pub fn parse(s: &str) -> anyhow::Result<RoutePolicy> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rr" | "roundrobin" | "round-robin" => RoutePolicy::RoundRobin,
            "least" | "leastloaded" | "least-loaded" => RoutePolicy::LeastLoaded,
            "affinity" | "hash" => RoutePolicy::Affinity,
            "scored" | "kv" | "kv-aware" => RoutePolicy::Scored,
            other => anyhow::bail!("unknown route policy '{other}'"),
        })
    }
}

/// A failed hand-off that returns the request to the caller — the router
/// (for failover) or the submitter (to reply/retry) — instead of dropping
/// it on the floor.  Not an `anyhow::Error`: the request's reply channel
/// is `Send` but not `Sync`, and losing the request to an opaque error was
/// exactly the bug this type fixes.
pub struct SubmitError {
    /// The request, intact, so the caller can retry or answer it.
    pub req: Request,
    /// Why the hand-off failed.
    pub reason: String,
}

impl std::fmt::Debug for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SubmitError")
            .field("req_id", &self.req.id)
            .field("reason", &self.reason)
            .finish()
    }
}

/// Live placement signals a replica publishes (scored routing input).
/// Defaults are the "know nothing" neutral reading so mocks and
/// non-engine replicas keep working.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaSignals {
    /// Whether the replica accepts work (not killed/hung/crashed).
    pub alive: bool,
    /// Serving-clock ms since the replica's last tick-loop heartbeat.
    pub heartbeat_age_ms: u64,
    /// Free pages in the replica's KV pool.
    pub free_pages: usize,
    /// Depth of the replica's FIFO admission queue.
    pub queue_depth: usize,
    /// Prompts mid-prefill on the replica.
    pub prefilling: usize,
    /// Requests accepted but not yet answered.
    pub pending: usize,
}

impl Default for ReplicaSignals {
    fn default() -> Self {
        ReplicaSignals {
            alive: true,
            heartbeat_age_ms: 0,
            free_pages: 0,
            queue_depth: 0,
            prefilling: 0,
            pending: 0,
        }
    }
}

/// What the router needs from a replica (implemented by `EngineServer`;
/// mocked in tests).
pub trait Replica {
    /// Hand one request to this replica's mailbox; on failure the request
    /// comes back in the [`SubmitError`].
    fn submit(&self, req: Request) -> Result<(), SubmitError>;
    /// Requests this replica has accepted but not yet answered.
    fn pending(&self) -> usize;
    /// Live placement signals (default: neutral, always-alive reading for
    /// replicas that don't publish occupancy).
    fn signals(&self) -> ReplicaSignals {
        ReplicaSignals { pending: self.pending(), ..ReplicaSignals::default() }
    }
}

/// Per-replica breaker state (serving-clock milliseconds).
#[derive(Debug, Clone, Default)]
struct Health {
    /// Submit failures since the last success (resets on success/trip).
    consecutive_failures: u32,
    /// No traffic until this serving-clock ms; 0 = closed.
    open_until: u64,
    /// Consecutive breaker trips (exponential-backoff exponent); resets
    /// on the first successful probe.
    trips: u32,
    /// Supervisor verdict: the replica crashed or hung and is permanently
    /// out of rotation (unlike a breaker trip, this never half-opens).
    quarantined: bool,
}

/// Dispatches requests across engine replicas (DESIGN.md §5), failing
/// over around unhealthy ones (DESIGN.md §6).
pub struct Router<R: Replica> {
    replicas: Vec<R>,
    health: Vec<Health>,
    policy: RoutePolicy,
    next_rr: usize,
    /// Jitter stream for half-open backoff (deterministic per seed).
    rng: Rng,
    /// Serving clock the breaker and heartbeat-age scoring read.
    clock: SharedClock,
    /// KV page size for prefix-affinity hashing (must match the engines').
    page_size: usize,
    /// First-page prefix hash → replica that last served it.
    affinity: HashMap<u64, usize>,
    /// Requests routed so far.
    pub routed: u64,
    /// Submits retried on another replica after a failure.
    pub failovers: u64,
    /// Circuit-breaker trips (a replica taken out of rotation).
    pub breaker_opens: u64,
    /// Scored routes that landed on their prefix-affinity target.
    pub affinity_hits: u64,
    /// Replicas permanently removed from rotation by the supervisor.
    pub quarantines: u64,
}

impl<R: Replica> Router<R> {
    /// Router over at least one replica (jitter seed 0; see
    /// [`Router::with_seed`]).
    pub fn new(replicas: Vec<R>, policy: RoutePolicy) -> Self {
        Self::with_seed(replicas, policy, 0)
    }

    /// Router with an explicit backoff-jitter seed (wall clock; swap it
    /// with [`Router::with_clock`] for deterministic tests).
    pub fn with_seed(replicas: Vec<R>, policy: RoutePolicy, seed: u64) -> Self {
        assert!(!replicas.is_empty());
        let health = replicas.iter().map(|_| Health::default()).collect();
        Router {
            replicas,
            health,
            policy,
            next_rr: 0,
            rng: Rng::new(seed),
            clock: WallClock::shared(),
            page_size: 16,
            affinity: HashMap::new(),
            routed: 0,
            failovers: 0,
            breaker_opens: 0,
            affinity_hits: 0,
            quarantines: 0,
        }
    }

    /// Use `clock` for breaker backoff and heartbeat-age scoring (must be
    /// the same clock the replicas stamp heartbeats on).
    pub fn with_clock(mut self, clock: SharedClock) -> Self {
        self.clock = clock;
        self
    }

    /// KV page size for prefix-affinity hashing; must match the engines'
    /// page size or affinity keys never match the prefix cache.
    pub fn with_page_size(mut self, page_size: usize) -> Self {
        self.page_size = page_size.max(1);
        self
    }

    /// The replica set, in submission-index order.
    pub fn replicas(&self) -> &[R] {
        &self.replicas
    }

    /// Consume the router, returning its replicas (for shutdown).
    pub fn into_replicas(self) -> Vec<R> {
        self.replicas
    }

    /// Permanently remove replica `i` from rotation (supervisor verdict
    /// after a crash or hang; unlike a breaker trip it never half-opens).
    pub fn quarantine(&mut self, i: usize) {
        if !self.health[i].quarantined {
            self.health[i].quarantined = true;
            self.quarantines += 1;
        }
    }

    /// Whether replica `i` is quarantined.
    pub fn is_quarantined(&self, i: usize) -> bool {
        self.health[i].quarantined
    }

    /// Whether replica `i`'s breaker admits traffic right now (not
    /// quarantined, and closed or open long enough to half-open probe).
    pub fn is_healthy(&self, i: usize) -> bool {
        let h = &self.health[i];
        !h.quarantined && h.open_until <= self.clock.now_ms()
    }

    /// Replica indices the breaker currently admits.
    fn available(&self) -> Vec<usize> {
        (0..self.replicas.len()).filter(|&i| self.is_healthy(i)).collect()
    }

    /// First-page prefix hash of `prompt` (the affinity key), if the
    /// prompt spans at least one full KV page.
    fn affinity_key(&self, prompt: &[u32]) -> Option<u64> {
        prefix_hashes(prompt, self.page_size).first().copied()
    }

    /// Health/KV-aware placement score for replica `i`: free pool pages
    /// minus load/queue/prefill pressure, discounted by heartbeat age.
    /// Higher is better; a non-accepting replica scores `-inf`.
    fn score(&self, i: usize) -> f64 {
        let s = self.replicas[i].signals();
        if !s.alive {
            return f64::NEG_INFINITY;
        }
        s.free_pages as f64
            - 2.0 * (s.pending + s.queue_depth) as f64
            - s.prefilling as f64
            - s.heartbeat_age_ms as f64 / 50.0
    }

    /// Apply the route policy over the available set, returning a
    /// position *within* `avail`.
    fn pick(&mut self, req: &Request, akey: Option<u64>, avail: &[usize]) -> usize {
        match self.policy {
            RoutePolicy::RoundRobin => {
                let p = self.next_rr % avail.len();
                self.next_rr = (self.next_rr + 1) % avail.len();
                p
            }
            RoutePolicy::LeastLoaded => avail
                .iter()
                .enumerate()
                .min_by_key(|(_, &i)| self.replicas[i].pending())
                .map(|(p, _)| p)
                .unwrap(),
            RoutePolicy::Affinity => {
                // FNV-1a over the first 8 prompt tokens + avalanche finaliser
                // (low-entropy token ids need the final mix to spread mod n)
                let mut h: u64 = 0xcbf29ce484222325;
                for &t in req.prompt.iter().take(8) {
                    h ^= t as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
                h = (h ^ (h >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
                h = (h ^ (h >> 27)).wrapping_mul(0x94d049bb133111eb);
                h ^= h >> 31;
                (h % avail.len() as u64) as usize
            }
            RoutePolicy::Scored => {
                // prefix-affinity first: the replica holding this prompt's
                // first KV page skips that prefill work entirely
                if let Some(target) = akey.and_then(|k| self.affinity.get(&k).copied()) {
                    if let Some(p) = avail.iter().position(|&i| i == target) {
                        self.affinity_hits += 1;
                        return p;
                    }
                }
                // otherwise the best-scoring live replica (falls back to
                // position 0 if every candidate scores -inf — the failover
                // loop will rotate off it)
                let mut best = 0usize;
                let mut best_score = f64::NEG_INFINITY;
                for (p, &i) in avail.iter().enumerate() {
                    let sc = self.score(i);
                    if sc > best_score {
                        best_score = sc;
                        best = p;
                    }
                }
                best
            }
        }
    }

    fn on_success(&mut self, i: usize) {
        let h = &mut self.health[i];
        h.consecutive_failures = 0;
        h.open_until = 0;
        h.trips = 0;
    }

    fn on_failure(&mut self, i: usize, now: u64) {
        let half_open = {
            let h = &self.health[i];
            h.trips > 0 && h.open_until <= now
        };
        let trip = {
            let h = &mut self.health[i];
            h.consecutive_failures += 1;
            half_open || h.consecutive_failures >= FAILURE_THRESHOLD
        };
        if trip {
            let h = &mut self.health[i];
            h.trips += 1;
            h.consecutive_failures = 0;
            let backoff = (BASE_BACKOFF_MS << (h.trips - 1).min(4)).min(MAX_BACKOFF_MS);
            let jitter = self.rng.range(0, backoff as usize / 2 + 1) as u64;
            self.health[i].open_until = now + backoff + jitter;
            self.breaker_opens += 1;
        }
    }

    /// Route one request: pick a replica by policy among the healthy set,
    /// and on a failed `submit` fail over to the next healthy replica
    /// while the request's retry budget lasts.  Returns the replica index
    /// that accepted the request, or the request itself (in the
    /// [`SubmitError`]) when every attempt failed — never loses it.
    pub fn route(&mut self, req: Request) -> Result<usize, SubmitError> {
        let now = self.clock.now_ms();
        let mut avail = self.available();
        if avail.is_empty() {
            // every breaker is open: force-probe the soonest non-quarantined
            // replica to recover rather than deadlock the fleet
            match (0..self.replicas.len())
                .filter(|&i| !self.health[i].quarantined)
                .min_by_key(|&i| self.health[i].open_until)
            {
                Some(i) => avail.push(i),
                None => {
                    return Err(SubmitError {
                        req,
                        reason: "every replica is quarantined".to_string(),
                    });
                }
            }
        }
        let akey = match self.policy {
            RoutePolicy::Scored => self.affinity_key(&req.prompt),
            _ => None,
        };
        let start = self.pick(&req, akey, &avail);
        let mut req = req;
        let mut last_reason = String::new();
        for attempt in 0..avail.len() {
            if attempt > 0 {
                if req.retries_left == 0 {
                    break;
                }
                req.retries_left -= 1;
                self.failovers += 1;
            }
            let i = avail[(start + attempt) % avail.len()];
            match self.replicas[i].submit(req) {
                Ok(()) => {
                    self.on_success(i);
                    self.routed += 1;
                    if let (RoutePolicy::Scored, Some(k)) = (self.policy, akey) {
                        if self.affinity.len() >= AFFINITY_CAP {
                            self.affinity.clear();
                        }
                        self.affinity.insert(k, i);
                    }
                    return Ok(i);
                }
                Err(se) => {
                    req = se.req;
                    last_reason = se.reason;
                    self.on_failure(i, now);
                }
            }
        }
        Err(SubmitError { req, reason: format!("no replica accepted: {last_reason}") })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::SimClock;
    use std::cell::Cell;
    use std::sync::mpsc::channel;

    struct MockReplica {
        sent: Cell<usize>,
        load: usize,
        /// When set, every submit fails and hands the request back.
        failing: Cell<bool>,
        /// Signals returned by `signals()` (scored-policy tests).
        sig: ReplicaSignals,
    }
    impl Replica for MockReplica {
        fn submit(&self, req: Request) -> Result<(), SubmitError> {
            if self.failing.get() {
                return Err(SubmitError { req, reason: "mock replica down".to_string() });
            }
            self.sent.set(self.sent.get() + 1);
            Ok(())
        }
        fn pending(&self) -> usize {
            self.load
        }
        fn signals(&self) -> ReplicaSignals {
            self.sig
        }
    }

    fn req(prompt: Vec<u32>) -> Request {
        let (tx, _rx) = channel();
        // leak the receiver side: mock never replies
        std::mem::forget(_rx);
        Request::new(0, prompt, 1, tx)
    }

    fn mocks(loads: &[usize]) -> Vec<MockReplica> {
        loads
            .iter()
            .map(|&l| MockReplica {
                sent: Cell::new(0),
                load: l,
                failing: Cell::new(false),
                sig: ReplicaSignals { pending: l, ..ReplicaSignals::default() },
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(mocks(&[0, 0, 0]), RoutePolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| r.route(req(vec![1])).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(r.routed, 6);
    }

    #[test]
    fn least_loaded_prefers_idle() {
        let mut r = Router::new(mocks(&[5, 0, 9]), RoutePolicy::LeastLoaded);
        assert_eq!(r.route(req(vec![1])).unwrap(), 1);
    }

    #[test]
    fn affinity_is_deterministic_and_spreads() {
        let mut r = Router::new(mocks(&[0, 0, 0, 0]), RoutePolicy::Affinity);
        let a1 = r.route(req(vec![1, 2, 3])).unwrap();
        let a2 = r.route(req(vec![1, 2, 3])).unwrap();
        assert_eq!(a1, a2, "same session, same replica");
        let mut hit = std::collections::BTreeSet::new();
        for seed in 0..32u32 {
            hit.insert(r.route(req(vec![seed, seed + 1])).unwrap());
        }
        assert!(hit.len() >= 3, "hashing should spread sessions: {hit:?}");
    }

    #[test]
    fn policy_parse() {
        assert_eq!(RoutePolicy::parse("rr").unwrap(), RoutePolicy::RoundRobin);
        assert_eq!(RoutePolicy::parse("scored").unwrap(), RoutePolicy::Scored);
        assert!(RoutePolicy::parse("nope").is_err());
    }

    #[test]
    fn failed_submit_returns_the_request_to_the_caller() {
        // The regression this PR fixes: a failed submit used to discard
        // the request (reply channel and all); now it comes back intact.
        let reps = mocks(&[0]);
        reps[0].failing.set(true);
        let mut r = Router::new(reps, RoutePolicy::RoundRobin);
        let original = req(vec![7, 8, 9]);
        let id = original.id;
        let err = r.route(original).unwrap_err();
        assert_eq!(err.req.id, id);
        assert_eq!(err.req.prompt, vec![7, 8, 9], "request must come back intact");
        assert!(err.reason.contains("mock replica down"));
        assert_eq!(r.routed, 0);
    }

    #[test]
    fn failover_retries_on_the_next_healthy_replica() {
        let reps = mocks(&[0, 0]);
        reps[0].failing.set(true);
        let mut r = Router::new(reps, RoutePolicy::RoundRobin);
        let i = r.route(req(vec![1]).with_retries(1)).unwrap();
        assert_eq!(i, 1, "must fail over from replica 0");
        assert_eq!(r.failovers, 1);
        assert_eq!(r.replicas()[1].sent.get(), 1);
    }

    #[test]
    fn no_retry_budget_means_no_failover() {
        let reps = mocks(&[0, 0]);
        reps[0].failing.set(true);
        let mut r = Router::new(reps, RoutePolicy::RoundRobin);
        let err = r.route(req(vec![1])).unwrap_err();
        assert_eq!(err.req.retries_left, 0);
        assert_eq!(r.failovers, 0);
        assert_eq!(r.replicas()[1].sent.get(), 0, "no budget, no second attempt");
    }

    #[test]
    fn breaker_opens_after_consecutive_failures_and_reprobes() {
        let sim = SimClock::new();
        let reps = mocks(&[0, 0]);
        reps[0].failing.set(true);
        let mut r =
            Router::with_seed(reps, RoutePolicy::RoundRobin, 7).with_clock(sim.clone());
        // round-robin alternates the first attempt, so every other route
        // hits replica 0 (and fails over to 1); the third failure trips it
        for _ in 0..6 {
            assert_eq!(r.route(req(vec![1]).with_retries(1)).unwrap(), 1);
        }
        assert_eq!(r.breaker_opens, 1, "threshold consecutive failures trip the breaker");
        assert!(!r.is_healthy(0));
        // while open, traffic routes straight to 1 with no failover — and
        // since the sim clock is frozen, the breaker cannot half-open
        let failovers_before = r.failovers;
        for _ in 0..4 {
            assert_eq!(r.route(req(vec![1]).with_retries(1)).unwrap(), 1);
        }
        assert_eq!(r.failovers, failovers_before, "open breaker removes 0 from rotation");
        // replica recovers; advancing past base backoff + max jitter makes
        // the next route half-open probe replica 0 and close its breaker
        r.replicas()[0].failing.set(false);
        sim.advance(BASE_BACKOFF_MS + BASE_BACKOFF_MS / 2 + 1);
        assert!(r.is_healthy(0), "hold-off elapsed on the sim clock");
        for _ in 0..4 {
            let _ = r.route(req(vec![1]).with_retries(1)).unwrap();
        }
        assert!(r.is_healthy(0), "successful probe must close the breaker");
        assert!(r.replicas()[0].sent.get() > 0, "replica 0 rejoined the rotation");
    }

    #[test]
    fn scored_prefers_free_pages_and_low_load() {
        let mut reps = mocks(&[0, 0, 0]);
        reps[0].sig = ReplicaSignals { free_pages: 10, pending: 4, ..ReplicaSignals::default() };
        reps[1].sig = ReplicaSignals { free_pages: 100, pending: 0, ..ReplicaSignals::default() };
        reps[2].sig = ReplicaSignals { free_pages: 100, queue_depth: 40, ..Default::default() };
        let mut r = Router::new(reps, RoutePolicy::Scored);
        assert_eq!(r.route(req(vec![1])).unwrap(), 1, "most free pages, least pressure");
    }

    #[test]
    fn scored_shuns_dead_and_stale_replicas() {
        let mut reps = mocks(&[0, 0]);
        reps[0].sig =
            ReplicaSignals { alive: false, free_pages: 1_000_000, ..ReplicaSignals::default() };
        reps[1].sig = ReplicaSignals { free_pages: 1, ..ReplicaSignals::default() };
        let mut r = Router::new(reps, RoutePolicy::Scored);
        assert_eq!(r.route(req(vec![1])).unwrap(), 1, "dead replica scores -inf");
        // stale heartbeat discounts an otherwise-attractive replica
        let mut reps = mocks(&[0, 0]);
        reps[0].sig =
            ReplicaSignals { free_pages: 50, heartbeat_age_ms: 10_000, ..Default::default() };
        reps[1].sig = ReplicaSignals { free_pages: 40, ..ReplicaSignals::default() };
        let mut r = Router::new(reps, RoutePolicy::Scored);
        assert_eq!(r.route(req(vec![1])).unwrap(), 1, "stale heartbeat loses the tiebreak");
    }

    #[test]
    fn scored_prefix_affinity_hits_and_falls_back_when_unhealthy() {
        // page_size 4 so an 8-token prompt has a stable first-page hash
        let prompt: Vec<u32> = vec![5, 6, 7, 8, 9, 10, 11, 12];
        let mut reps = mocks(&[0, 0, 0]);
        // replica 2 scores best initially, capturing the affinity entry
        reps[2].sig = ReplicaSignals { free_pages: 100, ..ReplicaSignals::default() };
        let mut r = Router::new(reps, RoutePolicy::Scored).with_page_size(4);
        assert_eq!(r.route(req(prompt.clone())).unwrap(), 2);
        assert_eq!(r.affinity_hits, 0, "first route is a placement, not a hit");
        // same prefix routes back to 2 even though scores are now equal
        assert_eq!(r.route(req(prompt.clone())).unwrap(), 2);
        assert_eq!(r.affinity_hits, 1);
        // quarantine the affinity target: same prefix must fall back to a
        // healthy replica and re-point the affinity entry at it
        r.quarantine(2);
        let fallback = r.route(req(prompt.clone())).unwrap();
        assert_ne!(fallback, 2, "quarantined replica is out of rotation");
        assert_eq!(r.affinity_hits, 1, "fallback is not an affinity hit");
        let again = r.route(req(prompt)).unwrap();
        assert_eq!(again, fallback, "affinity re-points to the fallback replica");
        assert_eq!(r.affinity_hits, 2);
    }

    #[test]
    fn all_quarantined_returns_the_request() {
        let mut r = Router::new(mocks(&[0, 0]), RoutePolicy::Scored);
        r.quarantine(0);
        r.quarantine(1);
        assert_eq!(r.quarantines, 2);
        let err = r.route(req(vec![1, 2])).unwrap_err();
        assert!(err.reason.contains("quarantined"));
        assert_eq!(err.req.prompt, vec![1, 2]);
    }
}
