//! Replica supervision (DESIGN.md §6): the supervisor owns the fleet's
//! [`Router`], keeps a shadow registry of every in-flight request, and
//! watches each replica's lock-free [`ReplicaStatus`] signals.  A crash
//! (panic on the replica thread) hands the drained requests back through
//! a [`ReplicaEvent`]; a hang (stale heartbeat with pending work and no
//! tick progress, confirmed on two consecutive polls) is killed via the
//! cooperative kill flag.  Either way the dead replica is quarantined and
//! its requests re-dispatched from their original prompts — decode is
//! batch-composition-invariant (DESIGN.md §4), so recovered requests'
//! tokens are bit-identical to a fault-free run, and the shadow registry
//! dedups any zombie reply so every request resolves to exactly one
//! [`Outcome`](super::request::Outcome).

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use anyhow::Result;

use crate::config::EngineConfig;
use crate::runtime::FaultSchedule;
use crate::util::clock::SharedClock;

use super::batcher::BatcherConfig;
use super::request::{Request, RequestId, Response};
use super::router::{RoutePolicy, Router, SubmitError};
use super::server::{EngineServer, ReplicaEvent, SpawnOpts};

/// Supervision knobs.
#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// Heartbeat age (serving-clock ms) past which a replica with pending
    /// work is suspected hung; confirmed (no tick progress) on the next
    /// poll.
    pub hang_timeout_ms: u64,
    /// Router retry budget granted to re-dispatched requests.
    pub redispatch_retries: u32,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig { hang_timeout_ms: 1000, redispatch_retries: 4 }
    }
}

/// Shadow copy of one in-flight request: enough to rebuild it from the
/// original prompt if its replica dies, plus the caller's reply channel
/// (the live request's reply is swapped to the supervisor so it can
/// intercept, dedup, and forward).
struct Tracked {
    replica: usize,
    prompt: Vec<u32>,
    max_new: usize,
    deadline_ms: Option<u64>,
    arrived_ms: Option<u64>,
    submitted: Instant,
    reply: Sender<Response>,
}

/// Two-strike watchdog state per replica.
#[derive(Debug, Clone, Copy, Default)]
struct Watch {
    /// Tick counter at the first strike.
    ticks_at_strike: u64,
    /// A strike is pending confirmation.
    striked: bool,
}

/// Supervises a fleet of [`EngineServer`] replicas behind a [`Router`].
pub struct Supervisor {
    router: Router<EngineServer>,
    registry: HashMap<RequestId, Tracked>,
    resp_tx: Sender<Response>,
    resp_rx: Receiver<Response>,
    ev_rx: Receiver<ReplicaEvent>,
    clock: SharedClock,
    cfg: SupervisorConfig,
    watch: Vec<Watch>,
    dead: Vec<bool>,
    /// Replicas the watchdog declared hung and killed.
    pub hangs: u64,
    /// Replica threads that crashed (panicked).
    pub crashes: u64,
    /// Requests re-dispatched off a dead replica.
    pub redispatched: u64,
    /// Responses forwarded to callers.
    pub completed: u64,
    /// Zombie replies (already answered elsewhere) swallowed by the
    /// registry dedup.
    pub duplicates_dropped: u64,
}

impl Supervisor {
    /// Spawn `n` supervised replicas sharing `clock`, with optional
    /// per-replica fault schedules (`faults[i]` drives replica `i`; the
    /// vec may be shorter than `n`).
    #[allow(clippy::too_many_arguments)]
    pub fn spawn(
        n: usize,
        cfg: EngineConfig,
        bcfg: BatcherConfig,
        caps: Option<Vec<usize>>,
        route: RoutePolicy,
        scfg: SupervisorConfig,
        clock: SharedClock,
        mut faults: Vec<Option<FaultSchedule>>,
    ) -> Result<Supervisor> {
        assert!(n > 0, "supervisor needs at least one replica");
        faults.resize_with(n, || None);
        let page_size = cfg.resolve_meta()?.page_size;
        let seed = cfg.seed;
        let (ev_tx, ev_rx) = channel::<ReplicaEvent>();
        let mut servers = Vec::with_capacity(n);
        for (i, fault) in faults.into_iter().enumerate() {
            let opts = SpawnOpts {
                index: i,
                clock: clock.clone(),
                faults: fault,
                events: Some(ev_tx.clone()),
            };
            servers.push(EngineServer::spawn_supervised(
                format!("r{i}"),
                cfg.clone(),
                bcfg.clone(),
                caps.clone(),
                opts,
            )?);
        }
        let router = Router::with_seed(servers, route, seed)
            .with_clock(clock.clone())
            .with_page_size(page_size);
        let (resp_tx, resp_rx) = channel::<Response>();
        Ok(Supervisor {
            router,
            registry: HashMap::new(),
            resp_tx,
            resp_rx,
            ev_rx,
            clock,
            cfg: scfg,
            watch: vec![Watch::default(); n],
            dead: vec![false; n],
            hangs: 0,
            crashes: 0,
            redispatched: 0,
            completed: 0,
            duplicates_dropped: 0,
        })
    }

    /// Submit one request: its reply is intercepted by the supervisor
    /// (for dedup + recovery) and forwarded to the original channel on
    /// completion.  On routing failure the request comes back intact.
    pub fn submit(&mut self, mut req: Request) -> Result<usize, SubmitError> {
        let caller_reply = std::mem::replace(&mut req.reply, self.resp_tx.clone());
        let shadow = Tracked {
            replica: usize::MAX,
            prompt: req.prompt.clone(),
            max_new: req.max_new,
            deadline_ms: req.deadline_ms,
            arrived_ms: req.arrived_ms,
            submitted: req.submitted,
            reply: caller_reply,
        };
        let id = req.id;
        match self.router.route(req) {
            Ok(i) => {
                let mut shadow = shadow;
                shadow.replica = i;
                self.registry.insert(id, shadow);
                Ok(i)
            }
            Err(mut se) => {
                se.req.reply = shadow.reply;
                Err(se)
            }
        }
    }

    /// One supervision pass: forward finished responses, handle lifecycle
    /// events (crash recovery), run the hang watchdog, and fail leftovers
    /// if the whole fleet is dead.  Returns `true` when no request is
    /// outstanding.
    pub fn poll(&mut self) -> bool {
        self.pump_responses();
        self.pump_events();
        self.watchdog();
        if self.dead.iter().all(|&d| d) && !self.registry.is_empty() {
            self.fail_all("every replica is dead");
        }
        self.registry.is_empty()
    }

    /// Requests currently tracked (submitted, not yet resolved).
    pub fn outstanding(&self) -> usize {
        self.registry.len()
    }

    /// Whether the supervisor has declared replica `i` dead.
    pub fn is_dead(&self, i: usize) -> bool {
        self.dead[i]
    }

    /// The underlying router (counters, replica signals).
    pub fn router(&self) -> &Router<EngineServer> {
        &self.router
    }

    /// Poll until idle or `max_polls` passes elapse; returns whether the
    /// fleet went idle.  (Wall-clock callers only — with a [`SimClock`]
    /// the caller must advance time between polls itself.)
    ///
    /// [`SimClock`]: crate::util::clock::SimClock
    pub fn run_until_idle(&mut self, max_polls: u64) -> bool {
        for _ in 0..max_polls {
            if self.poll() {
                return true;
            }
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        self.poll()
    }

    /// Drain replicas and join their threads.
    pub fn shutdown(self) {
        for r in self.router.into_replicas() {
            r.shutdown();
        }
    }

    fn pump_responses(&mut self) {
        while let Ok(resp) = self.resp_rx.try_recv() {
            match self.registry.remove(&resp.id) {
                Some(t) => {
                    self.completed += 1;
                    let _ = t.reply.send(resp);
                }
                None => self.duplicates_dropped += 1,
            }
        }
    }

    fn pump_events(&mut self) {
        let events: Vec<ReplicaEvent> =
            std::iter::from_fn(|| self.ev_rx.try_recv().ok()).collect();
        for ev in events {
            match ev {
                ReplicaEvent::Crashed { replica, requests, panic_msg } => {
                    self.crashes += 1;
                    self.mark_dead(replica);
                    // answers that raced out before the panic: forward them
                    // first so re-dispatch can't double-serve those ids
                    self.pump_responses();
                    self.redispatch_requests(requests, &panic_msg);
                    self.recover_stragglers(replica, &format!("replica crashed: {panic_msg}"));
                }
                ReplicaEvent::Stopped { .. } => {}
            }
        }
    }

    fn mark_dead(&mut self, i: usize) {
        if !self.dead[i] {
            self.dead[i] = true;
            self.router.quarantine(i);
        }
    }

    /// Two-strike hang detection: a replica with pending work whose
    /// heartbeat is stale *and* whose tick counter did not move between
    /// two polls is hung (an OS-descheduled replica still ticks; a wedged
    /// one does not).  Verdict: kill + quarantine + re-dispatch.
    fn watchdog(&mut self) {
        use std::sync::atomic::Ordering;
        let now = self.clock.now_ms();
        let mut hung: Vec<usize> = Vec::new();
        for i in 0..self.router.replicas().len() {
            if self.dead[i] {
                continue;
            }
            let status = &self.router.replicas()[i].status;
            let pending = status.load.load(Ordering::Relaxed);
            let hb = status.heartbeat_ms.load(Ordering::Relaxed);
            let ticks = status.ticks.load(Ordering::Relaxed);
            let stale = pending > 0 && now.saturating_sub(hb) >= self.cfg.hang_timeout_ms;
            let w = &mut self.watch[i];
            if !stale {
                w.striked = false;
            } else if !w.striked || ticks != w.ticks_at_strike {
                // first strike (or progress since the last one): note the
                // tick counter and confirm on the next poll
                w.striked = true;
                w.ticks_at_strike = ticks;
            } else {
                hung.push(i);
            }
        }
        for i in hung {
            self.hangs += 1;
            self.router.replicas()[i].mark_hung();
            self.mark_dead(i);
            self.pump_responses();
            self.recover_stragglers(i, "replica hung (watchdog)");
        }
    }

    /// Re-dispatch requests drained off a dead replica.  Requests whose
    /// id already left the registry (answered before the fault) are
    /// dropped — re-running them would double-answer.
    fn redispatch_requests(&mut self, requests: Vec<Request>, why: &str) {
        for mut req in requests {
            if !self.registry.contains_key(&req.id) {
                continue;
            }
            req.retries_left = req.retries_left.max(self.cfg.redispatch_retries);
            let id = req.id;
            match self.router.route(req) {
                Ok(i) => {
                    self.redispatched += 1;
                    if let Some(t) = self.registry.get_mut(&id) {
                        t.replica = i;
                    }
                }
                Err(se) => self.fail_one(se.req.id, &format!("{why}; re-dispatch: {}", se.reason)),
            }
        }
    }

    /// Rebuild and re-dispatch every registry entry still pointing at
    /// dead replica `i` (hang recovery: the wedged thread can't drain its
    /// own batcher, but the shadow registry has everything needed).
    fn recover_stragglers(&mut self, i: usize, why: &str) {
        let ids: Vec<RequestId> = self
            .registry
            .iter()
            .filter(|(_, t)| t.replica == i)
            .map(|(&id, _)| id)
            .collect();
        let rebuilt: Vec<Request> = ids
            .iter()
            .map(|&id| {
                let t = &self.registry[&id];
                let mut req = Request::new(id, t.prompt.clone(), t.max_new, self.resp_tx.clone());
                req.deadline_ms = t.deadline_ms;
                req.arrived_ms = t.arrived_ms; // keep the original deadline budget
                req.submitted = t.submitted; // and the original JCT origin
                req
            })
            .collect();
        self.redispatch_requests(rebuilt, why);
    }

    fn fail_one(&mut self, id: RequestId, why: &str) {
        if let Some(t) = self.registry.remove(&id) {
            let _ = t.reply.send(Response::err(id, t.submitted, why.to_string()));
        }
    }

    fn fail_all(&mut self, why: &str) {
        let ids: Vec<RequestId> = self.registry.keys().copied().collect();
        for id in ids {
            self.fail_one(id, why);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{BackendKind, EngineConfig};
    use crate::coordinator::request::Outcome;
    use crate::util::clock::SimClock;
    use std::sync::mpsc::channel;

    fn sim_cfg(seed: u64) -> EngineConfig {
        EngineConfig { backend: BackendKind::Sim, seed, ..EngineConfig::default() }
    }

    #[test]
    fn crash_with_no_survivor_fails_requests_instead_of_deadlocking() {
        let sim = SimClock::new();
        let faults = vec![Some(FaultSchedule::new(1).crash_at_tick(0))];
        let mut sup = Supervisor::spawn(
            1,
            sim_cfg(3),
            BatcherConfig::default(),
            Some(vec![64, 128]),
            RoutePolicy::Scored,
            SupervisorConfig { hang_timeout_ms: 200, redispatch_retries: 2 },
            sim.clone(),
            faults,
        )
        .expect("spawn");
        let (tx, rx) = channel();
        let mut accepted = 0u32;
        let mut rejected = 0u32;
        for id in 0..3u64 {
            let req = Request::new(id, vec![1, 2, 3, 4], 4, tx.clone());
            match sup.submit(req) {
                Ok(_) => accepted += 1,
                // the replica may already be dead by the later submits —
                // answer those directly, as a driver would
                Err(se) => {
                    rejected += 1;
                    let _ = se.req.reply.send(Response::err(
                        se.req.id,
                        se.req.submitted,
                        se.reason,
                    ));
                }
            }
        }
        assert!(accepted >= 1, "the first submit precedes the crash");
        let mut polls = 0u64;
        while !sup.poll() {
            sim.advance(50);
            std::thread::sleep(std::time::Duration::from_micros(300));
            polls += 1;
            assert!(polls < 20_000, "supervisor must not deadlock");
        }
        drop(tx);
        let mut outcomes: Vec<(u64, Outcome)> = rx.iter().map(|r| (r.id, r.outcome)).collect();
        outcomes.sort_unstable();
        assert_eq!(outcomes.len(), 3, "exactly one outcome per request");
        assert_eq!(
            outcomes.iter().map(|&(id, _)| id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "every id answered exactly once"
        );
        assert!(
            outcomes.iter().all(|&(_, o)| o == Outcome::Failed),
            "sole replica crashed: everything fails, nothing hangs: {outcomes:?}"
        );
        assert_eq!(sup.crashes, 1);
        assert_eq!(accepted + rejected, 3);
        sup.shutdown();
    }
}
