//! The execution-backend abstraction: everything the engine needs from a
//! model implementation, per decode step (DESIGN.md §4).
//!
//! Two implementations ship in-tree:
//!
//! * [`crate::runtime::SimBackend`] (default) — a deterministic, seeded
//!   pure-Rust transformer surrogate.  No native dependencies; used by CI
//!   and every figure harness that does not need trained weights.
//! * `ModelRuntime` (`--features backend-xla`) — the PJRT/HLO-text runtime
//!   over the AOT artifacts produced by `python/compile/aot.py`.
//!
//! The engine is written against this trait only; backends are selected at
//! runtime through [`crate::config::BackendKind`].

use anyhow::Result;

use crate::config::ModelSpec;

/// Output of one layer-qkv call.
pub struct Qkv {
    /// `[n_heads * head_dim]`, RoPE applied (or surrogate equivalent).
    pub q: Vec<f32>,
    /// `[n_kv_heads * head_dim]`, RoPE applied.
    pub k: Vec<f32>,
    /// `[n_kv_heads * head_dim]`.
    pub v: Vec<f32>,
}

/// Output of a dense prefill call.
pub struct PrefillOut {
    /// `[n_layers][padded][kv_dim]` post-RoPE keys.
    pub k: Vec<f32>,
    /// `[n_layers][padded][kv_dim]` values.
    pub v: Vec<f32>,
    /// Next-token logits `[vocab]`.
    pub logits: Vec<f32>,
    /// Padded sequence length of the `k`/`v` buffers.
    pub padded: usize,
}

impl PrefillOut {
    /// Slice one (layer, position) KV vector out of the prefill buffers.
    pub fn kv_at(&self, spec: &ModelSpec, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        let kv_dim = spec.n_kv_heads * spec.head_dim;
        let stride_layer = self.padded * kv_dim;
        let off = layer * stride_layer + pos * kv_dim;
        (&self.k[off..off + kv_dim], &self.v[off..off + kv_dim])
    }
}

/// A model execution backend.
///
/// The engine drives it per decode token, per layer:
/// `embed_tok` → `layer_qkv` → (policy select + gather) → `layer_attn_mlp`
/// → … → `lm_head`; prompts go through `prefill` in one call.
pub trait Backend: std::fmt::Debug {
    /// Short backend identifier (`"sim"`, `"xla"`).
    fn name(&self) -> &'static str;

    /// Architecture of the served model.
    fn spec(&self) -> &ModelSpec;

    /// Slot capacities this backend can attend over (informational; the
    /// ladder of compiled kernel shapes for AOT backends).
    fn capacities(&self) -> Vec<usize>;

    /// Smallest supported slot capacity >= `n_slots`.
    fn capacity_for(&self, n_slots: usize) -> Result<usize>;

    /// token -> hidden `[d_model]`.
    fn embed_tok(&self, token: u32) -> Result<Vec<f32>>;

    /// hidden `[d_model]` + absolute position -> (q, k, v).
    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv>;

    /// Attention over gathered slots + MLP.  `k_sel`/`v_sel` are
    /// `[capacity * kv_dim]`, `valid` is `[capacity]`; returns hidden'
    /// `[d_model]`.
    #[allow(clippy::too_many_arguments)]
    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>>;

    /// hidden `[d_model]` -> logits `[vocab]`.
    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>>;

    /// Dense prefill of `tokens`; returns per-layer post-RoPE KV for the
    /// first `tokens.len()` positions plus next-token logits.
    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_kv_slicing() {
        let spec = ModelSpec {
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 2,
            d_ff: 8,
        };
        let kv_dim = 2;
        let padded = 3;
        // k[layer][pos][c] = 100*layer + 10*pos + c
        let mut k = Vec::new();
        for layer in 0..2 {
            for pos in 0..padded {
                for c in 0..kv_dim {
                    k.push((100 * layer + 10 * pos + c) as f32);
                }
            }
        }
        let out = PrefillOut { k: k.clone(), v: k, logits: vec![], padded };
        let (ks, vs) = out.kv_at(&spec, 1, 2);
        assert_eq!(ks, &[120.0, 121.0]);
        assert_eq!(vs, &[120.0, 121.0]);
        let (ks, _) = out.kv_at(&spec, 0, 0);
        assert_eq!(ks, &[0.0, 1.0]);
    }
}
