//! The execution-backend abstraction: everything the engine needs from a
//! model implementation, per decode step (DESIGN.md §4).
//!
//! Two implementations ship in-tree:
//!
//! * [`crate::runtime::SimBackend`] (default) — a deterministic, seeded
//!   pure-Rust transformer surrogate.  No native dependencies; used by CI
//!   and every figure harness that does not need trained weights.
//! * `ModelRuntime` (`--features backend-xla`) — the PJRT/HLO-text runtime
//!   over the AOT artifacts produced by `python/compile/aot.py`.
//!
//! The engine is written against this trait only; backends are selected at
//! runtime through [`crate::config::BackendKind`].

use anyhow::{bail, Result};

use crate::config::ModelSpec;
use crate::kvcache::PageView;

/// Output of one layer-qkv call.
pub struct Qkv {
    /// `[n_heads * head_dim]`, RoPE applied (or surrogate equivalent).
    pub q: Vec<f32>,
    /// `[n_kv_heads * head_dim]`, RoPE applied.
    pub k: Vec<f32>,
    /// `[n_kv_heads * head_dim]`.
    pub v: Vec<f32>,
}

/// One sequence's inputs to a batched qkv call (DESIGN.md §2, batched
/// dataflow): the current hidden state and the absolute position of the
/// token being decoded.
pub struct QkvBatchItem<'a> {
    /// hidden `[d_model]`.
    pub h: &'a [f32],
    /// Absolute position of the decoded token.
    pub pos: usize,
}

/// One sequence's inputs to a batched attention+MLP call.  The slices have
/// the same shapes as the per-item [`Backend::layer_attn_mlp`] arguments;
/// `capacity` may differ between items (each sequence pads its gathered
/// selection to its own ladder capacity).
pub struct AttnBatchItem<'a> {
    /// Padded slot capacity of this item's gathered buffers.
    pub capacity: usize,
    /// hidden `[d_model]`.
    pub h: &'a [f32],
    /// query `[n_heads * head_dim]`.
    pub q: &'a [f32],
    /// gathered keys `[capacity * kv_dim]`.
    pub k_sel: &'a [f32],
    /// gathered values `[capacity * kv_dim]`.
    pub v_sel: &'a [f32],
    /// slot validity `[capacity]` (1.0 = real slot, 0.0 = padding).
    pub valid: &'a [f32],
}

/// Zero-copy input to the paged attention entry points (DESIGN.md §2,
/// paged route): the selected pages' K/V viewed *in place* in the pool
/// slabs — no gather copy, no capacity padding, no `valid` mask.  Views
/// are dtype-tagged ([`PageView`]): `f32` pools hand out master-slab
/// slices, quantized pools hand out byte slices plus each page's affine
/// dequantization params, and the backend decides where to dequantize
/// (scratch arena in `SimBackend`, fused in a native kernel).
pub struct PagedAttnInput<'a> {
    /// hidden `[d_model]`.
    pub h: &'a [f32],
    /// query `[n_heads * head_dim]`.
    pub q: &'a [f32],
    /// Selected pages in selection order, `len` live slots each, nothing
    /// padded.
    pub pages: &'a [PageView<'a>],
}

impl PagedAttnInput<'_> {
    /// Total live slots across the selected pages.
    pub fn n_slots(&self) -> usize {
        self.pages.iter().map(|p| p.len).sum()
    }
}

/// Output of a dense prefill call.
pub struct PrefillOut {
    /// `[n_layers][padded][kv_dim]` post-RoPE keys.
    pub k: Vec<f32>,
    /// `[n_layers][padded][kv_dim]` values.
    pub v: Vec<f32>,
    /// Next-token logits `[vocab]`.
    pub logits: Vec<f32>,
    /// Padded sequence length of the `k`/`v` buffers.
    pub padded: usize,
}

impl PrefillOut {
    /// Slice one (layer, position) KV vector out of the prefill buffers.
    pub fn kv_at(&self, spec: &ModelSpec, layer: usize, pos: usize) -> (&[f32], &[f32]) {
        self.kv_run(spec, layer, pos, 1)
    }

    /// Contiguous run of `len` positions of one layer's K/V starting at
    /// absolute position `pos` (positions are contiguous within a layer,
    /// padding notwithstanding) — lets the engine append straight from a
    /// monolithic prefill with no staging copy.
    pub fn kv_run(&self, spec: &ModelSpec, layer: usize, pos: usize, len: usize)
                  -> (&[f32], &[f32]) {
        let kv_dim = spec.n_kv_heads * spec.head_dim;
        let off = (layer * self.padded + pos) * kv_dim;
        (&self.k[off..off + len * kv_dim], &self.v[off..off + len * kv_dim])
    }
}

/// Output of one streaming-prefill chunk ([`Backend::prefill_chunk`]): the
/// chunk's per-layer post-RoPE K/V only — O(chunk), never O(prompt) — plus
/// next-token logits when the chunk completes the prompt (DESIGN.md §2,
/// prefill dataflow).
pub struct PrefillChunkOut {
    /// `[n_layers][chunk_len][kv_dim]` post-RoPE keys for the chunk.
    pub k: Vec<f32>,
    /// `[n_layers][chunk_len][kv_dim]` values.
    pub v: Vec<f32>,
    /// Next-token logits `[vocab]` — non-empty exactly when this chunk's
    /// `end` reached the prompt length.
    pub logits: Vec<f32>,
    /// Number of chunk positions held in `k`/`v`.
    pub chunk_len: usize,
}

impl PrefillChunkOut {
    /// Contiguous run of `len` positions of one layer's K/V, starting at
    /// chunk-relative `offset` — what the engine hands to the bulk
    /// page-granular `SeqCache::append_slots`.
    pub fn kv_run(&self, spec: &ModelSpec, layer: usize, offset: usize, len: usize)
                  -> (&[f32], &[f32]) {
        let kv_dim = spec.n_kv_heads * spec.head_dim;
        let off = (layer * self.chunk_len + offset) * kv_dim;
        (&self.k[off..off + len * kv_dim], &self.v[off..off + len * kv_dim])
    }
}

/// One sequence's chunk in a batched streaming-prefill call
/// ([`Backend::prefill_chunk_batch`]): the same `(tokens, start, end)`
/// arguments [`Backend::prefill_chunk`] takes, one entry per co-admitted
/// prompt.
pub struct PrefillChunkItem<'a> {
    /// The full prompt this chunk belongs to (positions are absolute).
    pub tokens: &'a [u32],
    /// First prompt position of the chunk (inclusive).
    pub start: usize,
    /// One past the last prompt position of the chunk (exclusive).
    pub end: usize,
}

/// A model execution backend.
///
/// The engine drives it per decode token, per layer:
/// `embed_tok` → `layer_qkv` → (policy select) → attention — the zero-copy
/// `layer_attn_mlp_paged` when `supports_paged()`, else gather +
/// `layer_attn_mlp` → … → `lm_head`; prompts stream through
/// `prefill_chunk` (a single whole-prompt chunk unless admission is
/// token-budgeted).
pub trait Backend: std::fmt::Debug {
    /// Short backend identifier (`"sim"`, `"xla"`).
    fn name(&self) -> &'static str;

    /// Architecture of the served model.
    fn spec(&self) -> &ModelSpec;

    /// Slot capacities this backend can attend over (informational; the
    /// ladder of compiled kernel shapes for AOT backends).
    fn capacities(&self) -> Vec<usize>;

    /// Smallest supported slot capacity >= `n_slots`.
    fn capacity_for(&self, n_slots: usize) -> Result<usize>;

    /// token -> hidden `[d_model]`.
    fn embed_tok(&self, token: u32) -> Result<Vec<f32>>;

    /// hidden `[d_model]` + absolute position -> (q, k, v).
    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv>;

    /// Attention over gathered slots + MLP.  `k_sel`/`v_sel` are
    /// `[capacity * kv_dim]`, `valid` is `[capacity]`; returns hidden'
    /// `[d_model]`.
    #[allow(clippy::too_many_arguments)]
    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>>;

    /// hidden `[d_model]` -> logits `[vocab]`.
    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>>;

    /// Dense prefill of `tokens`; returns per-layer post-RoPE KV for the
    /// first `tokens.len()` positions plus next-token logits.
    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut>;

    // ------------------------------------------------------------------
    // Streaming chunked prefill (DESIGN.md §2, prefill dataflow).
    //
    // The engine prefills prompts chunk by chunk through `prefill_chunk`,
    // so the backend only ever materializes O(chunk) KV — the basis of
    // prefill-token-budgeted admission (`coordinator::Batcher`), where a
    // long prompt's chunks interleave with the decode sweep instead of
    // stalling it.  Chunked and monolithic prefill are bit-identical end
    // to end (first token, KV slabs, page tables, RepBounds) — pinned by
    // `rust/tests/chunked_prefill.rs`.
    // ------------------------------------------------------------------

    /// Whether [`Backend::prefill_chunk`] streams natively (cost
    /// O(chunk)).  When false the default adapts the monolithic
    /// [`Backend::prefill`] — still correct, but each chunk re-runs the
    /// whole prefix, so schedulers should prefer whole-prompt chunks for
    /// such backends unless admission latency matters more than prefill
    /// throughput.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Prefill chunk `start..end` of the full prompt `tokens`, returning
    /// the chunk's per-layer KV and — when `end == tokens.len()` —
    /// next-token logits.  Default: run the monolithic [`Backend::prefill`]
    /// over `tokens[..end]` and copy the chunk's rows out, so the AOT
    /// `ModelRuntime` (whose prefill executables are compiled whole-prompt)
    /// keeps working unchanged; for a single whole-prompt chunk this is
    /// exactly the old path plus one copy.
    fn prefill_chunk(&self, tokens: &[u32], start: usize, end: usize)
                     -> Result<PrefillChunkOut> {
        if start >= end || end > tokens.len() {
            bail!("invalid prefill chunk {start}..{end} of {} tokens", tokens.len());
        }
        let spec = self.spec();
        let kv_dim = spec.n_kv_heads * spec.head_dim;
        let out = self.prefill(&tokens[..end])?;
        let chunk_len = end - start;
        let mut k = Vec::with_capacity(spec.n_layers * chunk_len * kv_dim);
        let mut v = Vec::with_capacity(spec.n_layers * chunk_len * kv_dim);
        for layer in 0..spec.n_layers {
            let (ks, vs) = out.kv_run(spec, layer, start, chunk_len);
            k.extend_from_slice(ks);
            v.extend_from_slice(vs);
        }
        let logits = if end == tokens.len() { out.logits } else { Vec::new() };
        Ok(PrefillChunkOut { k, v, logits, chunk_len })
    }

    /// Batched [`Backend::prefill_chunk`]: one chunk output per item —
    /// one call covers one admission tick across every co-admitted prompt,
    /// so a backend can amortize dispatch and share position-pure work
    /// between prompts (the prefill twin of the decode batch entry
    /// points).  Default: per-item loop, so the AOT `ModelRuntime` keeps
    /// working unchanged.  Semantics are all-or-nothing (an error fails
    /// the whole call; callers needing isolation retry item by item, see
    /// `Engine::prefill_batch`), and every override MUST stay
    /// bit-identical to the per-item loop — concurrent and sequential
    /// chunked prefill producing the same KV is pinned by
    /// `rust/tests/concurrent_prefill.rs`.
    fn prefill_chunk_batch(&self, items: &[PrefillChunkItem<'_>])
                           -> Result<Vec<PrefillChunkOut>> {
        items.iter().map(|it| self.prefill_chunk(it.tokens, it.start, it.end)).collect()
    }

    // ------------------------------------------------------------------
    // Batched entry points (DESIGN.md §2, batched dataflow).
    //
    // One call covers one scheduler iteration across all active sequences,
    // so a backend can amortize dispatch and share work between items.
    // The defaults loop over the per-item methods — `ModelRuntime` behind
    // `backend-xla` keeps working unchanged — while `SimBackend` overrides
    // them natively.  Semantics are all-or-nothing: an error fails the
    // whole call, and callers that need per-item isolation fall back to
    // the per-item methods (see `Engine::decode_batch`).  Every override
    // MUST stay bit-identical to the per-item loop: batched and sequential
    // decode producing the same tokens is the crate's core invariant.
    // ------------------------------------------------------------------

    /// Batched [`Backend::embed_tok`]: one hidden `[d_model]` per token.
    fn embed_tok_batch(&self, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        tokens.iter().map(|&t| self.embed_tok(t)).collect()
    }

    /// Batched [`Backend::layer_qkv`]: one [`Qkv`] per item.
    fn layer_qkv_batch(&self, layer: usize, items: &[QkvBatchItem<'_>]) -> Result<Vec<Qkv>> {
        items.iter().map(|it| self.layer_qkv(layer, it.h, it.pos)).collect()
    }

    /// Batched [`Backend::layer_attn_mlp`]: one hidden' `[d_model]` per item.
    fn layer_attn_mlp_batch(&self, layer: usize, items: &[AttnBatchItem<'_>])
                            -> Result<Vec<Vec<f32>>> {
        items
            .iter()
            .map(|it| {
                self.layer_attn_mlp(layer, it.capacity, it.h, it.q, it.k_sel, it.v_sel, it.valid)
            })
            .collect()
    }

    /// Batched [`Backend::lm_head`]: one logits `[vocab]` per hidden state.
    fn lm_head_batch(&self, hs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        hs.iter().map(|h| self.lm_head(h)).collect()
    }

    // ------------------------------------------------------------------
    // Paged (zero-copy) entry points (DESIGN.md §2, paged route).
    //
    // The engine routes attention through these when `supports_paged()` is
    // true, handing the backend in-place slab views of the selected pages
    // instead of gathering them into capacity-padded scratch — deleting
    // the dominant per-layer memcpy and the zero-fill of padding slots.
    // The defaults gather-and-delegate, so `ModelRuntime` behind
    // `backend-xla` keeps working unchanged (its compiled kernels want the
    // fixed-capacity layout); `SimBackend` overrides them natively.  Every
    // override MUST stay bit-identical to the gathered route — paged and
    // gathered decode producing the same tokens is pinned by
    // `rust/tests/paged_attention.rs`.
    // ------------------------------------------------------------------

    /// Whether this backend attends paged K/V in place.  When false the
    /// engine stays on the gather route and never calls the paged entry
    /// points.
    fn supports_paged(&self) -> bool {
        false
    }

    /// Attention over in-place page views + MLP; returns hidden'
    /// `[d_model]`.  Default: gather into scratch and delegate to
    /// [`Backend::layer_attn_mlp`] (reference semantics for backends
    /// without a native paged kernel).
    fn layer_attn_mlp_paged(&self, layer: usize, input: &PagedAttnInput<'_>)
                            -> Result<Vec<f32>> {
        let spec = self.spec();
        let kv_dim = spec.n_kv_heads * spec.head_dim;
        let n_slots = input.n_slots();
        let capacity = self.capacity_for(n_slots)?;
        let mut k_sel = vec![0.0f32; capacity * kv_dim];
        let mut v_sel = vec![0.0f32; capacity * kv_dim];
        let mut valid = vec![0.0f32; capacity];
        let mut used = 0usize;
        for page in input.pages {
            page.copy_k_into(&mut k_sel[used * kv_dim..(used + page.len) * kv_dim]);
            page.copy_v_into(&mut v_sel[used * kv_dim..(used + page.len) * kv_dim]);
            for s in 0..page.len {
                valid[used + s] = 1.0;
            }
            used += page.len;
        }
        self.layer_attn_mlp(layer, capacity, input.h, input.q, &k_sel, &v_sel, &valid)
    }

    /// Batched [`Backend::layer_attn_mlp_paged`]: one hidden' per item.
    fn layer_attn_mlp_paged_batch(&self, layer: usize, items: &[PagedAttnInput<'_>])
                                  -> Result<Vec<Vec<f32>>> {
        items.iter().map(|it| self.layer_attn_mlp_paged(layer, it)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefill_kv_slicing() {
        let spec = ModelSpec {
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 2,
            d_ff: 8,
        };
        let kv_dim = 2;
        let padded = 3;
        // k[layer][pos][c] = 100*layer + 10*pos + c
        let mut k = Vec::new();
        for layer in 0..2 {
            for pos in 0..padded {
                for c in 0..kv_dim {
                    k.push((100 * layer + 10 * pos + c) as f32);
                }
            }
        }
        let out = PrefillOut { k: k.clone(), v: k, logits: vec![], padded };
        let (ks, vs) = out.kv_at(&spec, 1, 2);
        assert_eq!(ks, &[120.0, 121.0]);
        assert_eq!(vs, &[120.0, 121.0]);
        let (ks, _) = out.kv_at(&spec, 0, 0);
        assert_eq!(ks, &[0.0, 1.0]);
        // run slicing spans contiguous positions within a layer
        let (ks, _) = out.kv_run(&spec, 1, 1, 2);
        assert_eq!(ks, &[110.0, 111.0, 120.0, 121.0]);
    }

    #[test]
    fn prefill_chunk_kv_run_slicing() {
        let spec = ModelSpec {
            vocab: 8,
            d_model: 4,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 2,
            d_ff: 8,
        };
        let kv_dim = 2;
        let chunk_len = 3;
        // k[layer][i][c] = 100*layer + 10*i + c
        let mut k = Vec::new();
        for layer in 0..2 {
            for i in 0..chunk_len {
                for c in 0..kv_dim {
                    k.push((100 * layer + 10 * i + c) as f32);
                }
            }
        }
        let out = PrefillChunkOut { k: k.clone(), v: k, logits: vec![], chunk_len };
        let (ks, _) = out.kv_run(&spec, 1, 1, 2);
        assert_eq!(ks, &[110.0, 111.0, 120.0, 121.0]);
        let (ks, vs) = out.kv_run(&spec, 0, 0, 1);
        assert_eq!(ks, &[0.0, 1.0]);
        assert_eq!(vs, &[0.0, 1.0]);
    }
}
