//! Execution backends (DESIGN.md §4).
//!
//! [`Backend`] is the abstraction the engine drives on the decode hot path.
//! The default implementation is [`SimBackend`], a deterministic pure-Rust
//! transformer surrogate with zero native dependencies.  With
//! `--features backend-xla` the PJRT runtime is also compiled: it loads the
//! AOT HLO-text artifacts (weights are HLO constants — python never runs on
//! the request path) through the `xla` crate.

pub mod backend;
pub mod sim_backend;
pub mod tokenizer;

#[cfg(feature = "backend-xla")]
pub mod client;
#[cfg(feature = "backend-xla")]
pub mod executable;
#[cfg(feature = "backend-xla")]
pub mod model;

pub use backend::{AttnBatchItem, Backend, PagedAttnInput, PrefillChunkOut, PrefillOut, Qkv,
                  QkvBatchItem};
pub use sim_backend::SimBackend;
pub use tokenizer::Tokenizer;

#[cfg(feature = "backend-xla")]
pub use client::RuntimeClient;
#[cfg(feature = "backend-xla")]
pub use executable::Executable;
#[cfg(feature = "backend-xla")]
pub use model::ModelRuntime;
