//! Execution backends (DESIGN.md §4).
//!
//! [`Backend`] is the abstraction the engine drives on the decode hot path.
//! The default implementation is [`SimBackend`], a deterministic pure-Rust
//! transformer surrogate with zero native dependencies.  With
//! `--features backend-xla` the PJRT runtime is also compiled: it loads the
//! AOT HLO-text artifacts (weights are HLO constants — python never runs on
//! the request path) through the `xla` crate.

pub mod backend;
pub mod fault;
pub mod sim_backend;
pub mod tokenizer;

// The PJRT modules predate the crate's missing_docs gate and are only
// compiled with `--features backend-xla` (which CI never builds); carved
// out like the harness modules in lib.rs so a feature build isn't broken
// by the gate.  Documenting them is tracked as a ROADMAP follow-up.
#[cfg(feature = "backend-xla")]
#[allow(missing_docs)]
pub mod client;
#[cfg(feature = "backend-xla")]
#[allow(missing_docs)]
pub mod executable;
#[cfg(feature = "backend-xla")]
#[allow(missing_docs)]
pub mod model;

pub use backend::{AttnBatchItem, Backend, PagedAttnInput, PrefillChunkItem, PrefillChunkOut,
                  PrefillOut, Qkv, QkvBatchItem};
pub use fault::{FaultInjector, FaultOp, FaultSchedule, ReplicaFault, StepFaultInjector};
pub use sim_backend::SimBackend;
pub use tokenizer::Tokenizer;

#[cfg(feature = "backend-xla")]
pub use client::RuntimeClient;
#[cfg(feature = "backend-xla")]
pub use executable::Executable;
#[cfg(feature = "backend-xla")]
pub use model::ModelRuntime;
