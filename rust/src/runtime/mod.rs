//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! decode hot path.  Python never runs here — the artifacts are
//! self-contained (weights are HLO constants).

pub mod client;
pub mod executable;
pub mod model;
pub mod tokenizer;

pub use client::RuntimeClient;
pub use executable::Executable;
pub use model::ModelRuntime;
pub use tokenizer::Tokenizer;
