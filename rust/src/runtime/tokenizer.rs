//! Tokenizer for the synthetic reasoning vocabulary (vocabulary and framing
//! are defined by python/compile/corpus.py and shipped in meta.json).

use crate::config::CorpusSpec;
use crate::workload;

/// Detokenizer/framing helper over the synthetic reasoning vocabulary.
#[derive(Debug, Clone)]
pub struct Tokenizer {
    /// Corpus framing (token ids, step bounds) from the artifact metadata.
    pub spec: CorpusSpec,
}

impl Tokenizer {
    /// Tokenizer over a corpus framing.
    pub fn new(spec: CorpusSpec) -> Self {
        Tokenizer { spec }
    }

    /// Render tokens as the corpus' human-readable notation.
    pub fn decode(&self, tokens: &[u32]) -> String {
        workload::detok(&self.spec, tokens)
    }

    /// Whether `t` is the end-of-sequence token.
    pub fn is_eos(&self, t: u32) -> bool {
        t == self.spec.eos
    }

    /// Extract the final answer digit from a decoded stream, if well-formed.
    pub fn parse_answer(&self, decoded: &[u32]) -> Option<u8> {
        workload::parse_answer(&self.spec, decoded)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{test_spec, Problem};
    use crate::util::rng::Rng;

    #[test]
    fn decode_and_answer() {
        let tok = Tokenizer::new(test_spec());
        let mut rng = Rng::new(0);
        let p = Problem::sample(&mut rng, &tok.spec, Some(3));
        let dec = p.encode_decode(&tok.spec);
        assert!(tok.is_eos(*dec.last().unwrap()));
        assert_eq!(tok.parse_answer(&dec), Some(p.answer()));
        assert!(tok.decode(&dec).contains('A'));
    }
}
