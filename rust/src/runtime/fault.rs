//! Deterministic fault injection (DESIGN.md §6): seeded, schedule-driven
//! failures threaded in front of a real backend so robustness behavior —
//! preemption, shedding, retry, failover — is testable without flaky
//! timing tricks or ad-hoc `fail_xxx` fields on mock backends.
//!
//! Two wrappers share one [`FaultSchedule`]:
//!
//! * [`FaultInjector`] implements [`Backend`] around any boxed model
//!   backend, failing `embed_tok`/`embed_tok_batch` (decode-step faults —
//!   injected *before* any KV append, so sequence state stays intact and
//!   the error is retryable) and `prefill`/`prefill_chunk`/
//!   `prefill_chunk_batch` (prefill-chunk faults).
//! * [`StepFaultInjector`] implements [`StepBackend`] around a scheduler
//!   backend, additionally injecting typed [`PoolExhausted`] allocation
//!   faults (the batcher's preemption trigger) and whole-admission
//!   `begin` faults.
//!
//! Faults are either *targeted* (fail the Nth call of an op, optionally
//! scoped to one sequence key — the replacement for the old
//! `fail_second_chunk_of` test field) or *rate-based* (each call fails
//! with seeded probability `p` via [`Rng::chance`]).  A schedule can also
//! *hang*: after a call budget every subsequent call fails permanently,
//! modelling a dead replica for the router's circuit breaker.  Everything
//! is driven by one [`Rng`] stream, so a chaos run is reproducible from
//! its seed alone.

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::{ModelSpec, PreemptMode};
use crate::coordinator::batcher::{PrefillBatchItem, PrefillProgress, StepBackend, StepItem};
use crate::coordinator::request::RequestId;
use crate::kvcache::PoolExhausted;
use crate::runtime::backend::{AttnBatchItem, Backend, PagedAttnInput, PrefillChunkItem,
                              PrefillChunkOut, PrefillOut, Qkv, QkvBatchItem};
use crate::util::rng::Rng;

/// Injection sites a [`FaultSchedule`] distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultOp {
    /// Whole-prompt admission ([`StepBackend::begin`]).
    Begin,
    /// One prefill chunk (model-level `prefill`/`prefill_chunk`, or
    /// scheduler-level [`StepBackend::prefill_chunk`]).
    Chunk,
    /// One decode step (model-level `embed_tok`, or scheduler-level
    /// [`StepBackend::step`]).
    Step,
    /// A KV-pool allocation: injected as a typed [`PoolExhausted`] so
    /// schedulers exercise the preemption path, not generic failure.
    Alloc,
    /// A replica `submit` (checked by router/serving harnesses directly;
    /// the backend wrappers never draw it).
    Submit,
}

const N_OPS: usize = 5;

impl FaultOp {
    fn idx(self) -> usize {
        match self {
            FaultOp::Begin => 0,
            FaultOp::Chunk => 1,
            FaultOp::Step => 2,
            FaultOp::Alloc => 3,
            FaultOp::Submit => 4,
        }
    }
}

/// A one-shot targeted fault: fail the `nth` checked call of `op`
/// (1-indexed), counted globally (`key == None`) or per sequence key.
#[derive(Debug, Clone)]
struct Targeted {
    op: FaultOp,
    key: Option<u64>,
    nth: u64,
}

/// A replica-level fault drawn by [`FaultSchedule::check_tick`] on the
/// supervised tick loop (DESIGN.md §6): the whole replica dies, not one
/// backend call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplicaFault {
    /// The replica thread panics mid-loop; the supervisor catches it,
    /// drains the batcher and re-dispatches.
    Crash,
    /// The replica freezes (no heartbeats, no ticks) until killed; the
    /// supervisor's watchdog detects and recovers from the shadow
    /// registry.
    Hang,
}

/// A seeded, deterministic fault plan (see module docs).  Built with the
/// `rate`/`fail_nth`/`fail_nth_for`/`hang_after` builders, consumed by the
/// injector wrappers through [`FaultSchedule::check`].
#[derive(Debug, Clone)]
pub struct FaultSchedule {
    rng: Rng,
    rates: [f64; N_OPS],
    targeted: Vec<Targeted>,
    /// Calls seen per `(op, key)`; the `None` key row counts every call of
    /// the op regardless of sequence.
    seen: HashMap<(usize, Option<u64>), u64>,
    hang_after: Option<u64>,
    calls: u64,
    hung: bool,
    injected: u64,
    /// Replica tick-loop passes observed by [`FaultSchedule::check_tick`].
    ticks: u64,
    /// Panic the replica thread at this tick (0-indexed), once.
    crash_at: Option<u64>,
    /// Freeze the replica tick loop at this tick (0-indexed), once.
    hang_at: Option<u64>,
}

impl FaultSchedule {
    /// A fault-free schedule seeded for the rate draws; faults are added
    /// with the builder methods.
    pub fn new(seed: u64) -> Self {
        FaultSchedule {
            rng: Rng::new(seed),
            rates: [0.0; N_OPS],
            targeted: Vec::new(),
            seen: HashMap::new(),
            hang_after: None,
            calls: 0,
            hung: false,
            injected: 0,
            ticks: 0,
            crash_at: None,
            hang_at: None,
        }
    }

    /// Fail each checked call of `op` with probability `p` (seeded draw).
    pub fn rate(mut self, op: FaultOp, p: f64) -> Self {
        self.rates[op.idx()] = p;
        self
    }

    /// Fail the `nth` checked call of `op` (1-indexed), counted across all
    /// sequences.  One-shot: the entry is consumed when it fires.
    pub fn fail_nth(mut self, op: FaultOp, nth: u64) -> Self {
        self.targeted.push(Targeted { op, key: None, nth });
        self
    }

    /// Fail the `nth` checked call of `op` whose sequence key is `key`
    /// (1-indexed; the wrappers key prefill ops by `prompt[0]`).  The
    /// schedule-level replacement for per-mock failure fields like the old
    /// `fail_second_chunk_of`: `fail_nth_for(Chunk, tag, 2)`.
    pub fn fail_nth_for(mut self, op: FaultOp, key: u64, nth: u64) -> Self {
        self.targeted.push(Targeted { op, key: Some(key), nth });
        self
    }

    /// After `calls` total checks, every subsequent call fails permanently
    /// (a dead replica, as seen by a router health check).
    pub fn hang_after(mut self, calls: u64) -> Self {
        self.hang_after = Some(calls);
        self
    }

    /// Panic the replica thread on its `tick`-th tick-loop pass
    /// (0-indexed) — the supervised crash fault ([`ReplicaFault::Crash`]).
    pub fn crash_at_tick(mut self, tick: u64) -> Self {
        self.crash_at = Some(tick);
        self
    }

    /// Freeze the replica tick loop on its `tick`-th pass (0-indexed) —
    /// heartbeats stop, the mailbox goes unread, exactly what a wedged
    /// engine call looks like from outside ([`ReplicaFault::Hang`]).
    pub fn hang_at_tick(mut self, tick: u64) -> Self {
        self.hang_at = Some(tick);
        self
    }

    /// Record one replica tick-loop pass and decide whether a
    /// replica-level fault fires on it.  A pending fault fires on the
    /// first pass *at or after* its scheduled tick (at most one fault per
    /// pass, crash first), so a fault is never silently skipped when
    /// another fault consumed its exact tick.  Both faults are one-shot
    /// (consumed when they fire).
    pub fn check_tick(&mut self) -> Option<ReplicaFault> {
        let t = self.ticks;
        self.ticks += 1;
        if self.crash_at.is_some_and(|c| t >= c) {
            self.crash_at = None;
            self.injected += 1;
            return Some(ReplicaFault::Crash);
        }
        if self.hang_at.is_some_and(|h| t >= h) {
            self.hang_at = None;
            self.injected += 1;
            return Some(ReplicaFault::Hang);
        }
        None
    }

    /// Record one call of `op` (scoped to `key` when the caller has one)
    /// and decide whether it faults.  Deterministic: targeted entries fire
    /// on exact call counts, rate draws consume the seeded stream only for
    /// ops with a nonzero rate.
    pub fn check(&mut self, op: FaultOp, key: Option<u64>) -> bool {
        self.calls += 1;
        if let Some(h) = self.hang_after {
            if self.calls > h {
                self.hung = true;
            }
        }
        if self.hung {
            self.injected += 1;
            return true;
        }
        let global = {
            let c = self.seen.entry((op.idx(), None)).or_insert(0);
            *c += 1;
            *c
        };
        let keyed = key.map(|k| {
            let c = self.seen.entry((op.idx(), Some(k))).or_insert(0);
            *c += 1;
            *c
        });
        let hit = self.targeted.iter().position(|t| {
            t.op == op
                && match t.key {
                    None => t.nth == global,
                    Some(k) => key == Some(k) && keyed == Some(t.nth),
                }
        });
        if let Some(i) = hit {
            self.targeted.remove(i);
            self.injected += 1;
            return true;
        }
        let p = self.rates[op.idx()];
        if p > 0.0 && self.rng.chance(p) {
            self.injected += 1;
            return true;
        }
        false
    }

    /// Total faults fired so far (targeted + rate + hang).
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// Whether the schedule has entered the permanent-failure (hung) state.
    pub fn is_hung(&self) -> bool {
        self.hung
    }
}

fn prompt_key(tokens: &[u32]) -> Option<u64> {
    tokens.first().map(|&t| t as u64)
}

/// [`Backend`] wrapper injecting schedule-driven faults in front of a real
/// model backend (see module docs for the injection sites).  All other
/// entry points delegate verbatim — including the capability probes, so
/// the engine routes (paged, chunked, batched) exactly as it would against
/// the bare inner backend.
#[derive(Debug)]
pub struct FaultInjector {
    inner: Box<dyn Backend>,
    /// `Backend` methods take `&self`; the schedule mutates per call.
    schedule: RefCell<FaultSchedule>,
}

impl FaultInjector {
    /// Wrap `inner`, drawing faults from `schedule`.
    pub fn new(inner: Box<dyn Backend>, schedule: FaultSchedule) -> Self {
        FaultInjector { inner, schedule: RefCell::new(schedule) }
    }

    /// Faults fired so far.
    pub fn injected(&self) -> u64 {
        self.schedule.borrow().injected()
    }

    fn fires(&self, op: FaultOp, key: Option<u64>) -> bool {
        self.schedule.borrow_mut().check(op, key)
    }
}

impl Backend for FaultInjector {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn spec(&self) -> &ModelSpec {
        self.inner.spec()
    }

    fn capacities(&self) -> Vec<usize> {
        self.inner.capacities()
    }

    fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        self.inner.capacity_for(n_slots)
    }

    fn embed_tok(&self, token: u32) -> Result<Vec<f32>> {
        if self.fires(FaultOp::Step, None) {
            bail!("injected step fault");
        }
        self.inner.embed_tok(token)
    }

    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv> {
        self.inner.layer_qkv(layer, h, pos)
    }

    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>> {
        self.inner.layer_attn_mlp(layer, capacity, h, q, k_sel, v_sel, valid)
    }

    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>> {
        self.inner.lm_head(h)
    }

    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut> {
        if self.fires(FaultOp::Chunk, prompt_key(tokens)) {
            bail!("injected prefill fault");
        }
        self.inner.prefill(tokens)
    }

    fn supports_chunked_prefill(&self) -> bool {
        self.inner.supports_chunked_prefill()
    }

    fn prefill_chunk(&self, tokens: &[u32], start: usize, end: usize)
                     -> Result<PrefillChunkOut> {
        if self.fires(FaultOp::Chunk, prompt_key(tokens)) {
            bail!("injected prefill fault");
        }
        self.inner.prefill_chunk(tokens, start, end)
    }

    fn prefill_chunk_batch(&self, items: &[PrefillChunkItem<'_>])
                           -> Result<Vec<PrefillChunkOut>> {
        // Backend batch semantics are all-or-nothing: any item's fault
        // fails the whole call, and the engine's per-item fallback then
        // isolates the failure (fresh draws happen there).
        for it in items {
            if self.fires(FaultOp::Chunk, prompt_key(it.tokens)) {
                bail!("injected prefill fault");
            }
        }
        self.inner.prefill_chunk_batch(items)
    }

    fn embed_tok_batch(&self, tokens: &[u32]) -> Result<Vec<Vec<f32>>> {
        for _ in tokens {
            if self.fires(FaultOp::Step, None) {
                bail!("injected step fault");
            }
        }
        self.inner.embed_tok_batch(tokens)
    }

    fn layer_qkv_batch(&self, layer: usize, items: &[QkvBatchItem<'_>]) -> Result<Vec<Qkv>> {
        self.inner.layer_qkv_batch(layer, items)
    }

    fn layer_attn_mlp_batch(&self, layer: usize, items: &[AttnBatchItem<'_>])
                            -> Result<Vec<Vec<f32>>> {
        self.inner.layer_attn_mlp_batch(layer, items)
    }

    fn lm_head_batch(&self, hs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        self.inner.lm_head_batch(hs)
    }

    fn supports_paged(&self) -> bool {
        self.inner.supports_paged()
    }

    fn layer_attn_mlp_paged(&self, layer: usize, input: &PagedAttnInput<'_>)
                            -> Result<Vec<f32>> {
        self.inner.layer_attn_mlp_paged(layer, input)
    }

    fn layer_attn_mlp_paged_batch(&self, layer: usize, items: &[PagedAttnInput<'_>])
                                  -> Result<Vec<Vec<f32>>> {
        self.inner.layer_attn_mlp_paged_batch(layer, items)
    }
}

/// [`StepBackend`] wrapper injecting scheduler-level faults: `begin`
/// failures, per-chunk prefill failures (keyed by `prompt[0]`, so one
/// co-admitted prompt fails in isolation), decode-step failures, and typed
/// [`PoolExhausted`] allocation faults that drive the batcher's preemption
/// path.  Batched entry points stay batched on fault-free ticks and fall
/// back per item only when a fault fires, so scheduling behavior is
/// unchanged until the moment of failure.
#[derive(Debug)]
pub struct StepFaultInjector<B: StepBackend> {
    /// The wrapped scheduler backend (public so tests can inspect it).
    pub inner: B,
    /// The driving fault plan (public so tests can assert on `injected`).
    pub schedule: FaultSchedule,
}

impl<B: StepBackend> StepFaultInjector<B> {
    /// Wrap `inner`, drawing faults from `schedule`.
    pub fn new(inner: B, schedule: FaultSchedule) -> Self {
        StepFaultInjector { inner, schedule }
    }

    /// Draw the decode-step fault pair (alloc first, then step), returning
    /// the error to report if either fires.
    fn step_fault(&mut self) -> Option<anyhow::Error> {
        if self.schedule.check(FaultOp::Alloc, None) {
            return Some(PoolExhausted { capacity_pages: 0 }.into());
        }
        if self.schedule.check(FaultOp::Step, None) {
            return Some(anyhow::anyhow!("injected step fault"));
        }
        None
    }
}

impl<B: StepBackend> StepBackend for StepFaultInjector<B> {
    type Seq = B::Seq;

    fn begin(&mut self, prompt: &[u32]) -> Result<(Self::Seq, u32)> {
        if self.schedule.check(FaultOp::Begin, prompt_key(prompt)) {
            bail!("injected begin fault");
        }
        self.inner.begin(prompt)
    }

    fn begin_chunked(&mut self) -> Option<Self::Seq> {
        self.inner.begin_chunked()
    }

    fn prefill_chunk(&mut self, seq: &mut Self::Seq, prompt: &[u32], done: usize,
                     max_tokens: usize) -> Result<PrefillProgress> {
        if self.schedule.check(FaultOp::Chunk, prompt_key(prompt)) {
            bail!("injected prefill failure");
        }
        self.inner.prefill_chunk(seq, prompt, done, max_tokens)
    }

    fn prefill_chunk_batch(&mut self, items: &mut [PrefillBatchItem<'_, Self::Seq>])
                           -> Vec<Result<PrefillProgress>> {
        let fire: Vec<bool> = items
            .iter()
            .map(|it| self.schedule.check(FaultOp::Chunk, prompt_key(it.prompt)))
            .collect();
        if fire.iter().all(|&f| !f) {
            return self.inner.prefill_chunk_batch(items);
        }
        // a fault fired: fall back per item so only the faulted prompts
        // fail (checks were already drawn above — delegate directly)
        items
            .iter_mut()
            .zip(fire)
            .map(|(it, f)| {
                if f {
                    bail!("injected prefill failure");
                }
                self.inner.prefill_chunk(it.seq, it.prompt, it.done, it.max_tokens)
            })
            .collect()
    }

    fn record_prefill_secs(&mut self, secs: f64) {
        self.inner.record_prefill_secs(secs);
    }

    fn step(&mut self, seq: &mut Self::Seq, token: u32, now: u64) -> Result<u32> {
        if let Some(e) = self.step_fault() {
            return Err(e);
        }
        self.inner.step(seq, token, now)
    }

    fn step_batch(&mut self, items: &mut [StepItem<'_, Self::Seq>]) -> Vec<Result<u32>> {
        let faults: Vec<Option<anyhow::Error>> =
            items.iter().map(|_| self.step_fault()).collect();
        if faults.iter().all(|f| f.is_none()) {
            return self.inner.step_batch(items);
        }
        items
            .iter_mut()
            .zip(faults)
            .map(|(it, f)| match f {
                Some(e) => Err(e),
                None => self.inner.step(it.seq, it.token, it.now),
            })
            .collect()
    }

    fn preempt(&mut self, id: RequestId, seq: Self::Seq, mode: PreemptMode) -> Result<()> {
        self.inner.preempt(id, seq, mode)
    }

    fn resume(&mut self, id: RequestId, prompt: &[u32], produced: &[u32]) -> Result<Self::Seq> {
        self.inner.resume(id, prompt, produced)
    }

    fn record_counter(&mut self, name: &'static str, delta: u64) {
        self.inner.record_counter(name, delta);
    }

    fn finish(&mut self, seq: Self::Seq) {
        self.inner.finish(seq);
    }

    fn is_eos(&self, token: u32) -> bool {
        self.inner.is_eos(token)
    }

    fn has_capacity(&self, active: usize) -> bool {
        self.inner.has_capacity(active)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArtifactMeta;
    use crate::runtime::SimBackend;

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let mut a = FaultSchedule::new(9).rate(FaultOp::Step, 0.3);
        let mut b = FaultSchedule::new(9).rate(FaultOp::Step, 0.3);
        let fa: Vec<bool> = (0..500).map(|_| a.check(FaultOp::Step, None)).collect();
        let fb: Vec<bool> = (0..500).map(|_| b.check(FaultOp::Step, None)).collect();
        assert_eq!(fa, fb);
        assert!(a.injected() > 0, "a 30% rate over 500 draws must fire");
        assert!(a.injected() < 500, "…but not always");
    }

    #[test]
    fn targeted_faults_fire_once_on_exact_counts() {
        let mut s = FaultSchedule::new(0)
            .fail_nth(FaultOp::Step, 3)
            .fail_nth_for(FaultOp::Chunk, 7, 2);
        let steps: Vec<bool> = (0..5).map(|_| s.check(FaultOp::Step, None)).collect();
        assert_eq!(steps, vec![false, false, true, false, false]);
        // key 5's chunks never fault; key 7 faults on its own second chunk
        assert!(!s.check(FaultOp::Chunk, Some(5)));
        assert!(!s.check(FaultOp::Chunk, Some(7)));
        assert!(!s.check(FaultOp::Chunk, Some(5)));
        assert!(s.check(FaultOp::Chunk, Some(7)));
        assert!(!s.check(FaultOp::Chunk, Some(7)), "targeted entries are one-shot");
        assert_eq!(s.injected(), 2);
    }

    #[test]
    fn hang_fails_everything_after_the_call_budget() {
        let mut s = FaultSchedule::new(1).hang_after(2);
        assert!(!s.check(FaultOp::Step, None));
        assert!(!s.check(FaultOp::Chunk, None));
        for _ in 0..10 {
            assert!(s.check(FaultOp::Step, Some(3)), "hung schedules fail every call");
        }
        assert!(s.is_hung());
    }

    #[test]
    fn backend_injector_is_transparent_without_faults() {
        let meta = ArtifactMeta::sim_default();
        let bare = SimBackend::new(&meta, 0);
        let wrapped =
            FaultInjector::new(Box::new(SimBackend::new(&meta, 0)), FaultSchedule::new(4));
        let tokens = [3u32, 4, 5, 6];
        let a = bare.prefill(&tokens).unwrap();
        let b = wrapped.prefill(&tokens).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(bare.embed_tok(3).unwrap(), wrapped.embed_tok(3).unwrap());
        assert_eq!(bare.supports_paged(), wrapped.supports_paged());
        assert_eq!(bare.capacities(), wrapped.capacities());
        assert_eq!(wrapped.injected(), 0);
    }

    #[test]
    fn backend_injector_fails_the_scheduled_calls() {
        let meta = ArtifactMeta::sim_default();
        let schedule = FaultSchedule::new(2)
            .fail_nth(FaultOp::Step, 2)
            .fail_nth_for(FaultOp::Chunk, 9, 1);
        let b = FaultInjector::new(Box::new(SimBackend::new(&meta, 0)), schedule);
        assert!(b.embed_tok(3).is_ok());
        let err = b.embed_tok(3).unwrap_err();
        assert!(format!("{err:#}").contains("injected step fault"));
        assert!(b.embed_tok(3).is_ok(), "targeted faults are one-shot");
        assert!(b.prefill(&[8, 8]).is_ok());
        let err = b.prefill(&[9, 9]).unwrap_err();
        assert!(format!("{err:#}").contains("injected prefill fault"));
        assert_eq!(b.injected(), 2);
    }

    /// Minimal scheduler backend for the step-injector tests.
    #[derive(Debug)]
    struct Counting {
        steps: u64,
    }

    impl StepBackend for Counting {
        type Seq = ();
        fn begin(&mut self, _prompt: &[u32]) -> Result<((), u32)> {
            Ok(((), 1))
        }
        fn step(&mut self, _seq: &mut (), _token: u32, _now: u64) -> Result<u32> {
            self.steps += 1;
            Ok(1)
        }
        fn finish(&mut self, _seq: ()) {}
        fn is_eos(&self, _token: u32) -> bool {
            false
        }
        fn has_capacity(&self, _active: usize) -> bool {
            true
        }
    }

    #[test]
    fn step_injector_surfaces_typed_pool_exhaustion() {
        let schedule = FaultSchedule::new(3).fail_nth(FaultOp::Alloc, 2);
        let mut b = StepFaultInjector::new(Counting { steps: 0 }, schedule);
        let (mut seq, _) = b.begin(&[1]).unwrap();
        assert!(b.step(&mut seq, 1, 1).is_ok());
        let err = b.step(&mut seq, 1, 2).unwrap_err();
        assert!(
            err.downcast_ref::<PoolExhausted>().is_some(),
            "alloc faults must stay typed through the injector: {err:#}"
        );
        assert!(b.step(&mut seq, 1, 3).is_ok());
        assert_eq!(b.inner.steps, 2, "faulted step never reached the inner backend");
    }

    #[test]
    fn tick_faults_fire_once_at_their_scheduled_tick() {
        let mut s = FaultSchedule::new(0).crash_at_tick(2);
        assert_eq!(s.check_tick(), None);
        assert_eq!(s.check_tick(), None);
        assert_eq!(s.check_tick(), Some(ReplicaFault::Crash));
        assert_eq!(s.check_tick(), None, "tick faults are one-shot");
        assert_eq!(s.injected(), 1);

        let mut h = FaultSchedule::new(0).hang_at_tick(0);
        assert_eq!(h.check_tick(), Some(ReplicaFault::Hang));
        assert_eq!(h.check_tick(), None);

        // crash wins when both land on the same tick
        let mut both = FaultSchedule::new(0).crash_at_tick(1).hang_at_tick(1);
        assert_eq!(both.check_tick(), None);
        assert_eq!(both.check_tick(), Some(ReplicaFault::Crash));
        assert_eq!(both.check_tick(), Some(ReplicaFault::Hang), "hang still pending");
    }
}
