//! PJRT CPU client + HLO-text loading (the pattern from
//! /opt/xla-example/load_hlo — HLO *text* is the interchange format, see
//! DESIGN.md and aot.py).

use std::path::Path;

use anyhow::{Context, Result};

use super::executable::Executable;

pub struct RuntimeClient {
    client: xla::PjRtClient,
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable::new(exe, path.display().to_string()))
    }
}

impl std::fmt::Debug for RuntimeClient {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "RuntimeClient({})", self.platform())
    }
}
