//! `SimBackend` — a deterministic, seeded pure-Rust transformer surrogate.
//!
//! It is *not* a trained model: it is a stateless family of hash-derived
//! feature functions chosen so that the engine observes the attention
//! structure the paper documents (Figure 3) while staying fully
//! reproducible and dependency-free:
//!
//! * every position `p` owns a pseudo-random unit feature `phi(layer, p)`;
//!   keys are scaled copies of `phi`, so Quest-style representative bounds
//!   recover query/position affinity faithfully;
//! * queries mix `phi` directions with the weights of a
//!   [`ModelProfile`](crate::sim::profiles::ModelProfile): a hot recency
//!   window, a sink component, **milestone** components that decay like the
//!   paper's waterfall (`milestone_hot * decay^(age/8)`), and periodic
//!   **phoenix** re-lights of early (prompt-region) positions;
//! * values and the post-attention mixing depend on the *gathered* KV, so
//!   evicting a page genuinely changes downstream logits — sparsity
//!   policies have end-to-end consequences, exactly as on the PJRT path.
//!
//! All functions are pure in `(seed, inputs)`: greedy decoding is
//! bit-deterministic, which the integration suite relies on.

use anyhow::{bail, Result};

use super::backend::{Backend, PrefillOut, Qkv};
use crate::config::{ArtifactMeta, ModelSpec};
use crate::sim::profiles::{ModelProfile, MODELS};

/// Period (in tokens) of milestone emission, mirroring the 9-token reasoning
/// steps of the synthetic corpus (`workload::Problem::encode_decode`).
const STEP_PERIOD: usize = 9;
/// Offset of the milestone (emitted value) token within a step.
const MILESTONE_OFFSET: usize = 7;
/// Milestones older than this many steps contribute negligible mass.
const MILESTONE_HORIZON: usize = 40;
/// Key feature scale: spreads pre-softmax page scores enough that the
/// waterfall survives `page_probs`' 1/sqrt(head_dim) temperature.
const KEY_SCALE: f32 = 4.0;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Domain tags keep the feature families independent.
const TAG_EMBED: u64 = 0xe1;
const TAG_POS: u64 = 0xe2;
const TAG_VAL: u64 = 0xe3;
const TAG_OUT: u64 = 0xe4;
const TAG_MIX: u64 = 0xe5;
const TAG_NOISE: u64 = 0xe6;

pub struct SimBackend {
    spec: ModelSpec,
    capacities: Vec<usize>,
    seed: u64,
    profile: ModelProfile,
    /// Precomputed lm-head dictionary, `[vocab * d_model]` (hot path:
    /// rebuilding it per decoded token is pure waste).
    out_dirs: Vec<f32>,
}

impl SimBackend {
    /// Build from artifact metadata (the sim default is
    /// [`ArtifactMeta::sim_default`]); attention structure follows
    /// `sim::profiles::MODELS[1]` (the qwen-math persona).
    pub fn new(meta: &ArtifactMeta, seed: u64) -> SimBackend {
        Self::with_capacities(meta, seed, &meta.capacities)
    }

    /// Restrict the advertised capacity ladder (mirrors
    /// `ModelRuntime::load`'s `only_capacities`); unlike the AOT backend the
    /// surrogate can serve any capacity, so the ladder only shapes padding.
    pub fn with_capacities(meta: &ArtifactMeta, seed: u64, caps: &[usize]) -> SimBackend {
        let mut capacities: Vec<usize> = caps.to_vec();
        capacities.sort_unstable();
        capacities.dedup();
        let mut b = SimBackend {
            spec: meta.model.clone(),
            capacities,
            seed,
            profile: MODELS[1],
            out_dirs: Vec::new(),
        };
        let mut dirs = Vec::with_capacity(b.spec.vocab * b.spec.d_model);
        for t in 0..b.spec.vocab {
            dirs.extend(b.feat(TAG_OUT, 0, t as u64, b.spec.d_model));
        }
        b.out_dirs = dirs;
        b
    }

    /// Deterministic pseudo-random unit vector for `(tag, a, b)`.
    fn feat(&self, tag: u64, a: u64, b: u64, dim: usize) -> Vec<f32> {
        let mut x = self
            .seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            ^ tag.wrapping_mul(0xd1342543de82ef95)
            ^ a.wrapping_mul(0xaf251af3b0f025b5)
            ^ b.wrapping_mul(0xb564ef22ec7aece5);
        let mut v = Vec::with_capacity(dim);
        let mut norm2 = 0.0f32;
        for _ in 0..dim {
            let r = splitmix64(&mut x);
            // uniform in [-1, 1)
            let f = ((r >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32;
            norm2 += f * f;
            v.push(f);
        }
        let inv = 1.0 / norm2.sqrt().max(1e-12);
        for f in v.iter_mut() {
            *f *= inv;
        }
        v
    }

    /// Positional key/query dictionary entry `phi(layer, pos)` (head_dim).
    fn phi(&self, layer: usize, pos: usize) -> Vec<f32> {
        self.feat(TAG_POS, layer as u64, pos as u64, self.spec.head_dim)
    }

    /// The query direction at `(layer, pos)`: weighted sum of dictionary
    /// entries reproducing recency + sink + waterfall + phoenix structure.
    fn query_dir(&self, layer: usize, pos: usize) -> Vec<f32> {
        let hd = self.spec.head_dim;
        let mp = &self.profile;
        let mut q = vec![0.0f32; hd];
        let add = |dir: &[f32], w: f32, q: &mut Vec<f32>| {
            for (qc, &dc) in q.iter_mut().zip(dir) {
                *qc += w * dc;
            }
        };
        // recency window: the active page stays hot
        for a in 0..4usize {
            let Some(p) = pos.checked_sub(a) else { break };
            add(&self.phi(layer, p), 0.6f32.powi(a as i32), &mut q);
        }
        // sink mass on the first positions
        add(&self.phi(layer, 0), 0.35, &mut q);
        // waterfall: decaying attention to previously emitted milestones
        if pos >= STEP_PERIOD {
            let cur_step = pos / STEP_PERIOD;
            let lo_step = cur_step.saturating_sub(MILESTONE_HORIZON);
            for s in lo_step..cur_step {
                let mpos = s * STEP_PERIOD + MILESTONE_OFFSET;
                if mpos >= pos {
                    continue;
                }
                let age = (pos - mpos) as f64;
                let w = mp.milestone_hot * mp.decay.powf(age / 8.0);
                if w > 1e-3 {
                    add(&self.phi(layer, mpos), w as f32 * 2.0, &mut q);
                }
            }
            // phoenix: mid-step, re-light an early (prompt-region) operand
            let in_step = pos % STEP_PERIOD;
            if in_step == STEP_PERIOD / 2 || in_step == STEP_PERIOD / 2 + 1 {
                let ppos = 6 + 4 * (cur_step % 12);
                if ppos < pos {
                    add(&self.phi(layer, ppos), (mp.phoenix_hot * 2.0) as f32, &mut q);
                }
            }
        }
        // background noise so estimated scores are never exactly tied
        add(&self.feat(TAG_NOISE, layer as u64, pos as u64, hd), mp.noise as f32, &mut q);
        q
    }

    /// Shared residual mixing: rotate the hidden stream, fold in a
    /// contribution vector (attention output on the decode path, the value
    /// vector on the attention-free prefill path) and a per-layer bias,
    /// then renormalise.
    fn mix_hidden(&self, layer: usize, h: &[f32], contrib: &[f32]) -> Vec<f32> {
        let d = self.spec.d_model;
        let bias = self.feat(TAG_MIX, layer as u64, 0, d);
        let clen = contrib.len();
        let mut out = Vec::with_capacity(d);
        let mut norm2 = 0.0f32;
        for i in 0..d {
            let sign = if (i / 2) % 2 == 0 { 1.0 } else { -1.0 };
            let x = 0.7 * sign * h[(i + 1) % d] + 0.6 * contrib[i % clen] + 0.15 * bias[i];
            norm2 += x * x;
            out.push(x);
        }
        let inv = 1.0 / norm2.sqrt().max(1e-12);
        for x in out.iter_mut() {
            *x *= inv;
        }
        out
    }
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn capacities(&self) -> Vec<usize> {
        self.capacities.clone()
    }

    fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        if let Some(&c) = self.capacities.iter().find(|&&c| c >= n_slots) {
            return Ok(c);
        }
        // the surrogate attends any width: fall through to a padded size
        Ok((n_slots.max(1) + 63) / 64 * 64)
    }

    fn embed_tok(&self, token: u32) -> Result<Vec<f32>> {
        if (token as usize) >= self.spec.vocab {
            bail!("token {token} out of vocab {}", self.spec.vocab);
        }
        Ok(self.feat(TAG_EMBED, 0, token as u64, self.spec.d_model))
    }

    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        // keys: the positional dictionary entry, shared across kv heads
        let phi = self.phi(layer, pos);
        let mut k = Vec::with_capacity(kv_dim);
        for _ in 0..s.n_kv_heads {
            k.extend(phi.iter().map(|&c| c * KEY_SCALE));
        }
        // queries: structured direction, shared across query heads
        let qdir = self.query_dir(layer, pos);
        let mut q = Vec::with_capacity(s.n_heads * hd);
        for _ in 0..s.n_heads {
            q.extend_from_slice(&qdir);
        }
        // values: positional feature tinted by the current hidden state, so
        // attended history influences downstream computation
        let val = self.feat(TAG_VAL, layer as u64, pos as u64, kv_dim);
        let mut v = Vec::with_capacity(kv_dim);
        for (i, &b) in val.iter().enumerate() {
            v.push(0.8 * b + 0.2 * h[i % h.len()]);
        }
        Ok(Qkv { q, k, v })
    }

    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; s.n_heads * hd];
        let mut scores = vec![0.0f32; capacity];
        for head in 0..s.n_heads {
            let g = head / group;
            let qh = &q[head * hd..(head + 1) * hd];
            let mut max = f32::NEG_INFINITY;
            for slot in 0..capacity {
                if valid[slot] < 0.5 {
                    scores[slot] = f32::NEG_INFINITY;
                    continue;
                }
                let ks = &k_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
                let mut dot = 0.0f32;
                for c in 0..hd {
                    dot += qh[c] * ks[c];
                }
                let sc = dot * scale;
                scores[slot] = sc;
                if sc > max {
                    max = sc;
                }
            }
            if max == f32::NEG_INFINITY {
                continue; // nothing valid: attention contributes nothing
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                if *sc > f32::NEG_INFINITY {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                } else {
                    *sc = 0.0;
                }
            }
            let out = &mut attn[head * hd..(head + 1) * hd];
            for slot in 0..capacity {
                let w = scores[slot] / denom;
                if w == 0.0 {
                    continue;
                }
                let vs = &v_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
                for c in 0..hd {
                    out[c] += w * vs[c];
                }
            }
        }
        // deterministic residual mixing, sensitive to which pages were
        // attended (and therefore to eviction decisions)
        Ok(self.mix_hidden(layer, h, &attn))
    }

    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        let d = s.d_model;
        let mut logits = Vec::with_capacity(s.vocab);
        for t in 0..s.vocab {
            let dir = &self.out_dirs[t * d..(t + 1) * d];
            let mut dot = 0.0f32;
            for (a, b) in h.iter().zip(dir) {
                dot += a * b;
            }
            logits.push(dot * 8.0);
        }
        Ok(logits)
    }

    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut> {
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        let s = &self.spec;
        let n = tokens.len();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let mut k = vec![0.0f32; s.n_layers * n * kv_dim];
        let mut v = vec![0.0f32; s.n_layers * n * kv_dim];
        let mut logits = Vec::new();
        for (pos, &tok) in tokens.iter().enumerate() {
            let mut h = self.embed_tok(tok)?;
            for layer in 0..s.n_layers {
                let qkv = self.layer_qkv(layer, &h, pos)?;
                let off = layer * n * kv_dim + pos * kv_dim;
                k[off..off + kv_dim].copy_from_slice(&qkv.k);
                v[off..off + kv_dim].copy_from_slice(&qkv.v);
                // attention-free hidden update: prefill hiddens only shape
                // the first decoded token, decode re-derives h per token
                h = self.mix_hidden(layer, &h, &qkv.v);
            }
            if pos == n - 1 {
                logits = self.lm_head(&h)?;
            }
        }
        Ok(PrefillOut { k, v, logits, padded: n })
    }
}

impl std::fmt::Debug for SimBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SimBackend(layers={}, d_model={}, seed={}, profile={})",
            self.spec.n_layers, self.spec.d_model, self.seed, self.profile.name
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> SimBackend {
        SimBackend::new(&ArtifactMeta::sim_default(), 0)
    }

    #[test]
    fn deterministic_and_unit_norm() {
        let b = backend();
        let a = b.embed_tok(5).unwrap();
        let c = b.embed_tok(5).unwrap();
        assert_eq!(a, c);
        let n2: f32 = a.iter().map(|x| x * x).sum();
        assert!((n2 - 1.0).abs() < 1e-4, "embed norm {n2}");
        assert_ne!(a, b.embed_tok(6).unwrap());
    }

    #[test]
    fn seeds_produce_different_models() {
        let meta = ArtifactMeta::sim_default();
        let a = SimBackend::new(&meta, 1).embed_tok(3).unwrap();
        let b = SimBackend::new(&meta, 2).embed_tok(3).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn qkv_shapes() {
        let b = backend();
        let s = b.spec().clone();
        let h = b.embed_tok(1).unwrap();
        let qkv = b.layer_qkv(0, &h, 3).unwrap();
        assert_eq!(qkv.q.len(), s.n_heads * s.head_dim);
        assert_eq!(qkv.k.len(), s.n_kv_heads * s.head_dim);
        assert_eq!(qkv.v.len(), s.n_kv_heads * s.head_dim);
    }

    #[test]
    fn waterfall_structure_in_scores() {
        // q(t) · k(p), averaged over layers and reasoning steps to wash out
        // the random-dictionary crosstalk: the active position scores above
        // a freshly emitted milestone, which scores above a long-faded one.
        let b = backend();
        let spec = b.spec().clone();
        let hd = spec.head_dim;
        let h = b.embed_tok(1).unwrap();
        let (mut fresh, mut stale, mut active) = (0.0f32, 0.0f32, 0.0f32);
        let mut n = 0.0f32;
        for layer in 0..spec.n_layers {
            for s in [10usize, 12, 14, 16] {
                // mid-step position: the step-(s-1) milestone is 5 tokens
                // back — outside the recency window, inside the waterfall
                let t = s * STEP_PERIOD + 3;
                let q = b.layer_qkv(layer, &h, t).unwrap().q;
                let score = |p: usize| -> f32 {
                    let k = b.layer_qkv(layer, &h, p).unwrap().k;
                    (0..hd).map(|c| q[c] * k[c]).sum()
                };
                fresh += score((s - 1) * STEP_PERIOD + MILESTONE_OFFSET);
                stale += score(2 * STEP_PERIOD + MILESTONE_OFFSET);
                active += score(t);
                n += 1.0;
            }
        }
        let (fresh, stale, active) = (fresh / n, stale / n, active / n);
        assert!(active > fresh + 0.3, "active {active} vs fresh milestone {fresh}");
        assert!(fresh > stale + 0.3, "fresh {fresh} vs stale milestone {stale}");
    }

    #[test]
    fn attention_responds_to_values() {
        // Two different gathered value sets must yield different hiddens —
        // eviction has end-to-end consequences.
        let b = backend();
        let s = b.spec().clone();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let h = b.embed_tok(2).unwrap();
        let qkv = b.layer_qkv(0, &h, 4).unwrap();
        let cap = 4;
        let mut k_sel = vec![0.0f32; cap * kv_dim];
        let mut v1 = vec![0.0f32; cap * kv_dim];
        let mut v2 = vec![0.0f32; cap * kv_dim];
        let valid = vec![1.0f32, 1.0, 0.0, 0.0];
        k_sel[..kv_dim].copy_from_slice(&qkv.k);
        v1[..kv_dim].copy_from_slice(&qkv.v);
        for (i, x) in v2.iter_mut().enumerate().take(kv_dim) {
            *x = (i as f32 * 0.1).sin();
        }
        let h1 = b.layer_attn_mlp(0, cap, &h, &qkv.q, &k_sel, &v1, &valid).unwrap();
        let h2 = b.layer_attn_mlp(0, cap, &h, &qkv.q, &k_sel, &v2, &valid).unwrap();
        assert_ne!(h1, h2);
        let n2: f32 = h1.iter().map(|x| x * x).sum();
        assert!((n2 - 1.0).abs() < 1e-4);
    }

    #[test]
    fn prefill_matches_decode_keys() {
        // Keys are purely positional: prefill and a hypothetical decode of
        // the same position agree, so RepBounds stay consistent.
        let b = backend();
        let toks = [1u32, 3, 4, 5, 9];
        let out = b.prefill(&toks).unwrap();
        let spec = b.spec().clone();
        let h = b.embed_tok(toks[2]).unwrap();
        let qkv = b.layer_qkv(1, &h, 2).unwrap();
        let (k, _) = out.kv_at(&spec, 1, 2);
        assert_eq!(k, &qkv.k[..]);
        assert_eq!(out.padded, 5);
        assert_eq!(out.logits.len(), spec.vocab);
    }

    #[test]
    fn capacity_ladder_and_fallback() {
        let b = backend();
        let caps = b.capacities();
        assert!(!caps.is_empty());
        assert_eq!(b.capacity_for(1).unwrap(), caps[0]);
        // beyond the ladder: padded fallback instead of an error
        let huge = caps.last().unwrap() + 1;
        assert!(b.capacity_for(huge).unwrap() >= huge);
    }
}
