//! `SimBackend` — a deterministic, seeded pure-Rust transformer surrogate.
//!
//! It is *not* a trained model: it is a stateless family of hash-derived
//! feature functions chosen so that the engine observes the attention
//! structure the paper documents (Figure 3) while staying fully
//! reproducible and dependency-free:
//!
//! * every position `p` owns a pseudo-random unit feature `phi(layer, p)`;
//!   keys are scaled copies of `phi`, so Quest-style representative bounds
//!   recover query/position affinity faithfully;
//! * queries mix `phi` directions with the weights of a
//!   [`ModelProfile`](crate::sim::profiles::ModelProfile): a hot recency
//!   window, a sink component, **milestone** components that decay like the
//!   paper's waterfall (`milestone_hot * decay^(age/8)`), and periodic
//!   **phoenix** re-lights of early (prompt-region) positions;
//! * values and the post-attention mixing depend on the *gathered* KV, so
//!   evicting a page genuinely changes downstream logits — sparsity
//!   policies have end-to-end consequences, exactly as on the PJRT path.
//!
//! All functions are pure in `(seed, inputs)`: greedy decoding is
//! bit-deterministic, which the integration suite relies on.
//!
//! Because every feature family is pure in `(layer, pos)`, the backend
//! memoizes them (`phi`, the structured query direction, the raw value
//! feature) behind a `RefCell` — decode used to recompute identical
//! hash-derived features every step.  The memo also powers the native
//! batched entry points: sequences decoding at the same positions share
//! the cached features, and [`SimBackend::layer_attn_mlp_batch`] reuses
//! softmax weights across batch items whose inputs are bit-identical
//! (keys and queries are position-pure here, so co-scheduled sequences at
//! the same positions qualify).  All sharing is bitwise-exact: batched and
//! sequential decode produce identical tokens.
//!
//! The backend also implements the zero-copy paged entry points natively
//! (`supports_paged` is true): [`SimBackend::layer_attn_mlp_paged`] reads
//! the selected pages' K/V in place — no gather copy, no capacity
//! padding — while reproducing the gathered reference bit for bit, and
//! its batch sibling carries the same cross-item weight reuse
//! (DESIGN.md §2, paged route; pinned by `rust/tests/paged_attention.rs`).

use std::cell::RefCell;
use std::collections::hash_map::Entry;
use std::collections::HashMap;

use anyhow::{bail, Result};

use super::backend::{AttnBatchItem, Backend, PagedAttnInput, PrefillChunkItem, PrefillChunkOut,
                     PrefillOut, Qkv};
use crate::config::{ArtifactMeta, ModelSpec};
use crate::kvcache::{PageData, PageView};
use crate::sim::profiles::{ModelProfile, MODELS};

/// Period (in tokens) of milestone emission, mirroring the 9-token reasoning
/// steps of the synthetic corpus (`workload::Problem::encode_decode`).
const STEP_PERIOD: usize = 9;
/// Offset of the milestone (emitted value) token within a step.
const MILESTONE_OFFSET: usize = 7;
/// Milestones older than this many steps contribute negligible mass.
const MILESTONE_HORIZON: usize = 40;
/// Key feature scale: spreads pre-softmax page scores enough that the
/// waterfall survives `page_probs`' 1/sqrt(head_dim) temperature.
const KEY_SCALE: f32 = 4.0;
/// Positions per layer the feature memo retains; later positions are
/// recomputed on the fly.  Worst-case footprint (filled lazily, DESIGN.md
/// §2): `n_layers * MEMO_MAX_POS * (2 * head_dim + kv_dim) * 4` bytes —
/// about 25 MB for the sim-default spec.
const MEMO_MAX_POS: usize = 16384;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Domain tags keep the feature families independent.
const TAG_EMBED: u64 = 0xe1;
const TAG_POS: u64 = 0xe2;
const TAG_VAL: u64 = 0xe3;
const TAG_OUT: u64 = 0xe4;
const TAG_MIX: u64 = 0xe5;
const TAG_NOISE: u64 = 0xe6;

/// Lazily filled per-layer feature memo (all families pure in `(layer, pos)`).
#[derive(Default)]
struct LayerMemo {
    /// `phi(layer, pos)` positional dictionary entries, each `[head_dim]`.
    phi: Vec<Option<Box<[f32]>>>,
    /// Structured query directions `query_dir(layer, pos)`, each `[head_dim]`.
    qdir: Vec<Option<Box<[f32]>>>,
    /// Raw value features `feat(TAG_VAL, layer, pos)`, each `[kv_dim]`.
    val: Vec<Option<Box<[f32]>>>,
}

/// The deterministic pure-Rust transformer surrogate (see the module
/// docs for the feature families and sharing machinery).
pub struct SimBackend {
    spec: ModelSpec,
    capacities: Vec<usize>,
    seed: u64,
    profile: ModelProfile,
    /// Precomputed lm-head dictionary, `[vocab * d_model]` (hot path:
    /// rebuilding it per decoded token is pure waste).
    out_dirs: Vec<f32>,
    /// Precomputed embedding dictionary, `[vocab * d_model]`.
    embed_dirs: Vec<f32>,
    /// Precomputed per-layer mixing bias, `[n_layers * d_model]`.
    mix_bias: Vec<f32>,
    /// Positional feature memo, one entry per layer.  Interior-mutable:
    /// the backend trait takes `&self` on the hot path.  `RefCell` (not a
    /// lock) — backends live on one replica thread.
    memo: RefCell<Vec<LayerMemo>>,
    /// Reusable dequantization scratch for the paged route: quantized
    /// [`PageView`]s decode into this arena at entry, `f32` views stay
    /// zero-copy, and the INVARIANT-pinned attention loops below run over
    /// plain `f32` slices either way.  Same `RefCell` discipline as `memo`.
    dequant: RefCell<Vec<f32>>,
}

impl SimBackend {
    /// Build from artifact metadata (the sim default is
    /// [`ArtifactMeta::sim_default`]); attention structure follows
    /// `sim::profiles::MODELS[1]` (the qwen-math persona).
    pub fn new(meta: &ArtifactMeta, seed: u64) -> SimBackend {
        Self::with_capacities(meta, seed, &meta.capacities)
    }

    /// Restrict the advertised capacity ladder (mirrors
    /// `ModelRuntime::load`'s `only_capacities`); unlike the AOT backend the
    /// surrogate can serve any capacity, so the ladder only shapes padding.
    pub fn with_capacities(meta: &ArtifactMeta, seed: u64, caps: &[usize]) -> SimBackend {
        let mut capacities: Vec<usize> = caps.to_vec();
        capacities.sort_unstable();
        capacities.dedup();
        let n_layers = meta.model.n_layers;
        let mut b = SimBackend {
            spec: meta.model.clone(),
            capacities,
            seed,
            profile: MODELS[1],
            out_dirs: Vec::new(),
            embed_dirs: Vec::new(),
            mix_bias: Vec::new(),
            memo: RefCell::new((0..n_layers).map(|_| LayerMemo::default()).collect()),
            dequant: RefCell::new(Vec::new()),
        };
        let mut out_dirs = Vec::with_capacity(b.spec.vocab * b.spec.d_model);
        let mut embed_dirs = Vec::with_capacity(b.spec.vocab * b.spec.d_model);
        for t in 0..b.spec.vocab {
            out_dirs.extend(b.feat(TAG_OUT, 0, t as u64, b.spec.d_model));
            embed_dirs.extend(b.feat(TAG_EMBED, 0, t as u64, b.spec.d_model));
        }
        b.out_dirs = out_dirs;
        b.embed_dirs = embed_dirs;
        let mut bias = Vec::with_capacity(n_layers * b.spec.d_model);
        for layer in 0..n_layers {
            bias.extend(b.feat(TAG_MIX, layer as u64, 0, b.spec.d_model));
        }
        b.mix_bias = bias;
        b
    }

    /// Deterministic pseudo-random unit vector for `(tag, a, b)`.
    fn feat(&self, tag: u64, a: u64, b: u64, dim: usize) -> Vec<f32> {
        let mut x = self
            .seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            ^ tag.wrapping_mul(0xd1342543de82ef95)
            ^ a.wrapping_mul(0xaf251af3b0f025b5)
            ^ b.wrapping_mul(0xb564ef22ec7aece5);
        let mut v = Vec::with_capacity(dim);
        let mut norm2 = 0.0f32;
        for _ in 0..dim {
            let r = splitmix64(&mut x);
            // uniform in [-1, 1)
            let f = ((r >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32;
            norm2 += f * f;
            v.push(f);
        }
        let inv = 1.0 / norm2.sqrt().max(1e-12);
        for f in v.iter_mut() {
            *f *= inv;
        }
        v
    }

    /// Positional key/query dictionary entry `phi(layer, pos)` (head_dim),
    /// computed from scratch (memo miss / beyond the memo horizon).
    fn phi_uncached(&self, layer: usize, pos: usize) -> Vec<f32> {
        self.feat(TAG_POS, layer as u64, pos as u64, self.spec.head_dim)
    }

    /// Get-or-compute one memoized feature vector, then run `f` over it.
    ///
    /// Memo discipline: `compute` runs with no `memo` borrow held, so it
    /// may re-enter another accessor (`query_dir_uncached` re-enters
    /// `with_phi`); the closure `f` runs under a borrow and must NOT
    /// re-enter any.
    fn with_feat_memo<R>(
        &self,
        layer: usize,
        pos: usize,
        family: fn(&mut LayerMemo) -> &mut Vec<Option<Box<[f32]>>>,
        compute: impl FnOnce() -> Vec<f32>,
        f: impl FnOnce(&[f32]) -> R,
    ) -> R {
        if pos >= MEMO_MAX_POS {
            return f(&compute());
        }
        {
            let mut memo = self.memo.borrow_mut();
            if let Some(Some(v)) = family(&mut memo[layer]).get(pos) {
                return f(&v[..]);
            }
        }
        let computed = compute().into_boxed_slice();
        let mut memo = self.memo.borrow_mut();
        let fam = family(&mut memo[layer]);
        if fam.len() <= pos {
            fam.resize_with(pos + 1, || None);
        }
        if fam[pos].is_none() {
            fam[pos] = Some(computed);
        }
        f(fam[pos].as_deref().unwrap())
    }

    /// Run `f` over the memoized `phi(layer, pos)`.
    fn with_phi<R>(&self, layer: usize, pos: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        self.with_feat_memo(layer, pos, |m| &mut m.phi, || self.phi_uncached(layer, pos), f)
    }

    /// Run `f` over the memoized `query_dir(layer, pos)`.
    fn with_qdir<R>(&self, layer: usize, pos: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        self.with_feat_memo(layer, pos, |m| &mut m.qdir,
                            || self.query_dir_uncached(layer, pos), f)
    }

    /// Run `f` over the memoized raw value feature at `(layer, pos)`.
    fn with_val<R>(&self, layer: usize, pos: usize, f: impl FnOnce(&[f32]) -> R) -> R {
        let kv_dim = self.spec.n_kv_heads * self.spec.head_dim;
        self.with_feat_memo(layer, pos, |m| &mut m.val,
                            || self.feat(TAG_VAL, layer as u64, pos as u64, kv_dim), f)
    }

    /// The query direction at `(layer, pos)`: weighted sum of dictionary
    /// entries reproducing recency + sink + waterfall + phoenix structure.
    fn query_dir_uncached(&self, layer: usize, pos: usize) -> Vec<f32> {
        let hd = self.spec.head_dim;
        let mp = &self.profile;
        let mut q = vec![0.0f32; hd];
        let add = |dir: &[f32], w: f32, q: &mut Vec<f32>| {
            for (qc, &dc) in q.iter_mut().zip(dir) {
                *qc += w * dc;
            }
        };
        // recency window: the active page stays hot
        for a in 0..4usize {
            let Some(p) = pos.checked_sub(a) else { break };
            self.with_phi(layer, p, |phi| add(phi, 0.6f32.powi(a as i32), &mut q));
        }
        // sink mass on the first positions
        self.with_phi(layer, 0, |phi| add(phi, 0.35, &mut q));
        // waterfall: decaying attention to previously emitted milestones
        if pos >= STEP_PERIOD {
            let cur_step = pos / STEP_PERIOD;
            let lo_step = cur_step.saturating_sub(MILESTONE_HORIZON);
            for s in lo_step..cur_step {
                let mpos = s * STEP_PERIOD + MILESTONE_OFFSET;
                if mpos >= pos {
                    continue;
                }
                let age = (pos - mpos) as f64;
                let w = mp.milestone_hot * mp.decay.powf(age / 8.0);
                if w > 1e-3 {
                    self.with_phi(layer, mpos, |phi| add(phi, w as f32 * 2.0, &mut q));
                }
            }
            // phoenix: mid-step, re-light an early (prompt-region) operand
            let in_step = pos % STEP_PERIOD;
            if in_step == STEP_PERIOD / 2 || in_step == STEP_PERIOD / 2 + 1 {
                let ppos = 6 + 4 * (cur_step % 12);
                if ppos < pos {
                    self.with_phi(layer, ppos, |phi| {
                        add(phi, (mp.phoenix_hot * 2.0) as f32, &mut q)
                    });
                }
            }
        }
        // background noise so estimated scores are never exactly tied
        add(&self.feat(TAG_NOISE, layer as u64, pos as u64, hd), mp.noise as f32, &mut q);
        q
    }

    /// One prompt token's full prefill column: post-RoPE K and V for every
    /// layer (`[n_layers * kv_dim]` each, layer-major) plus the final
    /// hidden state after the attention-free prefill update.  Pure in
    /// `(token, pos)` — the hidden stream starts from the token's own
    /// embedding, never its neighbors — which is what lets
    /// [`SimBackend::prefill_chunk_batch`] share columns across
    /// co-admitted prompts.
    ///
    /// INVARIANT (do not edit one side alone): this must stay op-for-op
    /// identical to the direct-write per-token loop in
    /// `SimBackend::prefill_chunk` (which skips the column staging on the
    /// TTFT hot path); f32 copies are exact, so staged and direct produce
    /// the same bits — pinned by
    /// `tests::prefill_chunk_batch_matches_per_item_bitwise`.
    fn prefill_column(&self, tok: u32, pos: usize) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let s = &self.spec;
        let kv_dim = s.n_kv_heads * s.head_dim;
        let mut k = Vec::with_capacity(s.n_layers * kv_dim);
        let mut v = Vec::with_capacity(s.n_layers * kv_dim);
        let mut h = self.embed_tok(tok)?;
        for layer in 0..s.n_layers {
            let qkv = self.layer_qkv(layer, &h, pos)?;
            k.extend_from_slice(&qkv.k);
            v.extend_from_slice(&qkv.v);
            // attention-free hidden update: prefill hiddens only shape the
            // first decoded token, decode re-derives h per token
            h = self.mix_hidden(layer, &h, &qkv.v);
        }
        Ok((k, v, h))
    }

    /// Shared residual mixing: rotate the hidden stream, fold in a
    /// contribution vector (attention output on the decode path, the value
    /// vector on the attention-free prefill path) and a per-layer bias,
    /// then renormalise.
    fn mix_hidden(&self, layer: usize, h: &[f32], contrib: &[f32]) -> Vec<f32> {
        let d = self.spec.d_model;
        let bias = &self.mix_bias[layer * d..(layer + 1) * d];
        let clen = contrib.len();
        let mut out = Vec::with_capacity(d);
        let mut norm2 = 0.0f32;
        for i in 0..d {
            let sign = if (i / 2) % 2 == 0 { 1.0 } else { -1.0 };
            let x = 0.7 * sign * h[(i + 1) % d] + 0.6 * contrib[i % clen] + 0.15 * bias[i];
            norm2 += x * x;
            out.push(x);
        }
        let inv = 1.0 / norm2.sqrt().max(1e-12);
        for x in out.iter_mut() {
            *x *= inv;
        }
        out
    }

    /// Softmax weights for one (query-head slice, kv group `g`) pair over an
    /// item's gathered slots, written into `dst` (`[capacity]`).
    ///
    /// INVARIANT (do not edit one side alone): this must stay bit-identical
    /// to the corresponding per-head pass inside `layer_attn_mlp` — same
    /// ops in the same order, including the invalid-slot, all-invalid and
    /// NaN handling.  `layer_attn_mlp` is the naive reference
    /// implementation; this is the optimized batch-path twin.  Divergence
    /// is caught by `tests::batched_attn_matches_per_item_bitwise` and the
    /// end-to-end suite in `rust/tests/batched_decode.rs`.
    fn softmax_weights(&self, it: &AttnBatchItem<'_>, qh: &[f32], g: usize, dst: &mut [f32]) {
        let hd = self.spec.head_dim;
        let kv_dim = self.spec.n_kv_heads * hd;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut max = f32::NEG_INFINITY;
        for slot in 0..it.capacity {
            if it.valid[slot] < 0.5 {
                dst[slot] = f32::NEG_INFINITY;
                continue;
            }
            let ks = &it.k_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
            let mut dot = 0.0f32;
            for c in 0..hd {
                dot += qh[c] * ks[c];
            }
            let sc = dot * scale;
            dst[slot] = sc;
            if sc > max {
                max = sc;
            }
        }
        if max == f32::NEG_INFINITY {
            // nothing valid: zero weights, attention contributes nothing
            for w in dst.iter_mut() {
                *w = 0.0;
            }
            return;
        }
        let mut denom = 0.0f32;
        for sc in dst.iter_mut() {
            if *sc > f32::NEG_INFINITY {
                *sc = (*sc - max).exp();
                denom += *sc;
            } else {
                *sc = 0.0;
            }
        }
        for w in dst.iter_mut() {
            *w /= denom;
        }
    }

    /// Per-head softmax weights `[n_heads * capacity]` for one item.
    ///
    /// The surrogate repeats the query direction across heads and `phi`
    /// across kv heads, so the per-head score/softmax work usually
    /// collapses: detected bitwise, computed once per distinct
    /// (query, kv group) pair and broadcast.  Returns whether all heads in
    /// each kv group carry identical rows (callers may then share value
    /// aggregation within a group).
    fn attn_weights(&self, it: &AttnBatchItem<'_>, weights: &mut Vec<f32>) -> bool {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        let cap = it.capacity;
        weights.clear();
        weights.resize(s.n_heads * cap, 0.0);
        let q0 = &it.q[..hd];
        let q_uniform = (1..s.n_heads).all(|h| bits_eq(&it.q[h * hd..(h + 1) * hd], q0));
        if !q_uniform {
            for head in 0..s.n_heads {
                let g = head / group;
                let qh = &it.q[head * hd..(head + 1) * hd];
                self.softmax_weights(it, qh, g, &mut weights[head * cap..(head + 1) * cap]);
            }
            return false;
        }
        let k_uniform = (0..cap).all(|slot| {
            let base = slot * kv_dim;
            (1..s.n_kv_heads).all(|g| {
                bits_eq(&it.k_sel[base + g * hd..base + (g + 1) * hd],
                        &it.k_sel[base..base + hd])
            })
        });
        let distinct = if k_uniform { 1 } else { s.n_kv_heads };
        for g in 0..distinct {
            let head0 = g * group;
            self.softmax_weights(it, q0, g, &mut weights[head0 * cap..(head0 + 1) * cap]);
        }
        // broadcast the computed rows to the remaining heads
        for head in 0..s.n_heads {
            let g = head / group;
            let src = if k_uniform { 0 } else { g * group };
            if head == src {
                continue;
            }
            let (lo, hi) = weights.split_at_mut(head * cap);
            hi[..cap].copy_from_slice(&lo[src * cap..src * cap + cap]);
        }
        true
    }

    /// Paged twin of [`SimBackend::softmax_weights`]: softmax weights for
    /// one (query-head slice, kv group `g`) pair over an item's live slots,
    /// read page by page from the resolved `f32` views
    /// ([`resolve_pages`]), written into `dst` (`[n_slots]`).
    ///
    /// INVARIANT (do not edit one side alone): this must stay bit-identical
    /// to the corresponding per-head pass of both `layer_attn_mlp_paged`
    /// and the gathered reference `layer_attn_mlp` — same ops over the live
    /// slots in the same (selection, slot) order, including the
    /// non-finite-score handling.  Gathered padding slots contribute
    /// nothing to max/denom there, so skipping them entirely here yields
    /// the same bits.  Divergence is caught by
    /// `tests::paged_attn_matches_gathered_bitwise` and
    /// `rust/tests/paged_attention.rs`.
    fn paged_softmax_weights(&self, pages: &[(&[f32], &[f32], usize)], qh: &[f32], g: usize,
                             dst: &mut [f32]) {
        let hd = self.spec.head_dim;
        let kv_dim = self.spec.n_kv_heads * hd;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut max = f32::NEG_INFINITY;
        let mut slot = 0usize;
        for &(pk, _, len) in pages {
            for t in 0..len {
                let ks = &pk[t * kv_dim + g * hd..t * kv_dim + (g + 1) * hd];
                let mut dot = 0.0f32;
                for c in 0..hd {
                    dot += qh[c] * ks[c];
                }
                let sc = dot * scale;
                dst[slot] = sc;
                if sc > max {
                    max = sc;
                }
                slot += 1;
            }
        }
        if max == f32::NEG_INFINITY {
            // no slots, or nothing finite: attention contributes nothing
            for w in dst.iter_mut() {
                *w = 0.0;
            }
            return;
        }
        let mut denom = 0.0f32;
        for sc in dst.iter_mut() {
            if *sc > f32::NEG_INFINITY {
                *sc = (*sc - max).exp();
                denom += *sc;
            } else {
                *sc = 0.0;
            }
        }
        for w in dst.iter_mut() {
            *w /= denom;
        }
    }

    /// Paged twin of [`SimBackend::attn_weights`]: per-head softmax weights
    /// `[n_heads * n_slots]` for one item over the resolved `f32` page
    /// views, with the same bitwise-detected head/kv-group collapse.
    /// Returns whether all heads in each kv group carry identical rows.
    fn paged_attn_weights(&self, q: &[f32], pages: &[(&[f32], &[f32], usize)], n_slots: usize,
                          weights: &mut Vec<f32>) -> bool {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        weights.clear();
        weights.resize(s.n_heads * n_slots, 0.0);
        let q0 = &q[..hd];
        let q_uniform = (1..s.n_heads).all(|h| bits_eq(&q[h * hd..(h + 1) * hd], q0));
        if !q_uniform {
            for head in 0..s.n_heads {
                let g = head / group;
                let qh = &q[head * hd..(head + 1) * hd];
                self.paged_softmax_weights(pages, qh, g,
                                           &mut weights[head * n_slots..(head + 1) * n_slots]);
            }
            return false;
        }
        let k_uniform = pages.iter().all(|&(pk, _, len)| {
            (0..len).all(|t| {
                let base = t * kv_dim;
                (1..s.n_kv_heads).all(|g| {
                    bits_eq(&pk[base + g * hd..base + (g + 1) * hd], &pk[base..base + hd])
                })
            })
        });
        let distinct = if k_uniform { 1 } else { s.n_kv_heads };
        for g in 0..distinct {
            let head0 = g * group;
            self.paged_softmax_weights(pages, q0, g,
                                       &mut weights[head0 * n_slots..(head0 + 1) * n_slots]);
        }
        // broadcast the computed rows to the remaining heads
        for head in 0..s.n_heads {
            let g = head / group;
            let src = if k_uniform { 0 } else { g * group };
            if head == src {
                continue;
            }
            let (lo, hi) = weights.split_at_mut(head * n_slots);
            hi[..n_slots].copy_from_slice(&lo[src * n_slots..src * n_slots + n_slots]);
        }
        true
    }
}

/// Bitwise slice equality — the reuse predicate for shared attention
/// weights.  Stricter than `==` (distinguishes -0.0, never equates NaN),
/// which is exactly what makes reuse sound: bit-identical inputs give
/// bit-identical outputs.
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Bitwise page-list equality on the weight-relevant parts (key storage,
/// dequantization params and live-slot structure) — the paged-path reuse
/// predicate, checked on the ORIGINAL dtype-tagged views before any
/// dequantization (arena copies have fresh storage, but dequantization is
/// a pure function of these inputs, so equal inputs give equal weights).
/// Values are deliberately not compared: weights don't depend on them.
fn pages_eq(a: &[PageView<'_>], b: &[PageView<'_>]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.len == y.len
                && match (&x.data, &y.data) {
                    (PageData::F32 { k: ak, .. }, PageData::F32 { k: bk, .. }) => bits_eq(ak, bk),
                    (
                        PageData::Quant { dtype: ad, k: ak, k_params: ap, .. },
                        PageData::Quant { dtype: bd, k: bk, k_params: bp, .. },
                    ) => {
                        ad == bd
                            && ap.scale.to_bits() == bp.scale.to_bits()
                            && ap.zero.to_bits() == bp.zero.to_bits()
                            && ak == bk
                    }
                    _ => false,
                }
        })
}

/// Resolve dtype-tagged page views into plain `f32` `(k, v, len)` views
/// for the attention loops: `F32` pages stay zero-copy (they alias the
/// pool's master slab), quantized pages decode into `arena` — one
/// reusable allocation per backend, cleared per call.  Decoding here is
/// bit-identical to `KvPool::read_page`'s gather-route decoding (same
/// `decode_slice`), which is what keeps paged ≡ gathered under every
/// dtype.
fn resolve_pages<'a>(views: &'a [PageView<'a>], arena: &'a mut Vec<f32>)
                     -> Vec<(&'a [f32], &'a [f32], usize)> {
    arena.clear();
    // pass 1: decode every quantized page, recording its arena offset
    // (slices are taken only after the arena stops growing)
    let mut offs = Vec::with_capacity(views.len());
    for w in views {
        match w.data {
            PageData::F32 { .. } => offs.push(usize::MAX),
            PageData::Quant { dtype, k, v, k_params, v_params } => {
                let off = arena.len();
                let n = k.len();
                arena.resize(off + 2 * n, 0.0);
                let (ka, va) = arena[off..off + 2 * n].split_at_mut(n);
                dtype.decode_slice(k, k_params, ka);
                dtype.decode_slice(v, v_params, va);
                offs.push(off);
            }
        }
    }
    let arena = &arena[..];
    views
        .iter()
        .zip(offs)
        .map(|(w, off)| match w.data {
            PageData::F32 { k, v } => (k, v, w.len),
            PageData::Quant { k, .. } => {
                let n = k.len();
                (&arena[off..off + n], &arena[off + n..off + 2 * n], w.len)
            }
        })
        .collect()
}

impl Backend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn capacities(&self) -> Vec<usize> {
        self.capacities.clone()
    }

    fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        if let Some(&c) = self.capacities.iter().find(|&&c| c >= n_slots) {
            return Ok(c);
        }
        // the surrogate attends any width: fall through to a padded size
        Ok((n_slots.max(1) + 63) / 64 * 64)
    }

    fn embed_tok(&self, token: u32) -> Result<Vec<f32>> {
        if (token as usize) >= self.spec.vocab {
            bail!("token {token} out of vocab {}", self.spec.vocab);
        }
        let d = self.spec.d_model;
        let t = token as usize;
        Ok(self.embed_dirs[t * d..(t + 1) * d].to_vec())
    }

    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        // keys: the positional dictionary entry, shared across kv heads
        let mut k = Vec::with_capacity(kv_dim);
        self.with_phi(layer, pos, |phi| {
            for _ in 0..s.n_kv_heads {
                k.extend(phi.iter().map(|&c| c * KEY_SCALE));
            }
        });
        // queries: structured direction, shared across query heads
        let mut q = Vec::with_capacity(s.n_heads * hd);
        self.with_qdir(layer, pos, |qdir| {
            for _ in 0..s.n_heads {
                q.extend_from_slice(qdir);
            }
        });
        // values: positional feature tinted by the current hidden state, so
        // attended history influences downstream computation
        let mut v = Vec::with_capacity(kv_dim);
        self.with_val(layer, pos, |val| {
            for (i, &b) in val.iter().enumerate() {
                v.push(0.8 * b + 0.2 * h[i % h.len()]);
            }
        });
        Ok(Qkv { q, k, v })
    }

    // Reference implementation of attention semantics: the optimized
    // batched twin (`softmax_weights`/`attn_weights` +
    // `layer_attn_mlp_batch`) must reproduce this bitwise — see the
    // INVARIANT note on `softmax_weights` and the pinning tests.
    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let mut attn = vec![0.0f32; s.n_heads * hd];
        let mut scores = vec![0.0f32; capacity];
        for head in 0..s.n_heads {
            let g = head / group;
            let qh = &q[head * hd..(head + 1) * hd];
            let mut max = f32::NEG_INFINITY;
            for slot in 0..capacity {
                if valid[slot] < 0.5 {
                    scores[slot] = f32::NEG_INFINITY;
                    continue;
                }
                let ks = &k_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
                let mut dot = 0.0f32;
                for c in 0..hd {
                    dot += qh[c] * ks[c];
                }
                let sc = dot * scale;
                scores[slot] = sc;
                if sc > max {
                    max = sc;
                }
            }
            if max == f32::NEG_INFINITY {
                continue; // nothing valid: attention contributes nothing
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                if *sc > f32::NEG_INFINITY {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                } else {
                    *sc = 0.0;
                }
            }
            let out = &mut attn[head * hd..(head + 1) * hd];
            for slot in 0..capacity {
                let w = scores[slot] / denom;
                if w == 0.0 {
                    continue;
                }
                let vs = &v_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
                for c in 0..hd {
                    out[c] += w * vs[c];
                }
            }
        }
        // deterministic residual mixing, sensitive to which pages were
        // attended (and therefore to eviction decisions)
        Ok(self.mix_hidden(layer, h, &attn))
    }

    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        let d = s.d_model;
        let mut logits = Vec::with_capacity(s.vocab);
        for t in 0..s.vocab {
            let dir = &self.out_dirs[t * d..(t + 1) * d];
            let mut dot = 0.0f32;
            for (a, b) in h.iter().zip(dir) {
                dot += a * b;
            }
            logits.push(dot * 8.0);
        }
        Ok(logits)
    }

    /// Monolithic prefill = one whole-prompt chunk of the native streaming
    /// path below (the layouts coincide when `chunk_len == n`), so the two
    /// entry points cannot drift apart.
    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut> {
        let c = self.prefill_chunk(tokens, 0, tokens.len())?;
        Ok(PrefillOut { k: c.k, v: c.v, logits: c.logits, padded: c.chunk_len })
    }

    // -- streaming chunked prefill (native implementation) ----------------

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    /// Native streaming chunk: every surrogate prefill feature is pure in
    /// `(token, pos)` — the per-token hidden stream starts from the token's
    /// own embedding, never from its neighbors — so a chunk needs no prefix
    /// recomputation and only O(chunk) buffers, and any chunking produces
    /// the monolithic path's bits exactly (`rust/tests/chunked_prefill.rs`).
    fn prefill_chunk(&self, tokens: &[u32], start: usize, end: usize)
                     -> Result<PrefillChunkOut> {
        if tokens.is_empty() {
            bail!("empty prompt");
        }
        if start >= end || end > tokens.len() {
            bail!("invalid prefill chunk {start}..{end} of {} tokens", tokens.len());
        }
        let s = &self.spec;
        let n = end - start;
        let kv_dim = s.n_kv_heads * s.head_dim;
        let mut k = vec![0.0f32; s.n_layers * n * kv_dim];
        let mut v = vec![0.0f32; s.n_layers * n * kv_dim];
        let mut logits = Vec::new();
        // Direct writes into the output slab — no per-column staging on the
        // TTFT hot path.  INVARIANT (do not edit one side alone): this
        // per-token loop must stay op-for-op identical to
        // `SimBackend::prefill_column`, the batch path's staged twin;
        // divergence is caught by
        // `tests::prefill_chunk_batch_matches_per_item_bitwise`.
        for (i, &tok) in tokens[start..end].iter().enumerate() {
            let pos = start + i;
            let mut h = self.embed_tok(tok)?;
            for layer in 0..s.n_layers {
                let qkv = self.layer_qkv(layer, &h, pos)?;
                let off = layer * n * kv_dim + i * kv_dim;
                k[off..off + kv_dim].copy_from_slice(&qkv.k);
                v[off..off + kv_dim].copy_from_slice(&qkv.v);
                // attention-free hidden update: prefill hiddens only shape
                // the first decoded token, decode re-derives h per token
                h = self.mix_hidden(layer, &h, &qkv.v);
            }
            if pos == tokens.len() - 1 {
                logits = self.lm_head(&h)?;
            }
        }
        Ok(PrefillChunkOut { k, v, logits, chunk_len: n })
    }

    /// One admission tick's prefill chunks for all co-admitted prompts,
    /// with cross-item work sharing: every prefill feature is pure in
    /// `(token, pos)`, so prompts that overlap on a (token, position) pair
    /// — identical co-admitted prompts, shared prefixes at the same
    /// offsets — compute that column once per call and copy it
    /// (`SimBackend::prefill_column`).  Copies are bitwise-exact, so the
    /// sharing is exactly as sound as recomputing: the batch is
    /// bit-identical to per-item [`SimBackend::prefill_chunk`] calls
    /// (pinned by `tests::prefill_chunk_batch_matches_per_item_bitwise`
    /// and `rust/tests/concurrent_prefill.rs`).
    fn prefill_chunk_batch(&self, items: &[PrefillChunkItem<'_>])
                           -> Result<Vec<PrefillChunkOut>> {
        // A lone item has nothing to share: take the direct-write path and
        // skip the column memo entirely (concurrency-1 admission must cost
        // exactly what the PR-4 per-item call did).
        if let [it] = items {
            return Ok(vec![self.prefill_chunk(it.tokens, it.start, it.end)?]);
        }
        let s = &self.spec;
        let kv_dim = s.n_kv_heads * s.head_dim;
        // per-call column memo (never engine-lifetime: prompts are
        // transient, unlike the positional feature memo)
        let mut cols: HashMap<(u32, usize), (Vec<f32>, Vec<f32>, Vec<f32>)> = HashMap::new();
        let mut outs = Vec::with_capacity(items.len());
        for it in items {
            if it.tokens.is_empty() {
                bail!("empty prompt");
            }
            if it.start >= it.end || it.end > it.tokens.len() {
                bail!("invalid prefill chunk {}..{} of {} tokens", it.start, it.end,
                      it.tokens.len());
            }
            let n = it.end - it.start;
            let mut k = vec![0.0f32; s.n_layers * n * kv_dim];
            let mut v = vec![0.0f32; s.n_layers * n * kv_dim];
            let mut logits = Vec::new();
            for (i, &tok) in it.tokens[it.start..it.end].iter().enumerate() {
                let pos = it.start + i;
                let (ck, cv, h) = match cols.entry((tok, pos)) {
                    Entry::Occupied(hit) => &*hit.into_mut(),
                    Entry::Vacant(slot) => &*slot.insert(self.prefill_column(tok, pos)?),
                };
                for layer in 0..s.n_layers {
                    let off = layer * n * kv_dim + i * kv_dim;
                    k[off..off + kv_dim]
                        .copy_from_slice(&ck[layer * kv_dim..(layer + 1) * kv_dim]);
                    v[off..off + kv_dim]
                        .copy_from_slice(&cv[layer * kv_dim..(layer + 1) * kv_dim]);
                }
                if pos == it.tokens.len() - 1 {
                    logits = self.lm_head(h)?;
                }
            }
            outs.push(PrefillChunkOut { k, v, logits, chunk_len: n });
        }
        Ok(outs)
    }

    // -- batched entry points (native implementations) --------------------
    //
    // `embed_tok_batch` and `layer_qkv_batch` deliberately stay on the
    // trait defaults (per-item loops): embeddings are one dictionary copy
    // per token, and qkv's cross-item sharing happens inside the feature
    // memo — items at the same `(layer, pos)` hit the same cached
    // `phi`/`query_dir`/value entries, so the per-item marginal cost is
    // the owned copies the `Qkv` contract requires either way.

    /// One scheduler iteration's attention for all sequences.  Keys and
    /// queries are position-pure in the surrogate, so co-scheduled
    /// sequences at the same positions present bit-identical
    /// `(q, k_sel, valid)` inputs: the score + softmax pass is computed
    /// once per distinct item and reused (detected bitwise — reuse is
    /// exactly as sound as recomputation).  Value aggregation stays
    /// per-item (values carry each sequence's hidden-state tint).
    fn layer_attn_mlp_batch(&self, layer: usize, items: &[AttnBatchItem<'_>])
                            -> Result<Vec<Vec<f32>>> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        let mut outs = Vec::with_capacity(items.len());
        // weights of the most recent distinct item, `[n_heads * capacity]`
        let mut weights: Vec<f32> = Vec::new();
        let mut grouped = false;
        let mut owner: Option<usize> = None;
        for (idx, it) in items.iter().enumerate() {
            let reuse = owner.is_some_and(|p| {
                let pv = &items[p];
                pv.capacity == it.capacity
                    && bits_eq(pv.q, it.q)
                    && bits_eq(pv.valid, it.valid)
                    && bits_eq(pv.k_sel, it.k_sel)
            });
            if !reuse {
                grouped = self.attn_weights(it, &mut weights);
                owner = Some(idx);
            }
            let mut attn = vec![0.0f32; s.n_heads * hd];
            if grouped {
                // identical weight rows within each kv group: aggregate once
                // per group, copy to the group's heads (same bits as the
                // per-head loop — same ops, same slot order, per head)
                let mut out_g = vec![0.0f32; hd];
                for g in 0..s.n_kv_heads {
                    let head0 = g * group;
                    let w = &weights[head0 * it.capacity..(head0 + 1) * it.capacity];
                    out_g.fill(0.0);
                    for slot in 0..it.capacity {
                        let wv = w[slot];
                        if wv == 0.0 {
                            continue;
                        }
                        let vs = &it.v_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
                        for c in 0..hd {
                            out_g[c] += wv * vs[c];
                        }
                    }
                    for head in head0..head0 + group {
                        attn[head * hd..(head + 1) * hd].copy_from_slice(&out_g);
                    }
                }
            } else {
                for head in 0..s.n_heads {
                    let g = head / group;
                    let w = &weights[head * it.capacity..(head + 1) * it.capacity];
                    let out = &mut attn[head * hd..(head + 1) * hd];
                    for slot in 0..it.capacity {
                        let wv = w[slot];
                        if wv == 0.0 {
                            continue;
                        }
                        let vs = &it.v_sel[slot * kv_dim + g * hd..slot * kv_dim + (g + 1) * hd];
                        for c in 0..hd {
                            out[c] += wv * vs[c];
                        }
                    }
                }
            }
            outs.push(self.mix_hidden(layer, it.h, &attn));
        }
        Ok(outs)
    }

    // -- paged (zero-copy) entry points (native implementations) ----------

    fn supports_paged(&self) -> bool {
        true
    }

    /// Attention over in-place page views: the reference paged
    /// implementation, mirroring `layer_attn_mlp` op for op over the live
    /// slots in (selection, slot) order.  Gathered padding slots carry
    /// `-inf` scores there and contribute nothing to max/denom/output, so
    /// iterating only the live slots here produces the same bits — the
    /// invariant `rust/tests/paged_attention.rs` pins end to end.
    fn layer_attn_mlp_paged(&self, layer: usize, inp: &PagedAttnInput<'_>)
                            -> Result<Vec<f32>> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        let scale = 1.0 / (hd as f32).sqrt();
        let n_slots = inp.n_slots();
        let mut arena = self.dequant.borrow_mut();
        let pages = resolve_pages(inp.pages, &mut arena);
        let mut attn = vec![0.0f32; s.n_heads * hd];
        let mut scores = vec![0.0f32; n_slots];
        for head in 0..s.n_heads {
            let g = head / group;
            let qh = &inp.q[head * hd..(head + 1) * hd];
            let mut max = f32::NEG_INFINITY;
            let mut slot = 0usize;
            for &(pk, _, len) in &pages {
                for t in 0..len {
                    let ks = &pk[t * kv_dim + g * hd..t * kv_dim + (g + 1) * hd];
                    let mut dot = 0.0f32;
                    for c in 0..hd {
                        dot += qh[c] * ks[c];
                    }
                    let sc = dot * scale;
                    scores[slot] = sc;
                    if sc > max {
                        max = sc;
                    }
                    slot += 1;
                }
            }
            if max == f32::NEG_INFINITY {
                continue; // no slots / nothing finite: contributes nothing
            }
            let mut denom = 0.0f32;
            for sc in scores.iter_mut() {
                if *sc > f32::NEG_INFINITY {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                } else {
                    *sc = 0.0;
                }
            }
            let out = &mut attn[head * hd..(head + 1) * hd];
            let mut slot = 0usize;
            for &(_, pv, len) in &pages {
                for t in 0..len {
                    let w = scores[slot] / denom;
                    slot += 1;
                    if w == 0.0 {
                        continue;
                    }
                    let vs = &pv[t * kv_dim + g * hd..t * kv_dim + (g + 1) * hd];
                    for c in 0..hd {
                        out[c] += w * vs[c];
                    }
                }
            }
        }
        Ok(self.mix_hidden(layer, inp.h, &attn))
    }

    /// One scheduler iteration's paged attention for all sequences, with
    /// the same cross-item sharing as the gathered batch path: the
    /// score+softmax pass is computed once per distinct `(q, pages)` item
    /// (detected bitwise via `pages_eq`) and reused; per-head work
    /// collapses across the head/kv-group repetition.  Value aggregation
    /// stays per-item.
    fn layer_attn_mlp_paged_batch(&self, layer: usize, items: &[PagedAttnInput<'_>])
                                  -> Result<Vec<Vec<f32>>> {
        let s = &self.spec;
        let hd = s.head_dim;
        let kv_dim = s.n_kv_heads * hd;
        let group = s.n_heads / s.n_kv_heads;
        let mut outs = Vec::with_capacity(items.len());
        // weights of the most recent distinct item, `[n_heads * n_slots]`
        let mut weights: Vec<f32> = Vec::new();
        let mut grouped = false;
        let mut n_slots = 0usize;
        let mut owner: Option<usize> = None;
        for (idx, it) in items.iter().enumerate() {
            // reuse is detected on the ORIGINAL dtype-tagged views (the
            // arena below is cleared per item, so its copies carry no
            // identity); dequantization is pure, so equal views ⇒ equal
            // resolved pages ⇒ equal weights
            let reuse = owner.is_some_and(|p| {
                let pv = &items[p];
                bits_eq(pv.q, it.q) && pages_eq(pv.pages, it.pages)
            });
            let mut arena = self.dequant.borrow_mut();
            let pages = resolve_pages(it.pages, &mut arena);
            if !reuse {
                n_slots = it.n_slots();
                grouped = self.paged_attn_weights(it.q, &pages, n_slots, &mut weights);
                owner = Some(idx);
            }
            let mut attn = vec![0.0f32; s.n_heads * hd];
            if grouped {
                // identical weight rows within each kv group: aggregate once
                // per group, copy to the group's heads (same bits as the
                // per-head loop — same ops, same slot order, per head)
                let mut out_g = vec![0.0f32; hd];
                for g in 0..s.n_kv_heads {
                    let head0 = g * group;
                    let w = &weights[head0 * n_slots..(head0 + 1) * n_slots];
                    out_g.fill(0.0);
                    let mut slot = 0usize;
                    for &(_, pv, len) in &pages {
                        for t in 0..len {
                            let wv = w[slot];
                            slot += 1;
                            if wv == 0.0 {
                                continue;
                            }
                            let vs = &pv[t * kv_dim + g * hd..t * kv_dim + (g + 1) * hd];
                            for c in 0..hd {
                                out_g[c] += wv * vs[c];
                            }
                        }
                    }
                    for head in head0..head0 + group {
                        attn[head * hd..(head + 1) * hd].copy_from_slice(&out_g);
                    }
                }
            } else {
                for head in 0..s.n_heads {
                    let g = head / group;
                    let w = &weights[head * n_slots..(head + 1) * n_slots];
                    let out = &mut attn[head * hd..(head + 1) * hd];
                    let mut slot = 0usize;
                    for &(_, pv, len) in &pages {
                        for t in 0..len {
                            let wv = w[slot];
                            slot += 1;
                            if wv == 0.0 {
                                continue;
                            }
                            let vs = &pv[t * kv_dim + g * hd..t * kv_dim + (g + 1) * hd];
                            for c in 0..hd {
                                out[c] += wv * vs[c];
                            }
                        }
                    }
                }
            }
            outs.push(self.mix_hidden(layer, it.h, &attn));
        }
        Ok(outs)
    }

    /// Per-item projection with bitwise dedup of identical hidden states
    /// (co-scheduled duplicate requests — compared against every prior
    /// item in the batch, duplicates need not be adjacent).
    fn lm_head_batch(&self, hs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let mut outs: Vec<Vec<f32>> = Vec::with_capacity(hs.len());
        for (i, h) in hs.iter().enumerate() {
            match (0..i).find(|&j| bits_eq(hs[j], h)) {
                Some(j) => {
                    let prev = outs[j].clone();
                    outs.push(prev);
                }
                None => outs.push(self.lm_head(h)?),
            }
        }
        Ok(outs)
    }
}

impl std::fmt::Debug for SimBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "SimBackend(layers={}, d_model={}, seed={}, profile={})",
            self.spec.n_layers, self.spec.d_model, self.seed, self.profile.name
        )
    }
}

#[cfg(test)]
mod tests {
    use super::super::backend::QkvBatchItem;
    use super::*;

    fn backend() -> SimBackend {
        SimBackend::new(&ArtifactMeta::sim_default(), 0)
    }

    #[test]
    fn deterministic_and_unit_norm() {
        let b = backend();
        let a = b.embed_tok(5).unwrap();
        let c = b.embed_tok(5).unwrap();
        assert_eq!(a, c);
        let n2: f32 = a.iter().map(|x| x * x).sum();
        assert!((n2 - 1.0).abs() < 1e-4, "embed norm {n2}");
        assert_ne!(a, b.embed_tok(6).unwrap());
    }

    #[test]
    fn seeds_produce_different_models() {
        let meta = ArtifactMeta::sim_default();
        let a = SimBackend::new(&meta, 1).embed_tok(3).unwrap();
        let b = SimBackend::new(&meta, 2).embed_tok(3).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn qkv_shapes() {
        let b = backend();
        let s = b.spec().clone();
        let h = b.embed_tok(1).unwrap();
        let qkv = b.layer_qkv(0, &h, 3).unwrap();
        assert_eq!(qkv.q.len(), s.n_heads * s.head_dim);
        assert_eq!(qkv.k.len(), s.n_kv_heads * s.head_dim);
        assert_eq!(qkv.v.len(), s.n_kv_heads * s.head_dim);
    }

    #[test]
    fn waterfall_structure_in_scores() {
        // q(t) · k(p), averaged over layers and reasoning steps to wash out
        // the random-dictionary crosstalk: the active position scores above
        // a freshly emitted milestone, which scores above a long-faded one.
        let b = backend();
        let spec = b.spec().clone();
        let hd = spec.head_dim;
        let h = b.embed_tok(1).unwrap();
        let (mut fresh, mut stale, mut active) = (0.0f32, 0.0f32, 0.0f32);
        let mut n = 0.0f32;
        for layer in 0..spec.n_layers {
            for s in [10usize, 12, 14, 16] {
                // mid-step position: the step-(s-1) milestone is 5 tokens
                // back — outside the recency window, inside the waterfall
                let t = s * STEP_PERIOD + 3;
                let q = b.layer_qkv(layer, &h, t).unwrap().q;
                let score = |p: usize| -> f32 {
                    let k = b.layer_qkv(layer, &h, p).unwrap().k;
                    (0..hd).map(|c| q[c] * k[c]).sum()
                };
                fresh += score((s - 1) * STEP_PERIOD + MILESTONE_OFFSET);
                stale += score(2 * STEP_PERIOD + MILESTONE_OFFSET);
                active += score(t);
                n += 1.0;
            }
        }
        let (fresh, stale, active) = (fresh / n, stale / n, active / n);
        assert!(active > fresh + 0.3, "active {active} vs fresh milestone {fresh}");
        assert!(fresh > stale + 0.3, "fresh {fresh} vs stale milestone {stale}");
    }

    #[test]
    fn attention_responds_to_values() {
        // Two different gathered value sets must yield different hiddens —
        // eviction has end-to-end consequences.
        let b = backend();
        let s = b.spec().clone();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let h = b.embed_tok(2).unwrap();
        let qkv = b.layer_qkv(0, &h, 4).unwrap();
        let cap = 4;
        let mut k_sel = vec![0.0f32; cap * kv_dim];
        let mut v1 = vec![0.0f32; cap * kv_dim];
        let mut v2 = vec![0.0f32; cap * kv_dim];
        let valid = vec![1.0f32, 1.0, 0.0, 0.0];
        k_sel[..kv_dim].copy_from_slice(&qkv.k);
        v1[..kv_dim].copy_from_slice(&qkv.v);
        for (i, x) in v2.iter_mut().enumerate().take(kv_dim) {
            *x = (i as f32 * 0.1).sin();
        }
        let h1 = b.layer_attn_mlp(0, cap, &h, &qkv.q, &k_sel, &v1, &valid).unwrap();
        let h2 = b.layer_attn_mlp(0, cap, &h, &qkv.q, &k_sel, &v2, &valid).unwrap();
        assert_ne!(h1, h2);
        let n2: f32 = h1.iter().map(|x| x * x).sum();
        assert!((n2 - 1.0).abs() < 1e-4);
    }

    #[test]
    fn prefill_matches_decode_keys() {
        // Keys are purely positional: prefill and a hypothetical decode of
        // the same position agree, so RepBounds stay consistent.
        let b = backend();
        let toks = [1u32, 3, 4, 5, 9];
        let out = b.prefill(&toks).unwrap();
        let spec = b.spec().clone();
        let h = b.embed_tok(toks[2]).unwrap();
        let qkv = b.layer_qkv(1, &h, 2).unwrap();
        let (k, _) = out.kv_at(&spec, 1, 2);
        assert_eq!(k, &qkv.k[..]);
        assert_eq!(out.padded, 5);
        assert_eq!(out.logits.len(), spec.vocab);
    }

    #[test]
    fn memoized_features_match_uncached() {
        let b = backend();
        for layer in 0..b.spec().n_layers {
            for pos in [0usize, 1, 7, 40, 123] {
                let cold = b.phi_uncached(layer, pos);
                b.with_phi(layer, pos, |warm| assert_eq!(warm, &cold[..]));
                // second hit reads the cache; must be the same bits
                b.with_phi(layer, pos, |warm| assert_eq!(warm, &cold[..]));
                let qcold = b.query_dir_uncached(layer, pos);
                b.with_qdir(layer, pos, |warm| assert_eq!(warm, &qcold[..]));
            }
        }
        // full qkv is stable across repeated (memo-hitting) calls
        let h = b.embed_tok(3).unwrap();
        let a = b.layer_qkv(2, &h, 57).unwrap();
        let c = b.layer_qkv(2, &h, 57).unwrap();
        assert_eq!(a.q, c.q);
        assert_eq!(a.k, c.k);
        assert_eq!(a.v, c.v);
    }

    #[test]
    fn chunked_prefill_matches_monolithic_bitwise() {
        // Any chunking of the prompt must reproduce the monolithic
        // prefill's KV and final logits bit for bit (per-token purity).
        let b = backend();
        let spec = b.spec().clone();
        let kv_dim = spec.n_kv_heads * spec.head_dim;
        let toks: Vec<u32> = (0..23u32).map(|i| 1 + i % 40).collect();
        let mono = b.prefill(&toks).unwrap();
        for splits in [vec![23], vec![1, 22], vec![7, 7, 7, 2], vec![16, 7]] {
            let mut start = 0usize;
            let mut logits = Vec::new();
            for len in splits {
                let end = start + len;
                let c = b.prefill_chunk(&toks, start, end).unwrap();
                assert_eq!(c.chunk_len, len);
                for layer in 0..spec.n_layers {
                    for i in 0..len {
                        let (ck, cv) = c.kv_run(&spec, layer, i, 1);
                        let (mk, mv) = mono.kv_at(&spec, layer, start + i);
                        assert_eq!(ck, mk, "key diverged at layer {layer} pos {}", start + i);
                        assert_eq!(cv, mv, "value diverged at layer {layer} pos {}", start + i);
                        assert_eq!(ck.len(), kv_dim);
                    }
                }
                if end == toks.len() {
                    logits = c.logits;
                } else {
                    assert!(c.logits.is_empty(), "mid-prompt chunk must not emit logits");
                }
                start = end;
            }
            assert_eq!(logits, mono.logits, "final-chunk logits diverged");
        }
    }

    #[test]
    fn prefill_chunk_batch_matches_per_item_bitwise() {
        // Co-admitted chunks — including two items sharing (token, pos)
        // pairs, which exercises the column-memo path — must reproduce the
        // per-item prefill_chunk outputs bit for bit.
        let b = backend();
        let long: Vec<u32> = (0..23u32).map(|i| 1 + i % 40).collect();
        let twin = long.clone(); // identical prompt: every column shared
        let short: Vec<u32> = (0..9u32).map(|i| 2 + i % 17).collect();
        let items = vec![
            PrefillChunkItem { tokens: &long, start: 0, end: 7 },
            PrefillChunkItem { tokens: &twin, start: 0, end: 7 },
            PrefillChunkItem { tokens: &short, start: 3, end: 9 }, // completes: logits
            PrefillChunkItem { tokens: &long, start: 7, end: 23 }, // completes: logits
        ];
        let batched = b.prefill_chunk_batch(&items).unwrap();
        assert_eq!(batched.len(), items.len());
        for (it, out) in items.iter().zip(&batched) {
            let solo = b.prefill_chunk(it.tokens, it.start, it.end).unwrap();
            assert_eq!(out.chunk_len, solo.chunk_len);
            assert_eq!(bits(&out.k), bits(&solo.k), "batched chunk keys diverged");
            assert_eq!(bits(&out.v), bits(&solo.v), "batched chunk values diverged");
            assert_eq!(bits(&out.logits), bits(&solo.logits), "batched logits diverged");
        }
        // mid-prompt chunks must not emit logits; completing ones must
        assert!(batched[0].logits.is_empty());
        assert!(!batched[2].logits.is_empty());
        assert!(!batched[3].logits.is_empty());
        // an invalid item fails the whole call (all-or-nothing contract)
        let bad = vec![PrefillChunkItem { tokens: &short, start: 5, end: 3 }];
        assert!(b.prefill_chunk_batch(&bad).is_err());
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn batched_attn_matches_per_item_bitwise() {
        // three items: 0 and 1 share bit-identical (q, k_sel, valid) —
        // exercising the weight-reuse path — item 2 differs
        let b = backend();
        let s = b.spec().clone();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let cap = 8;
        let h1 = b.embed_tok(1).unwrap();
        let h2 = b.embed_tok(2).unwrap();
        let qkv1 = b.layer_qkv(0, &h1, 5).unwrap();
        let qkv2 = b.layer_qkv(0, &h2, 9).unwrap();
        let mut k1 = vec![0.0f32; cap * kv_dim];
        let mut v1 = vec![0.0f32; cap * kv_dim];
        let mut v1b = vec![0.0f32; cap * kv_dim];
        let mut k2 = vec![0.0f32; cap * kv_dim];
        let mut v2 = vec![0.0f32; cap * kv_dim];
        k1[..kv_dim].copy_from_slice(&qkv1.k);
        v1[..kv_dim].copy_from_slice(&qkv1.v);
        for (i, x) in v1b.iter_mut().enumerate().take(kv_dim) {
            *x = (i as f32 * 0.3).cos();
        }
        k2[..kv_dim].copy_from_slice(&qkv2.k);
        v2[..kv_dim].copy_from_slice(&qkv2.v);
        let valid = {
            let mut v = vec![0.0f32; cap];
            v[0] = 1.0;
            v
        };
        let items = vec![
            AttnBatchItem { capacity: cap, h: &h1, q: &qkv1.q, k_sel: &k1, v_sel: &v1,
                            valid: &valid },
            AttnBatchItem { capacity: cap, h: &h2, q: &qkv1.q, k_sel: &k1, v_sel: &v1b,
                            valid: &valid },
            AttnBatchItem { capacity: cap, h: &h2, q: &qkv2.q, k_sel: &k2, v_sel: &v2,
                            valid: &valid },
        ];
        let batched = b.layer_attn_mlp_batch(0, &items).unwrap();
        for (it, out) in items.iter().zip(&batched) {
            let solo = b
                .layer_attn_mlp(0, it.capacity, it.h, it.q, it.k_sel, it.v_sel, it.valid)
                .unwrap();
            assert_eq!(&solo, out, "batched attention must be bit-identical");
        }
    }

    /// Build `n_pages` pages of KV from real (layer, pos) features, with
    /// varying live lengths, returning owned page buffers.
    fn make_pages(b: &SimBackend, layer: usize, lens: &[usize])
                  -> Vec<(Vec<f32>, Vec<f32>, usize)> {
        let s = b.spec().clone();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let h = b.embed_tok(1).unwrap();
        let mut pages = Vec::new();
        let mut pos = 0usize;
        for &len in lens {
            let mut k = Vec::with_capacity(len * kv_dim);
            let mut v = Vec::with_capacity(len * kv_dim);
            for _ in 0..len {
                let qkv = b.layer_qkv(layer, &h, pos).unwrap();
                k.extend_from_slice(&qkv.k);
                v.extend_from_slice(&qkv.v);
                pos += 1;
            }
            pages.push((k, v, len));
        }
        pages
    }

    /// Gather owned pages into the capacity-padded layout the gathered
    /// entry point expects.
    fn gather_pages(pages: &[(Vec<f32>, Vec<f32>, usize)], kv_dim: usize, capacity: usize)
                    -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut k_sel = vec![0.0f32; capacity * kv_dim];
        let mut v_sel = vec![0.0f32; capacity * kv_dim];
        let mut valid = vec![0.0f32; capacity];
        let mut used = 0usize;
        for (k, v, len) in pages {
            k_sel[used * kv_dim..(used + len) * kv_dim].copy_from_slice(k);
            v_sel[used * kv_dim..(used + len) * kv_dim].copy_from_slice(v);
            for s in 0..*len {
                valid[used + s] = 1.0;
            }
            used += len;
        }
        (k_sel, v_sel, valid)
    }

    #[test]
    fn paged_attn_matches_gathered_bitwise() {
        // The paged route must reproduce the gathered reference exactly,
        // including partially filled pages and capacity padding headroom.
        let b = backend();
        let s = b.spec().clone();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let h = b.embed_tok(2).unwrap();
        for (layer, lens) in [(0usize, vec![4usize, 4, 2]), (1, vec![1]), (2, vec![3, 1, 1, 5])] {
            let owned = make_pages(&b, layer, &lens);
            let n_slots: usize = lens.iter().sum();
            let qkv = b.layer_qkv(layer, &h, n_slots).unwrap();
            let views: Vec<PageView<'_>> = owned
                .iter()
                .map(|(k, v, len)| PageView { len: *len, data: PageData::F32 { k, v } })
                .collect();
            let inp = PagedAttnInput { h: &h, q: &qkv.q, pages: &views };
            let paged = b.layer_attn_mlp_paged(layer, &inp).unwrap();
            for capacity in [n_slots, n_slots + 7, 2 * n_slots + 64] {
                let (k_sel, v_sel, valid) = gather_pages(&owned, kv_dim, capacity);
                let gathered = b
                    .layer_attn_mlp(layer, capacity, &h, &qkv.q, &k_sel, &v_sel, &valid)
                    .unwrap();
                assert_eq!(paged, gathered,
                           "paged attention diverged (layer {layer}, capacity {capacity})");
            }
        }
    }

    #[test]
    fn quantized_paged_matches_dequantized_gather_bitwise() {
        // Quant-tagged views through the paged route must reproduce the
        // gathered reference over the SAME dequantized bytes exactly: the
        // arena decode and the gather-route decode share `decode_slice`,
        // so paged ≡ gathered holds under every dtype.
        use crate::kvcache::KvDtype;
        let b = backend();
        let s = b.spec().clone();
        let kv_dim = s.n_kv_heads * s.head_dim;
        let h = b.embed_tok(2).unwrap();
        for dtype in [KvDtype::Fp8E4M3, KvDtype::Int8] {
            let owned = make_pages(&b, 0, &[4, 3, 1]);
            let n_slots: usize = owned.iter().map(|(_, _, len)| len).sum();
            let qkv = b.layer_qkv(0, &h, n_slots).unwrap();
            // per-page quantization exactly as the pool does it: params from
            // the page's own min/max, one byte per element
            let quantized: Vec<(Vec<u8>, Vec<u8>, _, _, usize)> = owned
                .iter()
                .map(|(k, v, len)| {
                    let range = |xs: &[f32]| {
                        let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min);
                        let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                        dtype.params(lo, hi)
                    };
                    let (kp, vp) = (range(k), range(v));
                    let mut qk = vec![0u8; k.len()];
                    let mut qv = vec![0u8; v.len()];
                    dtype.encode_slice(k, kp, &mut qk);
                    dtype.encode_slice(v, vp, &mut qv);
                    (qk, qv, kp, vp, *len)
                })
                .collect();
            let views: Vec<PageView<'_>> = quantized
                .iter()
                .map(|(qk, qv, kp, vp, len)| PageView {
                    len: *len,
                    data: PageData::Quant { dtype, k: qk, v: qv, k_params: *kp, v_params: *vp },
                })
                .collect();
            let inp = PagedAttnInput { h: &h, q: &qkv.q, pages: &views };
            let paged = b.layer_attn_mlp_paged(0, &inp).unwrap();
            // gathered reference over the dequantized bytes
            let capacity = n_slots + 5;
            let mut k_sel = vec![0.0f32; capacity * kv_dim];
            let mut v_sel = vec![0.0f32; capacity * kv_dim];
            let mut valid = vec![0.0f32; capacity];
            let mut used = 0usize;
            for w in &views {
                w.copy_k_into(&mut k_sel[used * kv_dim..(used + w.len) * kv_dim]);
                w.copy_v_into(&mut v_sel[used * kv_dim..(used + w.len) * kv_dim]);
                for t in 0..w.len {
                    valid[used + t] = 1.0;
                }
                used += w.len;
            }
            let gathered = b
                .layer_attn_mlp(0, capacity, &h, &qkv.q, &k_sel, &v_sel, &valid)
                .unwrap();
            assert_eq!(paged, gathered, "quantized paged diverged from gathered ({dtype})");
            // batch path with a bit-identical twin: the pages_eq reuse
            // predicate must fire on Quant views and stay bit-identical
            let items =
                vec![PagedAttnInput { h: &h, q: &qkv.q, pages: &views },
                     PagedAttnInput { h: &h, q: &qkv.q, pages: &views }];
            let batched = b.layer_attn_mlp_paged_batch(0, &items).unwrap();
            assert_eq!(batched[0], paged);
            assert_eq!(batched[1], paged);
        }
    }

    #[test]
    fn paged_batch_matches_per_item_bitwise() {
        // items 0 and 1 share bit-identical (q, pages) — exercising the
        // weight-reuse path — item 2 differs in pages, item 3 in q
        let b = backend();
        let h1 = b.embed_tok(1).unwrap();
        let h2 = b.embed_tok(2).unwrap();
        let pages_a = make_pages(&b, 0, &[4, 3]);
        let pages_b = make_pages(&b, 0, &[2, 2, 2]);
        let q_a = b.layer_qkv(0, &h1, 7).unwrap().q;
        let q_b = b.layer_qkv(0, &h2, 11).unwrap().q;
        let va: Vec<PageView<'_>> = pages_a
            .iter()
            .map(|(k, v, len)| PageView { len: *len, data: PageData::F32 { k, v } })
            .collect();
        let vb: Vec<PageView<'_>> = pages_b
            .iter()
            .map(|(k, v, len)| PageView { len: *len, data: PageData::F32 { k, v } })
            .collect();
        let items = vec![
            PagedAttnInput { h: &h1, q: &q_a, pages: &va },
            PagedAttnInput { h: &h2, q: &q_a, pages: &va },
            PagedAttnInput { h: &h2, q: &q_a, pages: &vb },
            PagedAttnInput { h: &h1, q: &q_b, pages: &vb },
        ];
        let batched = b.layer_attn_mlp_paged_batch(0, &items).unwrap();
        assert_eq!(batched.len(), items.len());
        for (it, out) in items.iter().zip(&batched) {
            let solo = b.layer_attn_mlp_paged(0, it).unwrap();
            assert_eq!(&solo, out, "batched paged attention must be bit-identical");
        }
    }

    #[test]
    fn batched_qkv_embed_lm_head_match_per_item() {
        let b = backend();
        let toks = [1u32, 5, 1, 9];
        let embeds = b.embed_tok_batch(&toks).unwrap();
        for (&t, e) in toks.iter().zip(&embeds) {
            assert_eq!(e, &b.embed_tok(t).unwrap());
        }
        let items: Vec<QkvBatchItem<'_>> = embeds
            .iter()
            .enumerate()
            .map(|(i, h)| QkvBatchItem { h, pos: 4 + (i % 2) })
            .collect();
        let batched = b.layer_qkv_batch(1, &items).unwrap();
        for (it, qkv) in items.iter().zip(&batched) {
            let solo = b.layer_qkv(1, it.h, it.pos).unwrap();
            assert_eq!(solo.q, qkv.q);
            assert_eq!(solo.k, qkv.k);
            assert_eq!(solo.v, qkv.v);
        }
        let hs: Vec<&[f32]> = embeds.iter().map(|e| &e[..]).collect();
        let logits = b.lm_head_batch(&hs).unwrap();
        for (h, l) in hs.iter().zip(&logits) {
            assert_eq!(l, &b.lm_head(h).unwrap());
        }
        // items 0 and 2 are the same token: the dedup path must still agree
        assert_eq!(logits[0], logits[2]);
    }

    #[test]
    fn capacity_ladder_and_fallback() {
        let b = backend();
        let caps = b.capacities();
        assert!(!caps.is_empty());
        assert_eq!(b.capacity_for(1).unwrap(), caps[0]);
        // beyond the ladder: padded fallback instead of an error
        let huge = caps.last().unwrap() + 1;
        assert!(b.capacity_for(huge).unwrap() >= huge);
    }
}
