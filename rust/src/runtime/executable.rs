//! Typed execution wrapper over a compiled PJRT executable: literal
//! marshalling helpers + tuple decomposition (aot.py lowers with
//! `return_tuple=True`, so every module returns a tuple).

use anyhow::{anyhow, Context, Result};

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// Build an f32 literal from a slice + dims (zero intermediate copies).
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("f32 literal {dims:?}: {e:?}"))
}

/// Build an i32 literal from a slice + dims.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), dims.iter().product::<usize>());
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("i32 literal {dims:?}: {e:?}"))
}

impl Executable {
    pub fn new(exe: xla::PjRtLoadedExecutable, name: String) -> Self {
        Executable { exe, name }
    }

    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("{}: result not a tuple: {e:?}", self.name))
    }

    /// Execute and read all outputs as f32 vectors.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<Vec<f32>>> {
        self.run(inputs)?
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("{}: {e:?}", self.name)))
            .collect()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executable({})", self.name)
    }
}
