//! The PJRT model runtime: the per-layer executable set loaded from AOT
//! HLO-text artifacts, exposed to the engine through the [`Backend`] trait
//! (DESIGN.md §2 dataflow).  Compiled only with `--features backend-xla`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{Backend, PrefillOut, Qkv};
use super::client::RuntimeClient;
use super::executable::{lit_f32, lit_i32, Executable};
use crate::config::{ArtifactMeta, ModelSpec};

pub struct ModelRuntime {
    pub spec: ModelSpec,
    pub page_size: usize,
    embed: Executable,
    lm_head: Executable,
    qkv: Vec<Executable>,
    /// capacity -> per-layer attn_mlp executables
    attn_mlp: BTreeMap<usize, Vec<Executable>>,
    /// prefill size -> executable
    prefill: BTreeMap<usize, Executable>,
}

impl ModelRuntime {
    /// Load every artifact listed in `meta` (capacities can be restricted to
    /// save compile time, e.g. for tests).
    pub fn load(client: &RuntimeClient, meta: &ArtifactMeta,
                only_capacities: Option<&[usize]>) -> Result<ModelRuntime> {
        let dir = &meta.dir;
        let ld = |name: String| -> Result<Executable> { client.load(&dir.join(name)) };
        let embed = ld("embed.hlo.txt".into())?;
        let lm_head = ld("lm_head.hlo.txt".into())?;
        let mut qkv = Vec::new();
        for l in 0..meta.model.n_layers {
            qkv.push(ld(format!("qkv_l{l}.hlo.txt"))?);
        }
        let mut attn_mlp = BTreeMap::new();
        for &cap in &meta.capacities {
            if let Some(only) = only_capacities {
                if !only.contains(&cap) {
                    continue;
                }
            }
            let mut per_layer = Vec::new();
            for l in 0..meta.model.n_layers {
                per_layer.push(ld(format!("attn_mlp_l{l}_c{cap}.hlo.txt"))?);
            }
            attn_mlp.insert(cap, per_layer);
        }
        if attn_mlp.is_empty() {
            bail!("no attn_mlp capacities loaded");
        }
        let mut prefill = BTreeMap::new();
        for &p in &meta.prefill_sizes {
            prefill.insert(p, ld(format!("prefill_p{p}.hlo.txt"))?);
        }
        Ok(ModelRuntime {
            spec: meta.model.clone(),
            page_size: meta.page_size,
            embed,
            lm_head,
            qkv,
            attn_mlp,
            prefill,
        })
    }

    /// Smallest compiled slot capacity >= `n_slots`.
    pub fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        self.attn_mlp
            .keys()
            .find(|&&c| c >= n_slots)
            .copied()
            .ok_or_else(|| {
                anyhow!(
                    "no attn_mlp capacity >= {n_slots} (max compiled: {:?})",
                    self.attn_mlp.keys().last()
                )
            })
    }

    pub fn capacities(&self) -> Vec<usize> {
        self.attn_mlp.keys().copied().collect()
    }

    pub fn max_capacity(&self) -> usize {
        *self.attn_mlp.keys().last().unwrap()
    }
}

impl Backend for ModelRuntime {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn capacities(&self) -> Vec<usize> {
        // inherent method (executable-ladder keys); inherent methods take
        // precedence, so this does not recurse.
        ModelRuntime::capacities(self)
    }

    fn capacity_for(&self, n_slots: usize) -> Result<usize> {
        ModelRuntime::capacity_for(self, n_slots)
    }

    /// token -> hidden [d]
    fn embed_tok(&self, token: u32) -> Result<Vec<f32>> {
        let out = self.embed.run_f32(&[lit_i32(&[token as i32], &[1])?])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// hidden [d] + absolute position -> (q, k, v)
    fn layer_qkv(&self, layer: usize, h: &[f32], pos: usize) -> Result<Qkv> {
        let out = self.qkv[layer].run_f32(&[
            lit_f32(h, &[self.spec.d_model])?,
            lit_f32(&[pos as f32], &[1])?,
        ])?;
        let mut it = out.into_iter();
        Ok(Qkv {
            q: it.next().context("missing q")?,
            k: it.next().context("missing k")?,
            v: it.next().context("missing v")?,
        })
    }

    /// Attention over gathered slots + MLP.  `k_sel`/`v_sel` are
    /// [capacity * kv_dim], `valid` is [capacity]; returns hidden' [d].
    fn layer_attn_mlp(&self, layer: usize, capacity: usize, h: &[f32], q: &[f32],
                      k_sel: &[f32], v_sel: &[f32], valid: &[f32]) -> Result<Vec<f32>> {
        let s = &self.spec;
        let exes = self
            .attn_mlp
            .get(&capacity)
            .ok_or_else(|| anyhow!("capacity {capacity} not loaded"))?;
        let out = exes[layer].run_f32(&[
            lit_f32(h, &[s.d_model])?,
            lit_f32(q, &[s.n_heads, s.head_dim])?,
            lit_f32(k_sel, &[capacity, s.n_kv_heads, s.head_dim])?,
            lit_f32(v_sel, &[capacity, s.n_kv_heads, s.head_dim])?,
            lit_f32(valid, &[capacity])?,
        ])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// hidden [d] -> logits [vocab]
    fn lm_head(&self, h: &[f32]) -> Result<Vec<f32>> {
        let out = self.lm_head.run_f32(&[lit_f32(h, &[self.spec.d_model])?])?;
        Ok(out.into_iter().next().unwrap())
    }

    /// Dense prefill of `tokens`; returns per-layer post-RoPE KV for the
    /// first `tokens.len()` positions plus next-token logits.
    fn prefill(&self, tokens: &[u32]) -> Result<PrefillOut> {
        let n = tokens.len();
        let (&padded, exe) = self
            .prefill
            .iter()
            .find(|(&p, _)| p >= n)
            .ok_or_else(|| anyhow!("prompt of {n} tokens exceeds max prefill size"))?;
        let mut buf = vec![0i32; padded];
        for (i, &t) in tokens.iter().enumerate() {
            buf[i] = t as i32;
        }
        let out = exe.run_f32(&[
            lit_i32(&buf, &[padded])?,
            lit_i32(&[n as i32], &[])?,
        ])?;
        let mut it = out.into_iter();
        Ok(PrefillOut {
            k: it.next().context("missing K")?,
            v: it.next().context("missing V")?,
            logits: it.next().context("missing logits")?,
            padded,
        })
    }
}

impl std::fmt::Debug for ModelRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "ModelRuntime(layers={}, capacities={:?}, prefill={:?})",
            self.spec.n_layers,
            ModelRuntime::capacities(self),
            self.prefill.keys().collect::<Vec<_>>()
        )
    }
}
