//! The inference engine: sequence state machine + the per-token decode loop
//! that stitches the execution [`Backend`], the paged KV cache and the
//! sparsity policy together (DESIGN.md §2 dataflow).  The engine is backend
//! agnostic: the same loop drives the PJRT executables and the pure-Rust
//! surrogate.
//!
//! Per decode token, per layer:
//!   backend qkv → append (k,v) to the paged pool → rep-score resident pages
//!   (rust, O(pages)) → policy.select_into → attention → next layer.
//! Attention takes the zero-copy paged route (in-place pool-slab views,
//! `Backend::layer_attn_mlp_paged`) when the backend supports it, else the
//! gather route (copy selected slots into capacity-padded scratch,
//! `Backend::layer_attn_mlp` — the Pallas kernel on the xla path).  The two
//! routes decode bit-identically (DESIGN.md §2).
//! After all layers: lm_head exec → greedy sample → policy.observe +
//! budget-bounded eviction (timestamps/eviction are batched per iteration,
//! as in the paper's implementation, Appendix B).

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactMeta, BackendKind, EngineConfig, PolicyKind};
use crate::kvcache::page::{page_probs, reduce_head_scores_max, PageId, PageMeta, RepBounds};
use crate::kvcache::policy::{make_policy, resident_tokens, SparsityPolicy};
use crate::kvcache::{prefix_hashes, KvPool, PageView, PageViewBuf, PoolExhausted, PrefixIndex,
                     SeqCache, SwapHandle};
use crate::metrics::Metrics;
use crate::runtime::{AttnBatchItem, Backend, PagedAttnInput, PrefillChunkItem, Qkv,
                     QkvBatchItem, SimBackend, Tokenizer};

/// Generation controls for [`Engine::generate`].
#[derive(Debug, Clone, Default)]
pub struct GenOptions {
    /// Stop after this many decoded tokens (EOS may stop earlier).
    pub max_new: usize,
    /// Decode exactly this many tokens, ignoring EOS (Figure-7 workloads).
    pub force_len: Option<usize>,
    /// Record per-step layer-0 page probabilities (Figure-3 analysis).
    pub log_scores: bool,
    /// Record cumulative decode latency and resident bytes at each step
    /// (Figure-7 series).
    pub log_series: bool,
}

/// Everything [`Engine::generate`] measures for one request.
#[derive(Debug, Default)]
pub struct GenOutput {
    /// Decoded tokens (the first is the prefill's next-token sample).
    pub tokens: Vec<u32>,
    /// Prefill wall seconds (TTFT).
    pub prefill_secs: f64,
    /// Decode-loop wall seconds.
    pub decode_secs: f64,
    /// High-water resident KV bytes (the Figure-7 memory axis).
    pub peak_resident_bytes: usize,
    /// High-water layer-0 resident tokens.
    pub peak_resident_tokens_l0: usize,
    /// (step, cumulative decode secs, resident bytes) — when log_series.
    pub series: Vec<(usize, f64, usize)>,
    /// (step, [(page_start_pos, prob)]) for layer 0 — when log_scores.
    pub score_log: Vec<(u64, Vec<(usize, f32)>)>,
}

/// One sequence's slot in a batched decode iteration (`Engine::decode_batch`).
pub struct BatchEntry<'a> {
    /// The decoding sequence.
    pub seq: &'a mut SeqCache,
    /// The token decoded this iteration (last step's output).
    pub token: u32,
    /// Per-sequence step counter (policy timestamp).
    pub now: u64,
    /// Optional Figure-3 score log, appended exactly like the sequential
    /// path's (`decode_step`): layer-0 page probabilities at capture time.
    pub log: Option<&'a mut Vec<(u64, Vec<(usize, f32)>)>>,
}

impl<'a> BatchEntry<'a> {
    /// Entry without a score log (the serving path's shape).
    pub fn new(seq: &'a mut SeqCache, token: u32, now: u64) -> Self {
        BatchEntry { seq, token, now, log: None }
    }
}

/// One sequence's slot in a batched prefill tick
/// ([`Engine::prefill_batch`]): a co-admitted prompt and how much of it to
/// admit this tick.
pub struct PrefillEntry<'a> {
    /// The sequence being prefilled; tracks its own progress in
    /// `n_tokens` (like [`Engine::prefill_seq_partial`]).
    pub seq: &'a mut SeqCache,
    /// The full prompt (positions are absolute prompt offsets).
    pub prompt: &'a [u32],
    /// Admit at most this many more prompt tokens this tick (clamped to
    /// at least 1 so every entry makes progress).
    pub max_tokens: usize,
}

/// Per-item scratch for the batched decode path, reused across layers and
/// iterations (steady state allocates nothing).
#[derive(Default)]
struct BatchSlot {
    h: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    valid: Vec<f32>,
    capacity: usize,
    /// This layer's page selection (reusable `select_into` scratch).
    sel: Vec<usize>,
    /// Pending layer-0 score-log entry for the current iteration.
    log_entry: Option<Vec<(usize, f32)>>,
}

/// The backend-agnostic inference engine: one model, one KV pool, one
/// sparsity policy, and the prefill/decode drivers that connect them.
///
/// # Example — one-sequence decode under RaaS
///
/// The default config serves the hermetic sim backend under the RaaS
/// policy; `generate` runs prefill + decode end to end (this example runs
/// under `cargo test` as a doctest):
///
/// ```
/// use raas::config::EngineConfig;
/// use raas::engine::{Engine, GenOptions};
///
/// let mut engine = Engine::new(EngineConfig::default()).unwrap();
/// let prompt = [1u32, 3, 13, 4];
/// let opts = GenOptions { max_new: 8, ..Default::default() };
/// let out = engine.generate(&prompt, &opts).unwrap();
/// assert!(!out.tokens.is_empty() && out.tokens.len() <= 8);
/// // bit-deterministic: the same prompt decodes the same tokens
/// assert_eq!(engine.generate(&prompt, &opts).unwrap().tokens, out.tokens);
/// ```
pub struct Engine {
    /// Engine/policy configuration this engine was built from.
    pub cfg: EngineConfig,
    /// Artifact metadata (model architecture, page size, corpus framing).
    pub meta: ArtifactMeta,
    /// Detokenizer/framing helper over the corpus vocabulary.
    pub tokenizer: Tokenizer,
    /// Wall-time and counter registry (`step.*`, `admit.*`, pool gauges).
    pub metrics: Metrics,
    model: Box<dyn Backend>,
    pool: KvPool,
    policy: Box<dyn SparsityPolicy>,
    /// Pool-level prefix index (`cfg.prefix_cache`; zero-capacity when off).
    prefix: PrefixIndex,
    /// Boosted page-table clone for shared-aware eviction (scratch).
    evict_scratch: Vec<PageMeta>,
    // scratch buffers reused across steps (no allocation in the hot loop)
    scores: Vec<f32>,
    /// Page-major per-head rep scores (`[n_pages * n_heads]`) — only
    /// populated when the policy asks for unified cross-head selection.
    head_scores: Vec<f32>,
    probs: Vec<f32>,
    sel_buf: Vec<usize>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    valid_buf: Vec<f32>,
    // per-sequence scratch for decode_batch, grown to the batch width
    batch_scratch: Vec<BatchSlot>,
}

impl Engine {
    /// Build an engine on the backend named by `cfg.backend` (sim by
    /// default — hermetic; xla needs `--features backend-xla` + artifacts).
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        Self::build(cfg, None)
    }

    /// Restrict loaded capacities (tests / fast startup).  For the AOT
    /// backend this limits which executables are compiled; for the
    /// surrogate it only shapes attention padding.
    pub fn new_with_capacities(cfg: EngineConfig, caps: &[usize]) -> Result<Self> {
        Self::build(cfg, Some(caps))
    }

    fn build(cfg: EngineConfig, caps: Option<&[usize]>) -> Result<Self> {
        // Fail on the missing feature *before* touching artifact metadata,
        // so the user is pointed at the right fix (rebuild), not at
        // `make artifacts`.
        if cfg.backend == BackendKind::Xla && !cfg!(feature = "backend-xla") {
            bail!("{NO_XLA_BACKEND}");
        }
        let meta = cfg.resolve_meta()?;
        let model: Box<dyn Backend> = match cfg.backend {
            BackendKind::Sim => match caps {
                Some(c) => Box::new(SimBackend::with_capacities(&meta, cfg.seed, c)),
                None => Box::new(SimBackend::new(&meta, cfg.seed)),
            },
            BackendKind::Xla => load_xla_backend(&meta, caps)?,
        };
        Self::with_backend(cfg, meta, model)
    }

    /// Build over an explicit backend instance (tests wrap/mask backends
    /// this way; `Engine::new` is the config-driven front door).
    pub fn with_backend(cfg: EngineConfig, meta: ArtifactMeta, model: Box<dyn Backend>)
                        -> Result<Self> {
        let kv_dim = meta.model.n_kv_heads * meta.model.head_dim;
        let pool = KvPool::new_with_dtype(cfg.pool_pages, meta.page_size, kv_dim, cfg.kv_dtype);
        let policy = make_policy(&cfg);
        // a quarter of the pool for cached prefixes; one index entry
        // retains one physical page per layer
        let prefix_cap = if cfg.prefix_cache {
            (cfg.pool_pages / 4) / meta.model.n_layers.max(1)
        } else {
            0
        };
        Ok(Engine {
            tokenizer: Tokenizer::new(meta.corpus.clone()),
            metrics: Metrics::new(),
            model,
            pool,
            policy,
            prefix: PrefixIndex::new(prefix_cap),
            evict_scratch: Vec::new(),
            cfg,
            meta,
            scores: Vec::new(),
            head_scores: Vec::new(),
            probs: Vec::new(),
            sel_buf: Vec::new(),
            k_buf: Vec::new(),
            v_buf: Vec::new(),
            valid_buf: Vec::new(),
            batch_scratch: Vec::new(),
        })
    }

    /// The execution backend this engine drives.
    pub fn model(&self) -> &dyn Backend {
        self.model.as_ref()
    }
    /// The shared physical KV page pool.
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }
    /// Which sparsity policy drives the cache.
    pub fn policy_kind(&self) -> PolicyKind {
        self.cfg.policy
    }

    /// Return a finished sequence's pages to the pool.
    pub fn release_seq(&mut self, seq: &mut SeqCache) {
        seq.release_all(&mut self.pool);
    }

    /// Create a fresh sequence cache for this engine's model.
    pub fn new_seq(&self) -> SeqCache {
        let kv_dim = self.meta.model.n_kv_heads * self.meta.model.head_dim;
        SeqCache::new(self.meta.model.n_layers, self.meta.page_size, kv_dim)
    }

    /// Fork `seq`: copy its logical page tables only, sharing every
    /// physical page (refcounted; first divergent append copy-on-writes).
    /// The fork decodes bit-identically to an independently prefilled
    /// sequence and must be released like any other
    /// (`rust/tests/prefix_sharing.rs`).
    pub fn fork_seq(&mut self, seq: &SeqCache) -> SeqCache {
        seq.fork(&mut self.pool)
    }

    /// Swap every resident page of `seq` out to a host-side buffer
    /// (restore-mode preemption, DESIGN.md §6): the slab ranges are freed
    /// for other sequences while the bytes (master + quantized + params +
    /// stamp aggregates) park in the returned [`SwapHandle`].  The page
    /// tables keep their metadata — [`Engine::swap_in_seq`] remaps the
    /// now-stale pool ids on resume.  Pages must be exclusively owned
    /// (the serving path's invariant; a shared page panics in the pool).
    pub fn swap_out_seq(&mut self, seq: &mut SeqCache) -> SwapHandle {
        let ids: Vec<PageId> =
            seq.layers.iter().flat_map(|lc| lc.table.iter().map(|p| p.pool_id)).collect();
        let handle = self.pool.swap_out(&ids);
        self.metrics.add("preempt.restore_bytes", handle.bytes() as u64);
        handle
    }

    /// Swap a parked sequence's pages back in, remapping every page-table
    /// entry from its old pool id to the freshly allocated one.  Fails
    /// with [`PoolExhausted`] (all-or-nothing, pool and handle untouched)
    /// when the pool cannot hold the whole set yet — retry after more
    /// pages free up.  After a successful swap-in the sequence decodes
    /// bit-identically to one that was never swapped (the restored bytes
    /// are verbatim; only pool ids differ).
    pub fn swap_in_seq(&mut self, seq: &mut SeqCache, handle: &SwapHandle) -> Result<()> {
        let map: HashMap<PageId, PageId> = self.pool.swap_in(handle)?.into_iter().collect();
        for lc in &mut seq.layers {
            for p in &mut lc.table {
                p.pool_id = *map.get(&p.pool_id).expect("swap handle covers every resident page");
            }
        }
        Ok(())
    }

    /// Entries currently held by the pool-level prefix index.
    pub fn prefix_len(&self) -> usize {
        self.prefix.len()
    }

    /// Drop every prefix-index entry, releasing its retained pages
    /// (tests asserting pool drain; serving-layer cache flush).
    pub fn prefix_clear(&mut self) {
        self.prefix.release_all(&mut self.pool);
    }

    /// Attach as many cached prefix pages as the index holds for `prompt`
    /// onto a FRESH sequence (`seq.n_tokens == 0`), advancing `n_tokens`
    /// past the attached pages so the caller prefills only the remainder.
    /// The final prompt token is never attached — its chunk must execute
    /// to produce the first-token logits — so at least one token always
    /// reaches the backend.  Counters: `prefix.hit_pages` /
    /// `prefix.miss_pages` (cacheable pages only) and
    /// `prefix.hit_requests`.
    fn attach_prefix(&mut self, seq: &mut SeqCache, prompt: &[u32]) -> Result<()> {
        debug_assert_eq!(seq.n_tokens, 0);
        let page = self.meta.page_size;
        let n_layers = self.meta.model.n_layers;
        let hashes = prefix_hashes(prompt, page);
        // pages whose end stays strictly inside the prompt are cacheable
        let cacheable = hashes.len().min(prompt.len().saturating_sub(1) / page);
        let mut attached = 0usize;
        for &h in &hashes[..cacheable] {
            let end = (attached + 1) * page;
            let toks = &prompt[attached * page..end];
            let Some(pages) = self.prefix.lookup(h, toks) else { break };
            let pages: Vec<(PageId, RepBounds)> = pages.to_vec();
            debug_assert_eq!(pages.len(), n_layers);
            for (layer, (id, rep)) in pages.iter().enumerate() {
                seq.attach_shared_page(layer, &mut self.pool, *id, rep, self.cfg.pin_prefill)?;
            }
            seq.n_tokens = end;
            seq.prefix_cached_tokens = end;
            attached += 1;
        }
        self.metrics.add("prefix.hit_pages", attached as u64);
        self.metrics.add("prefix.miss_pages", (cacheable - attached) as u64);
        if attached > 0 {
            self.metrics.inc("prefix.hit_requests");
        }
        Ok(())
    }

    /// Cache this completed prefill's full prompt pages in the prefix
    /// index (retaining them), then reclaim the index down to capacity.
    /// Runs BEFORE post-prefill budget enforcement so Sink/H2O trims
    /// cannot drop a page the next request could have reused.
    fn prefix_insert(&mut self, seq: &SeqCache, prompt: &[u32]) {
        let page = self.meta.page_size;
        let n_layers = self.meta.model.n_layers;
        let hashes = prefix_hashes(prompt, page);
        let cacheable = hashes.len().min(prompt.len().saturating_sub(1) / page);
        let mut inserted = 0usize;
        for (pidx, &h) in hashes[..cacheable].iter().enumerate() {
            let mut pages: Vec<(PageId, RepBounds)> = Vec::with_capacity(n_layers);
            for lc in &seq.layers {
                match (lc.table.get(pidx), lc.reps.get(pidx)) {
                    (Some(m), Some(r)) if m.start_pos == pidx * page && m.len == page => {
                        pages.push((m.pool_id, r.clone()));
                    }
                    _ => return, // table no longer holds the plain prefill prefix
                }
            }
            let toks = &prompt[pidx * page..(pidx + 1) * page];
            if self.prefix.insert(h, toks, pages, &mut self.pool) {
                inserted += 1;
            }
        }
        self.metrics.add("prefix.inserted_pages", inserted as u64);
        let evicted = self.prefix.reclaim(&mut self.pool);
        self.metrics.add("prefix.evicted_pages", evicted as u64);
    }

    /// Run prefill for `prompt`, filling `seq` (pinned pages) and returning
    /// the first decoded token.  One whole-prompt chunk of the streaming
    /// path below — the monolithic route IS the degenerate chunked route.
    pub fn prefill_seq(&mut self, seq: &mut SeqCache, prompt: &[u32]) -> Result<u32> {
        match self.prefill_seq_partial(seq, prompt, prompt.len().max(1))? {
            Some(tok) => Ok(tok),
            None => unreachable!("whole-prompt chunk must complete the prefill"),
        }
    }

    /// Streaming chunked prefill (DESIGN.md §2, prefill dataflow): advance
    /// `seq` — which tracks its own progress in `seq.n_tokens` — by up to
    /// `max_tokens` more prompt tokens in ONE backend `prefill_chunk` call,
    /// writing the chunk's K/V pool-direct via the bulk page-granular
    /// `SeqCache::append_slots`.  Returns the first decoded token once the
    /// prompt completes, `None` while prefill is still partial (the
    /// batcher's budgeted-admission state).
    ///
    /// Appends run page-run-major (per page-aligned run, per layer), so
    /// the pool's page-allocation order is `(page, layer)` lexicographic —
    /// invariant to chunk boundaries, even mid-page ones.  That is what
    /// makes chunked and monolithic prefill bit-identical end to end:
    /// same first token, same slab bytes, same page tables (pool ids
    /// included), same RepBounds, for every chunk size
    /// (`rust/tests/chunked_prefill.rs`).  Budget enforcement runs once,
    /// at prompt completion, exactly like the monolithic path.
    ///
    /// On `Err` (pool exhaustion mid-chunk) the sequence is left with a
    /// partially-appended chunk and MUST be released (`release_all`), not
    /// retried — a retry fails cleanly on the append contiguity check
    /// rather than corrupting the cache.
    pub fn prefill_seq_partial(&mut self, seq: &mut SeqCache, prompt: &[u32],
                               max_tokens: usize) -> Result<Option<u32>> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if seq.n_tokens >= prompt.len() {
            bail!("sequence already holds {} tokens of a {}-token prompt", seq.n_tokens,
                  prompt.len());
        }
        // prefix-cache fast path: a fresh sequence attaches every cached
        // full prompt page before any backend work, so only the remainder
        // is prefilled (and charged) below
        if self.cfg.prefix_cache && seq.n_tokens == 0 {
            self.attach_prefix(seq, prompt)?;
        }
        let start = seq.n_tokens;
        // saturating: callers may pass usize::MAX as "finish the rest"
        let end = prompt.len().min(start.saturating_add(max_tokens.max(1)));
        // KV source for this chunk: the streaming entry point when the
        // backend has one; otherwise a monolithic prefill of the prefix,
        // sliced in place — no PrefillChunkOut staging copy, so the
        // whole-prompt call on the AOT path costs exactly what the old
        // monolithic route did.
        enum KvSrc {
            Streamed(crate::runtime::PrefillChunkOut),
            Monolithic(crate::runtime::PrefillOut),
        }
        let src = if self.model.supports_chunked_prefill() {
            KvSrc::Streamed(self.model.prefill_chunk(prompt, start, end)
                                .context("prefill chunk")?)
        } else {
            KvSrc::Monolithic(self.model.prefill(&prompt[..end]).context("prefill")?)
        };
        let spec = &self.meta.model;
        seq.append_prefill_runs(&mut self.pool, start, end, self.cfg.pin_prefill,
                                |layer, pos, len| match &src {
                                    KvSrc::Streamed(c) => c.kv_run(spec, layer, pos - start, len),
                                    KvSrc::Monolithic(m) => m.kv_run(spec, layer, pos, len),
                                })?;
        seq.n_tokens = end;
        if end < prompt.len() {
            return Ok(None);
        }
        let logits = match &src {
            KvSrc::Streamed(c) => &c.logits,
            KvSrc::Monolithic(m) => &m.logits,
        };
        Ok(Some(self.finish_prefill(seq, prompt, logits)))
    }

    /// Shared tail of every prefill driver once a sequence's prompt
    /// completes: stamp `prompt_len`, publish the prompt's full pages into
    /// the prefix index (before any trim can drop them), run post-prefill
    /// budget enforcement (Sink/H2O trim immediately; RaaS pins prefill so
    /// nothing is evictable — paper §4.2's small-budget pathology
    /// reproduces here), then greedy-sample the first token from the
    /// final-chunk logits.
    fn finish_prefill(&mut self, seq: &mut SeqCache, prompt: &[u32], logits: &[f32]) -> u32 {
        seq.prompt_len = prompt.len();
        if self.cfg.prefix_cache {
            self.prefix_insert(seq, prompt);
        }
        for layer in 0..self.meta.model.n_layers {
            self.enforce_budget(seq, layer);
        }
        argmax(logits) as u32
    }

    /// One co-admitted prefill tick (DESIGN.md §5, concurrent chunked
    /// admission): advance every entry's sequence by up to its
    /// `max_tokens` more prompt tokens through ONE batched
    /// [`Backend::prefill_chunk_batch`] call, then run the page-run-major
    /// appends per sequence in entry order.  Returns one result per entry,
    /// index-aligned: `Ok(Some(first_token))` when that prompt completed,
    /// `Ok(None)` while its prefill is still partial.
    ///
    /// Bit-identity contract (pinned by `rust/tests/concurrent_prefill.rs`):
    /// this is bit-identical to calling [`Engine::prefill_seq_partial`]
    /// per entry in order — same KV slabs, same page tables including
    /// pool ids, same RepBounds, same first tokens — because backend
    /// calls never touch the pool, and the per-sequence appends (plus any
    /// post-completion eviction) run in the same entry order as the
    /// sequential loop.
    ///
    /// Failure isolation: entry validation errors fail only that entry;
    /// when the batched backend call fails, the engine retries on the
    /// sequential per-entry path so only the actually-failing prompts
    /// error out.  Backends without native streaming
    /// ([`Backend::supports_chunked_prefill`] false) take the sequential
    /// path directly — their whole-prompt prefill cannot be batched.
    pub fn prefill_batch(&mut self, entries: &mut [PrefillEntry<'_>]) -> Vec<Result<Option<u32>>> {
        let n = entries.len();
        if n == 0 {
            return Vec::new();
        }
        if !self.model.supports_chunked_prefill() {
            return self.prefill_sequential(entries);
        }
        let mut out: Vec<Result<Option<u32>>> = (0..n).map(|_| Ok(None)).collect();
        // plan: (entry index, start, end) for every valid entry
        let mut plan: Vec<(usize, usize, usize)> = Vec::with_capacity(n);
        for (i, e) in entries.iter_mut().enumerate() {
            if e.prompt.is_empty() {
                out[i] = Err(anyhow::anyhow!("empty prompt"));
                continue;
            }
            if e.seq.n_tokens >= e.prompt.len() {
                out[i] = Err(anyhow::anyhow!(
                    "sequence already holds {} tokens of a {}-token prompt",
                    e.seq.n_tokens,
                    e.prompt.len()
                ));
                continue;
            }
            // prefix-cache fast path, per entry, in entry order — exactly
            // what the sequential loop would have attached
            if self.cfg.prefix_cache && e.seq.n_tokens == 0 {
                if let Err(err) = self.attach_prefix(e.seq, e.prompt) {
                    out[i] = Err(err);
                    continue;
                }
            }
            let start = e.seq.n_tokens;
            // saturating: callers may pass usize::MAX as "finish the rest"
            let end = e.prompt.len().min(start.saturating_add(e.max_tokens.max(1)));
            plan.push((i, start, end));
        }
        let items: Vec<PrefillChunkItem<'_>> = plan
            .iter()
            .map(|&(i, start, end)| PrefillChunkItem { tokens: entries[i].prompt, start, end })
            .collect();
        let chunks = match self.model.prefill_chunk_batch(&items) {
            // hard contract: a misbehaving backend returning the wrong
            // item count must not panic or desync entries — retry per item
            Ok(c) if c.len() == items.len() => c,
            _ => return self.prefill_sequential(entries),
        };
        let spec = self.meta.model.clone();
        for (&(i, start, end), chunk) in plan.iter().zip(&chunks) {
            let e = &mut entries[i];
            let appended = e.seq.append_prefill_runs(
                &mut self.pool, start, end, self.cfg.pin_prefill,
                |layer, pos, len| chunk.kv_run(&spec, layer, pos - start, len),
            );
            if let Err(err) = appended {
                // the sequence holds a partial chunk: the caller must
                // release it, exactly like a failed sequential chunk
                out[i] = Err(err.context("prefill chunk append"));
                continue;
            }
            e.seq.n_tokens = end;
            if end == e.prompt.len() {
                let prompt = e.prompt;
                let seq = &mut *e.seq;
                out[i] = Ok(Some(self.finish_prefill(seq, prompt, &chunk.logits)));
            }
        }
        out
    }

    /// Per-entry sequential prefill — the isolation fallback and the
    /// non-streaming-backend path of [`Engine::prefill_batch`]: exactly
    /// one [`Engine::prefill_seq_partial`] call per entry, in entry order.
    fn prefill_sequential(&mut self, entries: &mut [PrefillEntry<'_>])
                          -> Vec<Result<Option<u32>>> {
        entries
            .iter_mut()
            .map(|e| self.prefill_seq_partial(e.seq, e.prompt, e.max_tokens))
            .collect()
    }

    fn enforce_budget(&mut self, seq: &mut SeqCache, layer: usize) {
        while resident_tokens(&seq.layers[layer].table) > self.cfg.budget {
            // Shared pages are judged on the max stamp over ALL sharers
            // (the pool-level aggregate), not just this sequence's view —
            // a page another sharer still finds hot must not look stale
            // here.  The candidate runs on a boosted clone of the table
            // (index-aligned) only while any sharing is active; the
            // exclusive path is untouched.  RaaS stamps are monotone, so
            // an exclusive page's aggregate equals its own stamp and the
            // boost is exact, never speculative.
            let cand = if self.pool.any_shared() {
                self.evict_scratch.clear();
                self.evict_scratch.extend(seq.layers[layer].table.iter().cloned());
                for m in &mut self.evict_scratch {
                    if self.pool.is_shared(m.pool_id) {
                        m.last_stamp = m.last_stamp.max(self.pool.stamp_max(m.pool_id));
                    }
                }
                self.policy.evict_candidate(&self.evict_scratch)
            } else {
                self.policy.evict_candidate(&seq.layers[layer].table)
            };
            match cand {
                Some(idx) => seq.evict(layer, idx, &mut self.pool),
                None => break,
            }
        }
    }

    /// Decode one token: returns the next token id.
    ///
    /// Attention routes through the backend's zero-copy paged entry point
    /// when [`Backend::supports_paged`] is true (in-place slab views, no
    /// copy, no capacity padding); otherwise through the classic gather
    /// path.  Both routes are bit-identical end to end (tokens and score
    /// logs — pinned by `rust/tests/paged_attention.rs`).
    ///
    /// Per-phase wall time is accumulated into the metrics registry
    /// (`step.exec_secs` = PJRT executions, `step.policy_secs` = rep scoring
    /// + selection + stamps + eviction, `step.gather_secs` = page gather, or
    /// page-view assembly on the paged route) — the basis of the
    /// EXPERIMENTS.md §Perf breakdown.
    pub fn decode_step(&mut self, seq: &mut SeqCache, token: u32, now: u64,
                       score_log: Option<&mut Vec<(u64, Vec<(usize, f32)>)>>)
                       -> Result<u32> {
        // Pre-mutation headroom check (DESIGN.md §6): the per-layer loop
        // below appends as it goes, so an alloc failure at layer k would
        // leave layers 0..k appended and the sequence poisoned (a retry
        // trips the contiguity check).  Failing BEFORE any append keeps
        // the sequence intact, so the batcher can preempt a victim and
        // retry this exact step.
        let need = seq.pages_needed_for_next_token(&self.pool);
        if need > self.pool.free_pages() {
            return Err(PoolExhausted { capacity_pages: self.pool.capacity_pages() }.into());
        }
        let spec = self.meta.model.clone();
        let paged = self.model.supports_paged();
        let pos = seq.n_tokens;
        let mut t_exec = 0.0f64;
        let mut t_policy = 0.0f64;
        let mut t_gather = 0.0f64;

        let t0 = Instant::now();
        let mut h = self.model.embed_tok(token)?;
        t_exec += t0.elapsed().as_secs_f64();
        let mut log_entry: Option<Vec<(usize, f32)>> = None;

        for layer in 0..spec.n_layers {
            let t0 = Instant::now();
            let qkv = self.model.layer_qkv(layer, &h, pos)?;
            t_exec += t0.elapsed().as_secs_f64();
            // append first so the token attends to itself
            seq.append(layer, &mut self.pool, pos, &qkv.k, &qkv.v, false, now)?;

            let t0 = Instant::now();
            let lc = &seq.layers[layer];
            // Unified cross-head policies (LessIsMore) score head-major and
            // select from the full profile; the classic path reduces inside
            // `RepBounds::score`.  `reduce_head_scores_max` is bitwise that
            // reduction, so probs/observe/logs are identical either way.
            let unified = self.policy.unified_selection();
            if unified {
                lc.rep_scores_heads(&qkv.q, spec.n_heads, spec.n_kv_heads, spec.head_dim,
                                    &mut self.head_scores);
                reduce_head_scores_max(&self.head_scores, spec.n_heads, &mut self.scores);
            } else {
                lc.rep_scores(&qkv.q, spec.n_heads, spec.n_kv_heads, spec.head_dim,
                              &mut self.scores);
            }
            page_probs(&self.scores, spec.head_dim, &mut self.probs);
            // Figure-3 capture: layer-0 page probabilities exactly as
            // computed this step, paired with the page table *before* any
            // select/observe/evict runs for this entry — the capture point
            // the analysis assumes.  (`observe` only mutates stamps and
            // accumulators, never `probs` or page order, but capturing here
            // makes that explicit and keeps the batched path identical.)
            if layer == 0 && score_log.is_some() {
                log_entry = Some(
                    lc.table
                        .iter()
                        .zip(&self.probs)
                        .map(|(p, &pr)| (p.start_pos, pr))
                        .collect(),
                );
            }
            if unified {
                self.policy.select_unified_into(&lc.table, &self.head_scores, spec.n_heads,
                                                self.cfg.budget, self.meta.page_size,
                                                &mut self.sel_buf);
            } else {
                self.policy.select_into(&lc.table, &self.scores, self.cfg.budget,
                                        self.meta.page_size, &mut self.sel_buf);
            }
            t_policy += t0.elapsed().as_secs_f64();

            if paged {
                // zero-copy route: hand the backend in-place views of the
                // selected pages.  View assembly is timed under
                // `step.gather_secs` so the perf breakdown shows the copy
                // collapse directly.  (The buffer is a per-layer stack
                // inline `PageViewBuf` — no heap allocation for
                // budget-bounded selections; full-table selections spill
                // to a Vec like before.  It must stay layer-local because
                // the views borrow the pool and cannot outlive the next
                // append.)
                let t0 = Instant::now();
                let mut pages = PageViewBuf::new();
                seq.page_views_into(layer, &self.pool, &self.sel_buf, &mut pages);
                t_gather += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let inp = PagedAttnInput { h: &h, q: &qkv.q, pages: pages.views() };
                h = self.model.layer_attn_mlp_paged(layer, &inp)?;
                t_exec += t0.elapsed().as_secs_f64();
            } else {
                let n_slots: usize = self.sel_buf.iter().map(|&i| lc.table[i].len).sum();
                let capacity = self.model.capacity_for(n_slots)?;
                let t0 = Instant::now();
                let used = seq.gather(layer, &self.pool, &self.sel_buf, capacity,
                                      &mut self.k_buf, &mut self.v_buf, &mut self.valid_buf);
                t_gather += t0.elapsed().as_secs_f64();
                debug_assert_eq!(used, n_slots);
                let t0 = Instant::now();
                h = self.model.layer_attn_mlp(layer, capacity, &h, &qkv.q, &self.k_buf,
                                              &self.v_buf, &self.valid_buf)?;
                t_exec += t0.elapsed().as_secs_f64();
            }
            // per-layer observation (stamps, accumulators)
            let t0 = Instant::now();
            self.policy.observe(&mut seq.layers[layer].table, &self.probs, now);
            // feed shared pages' fresh stamps into the pool aggregate so
            // other sharers' eviction sees them (O(1) gate when exclusive)
            if self.pool.any_shared() {
                for p in &seq.layers[layer].table {
                    if self.pool.is_shared(p.pool_id) {
                        self.pool.note_stamp(p.pool_id, p.last_stamp);
                    }
                }
            }
            t_policy += t0.elapsed().as_secs_f64();
        }
        // batched eviction after the full iteration (paper Appendix B)
        let t0 = Instant::now();
        for layer in 0..spec.n_layers {
            self.enforce_budget(seq, layer);
        }
        t_policy += t0.elapsed().as_secs_f64();
        seq.n_tokens += 1;
        if let (Some(log), Some(entry)) = (score_log, log_entry) {
            log.push((now, entry));
        }
        let t0 = Instant::now();
        let logits = self.model.lm_head(&h)?;
        t_exec += t0.elapsed().as_secs_f64();
        self.metrics.record_secs("step.exec_secs", t_exec);
        self.metrics.record_secs("step.policy_secs", t_policy);
        self.metrics.record_secs("step.gather_secs", t_gather);
        Ok(argmax(&logits) as u32)
    }

    /// Decode one token for every sequence in `entries` — one scheduler
    /// iteration, layer by layer across the whole batch (DESIGN.md §2,
    /// batched dataflow).  Returns one result per entry, index-aligned.
    ///
    /// Semantics are identical to calling [`Engine::decode_step`] per
    /// entry — batched and sequential decode produce bit-identical tokens
    /// (the crate's core invariant; see `rust/tests/batched_decode.rs`) —
    /// but the backend sees one batched call per phase instead of one call
    /// per sequence, so it can amortize dispatch and share position-pure
    /// work between co-scheduled sequences.
    ///
    /// Failure isolation: a per-sequence failure (pool exhaustion on
    /// append, invalid token) fails only that entry; when a batched
    /// backend call fails, the engine retries that phase item by item so
    /// only the actually-failing sequences error out — one bad sequence
    /// never takes down its co-scheduled neighbors.
    pub fn decode_batch(&mut self, entries: &mut [BatchEntry<'_>]) -> Vec<Result<u32>> {
        let n = entries.len();
        if n == 0 {
            return Vec::new();
        }
        let spec = self.meta.model.clone();
        let paged = self.model.supports_paged();
        let mut out: Vec<Result<u32>> = (0..n).map(|_| Ok(0u32)).collect();
        let mut alive = vec![true; n];
        // Pre-mutation headroom admission (DESIGN.md §6): fail entries the
        // pool cannot hold BEFORE any append, in entry order — an entry
        // that fails here is untouched and retryable after preemption
        // frees pages.  Entries needing no new pages always proceed, so
        // one hungry entry never starves its fitting neighbors.
        let mut headroom = self.pool.free_pages();
        for (i, e) in entries.iter().enumerate() {
            let need = e.seq.pages_needed_for_next_token(&self.pool);
            if need <= headroom {
                headroom -= need;
            } else {
                alive[i] = false;
                out[i] = Err(PoolExhausted { capacity_pages: self.pool.capacity_pages() }.into());
            }
        }
        let mut t_exec = 0.0f64;
        let mut t_policy = 0.0f64;
        let mut t_gather = 0.0f64;
        if self.batch_scratch.len() < n {
            self.batch_scratch.resize_with(n, BatchSlot::default);
        }
        for slot in &mut self.batch_scratch[..n] {
            slot.log_entry = None;
        }

        // embed (per-item fallback isolates an out-of-vocab token)
        let t0 = Instant::now();
        let tokens: Vec<u32> = entries.iter().map(|e| e.token).collect();
        match self.model.embed_tok_batch(&tokens) {
            Ok(hs) => {
                for (i, h) in hs.into_iter().enumerate() {
                    self.batch_scratch[i].h = h;
                }
            }
            Err(_) => {
                for i in 0..n {
                    match self.model.embed_tok(tokens[i]) {
                        Ok(h) => self.batch_scratch[i].h = h,
                        Err(e) => {
                            alive[i] = false;
                            out[i] = Err(e);
                        }
                    }
                }
            }
        }
        t_exec += t0.elapsed().as_secs_f64();

        for layer in 0..spec.n_layers {
            let idxs: Vec<usize> = (0..n).filter(|&i| alive[i]).collect();
            if idxs.is_empty() {
                break;
            }
            // qkv for the whole batch
            let t0 = Instant::now();
            let qkv_in: Vec<QkvBatchItem<'_>> = idxs
                .iter()
                .map(|&i| QkvBatchItem {
                    h: &self.batch_scratch[i].h,
                    pos: entries[i].seq.n_tokens,
                })
                .collect();
            let qkvs = match self.model.layer_qkv_batch(layer, &qkv_in) {
                Ok(v) => v,
                Err(_) => {
                    // per-item fallback: isolate the failing sequence(s);
                    // dead items get an empty placeholder (skipped below)
                    let mut v = Vec::with_capacity(idxs.len());
                    for &i in &idxs {
                        match self.model.layer_qkv(layer, &self.batch_scratch[i].h,
                                                   entries[i].seq.n_tokens) {
                            Ok(q) => v.push(q),
                            Err(err) => {
                                alive[i] = false;
                                out[i] = Err(err.context(format!("qkv (layer {layer})")));
                                v.push(Qkv { q: Vec::new(), k: Vec::new(), v: Vec::new() });
                            }
                        }
                    }
                    v
                }
            };
            t_exec += t0.elapsed().as_secs_f64();

            // Cross-sequence rep-score sharing: when refcounted page
            // sharing is live (forks, prefix hits), sequences whose logical
            // tables resolve to the same physical page hold bit-identical
            // `RepBounds` clones for it (fork clones them, prefix attach
            // copies the donor's), so the O(kv_dim) score fold for a shared
            // page is computed once per distinct query and copied —
            // copying an f32 is exact, pinned by
            // `rust/tests/batched_decode.rs::forked_*`.  Cache key:
            // (physical page, query equivalence class); query classes are
            // detected bitwise, the same predicate the backend's weight
            // reuse trusts.  Shared pages are never written in place (COW
            // detaches first, under a fresh pool id) and a shared page's id
            // cannot be freed or reallocated inside this loop, so entries
            // never go stale within the layer.
            let share_scores = self.pool.any_shared();
            let unified = self.policy.unified_selection();
            let mut score_cache: HashMap<(PageId, usize), f32> = HashMap::new();
            // Unified policies share the whole head profile, not the
            // reduced scalar: the cache stores an offset into a per-layer
            // arena of `n_heads`-wide slices (same key, same lifetime
            // argument as `score_cache` above).
            let mut head_cache: HashMap<(PageId, usize), usize> = HashMap::new();
            let mut head_arena: Vec<f32> = Vec::new();
            let mut qclass: Vec<usize> = Vec::with_capacity(qkvs.len());
            if share_scores {
                for j in 0..qkvs.len() {
                    let q = &qkvs[j].q[..];
                    let c = (0..j)
                        .find(|&p| {
                            let pq = &qkvs[p].q[..];
                            !q.is_empty()
                                && pq.len() == q.len()
                                && pq.iter().zip(q).all(|(a, b)| a.to_bits() == b.to_bits())
                        })
                        .unwrap_or(j);
                    qclass.push(c);
                }
            }

            // append + rep-score + select + gather + observe, per sequence
            for (j, &i) in idxs.iter().enumerate() {
                if !alive[i] {
                    continue;
                }
                let e = &mut entries[i];
                let pos = e.seq.n_tokens;
                // append first so the token attends to itself
                if let Err(err) =
                    e.seq.append(layer, &mut self.pool, pos, &qkvs[j].k, &qkvs[j].v, false, e.now)
                {
                    alive[i] = false;
                    out[i] = Err(err);
                    continue;
                }
                let t0 = Instant::now();
                let lc = &e.seq.layers[layer];
                if unified {
                    // head-major scoring for unified cross-head selection;
                    // the shared-page reuse copies whole head profiles out
                    // of the arena instead of a single reduced f32
                    self.head_scores.clear();
                    if share_scores {
                        for (p, rep) in lc.table.iter().zip(&lc.reps) {
                            if self.pool.is_shared(p.pool_id) {
                                let off = match head_cache.entry((p.pool_id, qclass[j])) {
                                    Entry::Occupied(hit) => {
                                        self.metrics.inc("decode.rep_score_shared");
                                        *hit.get()
                                    }
                                    Entry::Vacant(slot) => {
                                        let off = head_arena.len();
                                        rep.score_heads_into(&qkvs[j].q, spec.n_heads,
                                                             spec.n_kv_heads, spec.head_dim,
                                                             &mut head_arena);
                                        *slot.insert(off)
                                    }
                                };
                                self.head_scores
                                    .extend_from_slice(&head_arena[off..off + spec.n_heads]);
                            } else {
                                rep.score_heads_into(&qkvs[j].q, spec.n_heads, spec.n_kv_heads,
                                                     spec.head_dim, &mut self.head_scores);
                            }
                        }
                    } else {
                        lc.rep_scores_heads(&qkvs[j].q, spec.n_heads, spec.n_kv_heads,
                                            spec.head_dim, &mut self.head_scores);
                    }
                    reduce_head_scores_max(&self.head_scores, spec.n_heads, &mut self.scores);
                } else if share_scores {
                    self.scores.clear();
                    for (p, rep) in lc.table.iter().zip(&lc.reps) {
                        let s = if self.pool.is_shared(p.pool_id) {
                            match score_cache.entry((p.pool_id, qclass[j])) {
                                Entry::Occupied(hit) => {
                                    self.metrics.inc("decode.rep_score_shared");
                                    *hit.get()
                                }
                                Entry::Vacant(slot) => *slot.insert(rep.score(
                                    &qkvs[j].q, spec.n_heads, spec.n_kv_heads, spec.head_dim,
                                )),
                            }
                        } else {
                            rep.score(&qkvs[j].q, spec.n_heads, spec.n_kv_heads, spec.head_dim)
                        };
                        self.scores.push(s);
                    }
                } else {
                    lc.rep_scores(&qkvs[j].q, spec.n_heads, spec.n_kv_heads, spec.head_dim,
                                  &mut self.scores);
                }
                page_probs(&self.scores, spec.head_dim, &mut self.probs);
                // Figure-3 capture: same point as the sequential path —
                // layer-0 probs as computed, before select/observe/evict
                if layer == 0 && e.log.is_some() {
                    self.batch_scratch[i].log_entry = Some(
                        lc.table
                            .iter()
                            .zip(&self.probs)
                            .map(|(p, &pr)| (p.start_pos, pr))
                            .collect(),
                    );
                }
                if unified {
                    self.policy.select_unified_into(&lc.table, &self.head_scores, spec.n_heads,
                                                    self.cfg.budget, self.meta.page_size,
                                                    &mut self.batch_scratch[i].sel);
                } else {
                    self.policy.select_into(&lc.table, &self.scores, self.cfg.budget,
                                            self.meta.page_size,
                                            &mut self.batch_scratch[i].sel);
                }
                t_policy += t0.elapsed().as_secs_f64();

                // the paged route defers to one batched zero-copy call
                // after every append is done (views borrow the pool, so
                // they cannot be captured while neighbors still append)
                if !paged {
                    let slot = &mut self.batch_scratch[i];
                    let n_slots: usize = slot.sel.iter().map(|&s| lc.table[s].len).sum();
                    let capacity = match self.model.capacity_for(n_slots) {
                        Ok(c) => c,
                        Err(err) => {
                            alive[i] = false;
                            out[i] = Err(err);
                            continue;
                        }
                    };
                    let t0 = Instant::now();
                    let used = e.seq.gather(layer, &self.pool, &slot.sel, capacity,
                                            &mut slot.k, &mut slot.v, &mut slot.valid);
                    debug_assert_eq!(used, n_slots);
                    slot.capacity = capacity;
                    t_gather += t0.elapsed().as_secs_f64();
                }
                // per-layer observation (stamps, accumulators) — moved
                // before the attention call relative to the sequential
                // path; the policies consume only this layer's probs, so
                // the observable behavior is identical
                let t0 = Instant::now();
                self.policy.observe(&mut e.seq.layers[layer].table, &self.probs, e.now);
                // same pool-aggregate stamp feed as the sequential path
                if self.pool.any_shared() {
                    for p in &e.seq.layers[layer].table {
                        if self.pool.is_shared(p.pool_id) {
                            self.pool.note_stamp(p.pool_id, p.last_stamp);
                        }
                    }
                }
                t_policy += t0.elapsed().as_secs_f64();
            }

            // attention + MLP for the whole batch
            if paged {
                // zero-copy route: flatten in-place slab views for every
                // live item (all appends for this layer are done, so the
                // pool is stable), then ONE batched paged call.  View
                // assembly is timed as the gather phase it replaces.
                let t0 = Instant::now();
                let mut flat: Vec<PageView<'_>> = Vec::new();
                // (entry index, qkvs index, flat range) per live item
                let mut spans: Vec<(usize, usize, usize, usize)> = Vec::with_capacity(idxs.len());
                for (j, &i) in idxs.iter().enumerate() {
                    if !alive[i] {
                        continue;
                    }
                    let start = flat.len();
                    flat.extend(entries[i].seq.page_view_iter(layer, &self.pool,
                                                              &self.batch_scratch[i].sel));
                    spans.push((i, j, start, flat.len()));
                }
                t_gather += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let mut attn_in: Vec<PagedAttnInput<'_>> = Vec::with_capacity(spans.len());
                let mut live: Vec<usize> = Vec::with_capacity(spans.len());
                for &(i, j, start, end) in &spans {
                    attn_in.push(PagedAttnInput {
                        h: &self.batch_scratch[i].h,
                        q: &qkvs[j].q,
                        pages: &flat[start..end],
                    });
                    live.push(i);
                }
                let results = batch_then_per_item(
                    self.model.layer_attn_mlp_paged_batch(layer, &attn_in),
                    &attn_in,
                    |it| self.model.layer_attn_mlp_paged(layer, it),
                );
                drop(attn_in);
                commit_attn_results(layer, &live, results, &mut self.batch_scratch,
                                    &mut alive, &mut out);
                t_exec += t0.elapsed().as_secs_f64();
            } else {
                let t0 = Instant::now();
                let mut attn_in: Vec<AttnBatchItem<'_>> = Vec::with_capacity(idxs.len());
                let mut live: Vec<usize> = Vec::with_capacity(idxs.len());
                for (j, &i) in idxs.iter().enumerate() {
                    if !alive[i] {
                        continue;
                    }
                    let slot = &self.batch_scratch[i];
                    attn_in.push(AttnBatchItem {
                        capacity: slot.capacity,
                        h: &slot.h,
                        q: &qkvs[j].q,
                        k_sel: &slot.k,
                        v_sel: &slot.v,
                        valid: &slot.valid,
                    });
                    live.push(i);
                }
                let results = batch_then_per_item(
                    self.model.layer_attn_mlp_batch(layer, &attn_in),
                    &attn_in,
                    |it| {
                        self.model.layer_attn_mlp(layer, it.capacity, it.h, it.q, it.k_sel,
                                                  it.v_sel, it.valid)
                    },
                );
                drop(attn_in);
                commit_attn_results(layer, &live, results, &mut self.batch_scratch,
                                    &mut alive, &mut out);
                t_exec += t0.elapsed().as_secs_f64();
            }
        }

        // batched eviction after the full iteration (paper Appendix B)
        let t0 = Instant::now();
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            for layer in 0..spec.n_layers {
                self.enforce_budget(entries[i].seq, layer);
            }
        }
        t_policy += t0.elapsed().as_secs_f64();
        for i in 0..n {
            if !alive[i] {
                continue;
            }
            let e = &mut entries[i];
            e.seq.n_tokens += 1;
            if let (Some(log), Some(entry)) =
                (e.log.as_deref_mut(), self.batch_scratch[i].log_entry.take())
            {
                log.push((e.now, entry));
            }
        }

        // lm head + greedy sample for the whole batch
        let t0 = Instant::now();
        let mut hs: Vec<&[f32]> = Vec::with_capacity(n);
        let mut live: Vec<usize> = Vec::with_capacity(n);
        for (i, slot) in self.batch_scratch[..n].iter().enumerate() {
            if alive[i] {
                hs.push(&slot.h);
                live.push(i);
            }
        }
        if !hs.is_empty() {
            match self.model.lm_head_batch(&hs) {
                Ok(all_logits) => {
                    for (&i, logits) in live.iter().zip(&all_logits) {
                        out[i] = Ok(argmax(logits) as u32);
                    }
                }
                Err(_) => {
                    // per-item fallback: isolate the failing sequence(s)
                    for (&i, h) in live.iter().zip(&hs) {
                        out[i] = self
                            .model
                            .lm_head(h)
                            .map(|logits| argmax(&logits) as u32)
                            .map_err(|err| err.context("lm_head"));
                    }
                }
            }
        }
        t_exec += t0.elapsed().as_secs_f64();
        // Record per-sequence shares so the step.* timers keep their
        // "per sequence-step" semantics (decode_step records one sample per
        // sequence; a raw per-iteration sample here would look n× slower
        // and corrupt the EXPERIMENTS.md §Perf breakdown).
        let share = 1.0 / n as f64;
        self.metrics.record_secs("step.exec_secs", t_exec * share);
        self.metrics.record_secs("step.policy_secs", t_policy * share);
        self.metrics.record_secs("step.gather_secs", t_gather * share);
        out
    }

    /// Full request: prefill + decode until EOS/limit.
    pub fn generate(&mut self, prompt: &[u32], opts: &GenOptions) -> Result<GenOutput> {
        let mut out = GenOutput::default();
        let mut seq = self.new_seq();
        let t0 = Instant::now();
        let mut token = self.prefill_seq(&mut seq, prompt)?;
        out.prefill_secs = t0.elapsed().as_secs_f64();
        self.metrics.record_secs("prefill_secs", out.prefill_secs);

        let limit = opts.force_len.unwrap_or(opts.max_new);
        let t1 = Instant::now();
        let mut score_log = Vec::new();
        for step in 1..=limit {
            out.tokens.push(token);
            if opts.force_len.is_none() && self.tokenizer.is_eos(token) {
                break;
            }
            let log = if opts.log_scores { Some(&mut score_log) } else { None };
            token = self
                .decode_step(&mut seq, token, step as u64, log)
                .with_context(|| format!("decode step {step}"))?;
            let resident = seq.resident_bytes(&self.pool);
            out.peak_resident_bytes = out.peak_resident_bytes.max(resident);
            out.peak_resident_tokens_l0 =
                out.peak_resident_tokens_l0.max(seq.resident_tokens(0));
            if opts.log_series {
                out.series.push((step, t1.elapsed().as_secs_f64(), resident));
            }
        }
        out.decode_secs = t1.elapsed().as_secs_f64();
        out.score_log = score_log;
        self.metrics.record_secs("decode_secs", out.decode_secs);
        self.metrics.add("decode_tokens", out.tokens.len() as u64);
        self.metrics.gauge_max("pool_high_water_bytes", self.pool.high_water_bytes() as f64);
        seq.release_all(&mut self.pool);
        Ok(out)
    }
}

/// All-or-nothing batched backend call with per-item fallback: when the
/// batched call fails, retry item by item so only the actually-failing
/// items carry an error (shared by the paged and gathered attention
/// phases of [`Engine::decode_batch`]).
fn batch_then_per_item<I>(batched: Result<Vec<Vec<f32>>>, items: &[I],
                          per_item: impl Fn(&I) -> Result<Vec<f32>>)
                          -> Vec<Result<Vec<f32>>> {
    match batched {
        Ok(hs) => hs.into_iter().map(Ok).collect(),
        Err(_) => items.iter().map(per_item).collect(),
    }
}

/// Write per-item attention results back into the batch scratch, marking
/// failed items dead with a layer-tagged error (the shared isolation
/// bookkeeping of both attention routes).
fn commit_attn_results(layer: usize, live: &[usize], results: Vec<Result<Vec<f32>>>,
                       scratch: &mut [BatchSlot], alive: &mut [bool],
                       out: &mut [Result<u32>]) {
    for (&i, r) in live.iter().zip(results) {
        match r {
            Ok(h) => scratch[i].h = h,
            Err(err) => {
                alive[i] = false;
                out[i] = Err(err.context(format!("attention (layer {layer})")));
            }
        }
    }
}

const NO_XLA_BACKEND: &str = "this build does not include the XLA/PJRT backend; rebuild \
                              with `--features backend-xla` or run with `--backend sim`";

#[cfg(feature = "backend-xla")]
fn load_xla_backend(meta: &ArtifactMeta, caps: Option<&[usize]>) -> Result<Box<dyn Backend>> {
    use crate::runtime::{ModelRuntime, RuntimeClient};
    let client = RuntimeClient::cpu()?;
    Ok(Box::new(ModelRuntime::load(&client, meta, caps)?))
}

/// Unreachable in practice — `Engine::build` bails first — but kept so the
/// dispatch match stays total without feature-conditional arms.
#[cfg(not(feature = "backend-xla"))]
fn load_xla_backend(_meta: &ArtifactMeta, _caps: Option<&[usize]>) -> Result<Box<dyn Backend>> {
    bail!("{NO_XLA_BACKEND}")
}

/// Greedy sampling: index of the largest logit, ties breaking low.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0, "ties break low");
    }

    #[test]
    fn sim_engine_decodes_deterministically() {
        let cfg = EngineConfig { budget: 128, ..Default::default() };
        let mut e = Engine::new(cfg).unwrap();
        let prompt = vec![1, 3, 13, 4];
        let opts = GenOptions { max_new: 24, force_len: Some(24), ..Default::default() };
        let a = e.generate(&prompt, &opts).unwrap();
        let b = e.generate(&prompt, &opts).unwrap();
        assert_eq!(a.tokens, b.tokens, "sim backend must be bit-deterministic");
        assert_eq!(a.tokens.len(), 24);
        assert!(a.tokens.iter().all(|&t| (t as usize) < e.meta.model.vocab));
    }

    #[test]
    fn partial_prefill_streams_to_the_same_first_token() {
        // Streaming the prompt in 3-token chunks and in one whole-prompt
        // chunk must agree on progress tracking and the first decoded token
        // (full bit-identicality is pinned by rust/tests/chunked_prefill.rs).
        let prompt: Vec<u32> = (0..13u32).map(|i| 1 + i % 40).collect();
        let cfg = EngineConfig { budget: 128, ..Default::default() };
        let mut mono = Engine::new(cfg.clone()).unwrap();
        let mut seq_m = mono.new_seq();
        let tok_m = mono.prefill_seq(&mut seq_m, &prompt).unwrap();

        let mut chunked = Engine::new(cfg).unwrap();
        let mut seq_c = chunked.new_seq();
        let mut done = 0usize;
        let mut first = None;
        while first.is_none() {
            first = chunked.prefill_seq_partial(&mut seq_c, &prompt, 3).unwrap();
            assert_eq!(seq_c.n_tokens, (done + 3).min(prompt.len()));
            done = seq_c.n_tokens;
        }
        assert_eq!(done, prompt.len());
        assert_eq!(seq_c.prompt_len, prompt.len());
        assert_eq!(first, Some(tok_m));
        // resuming past the prompt is a caller bug, reported not ignored
        assert!(chunked.prefill_seq_partial(&mut seq_c, &prompt, 3).is_err());
        mono.release_seq(&mut seq_m);
        chunked.release_seq(&mut seq_c);
    }

    #[test]
    fn prefill_batch_matches_sequential_entries() {
        // Two co-admitted prompts driven through `prefill_batch` must
        // reach the same first tokens as per-entry `prefill_seq_partial`
        // calls (full bit-identity incl. slabs/page tables is pinned by
        // rust/tests/concurrent_prefill.rs); validation errors must stay
        // per-entry.
        let pa: Vec<u32> = (0..17u32).map(|i| 1 + i % 40).collect();
        let pb: Vec<u32> = (0..9u32).map(|i| 2 + i % 31).collect();
        let cfg = EngineConfig { budget: 128, ..Default::default() };

        let mut seqd = Engine::new(cfg.clone()).unwrap();
        let mut sa = seqd.new_seq();
        let mut sb = seqd.new_seq();
        let mut ref_first = [None, None];
        while ref_first.iter().any(Option::is_none) {
            if ref_first[0].is_none() {
                ref_first[0] = seqd.prefill_seq_partial(&mut sa, &pa, 4).unwrap();
            }
            if ref_first[1].is_none() {
                ref_first[1] = seqd.prefill_seq_partial(&mut sb, &pb, 4).unwrap();
            }
        }

        let mut conc = Engine::new(cfg).unwrap();
        let mut ca = conc.new_seq();
        let mut cb = conc.new_seq();
        let mut got = [None, None];
        while got.iter().any(Option::is_none) {
            let mut idx = Vec::new();
            let mut entries = Vec::new();
            if got[0].is_none() {
                idx.push(0);
                entries.push(PrefillEntry { seq: &mut ca, prompt: &pa, max_tokens: 4 });
            }
            if got[1].is_none() {
                idx.push(1);
                entries.push(PrefillEntry { seq: &mut cb, prompt: &pb, max_tokens: 4 });
            }
            for (j, r) in conc.prefill_batch(&mut entries).into_iter().enumerate() {
                if let Some(t) = r.unwrap() {
                    got[idx[j]] = Some(t);
                }
            }
        }
        assert_eq!(got, ref_first);
        assert_eq!(ca.n_tokens, pa.len());
        assert_eq!(cb.prompt_len, pb.len());

        // a completed entry in the batch is a per-entry error, not a panic
        // and not a poisoned batch: the co-scheduled fresh entry proceeds
        let mut fresh = conc.new_seq();
        let mut entries = vec![
            PrefillEntry { seq: &mut ca, prompt: &pa, max_tokens: 4 },
            PrefillEntry { seq: &mut fresh, prompt: &pb, max_tokens: 4 },
        ];
        let res = conc.prefill_batch(&mut entries);
        assert!(res[0].is_err(), "re-prefilling a complete sequence must error");
        assert_eq!(*res[1].as_ref().unwrap(), None, "fresh entry keeps streaming");
        assert_eq!(fresh.n_tokens, 4);

        seqd.release_seq(&mut sa);
        seqd.release_seq(&mut sb);
        conc.release_seq(&mut ca);
        conc.release_seq(&mut cb);
        conc.release_seq(&mut fresh);
    }

    #[test]
    fn decode_exhaustion_fails_pre_mutation_and_is_retryable() {
        // Two prefilled sequences fill the pool exactly; the next decode
        // step must fail with the typed `PoolExhausted` BEFORE any layer
        // appends, leaving the sequence intact — and once the other
        // sequence releases, the retried step decodes the token an
        // uncrowded engine would have produced.
        // Sim geometry: 4 layers, 16-token pages → a 16-token prompt
        // prefills 4 pages; the first decode token needs 4 more (pinned
        // boundary on every layer).
        let prompt: Vec<u32> = (0..16u32).map(|i| 1 + i % 40).collect();
        let cfg = EngineConfig { budget: 10_000, pool_pages: 8, ..Default::default() };
        let mut crowded = Engine::new_with_capacities(cfg.clone(), &[64, 128]).unwrap();
        let mut sa = crowded.new_seq();
        let tok = crowded.prefill_seq(&mut sa, &prompt).unwrap();
        let mut sb = crowded.new_seq();
        let other: Vec<u32> = (0..16u32).map(|i| 2 + i % 31).collect();
        crowded.prefill_seq(&mut sb, &other).unwrap();
        assert_eq!(crowded.pool().free_pages(), 0);

        let before = (sa.n_tokens, sa.resident_pages_total());
        let err = crowded.decode_step(&mut sa, tok, 1, None).unwrap_err();
        assert!(err.downcast_ref::<crate::kvcache::PoolExhausted>().is_some(),
                "exhaustion must surface as the typed signal, got: {err:#}");
        assert_eq!((sa.n_tokens, sa.resident_pages_total()), before,
                   "failed step must not mutate the sequence");

        // victim teardown frees headroom; the exact same step now succeeds
        crowded.release_seq(&mut sb);
        let got = crowded.decode_step(&mut sa, tok, 1, None).unwrap();

        let mut control = Engine::new_with_capacities(cfg, &[64, 128]).unwrap();
        let mut sc = control.new_seq();
        let ctok = control.prefill_seq(&mut sc, &prompt).unwrap();
        assert_eq!(tok, ctok);
        assert_eq!(got, control.decode_step(&mut sc, ctok, 1, None).unwrap(),
                   "retried step must decode exactly what an uncrowded run does");
        crowded.release_seq(&mut sa);
        control.release_seq(&mut sc);
        assert_eq!(crowded.pool().allocated_pages(), 0);
    }

    #[test]
    fn swap_out_in_roundtrip_decodes_bit_identically() {
        let prompt: Vec<u32> = (0..20u32).map(|i| 1 + i % 40).collect();
        let cfg = EngineConfig { budget: 128, ..Default::default() };
        let opts = GenOptions { max_new: 12, force_len: Some(12), log_scores: true,
                                ..Default::default() };
        let mut plain = Engine::new(cfg.clone()).unwrap();
        let reference = plain.generate(&prompt, &opts).unwrap();

        let mut e = Engine::new(cfg).unwrap();
        let mut seq = e.new_seq();
        let mut tok = e.prefill_seq(&mut seq, &prompt).unwrap();
        let mut tokens = vec![tok];
        let mut log = Vec::new();
        for step in 1..=4u64 {
            tok = e.decode_step(&mut seq, tok, step, Some(&mut log)).unwrap();
            tokens.push(tok);
        }
        // park: every resident page leaves the pool, bytes go host-side
        let pages = seq.resident_pages_total();
        let handle = e.swap_out_seq(&mut seq);
        assert_eq!(handle.pages(), pages);
        assert_eq!(e.pool().allocated_pages(), 0);
        // churn the freed ranges so swap-in really has to remap ids
        let filler: Vec<_> = (0..3).map(|_| {
            let mut s = e.new_seq();
            e.prefill_seq(&mut s, &prompt).unwrap();
            s
        }).collect();
        for mut s in filler {
            e.release_seq(&mut s);
        }
        e.swap_in_seq(&mut seq, &handle).unwrap();
        for step in 5..=12u64 {
            tok = e.decode_step(&mut seq, tok, step, Some(&mut log)).unwrap();
            tokens.push(tok);
        }
        // `generate` discards the final decode's output token (it pushes
        // before decoding), so compare the same 12-token window
        tokens.truncate(reference.tokens.len());
        assert_eq!(tokens, reference.tokens, "swap roundtrip must not change the decode");
        assert_eq!(log, reference.score_log, "Figure-3 logs must survive the roundtrip");
        e.release_seq(&mut seq);
        assert_eq!(e.pool().allocated_pages(), 0);
    }

    #[test]
    fn xla_backend_unavailable_is_a_clean_error() {
        // Without `--features backend-xla` (and without artifacts on disk)
        // requesting the PJRT backend must fail with a diagnostic, not panic.
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            artifacts_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        };
        assert!(Engine::new(cfg).is_err());
    }
}
