//! The inference engine: sequence state machine + the per-token decode loop
//! that stitches the execution [`Backend`], the paged KV cache and the
//! sparsity policy together (DESIGN.md §2 dataflow).  The engine is backend
//! agnostic: the same loop drives the PJRT executables and the pure-Rust
//! surrogate.
//!
//! Per decode token, per layer:
//!   backend qkv → append (k,v) to the paged pool → rep-score resident pages
//!   (rust, O(pages)) → policy.select → gather selected slots O(L) →
//!   backend attn_mlp (Pallas kernel on the xla path) → next layer.
//! After all layers: lm_head exec → greedy sample → policy.observe +
//! budget-bounded eviction (timestamps/eviction are batched per iteration,
//! as in the paper's implementation, Appendix B).

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactMeta, BackendKind, EngineConfig, PolicyKind};
use crate::kvcache::page::page_probs;
use crate::kvcache::policy::{make_policy, resident_tokens, SparsityPolicy};
use crate::kvcache::{KvPool, SeqCache};
use crate::metrics::Metrics;
use crate::runtime::{Backend, SimBackend, Tokenizer};

#[derive(Debug, Clone, Default)]
pub struct GenOptions {
    pub max_new: usize,
    /// Decode exactly this many tokens, ignoring EOS (Figure-7 workloads).
    pub force_len: Option<usize>,
    /// Record per-step layer-0 page probabilities (Figure-3 analysis).
    pub log_scores: bool,
    /// Record cumulative decode latency and resident bytes at each step
    /// (Figure-7 series).
    pub log_series: bool,
}

#[derive(Debug, Default)]
pub struct GenOutput {
    pub tokens: Vec<u32>,
    pub prefill_secs: f64,
    pub decode_secs: f64,
    pub peak_resident_bytes: usize,
    pub peak_resident_tokens_l0: usize,
    /// (step, cumulative decode secs, resident bytes) — when log_series.
    pub series: Vec<(usize, f64, usize)>,
    /// (step, [(page_start_pos, prob)]) for layer 0 — when log_scores.
    pub score_log: Vec<(u64, Vec<(usize, f32)>)>,
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub meta: ArtifactMeta,
    pub tokenizer: Tokenizer,
    pub metrics: Metrics,
    model: Box<dyn Backend>,
    pool: KvPool,
    policy: Box<dyn SparsityPolicy>,
    // scratch buffers reused across steps (no allocation in the hot loop)
    scores: Vec<f32>,
    probs: Vec<f32>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    valid_buf: Vec<f32>,
}

impl Engine {
    /// Build an engine on the backend named by `cfg.backend` (sim by
    /// default — hermetic; xla needs `--features backend-xla` + artifacts).
    pub fn new(cfg: EngineConfig) -> Result<Self> {
        Self::build(cfg, None)
    }

    /// Restrict loaded capacities (tests / fast startup).  For the AOT
    /// backend this limits which executables are compiled; for the
    /// surrogate it only shapes attention padding.
    pub fn new_with_capacities(cfg: EngineConfig, caps: &[usize]) -> Result<Self> {
        Self::build(cfg, Some(caps))
    }

    fn build(cfg: EngineConfig, caps: Option<&[usize]>) -> Result<Self> {
        // Fail on the missing feature *before* touching artifact metadata,
        // so the user is pointed at the right fix (rebuild), not at
        // `make artifacts`.
        if cfg.backend == BackendKind::Xla && !cfg!(feature = "backend-xla") {
            bail!("{NO_XLA_BACKEND}");
        }
        let meta = cfg.resolve_meta()?;
        let model: Box<dyn Backend> = match cfg.backend {
            BackendKind::Sim => match caps {
                Some(c) => Box::new(SimBackend::with_capacities(&meta, cfg.seed, c)),
                None => Box::new(SimBackend::new(&meta, cfg.seed)),
            },
            BackendKind::Xla => load_xla_backend(&meta, caps)?,
        };
        Self::with_backend(cfg, meta, model)
    }

    pub fn with_backend(cfg: EngineConfig, meta: ArtifactMeta, model: Box<dyn Backend>)
                        -> Result<Self> {
        let kv_dim = meta.model.n_kv_heads * meta.model.head_dim;
        let pool = KvPool::new(cfg.pool_pages, meta.page_size, kv_dim);
        let policy = make_policy(&cfg);
        Ok(Engine {
            tokenizer: Tokenizer::new(meta.corpus.clone()),
            metrics: Metrics::new(),
            model,
            pool,
            policy,
            cfg,
            meta,
            scores: Vec::new(),
            probs: Vec::new(),
            k_buf: Vec::new(),
            v_buf: Vec::new(),
            valid_buf: Vec::new(),
        })
    }

    pub fn model(&self) -> &dyn Backend {
        self.model.as_ref()
    }
    pub fn pool(&self) -> &KvPool {
        &self.pool
    }
    pub fn policy_kind(&self) -> PolicyKind {
        self.cfg.policy
    }

    /// Return a finished sequence's pages to the pool.
    pub fn release_seq(&mut self, seq: &mut SeqCache) {
        seq.release_all(&mut self.pool);
    }

    /// Create a fresh sequence cache for this engine's model.
    pub fn new_seq(&self) -> SeqCache {
        let kv_dim = self.meta.model.n_kv_heads * self.meta.model.head_dim;
        SeqCache::new(self.meta.model.n_layers, self.meta.page_size, kv_dim)
    }

    /// Run prefill for `prompt`, filling `seq` (pinned pages) and returning
    /// the first decoded token.
    pub fn prefill_seq(&mut self, seq: &mut SeqCache, prompt: &[u32]) -> Result<u32> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        let out = self.model.prefill(prompt).context("prefill")?;
        let n_layers = self.meta.model.n_layers;
        for layer in 0..n_layers {
            for pos in 0..prompt.len() {
                let (k, v) = out.kv_at(&self.meta.model, layer, pos);
                seq.append(layer, &mut self.pool, pos, k, v, self.cfg.pin_prefill, 0)?;
            }
        }
        seq.n_tokens = prompt.len();
        seq.prompt_len = prompt.len();
        // budget enforcement after prefill (Sink/H2O trim immediately; RaaS
        // pins prefill so nothing is evictable — paper §4.2's small-budget
        // pathology reproduces here)
        for layer in 0..n_layers {
            self.enforce_budget(seq, layer);
        }
        Ok(argmax(&out.logits) as u32)
    }

    fn enforce_budget(&mut self, seq: &mut SeqCache, layer: usize) {
        while resident_tokens(&seq.layers[layer].table) > self.cfg.budget {
            match self.policy.evict_candidate(&seq.layers[layer].table) {
                Some(idx) => seq.evict(layer, idx, &mut self.pool),
                None => break,
            }
        }
    }

    /// Decode one token: returns the next token id.
    ///
    /// Per-phase wall time is accumulated into the metrics registry
    /// (`step.exec_secs` = PJRT executions, `step.policy_secs` = rep scoring
    /// + selection + stamps + eviction, `step.gather_secs` = page gather) —
    /// the basis of the EXPERIMENTS.md §Perf breakdown.
    pub fn decode_step(&mut self, seq: &mut SeqCache, token: u32, now: u64,
                       score_log: Option<&mut Vec<(u64, Vec<(usize, f32)>)>>)
                       -> Result<u32> {
        let spec = self.meta.model.clone();
        let pos = seq.n_tokens;
        let mut t_exec = 0.0f64;
        let mut t_policy = 0.0f64;
        let mut t_gather = 0.0f64;

        let t0 = Instant::now();
        let mut h = self.model.embed_tok(token)?;
        t_exec += t0.elapsed().as_secs_f64();
        let mut log_entry: Option<Vec<(usize, f32)>> = None;

        for layer in 0..spec.n_layers {
            let t0 = Instant::now();
            let qkv = self.model.layer_qkv(layer, &h, pos)?;
            t_exec += t0.elapsed().as_secs_f64();
            // append first so the token attends to itself
            seq.append(layer, &mut self.pool, pos, &qkv.k, &qkv.v, false, now)?;

            let t0 = Instant::now();
            let lc = &seq.layers[layer];
            lc.rep_scores(&qkv.q, spec.n_heads, spec.n_kv_heads, spec.head_dim,
                          &mut self.scores);
            page_probs(&self.scores, spec.head_dim, &mut self.probs);
            let sel = self.policy.select(&lc.table, &self.scores, self.cfg.budget,
                                         self.meta.page_size);
            t_policy += t0.elapsed().as_secs_f64();

            let n_slots: usize = sel.iter().map(|&i| lc.table[i].len).sum();
            let capacity = self.model.capacity_for(n_slots)?;
            let t0 = Instant::now();
            let used = seq.gather(layer, &self.pool, &sel, capacity, &mut self.k_buf,
                                  &mut self.v_buf, &mut self.valid_buf);
            t_gather += t0.elapsed().as_secs_f64();
            debug_assert_eq!(used, n_slots);
            let t0 = Instant::now();
            h = self.model.layer_attn_mlp(layer, capacity, &h, &qkv.q, &self.k_buf,
                                          &self.v_buf, &self.valid_buf)?;
            t_exec += t0.elapsed().as_secs_f64();
            // per-layer observation (stamps, accumulators)
            let t0 = Instant::now();
            self.policy.observe(&mut seq.layers[layer].table, &self.probs, now);
            t_policy += t0.elapsed().as_secs_f64();
            if layer == 0 && score_log.is_some() {
                log_entry = Some(
                    seq.layers[0]
                        .table
                        .iter()
                        .zip(&self.probs)
                        .map(|(p, &pr)| (p.start_pos, pr))
                        .collect(),
                );
            }
        }
        // batched eviction after the full iteration (paper Appendix B)
        let t0 = Instant::now();
        for layer in 0..spec.n_layers {
            self.enforce_budget(seq, layer);
        }
        t_policy += t0.elapsed().as_secs_f64();
        seq.n_tokens += 1;
        if let (Some(log), Some(entry)) = (score_log, log_entry) {
            log.push((now, entry));
        }
        let t0 = Instant::now();
        let logits = self.model.lm_head(&h)?;
        t_exec += t0.elapsed().as_secs_f64();
        self.metrics.record_secs("step.exec_secs", t_exec);
        self.metrics.record_secs("step.policy_secs", t_policy);
        self.metrics.record_secs("step.gather_secs", t_gather);
        Ok(argmax(&logits) as u32)
    }

    /// Full request: prefill + decode until EOS/limit.
    pub fn generate(&mut self, prompt: &[u32], opts: &GenOptions) -> Result<GenOutput> {
        let mut out = GenOutput::default();
        let mut seq = self.new_seq();
        let t0 = Instant::now();
        let mut token = self.prefill_seq(&mut seq, prompt)?;
        out.prefill_secs = t0.elapsed().as_secs_f64();
        self.metrics.record_secs("prefill_secs", out.prefill_secs);

        let limit = opts.force_len.unwrap_or(opts.max_new);
        let t1 = Instant::now();
        let mut score_log = Vec::new();
        for step in 1..=limit {
            out.tokens.push(token);
            if opts.force_len.is_none() && self.tokenizer.is_eos(token) {
                break;
            }
            let log = if opts.log_scores { Some(&mut score_log) } else { None };
            token = self
                .decode_step(&mut seq, token, step as u64, log)
                .with_context(|| format!("decode step {step}"))?;
            let resident = seq.resident_bytes(&self.pool);
            out.peak_resident_bytes = out.peak_resident_bytes.max(resident);
            out.peak_resident_tokens_l0 =
                out.peak_resident_tokens_l0.max(seq.resident_tokens(0));
            if opts.log_series {
                out.series.push((step, t1.elapsed().as_secs_f64(), resident));
            }
        }
        out.decode_secs = t1.elapsed().as_secs_f64();
        out.score_log = score_log;
        self.metrics.record_secs("decode_secs", out.decode_secs);
        self.metrics.add("decode_tokens", out.tokens.len() as u64);
        self.metrics.gauge_max("pool_high_water_bytes", self.pool.high_water_bytes() as f64);
        seq.release_all(&mut self.pool);
        Ok(out)
    }
}

const NO_XLA_BACKEND: &str = "this build does not include the XLA/PJRT backend; rebuild \
                              with `--features backend-xla` or run with `--backend sim`";

#[cfg(feature = "backend-xla")]
fn load_xla_backend(meta: &ArtifactMeta, caps: Option<&[usize]>) -> Result<Box<dyn Backend>> {
    use crate::runtime::{ModelRuntime, RuntimeClient};
    let client = RuntimeClient::cpu()?;
    Ok(Box::new(ModelRuntime::load(&client, meta, caps)?))
}

/// Unreachable in practice — `Engine::build` bails first — but kept so the
/// dispatch match stays total without feature-conditional arms.
#[cfg(not(feature = "backend-xla"))]
fn load_xla_backend(_meta: &ArtifactMeta, _caps: Option<&[usize]>) -> Result<Box<dyn Backend>> {
    bail!("{NO_XLA_BACKEND}")
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0, "ties break low");
    }

    #[test]
    fn sim_engine_decodes_deterministically() {
        let cfg = EngineConfig { budget: 128, ..Default::default() };
        let mut e = Engine::new(cfg).unwrap();
        let prompt = vec![1, 3, 13, 4];
        let opts = GenOptions { max_new: 24, force_len: Some(24), ..Default::default() };
        let a = e.generate(&prompt, &opts).unwrap();
        let b = e.generate(&prompt, &opts).unwrap();
        assert_eq!(a.tokens, b.tokens, "sim backend must be bit-deterministic");
        assert_eq!(a.tokens.len(), 24);
        assert!(a.tokens.iter().all(|&t| (t as usize) < e.meta.model.vocab));
    }

    #[test]
    fn xla_backend_unavailable_is_a_clean_error() {
        // Without `--features backend-xla` (and without artifacts on disk)
        // requesting the PJRT backend must fail with a diagnostic, not panic.
        let cfg = EngineConfig {
            backend: BackendKind::Xla,
            artifacts_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
            ..Default::default()
        };
        assert!(Engine::new(cfg).is_err());
    }
}
