//! Serving metrics: counters, timers, gauges, JCT tracking, and the memory
//! high-water series behind the Figure-7 memory axis.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::Summary;

/// A registry of named metrics for one engine/coordinator instance.
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    timers: BTreeMap<String, Summary>,
}

impl Metrics {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment counter `name` by one.
    pub fn inc(&mut self, name: &str) {
        self.add(name, 1);
    }
    /// Increment counter `name` by `n`.
    pub fn add(&mut self, name: &str, n: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += n;
    }
    /// Current value of counter `name` (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set gauge `name` to `v`.
    pub fn gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }
    /// Keep the maximum seen (high-water gauges, e.g. pool bytes).
    pub fn gauge_max(&mut self, name: &str, v: f64) {
        let e = self.gauges.entry(name.to_string()).or_insert(f64::NEG_INFINITY);
        if v > *e {
            *e = v;
        }
    }
    /// Current value of gauge `name`, if ever set.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Add one wall-time sample to timer `name`.
    pub fn record_secs(&mut self, name: &str, secs: f64) {
        self.timers.entry(name.to_string()).or_default().add(secs);
    }
    /// Run `f`, recording its wall time under timer `name`.
    pub fn time<R>(&mut self, name: &str, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.record_secs(name, t0.elapsed().as_secs_f64());
        r
    }
    /// Sample summary of timer `name`, if any samples were recorded.
    pub fn timer(&self, name: &str) -> Option<&Summary> {
        self.timers.get(name)
    }

    /// Dump every metric as a flat JSON object.
    pub fn to_json(&self) -> Json {
        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        for (k, v) in &self.counters {
            obj.insert(format!("counter.{k}"), Json::Num(*v as f64));
        }
        for (k, v) in &self.gauges {
            obj.insert(format!("gauge.{k}"), Json::Num(*v));
        }
        for (k, s) in &self.timers {
            obj.insert(
                format!("timer.{k}"),
                Json::obj(vec![
                    ("count", Json::from(s.count())),
                    ("mean_s", Json::from(s.mean())),
                    ("p50_s", Json::from(s.percentile(50.0))),
                    ("p99_s", Json::from(s.percentile(99.0))),
                ]),
            );
        }
        Json::Obj(obj)
    }
}

/// Per-request latency breakdown (the paper's JCT metric).
#[derive(Debug, Clone)]
pub struct RequestTiming {
    /// When the request entered the system.
    pub arrival: Instant,
    /// When its prefill completed (first token ready).
    pub prefill_done: Option<Instant>,
    /// When its full response was delivered.
    pub finished: Option<Instant>,
}

impl RequestTiming {
    /// Timing anchored at "now".
    pub fn start() -> Self {
        RequestTiming { arrival: Instant::now(), prefill_done: None, finished: None }
    }
    /// Time to first token, once prefill completed.
    pub fn ttft(&self) -> Option<Duration> {
        self.prefill_done.map(|t| t - self.arrival)
    }
    /// Job completion time, once finished.
    pub fn jct(&self) -> Option<Duration> {
        self.finished.map(|t| t - self.arrival)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.inc("req");
        m.add("req", 4);
        assert_eq!(m.counter("req"), 5);
        assert_eq!(m.counter("missing"), 0);
        m.gauge_max("hw", 10.0);
        m.gauge_max("hw", 3.0);
        assert_eq!(m.gauge_value("hw"), Some(10.0));
    }

    #[test]
    fn timers_record() {
        let mut m = Metrics::new();
        let out = m.time("op", || 42);
        assert_eq!(out, 42);
        m.record_secs("op", 0.5);
        let t = m.timer("op").unwrap();
        assert_eq!(t.count(), 2);
    }

    #[test]
    fn json_dump_parses() {
        let mut m = Metrics::new();
        m.inc("x");
        m.gauge("g", 1.5);
        m.record_secs("t", 0.1);
        let j = m.to_json().to_string();
        assert!(crate::util::json::Json::parse(&j).is_ok());
    }

    #[test]
    fn request_timing() {
        let mut t = RequestTiming::start();
        assert!(t.ttft().is_none());
        t.prefill_done = Some(Instant::now());
        t.finished = Some(Instant::now());
        assert!(t.ttft().unwrap() <= t.jct().unwrap());
    }
}
