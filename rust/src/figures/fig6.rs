//! Figure 6: accuracy vs cache budget — 5 algorithms × 3 datasets × 4 model
//! profiles, 200 trials per cell (the paper's 200 questions per dataset).

use anyhow::Result;

use crate::config::{EngineConfig, PolicyKind};
use crate::kvcache::policy::make_policy;
use crate::sim::reasoning::{run_trials, SimParams};
use crate::sim::{DATASETS, MODELS};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::ascii_plot;

use super::common::{print_table, results_dir, write_csv, DEFAULT_BUDGETS};

/// Run the Figure-6 command (`raas fig6`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let trials = args.usize_or("trials", 200);
    let budgets = args.usize_list_or("budgets", &DEFAULT_BUDGETS);
    let seed = args.u64_or("seed", 6);
    let alpha = args.f64_or("alpha", 1e-4);

    let mut rows = Vec::new();
    for dp in &DATASETS {
        for mp in &MODELS {
            for kind in PolicyKind::all() {
                for &budget in &budgets {
                    let cfg = EngineConfig { policy: kind, budget, alpha, ..Default::default() };
                    let policy = make_policy(&cfg);
                    let params = SimParams {
                        budget_tokens: budget,
                        max_decode: 4096,
                        ..Default::default()
                    };
                    let mut rng = Rng::new(seed ^ (budget as u64) << 3
                        ^ (kind as u64) << 17 ^ (dp.idx as u64) << 23);
                    let agg = run_trials(policy.as_ref(), &params, mp, dp, trials, &mut rng);
                    rows.push(vec![
                        dp.name.to_string(),
                        mp.name.to_string(),
                        kind.name().to_string(),
                        budget.to_string(),
                        format!("{:.3}", agg.accuracy),
                        format!("{:.3}", agg.milestone_miss_rate),
                        format!("{:.3}", agg.phoenix_miss_rate),
                        format!("{:.1}", agg.mean_peak_resident),
                    ]);
                }
            }
        }
    }
    let path = dir.join("fig6.csv");
    write_csv(
        &path,
        &["dataset", "model", "policy", "budget", "accuracy", "milestone_misses",
          "phoenix_misses", "peak_resident_tokens"],
        &rows,
    )?;
    println!("wrote {path:?} ({} cells)", rows.len());

    // summary: per dataset, accuracy at each budget averaged over models
    for dp in &DATASETS {
        let mut series_store: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
        let mut tbl = Vec::new();
        for kind in PolicyKind::all() {
            let mut pts = Vec::new();
            for &budget in &budgets {
                let accs: Vec<f64> = rows
                    .iter()
                    .filter(|r| r[0] == dp.name && r[2] == kind.name()
                            && r[3] == budget.to_string())
                    .map(|r| r[4].parse::<f64>().unwrap())
                    .collect();
                let mean = accs.iter().sum::<f64>() / accs.len().max(1) as f64;
                pts.push((budget as f64, mean));
            }
            tbl.push({
                let mut row = vec![kind.name().to_string()];
                row.extend(pts.iter().map(|(_, a)| format!("{a:.3}")));
                row
            });
            series_store.push((kind.name().to_string(), pts));
        }
        println!("\nFigure 6 — {} (accuracy vs budget, mean over 4 model profiles)", dp.name);
        let mut headers = vec!["policy"];
        let budget_strs: Vec<String> = budgets.iter().map(|b| b.to_string()).collect();
        headers.extend(budget_strs.iter().map(|s| s.as_str()));
        print_table(&headers, &tbl);
        let series: Vec<(&str, &[(f64, f64)])> = series_store
            .iter()
            .map(|(n, p)| (n.as_str(), p.as_slice()))
            .collect();
        println!("{}", ascii_plot(&format!("{} accuracy vs budget", dp.name), &series, 64, 12));
    }
    println!("paper shape check: Quest ≈ RaaS ≈ Dense by budget 1024; Sink/H2O");
    println!("collapse at small budgets; RaaS dips at 64 (pinned prefill eats budget).");
    Ok(())
}
