//! Figure 3: the waterfall attention pattern — fractions of attention maps
//! showing milestone columns (paper: 20–25 %), phoenix tokens (1–2 %), and
//! lazy sink+recent structure (> 70 %).
//!
//! Two data sources:
//!  * `artifacts/fig3_attention_stats.json` — per-(layer, head) token-level
//!    classification from the trained model (python/compile/analyze_attention.py,
//!    run at build time via `make fig3data`);
//!  * the engine's own page-level score logs from a dense run (always
//!    available once artifacts are built) — classified here.

use anyhow::Result;

use crate::config::{EngineConfig, PolicyKind};
use crate::engine::{Engine, GenOptions};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::Problem;

use super::common::{print_table, results_dir, write_csv};

/// Classification of one page's probability time series.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ColumnKind {
    /// High attention while derived, then permanent fade (paper Figure 3a).
    Milestone,
    /// Re-ignites after a long quiet gap (prompt operands — Figure 3b).
    Phoenix,
    /// Neither pattern: uniformly low or noisy attention.
    Background,
}

/// Detector thresholds (page-level analogues of the paper's map inspection).
pub struct Detector {
    /// Probability above which a step counts as a "high" for the page.
    pub hi: f32,
    /// Probability below which a step counts as quiet.
    pub lo: f32,
    /// Steps of sustained quiet after the last high for a milestone.
    pub fade_window: usize,
    /// Minimum inactive gap between highs for a phoenix (paper: 128).
    pub phoenix_gap: usize,
}

impl Default for Detector {
    fn default() -> Self {
        Detector { hi: 0.25, lo: 0.02, fade_window: 12, phoenix_gap: 64 }
    }
}

impl Detector {
    /// Classify a page's probability series over decode steps.
    pub fn classify(&self, series: &[f32]) -> ColumnKind {
        let highs: Vec<usize> = series
            .iter()
            .enumerate()
            .filter(|(_, &p)| p >= self.hi)
            .map(|(i, _)| i)
            .collect();
        if highs.is_empty() {
            return ColumnKind::Background;
        }
        // phoenix: two highs separated by a long quiet gap
        for w in highs.windows(2) {
            if w[1] - w[0] >= self.phoenix_gap
                && series[w[0] + 1..w[1]].iter().all(|&p| p < self.hi)
            {
                return ColumnKind::Phoenix;
            }
        }
        // milestone: after the last high, sustained quiet until the end
        let last_hi = *highs.last().unwrap();
        let tail = &series[(last_hi + 1).min(series.len())..];
        if tail.len() >= self.fade_window && tail.iter().all(|&p| p < self.lo * 8.0) {
            return ColumnKind::Milestone;
        }
        ColumnKind::Background
    }
}

/// Run the Figure-3 command (`raas fig3`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    // --- source 1: python per-head stats, if generated -----------------------
    let stats_path = std::path::PathBuf::from(args.str_or("artifacts", "artifacts"))
        .join("fig3_attention_stats.json");
    if let Ok(text) = std::fs::read_to_string(&stats_path) {
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        println!("Figure 3 — trained-model attention maps ({} maps analysed):",
                 j.get("n_maps").and_then(|v| v.as_i64()).unwrap_or(0));
        print_table(
            &["pattern", "paper", "measured"],
            &[
                vec!["milestone maps".into(), "20–25 %".into(),
                     pct(j.get("milestone_frac"))],
                vec!["phoenix maps".into(), "1–2 %".into(), pct(j.get("phoenix_frac"))],
                vec!["lazy (sink+recent) maps".into(), "> 70 %".into(),
                     pct(j.get("lazy_frac"))],
            ],
        );
    } else {
        println!("(no {stats_path:?} — run `make fig3data` for per-head map stats)");
    }

    // --- source 2: engine page-level score logs ------------------------------
    if !args.switch("no-engine") {
        match engine_page_stats(args) {
            Ok((rows, n_pages)) => {
                let path = dir.join("fig3_pages.csv");
                write_csv(&path, &["kind", "count", "fraction"], &rows)?;
                println!("\nengine page-column classification over {n_pages} decode pages");
                println!("wrote {path:?}");
            }
            Err(e) => println!("(engine page stats skipped: {e:#})"),
        }
    }
    Ok(())
}

fn pct(v: Option<&Json>) -> String {
    v.and_then(|x| x.as_f64()).map(|f| format!("{:.1} %", 100.0 * f)).unwrap_or("-".into())
}

/// Run the real engine densely over a few problems, log layer-0 page probs
/// and classify the columns.
fn engine_page_stats(args: &Args) -> Result<(Vec<Vec<String>>, usize)> {
    let mut cfg = EngineConfig::from_args(args)?;
    cfg.policy = PolicyKind::Dense;
    let mut engine = Engine::new_with_capacities(cfg, &[256, 2048])?;
    let spec = engine.meta.corpus.clone();
    let mut rng = Rng::new(args.u64_or("seed", 3));
    let det = Detector::default();
    let n_problems = args.usize_or("problems", 8);

    let mut counts = [0usize; 3];
    let mut total = 0usize;
    for _ in 0..n_problems {
        let p = Problem::sample(&mut rng, &spec, Some(spec.max_steps));
        let prompt = p.encode_prompt(&spec);
        let out = engine.generate(
            &prompt,
            &GenOptions { max_new: 128, log_scores: true, ..Default::default() },
        )?;
        // pivot the log: page start_pos -> series over steps
        let mut pages: std::collections::BTreeMap<usize, Vec<f32>> = Default::default();
        for (step_idx, (_, entries)) in out.score_log.iter().enumerate() {
            for &(start_pos, prob) in entries {
                let series = pages.entry(start_pos).or_insert_with(|| vec![0.0; step_idx]);
                while series.len() < step_idx {
                    series.push(0.0);
                }
                series.push(prob);
            }
        }
        for (_, series) in pages {
            match det.classify(&series) {
                ColumnKind::Milestone => counts[0] += 1,
                ColumnKind::Phoenix => counts[1] += 1,
                ColumnKind::Background => counts[2] += 1,
            }
            total += 1;
        }
    }
    let rows = [("milestone", 0), ("phoenix", 1), ("background", 2)]
        .iter()
        .map(|&(name, i)| {
            vec![
                name.to_string(),
                counts[i].to_string(),
                format!("{:.3}", counts[i] as f64 / total.max(1) as f64),
            ]
        })
        .collect();
    Ok((rows, total))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detects_milestone_shape() {
        let det = Detector { fade_window: 8, ..Default::default() };
        // hot early, then fades for good
        let mut s = vec![0.3, 0.4, 0.3, 0.05];
        s.extend(vec![0.001; 30]);
        assert_eq!(det.classify(&s), ColumnKind::Milestone);
    }

    #[test]
    fn detects_phoenix_shape() {
        let det = Detector { phoenix_gap: 16, ..Default::default() };
        let mut s = vec![0.3];
        s.extend(vec![0.0001; 30]);
        s.push(0.3);
        assert_eq!(det.classify(&s), ColumnKind::Phoenix);
    }

    #[test]
    fn background_stays_background() {
        let det = Detector::default();
        assert_eq!(det.classify(&vec![0.001; 50]), ColumnKind::Background);
        assert_eq!(det.classify(&[]), ColumnKind::Background);
        // still hot at the end: not a milestone
        let mut s = vec![0.001; 30];
        s.push(0.5);
        assert_eq!(det.classify(&s), ColumnKind::Background);
    }
}
