//! Figure 7: latency and memory vs decode length (prefill fixed at 128),
//! measured end-to-end on the real engine: Dense grows quadratically in
//! total decode time and linearly in memory; Quest is O(L) per step but O(N)
//! memory; RaaS is O(L) in both.

use anyhow::Result;

use crate::config::{EngineConfig, PolicyKind};
use crate::engine::{Engine, GenOptions};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::{ascii_plot, loglog_slope};
use crate::workload::Problem;

use super::common::{fmt_bytes, print_table, results_dir, write_csv};

/// Run the Figure-7 command (`raas fig7`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let max_decode = args.usize_or("max-decode", 4096);
    let prefill_len = args.usize_or("prefill", 128);
    let budget = args.usize_or("budget", 1024);
    let policies = args.str_list_or("policies", &["dense", "quest", "raas", "sink", "h2o"]);
    let checkpoints: Vec<usize> = {
        let mut cs = vec![];
        let mut c = 512;
        while c <= max_decode {
            cs.push(c);
            c *= 2;
        }
        cs
    };

    let mut rows = Vec::new();
    let mut lat_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut mem_series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut summary = Vec::new();

    for pname in &policies {
        let mut cfg = EngineConfig::from_args(args)?;
        cfg.policy = PolicyKind::parse(pname)?;
        cfg.budget = budget;
        // one engine per policy; fresh pool so high-water is per-policy
        let mut engine = Engine::new(cfg)?;
        let spec = engine.meta.corpus.clone();
        let mut rng = Rng::new(args.u64_or("seed", 7));

        // synth a prompt of exactly prefill_len tokens
        let mut prompt = Vec::new();
        while prompt.len() < prefill_len {
            prompt.extend(Problem::sample(&mut rng, &spec, None).encode_prompt(&spec));
        }
        prompt.truncate(prefill_len);

        let out = engine.generate(
            &prompt,
            &GenOptions {
                max_new: max_decode,
                force_len: Some(max_decode),
                log_series: true,
                ..Default::default()
            },
        )?;

        let mut lat_pts = Vec::new();
        let mut mem_pts = Vec::new();
        for &cp in &checkpoints {
            if let Some(&(step, secs, bytes)) = out.series.iter().find(|(s, _, _)| *s == cp) {
                rows.push(vec![
                    pname.clone(),
                    step.to_string(),
                    format!("{secs:.3}"),
                    bytes.to_string(),
                ]);
                lat_pts.push((step as f64, secs));
                mem_pts.push((step as f64, bytes as f64));
            }
        }
        let xs: Vec<f64> = lat_pts.iter().map(|p| p.0).collect();
        let lat: Vec<f64> = lat_pts.iter().map(|p| p.1).collect();
        let mem: Vec<f64> = mem_pts.iter().map(|p| p.1).collect();
        summary.push(vec![
            pname.clone(),
            format!("{:.2}", loglog_slope(&xs, &lat)),
            format!("{:.2}", loglog_slope(&xs, &mem)),
            format!("{:.1}s", out.decode_secs),
            fmt_bytes(*mem.last().unwrap_or(&0.0)),
        ]);
        lat_series.push((pname.clone(), lat_pts));
        mem_series.push((pname.clone(), mem_pts));
        println!("{pname}: decode {max_decode} tokens in {:.1}s", out.decode_secs);
    }

    let path = dir.join("fig7.csv");
    write_csv(&path, &["policy", "decode_tokens", "cum_decode_secs", "resident_bytes"], &rows)?;
    println!("wrote {path:?}");

    println!("\nFigure 7 summary (log-log slopes: latency exponent ≈2 ⇒ O(N²) total,");
    println!("≈1 ⇒ O(N) total i.e. O(L)/step; memory exponent ≈1 ⇒ O(N), ≈0 ⇒ O(L)):");
    print_table(
        &["policy", "latency slope", "memory slope", "total decode", "final resident"],
        &summary,
    );
    let ls: Vec<(&str, &[(f64, f64)])> =
        lat_series.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!("{}", ascii_plot("cumulative decode latency vs decode length", &ls, 64, 12));
    let ms: Vec<(&str, &[(f64, f64)])> =
        mem_series.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!("{}", ascii_plot("resident KV bytes vs decode length", &ms, 64, 12));
    println!("paper shape check: Dense latency superlinear; Quest/RaaS linear;");
    println!("Dense+Quest memory linear; RaaS (and Sink/H2O) plateau at the budget.");
    Ok(())
}
