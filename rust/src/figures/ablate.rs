//! Ablations of RaaS design choices (DESIGN.md §7 calls these out):
//!
//!  A. **Prefill pinning** on/off — removes idea #2; phoenix operands get
//!     evicted and accuracy collapses on reasoning prompts.
//!  B. **alpha-threshold vs top-r stamping** — the paper argues the two are
//!     "two sides of the same coin" (§3.2); the grid shows they track.
//!  C. **Page size** 8/16/32 — granularity of eviction decisions.

use anyhow::Result;

use crate::config::{EngineConfig, PolicyKind};
use crate::kvcache::policy::make_policy;
use crate::sim::reasoning::{run_trials, SimParams};
use crate::sim::{DATASETS, MODELS};
use crate::util::cli::Args;
use crate::util::rng::Rng;

use super::common::{print_table, results_dir, write_csv};

/// Run the ablation sweep (`raas ablate`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let trials = args.usize_or("trials", 150);
    let budgets = args.usize_list_or("budgets", &[128, 256, 512]);
    let seed = args.u64_or("seed", 77);
    let dp = DATASETS[1];
    let mp = MODELS[1];
    let mut rows = Vec::new();

    // --- A: prefill pinning ---------------------------------------------------
    let mut tbl = Vec::new();
    for (label, pin) in [("raas (pinned prefill)", true), ("raas (no pinning)", false)] {
        let mut line = vec![label.to_string()];
        for &budget in &budgets {
            let cfg = EngineConfig { policy: PolicyKind::Raas, budget, ..Default::default() };
            let policy = make_policy(&cfg);
            let params = SimParams {
                budget_tokens: budget,
                pin_prefill: pin,
                ..Default::default()
            };
            let mut rng = Rng::new(seed ^ budget as u64 ^ pin as u64);
            let agg = run_trials(policy.as_ref(), &params, &mp, &dp, trials, &mut rng);
            line.push(format!("{:.3}", agg.accuracy));
            rows.push(vec![
                "pinning".into(),
                label.into(),
                budget.to_string(),
                format!("{:.3}", agg.accuracy),
                format!("{:.2}", agg.phoenix_miss_rate),
            ]);
        }
        tbl.push(line);
    }
    println!("Ablation A — prefill pinning (math500 persona, accuracy):");
    let mut headers = vec!["variant"];
    let bs: Vec<String> = budgets.iter().map(|b| b.to_string()).collect();
    headers.extend(bs.iter().map(|s| s.as_str()));
    print_table(&headers, &tbl);

    // --- B: alpha vs top-r stamping --------------------------------------------
    let mut tbl = Vec::new();
    for (label, alpha, frac) in [
        ("alpha = 1e-4", 1e-4, 0.5),
        ("top-r, r = 0.5", 0.0, 0.5),
        ("top-r, r = 0.25", 0.0, 0.25),
        ("top-r, r = 0.75", 0.0, 0.75),
    ] {
        let mut line = vec![label.to_string()];
        for &budget in &budgets {
            let cfg = EngineConfig {
                policy: PolicyKind::Raas,
                budget,
                alpha,
                stamp_fraction: frac,
                ..Default::default()
            };
            let policy = make_policy(&cfg);
            let params = SimParams { budget_tokens: budget, ..Default::default() };
            let mut rng = Rng::new(seed ^ budget as u64 ^ alpha.to_bits() ^ frac.to_bits());
            let agg = run_trials(policy.as_ref(), &params, &mp, &dp, trials, &mut rng);
            line.push(format!("{:.3}", agg.accuracy));
            rows.push(vec![
                "stamping".into(),
                label.into(),
                budget.to_string(),
                format!("{:.3}", agg.accuracy),
                format!("{:.2}", agg.milestone_miss_rate),
            ]);
        }
        tbl.push(line);
    }
    println!("\nAblation B — stamping rule (alpha threshold vs top-r fraction):");
    print_table(&headers, &tbl);

    // --- C: page size -----------------------------------------------------------
    let mut tbl = Vec::new();
    for page_size in [8usize, 16, 32] {
        let mut line = vec![format!("page_size = {page_size}")];
        for &budget in &budgets {
            let cfg = EngineConfig { policy: PolicyKind::Raas, budget, ..Default::default() };
            let policy = make_policy(&cfg);
            let params =
                SimParams { budget_tokens: budget, page_size, ..Default::default() };
            let mut rng = Rng::new(seed ^ budget as u64 ^ (page_size as u64) << 40);
            let agg = run_trials(policy.as_ref(), &params, &mp, &dp, trials, &mut rng);
            line.push(format!("{:.3}", agg.accuracy));
            rows.push(vec![
                "page_size".into(),
                page_size.to_string(),
                budget.to_string(),
                format!("{:.3}", agg.accuracy),
                format!("{:.2}", agg.milestone_miss_rate),
            ]);
        }
        tbl.push(line);
    }
    println!("\nAblation C — page size:");
    print_table(&headers, &tbl);

    let path = dir.join("ablation.csv");
    write_csv(&path, &["ablation", "variant", "budget", "accuracy", "miss_rate"], &rows)?;
    println!("\nwrote {path:?}");
    Ok(())
}
