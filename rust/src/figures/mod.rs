//! One module per figure of the paper's evaluation section.  Every command
//! writes `results/figN*.csv` (the data behind the figure) plus an ASCII
//! rendering, and prints the paper-vs-measured comparison recorded in
//! EXPERIMENTS.md.

pub mod common;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod ablate;
pub mod fig9;
