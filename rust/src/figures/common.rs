//! Shared figure-harness helpers: results directory, CSV writing, budgets.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Cache-budget sweep used by the figure commands unless `--budgets` is set.
pub const DEFAULT_BUDGETS: [usize; 5] = [64, 128, 256, 512, 1024];

/// Resolve (and create) the output directory — `results/` by default.
pub fn results_dir(custom: Option<String>) -> Result<PathBuf> {
    let dir = PathBuf::from(custom.unwrap_or_else(|| "results".to_string()));
    std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
    Ok(dir)
}

/// Write a CSV file with a header row.
pub fn write_csv(path: &Path, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Human-readable byte count with auto-scaled binary unit (B/KiB/MiB/GiB).
pub fn fmt_bytes(b: f64) -> String {
    if b < 1024.0 {
        format!("{b:.0} B")
    } else if b < 1024.0 * 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else if b < 1024.0 * 1024.0 * 1024.0 {
        format!("{:.2} MiB", b / (1024.0 * 1024.0))
    } else {
        format!("{:.2} GiB", b / (1024.0 * 1024.0 * 1024.0))
    }
}

/// Simple aligned table printer.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(headers.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for row in rows {
        line(row.clone());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("raas_fig_common_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        write_csv(&p, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bytes_fmt() {
        assert_eq!(fmt_bytes(512.0), "512 B");
        assert!(fmt_bytes(2048.0).contains("KiB"));
        assert!(fmt_bytes(3.0 * 1024.0 * 1024.0).contains("MiB"));
    }
}
