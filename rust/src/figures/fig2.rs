//! Figure 2: the "impossible trinity" comparison table — accuracy / time /
//! memory per algorithm.  Analytic complexity columns come from the policy
//! definitions; the *measured* columns are the log-log exponents fitted to
//! the Figure 7 series and the Figure 6 accuracy at budget 1024 (run those
//! first, or pass --analytic for the paper's table only).

use std::path::Path;

use anyhow::Result;

use crate::config::PolicyKind;
use crate::util::cli::Args;
use crate::util::stats::loglog_slope;

use super::common::{print_table, results_dir};

struct RowSpec {
    kind: PolicyKind,
    time: &'static str,
    memory: &'static str,
    accuracy: &'static str,
    note: &'static str,
}

const ANALYTIC: [RowSpec; 5] = [
    RowSpec { kind: PolicyKind::Dense, time: "O(N)", memory: "O(N)", accuracy: "high",
              note: "reference" },
    RowSpec { kind: PolicyKind::Sink, time: "O(L)", memory: "O(L)", accuracy: "low",
              note: "drops milestones" },
    RowSpec { kind: PolicyKind::H2o, time: "O(L)*", memory: "O(L)*", accuracy: "low",
              note: "* theoretical; stale heavy hitters" },
    RowSpec { kind: PolicyKind::Quest, time: "O(L)", memory: "O(N)", accuracy: "high",
              note: "retains all KV" },
    RowSpec { kind: PolicyKind::Raas, time: "O(L)", memory: "O(L)", accuracy: "high",
              note: "this paper" },
];

/// Run the Figure-2 command (`raas fig2`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let fig7 = dir.join("fig7.csv");
    let fig6 = dir.join("fig6.csv");

    let mut rows = Vec::new();
    for spec in &ANALYTIC {
        let (lat_slope, mem_slope) = measured_slopes(&fig7, spec.kind)
            .map(|(l, m)| (format!("{l:.2}"), format!("{m:.2}")))
            .unwrap_or_else(|| ("-".into(), "-".into()));
        let acc = measured_accuracy(&fig6, spec.kind)
            .map(|a| format!("{a:.2}"))
            .unwrap_or_else(|| "-".into());
        rows.push(vec![
            spec.kind.name().to_string(),
            spec.time.to_string(),
            spec.memory.to_string(),
            spec.accuracy.to_string(),
            lat_slope,
            mem_slope,
            acc,
            spec.note.to_string(),
        ]);
    }
    println!("Figure 2: sparsity-algorithm comparison (paper analytic + this repo measured)");
    print_table(
        &["algorithm", "time", "memory", "acc (paper)", "lat exp*", "mem exp*",
          "acc@1024 (sim)", "note"],
        &rows,
    );
    println!("* fitted log-log exponents from results/fig7.csv (run `raas fig7`);");
    println!("  accuracy from results/fig6.csv (run `raas fig6`).  Latency exponent is");
    println!("  for TOTAL decode time: O(L)/step ⇒ ≈1, O(N)/step ⇒ ≈2.");
    Ok(())
}

/// (latency slope, memory slope) for one policy from fig7.csv, if present.
fn measured_slopes(path: &Path, kind: PolicyKind) -> Option<(f64, f64)> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut xs = Vec::new();
    let mut lat = Vec::new();
    let mut mem = Vec::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() == 4 && f[0] == kind.name() {
            xs.push(f[1].parse::<f64>().ok()?);
            lat.push(f[2].parse::<f64>().ok()?);
            mem.push(f[3].parse::<f64>().ok()?);
        }
    }
    if xs.len() < 2 {
        return None;
    }
    Some((loglog_slope(&xs, &lat), loglog_slope(&xs, &mem)))
}

/// Mean accuracy at the largest budget for one policy from fig6.csv.
fn measured_accuracy(path: &Path, kind: PolicyKind) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut max_budget = 0usize;
    let mut rows: Vec<(usize, f64)> = Vec::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() >= 5 && f[2] == kind.name() {
            let b = f[3].parse::<usize>().ok()?;
            let a = f[4].parse::<f64>().ok()?;
            max_budget = max_budget.max(b);
            rows.push((b, a));
        }
    }
    let accs: Vec<f64> = rows.iter().filter(|(b, _)| *b == max_budget).map(|(_, a)| *a).collect();
    if accs.is_empty() {
        return None;
    }
    Some(accs.iter().sum::<f64>() / accs.len() as f64)
}
