//! Figure 8: decode-length inflation when milestone tokens are discarded —
//! H2O-128 / Sink-128 derail, re-reason and hit the 4k cap; Dense/Quest/RaaS
//! do not.  Plus the qualitative derailment demo on the real model.

use anyhow::Result;

use crate::config::{EngineConfig, PolicyKind};
use crate::engine::{Engine, GenOptions};
use crate::kvcache::policy::make_policy;
use crate::sim::reasoning::{run_trials, SimParams};
use crate::sim::{DATASETS, MODELS};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::workload::Problem;

use super::common::{print_table, results_dir, write_csv};

/// Run the Figure-8 command (`raas fig8`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let trials = args.usize_or("trials", 200);
    let cap = args.usize_or("max-decode", 4096);
    let seed = args.u64_or("seed", 8);

    // paper setup: five configurations on MATH500 with a 4k context cap
    let configs: [(&str, PolicyKind, usize); 5] = [
        ("dense", PolicyKind::Dense, usize::MAX / 2),
        ("quest-1024", PolicyKind::Quest, 1024),
        ("raas-1024", PolicyKind::Raas, 1024),
        ("h2o-128", PolicyKind::H2o, 128),
        ("sink-128", PolicyKind::Sink, 128),
    ];
    let dp = DATASETS[1]; // math500
    let mp = MODELS[1]; // qwen-math persona

    let mut rows = Vec::new();
    let mut tbl = Vec::new();
    for (name, kind, budget) in configs {
        let cfg = EngineConfig { policy: kind, budget, ..Default::default() };
        let policy = make_policy(&cfg);
        let params = SimParams { budget_tokens: budget, max_decode: cap, ..Default::default() };
        let mut rng = Rng::new(seed ^ (budget as u64));
        let agg = run_trials(policy.as_ref(), &params, &mp, &dp, trials, &mut rng);
        rows.push(vec![
            name.to_string(),
            format!("{:.1}", agg.mean_decode_len),
            format!("{:.3}", agg.cap_rate),
            format!("{:.3}", agg.accuracy),
            format!("{:.2}", agg.milestone_miss_rate),
        ]);
        tbl.push(vec![
            name.to_string(),
            format!("{:.0}", agg.mean_decode_len),
            format!("{:.0}%", 100.0 * agg.cap_rate),
            format!("{:.2}", agg.milestone_miss_rate),
        ]);
    }
    let path = dir.join("fig8.csv");
    write_csv(&path, &["config", "mean_decode_len", "cap_rate", "accuracy",
                       "milestone_miss_rate"], &rows)?;
    println!("wrote {path:?}");
    println!("Figure 8: decode lengths on math500 (cap {cap})");
    print_table(&["config", "mean decode len", "hits 4k cap", "milestone misses/req"], &tbl);
    println!("paper shape check: H2O-128/Sink-128 inflate decode length and hit the");
    println!("cap; Dense/Quest-1024/RaaS-1024 stay near the natural chain length.\n");

    if args.switch("demo") {
        demo_real_model(args)?;
    } else {
        println!("(run with --demo and built artifacts for the real-model derailment sample)");
    }
    Ok(())
}

/// Right panel of Figure 8: decode a real problem under a milestone-hostile
/// policy and show the derailment in the token stream.
fn demo_real_model(args: &Args) -> Result<()> {
    let mut cfg = EngineConfig::from_args(args)?;
    cfg.policy = PolicyKind::Sink;
    cfg.budget = 64;
    let mut engine = Engine::new_with_capacities(cfg, &[64, 256, 2048])?;
    let spec = engine.meta.corpus.clone();
    let mut rng = Rng::new(args.u64_or("seed", 8));
    let p = Problem::sample(&mut rng, &spec, Some(spec.max_steps));
    let prompt = p.encode_prompt(&spec);
    let opts =
        GenOptions { max_new: spec.max_decode_tokens(spec.max_steps), ..Default::default() };
    let out = engine.generate(&prompt, &opts)?;
    println!("prompt:   {}", engine.tokenizer.decode(&prompt));
    println!("expected: {}", engine.tokenizer.decode(&p.encode_decode(&spec)));
    println!("sink-64:  {}", engine.tokenizer.decode(&out.tokens));
    let got = engine.tokenizer.parse_answer(&out.tokens);
    println!(
        "answer: expected {} got {:?} — decode len {} (expected {})",
        p.answer(),
        got,
        out.tokens.len(),
        p.encode_decode(&spec).len()
    );
    Ok(())
}
