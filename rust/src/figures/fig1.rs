//! Figure 1: (a) prefill/decode length CDFs for long-prefill (LongBench)
//! datasets; (b) the same for math reasoning datasets; (c) prefill-vs-decode
//! time breakdown at a fixed total length, measured on the real engine.

use anyhow::Result;

use crate::config::EngineConfig;
use crate::engine::{Engine, GenOptions};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::{ascii_plot, cdf_points};
use crate::workload::{LengthProfile, Problem, LONGBENCH, MATH};

use super::common::{print_table, results_dir, write_csv};

/// Run the Figure-1 command (`raas fig1`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let n = args.usize_or("samples", 2000);
    let seed = args.u64_or("seed", 1);
    let measure = args.switch("measure");

    // -- (a)/(b): length CDFs ------------------------------------------------
    for (panel, profiles) in [("a", &LONGBENCH[..]), ("b", &MATH[..])] {
        let mut rows = Vec::new();
        let mut series_store: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
        for p in profiles {
            let mut rng = Rng::new(seed);
            let prefills: Vec<f64> =
                (0..n).map(|_| p.sample_prefill(&mut rng) as f64).collect();
            let decodes: Vec<f64> =
                (0..n).map(|_| p.sample_decode(&mut rng) as f64).collect();
            for (kind, samples) in [("prefill", &prefills), ("decode", &decodes)] {
                let pts = cdf_points(samples);
                // decimate for the CSV
                for (x, y) in pts.iter().step_by((pts.len() / 64).max(1)) {
                    rows.push(vec![
                        p.name.to_string(),
                        kind.to_string(),
                        format!("{x:.0}"),
                        format!("{y:.4}"),
                    ]);
                }
                series_store.push((
                    format!("{}-{}", p.name, &kind[..1].to_uppercase()),
                    pts.iter()
                        .step_by((pts.len() / 48).max(1))
                        .map(|&(x, y)| (x.max(1.0).log2(), y))
                        .collect(),
                ));
            }
        }
        let path = dir.join(format!("fig1{panel}.csv"));
        write_csv(&path, &["dataset", "phase", "tokens", "cdf"], &rows)?;
        println!("wrote {path:?}");
        let series: Vec<(&str, &[(f64, f64)])> = series_store
            .iter()
            .map(|(n, p)| (n.as_str(), p.as_slice()))
            .collect();
        println!(
            "{}",
            ascii_plot(
                &format!("Figure 1({panel}): token-length CDF (x = log2 tokens)"),
                &series,
                72,
                14
            )
        );
    }
    println!("paper shape check: reasoning datasets (b) have prefill ≪ decode;");
    println!("RAG datasets (a) the opposite.\n");

    // -- (c): measured prefill/decode time breakdown -------------------------
    if measure {
        measure_breakdown(args, &dir)?;
    } else {
        println!("(run with --measure and built artifacts for Figure 1(c))");
    }
    Ok(())
}

/// Figure 1(c): fixed total token count, sweep the prefill/decode split and
/// measure where the time goes (paper: decode dominates as its share grows;
/// total 32k on an A100 → scaled to the CPU testbed by --total).
fn measure_breakdown(args: &Args, dir: &std::path::Path) -> Result<()> {
    let total = args.usize_or("total", 768);
    let cfg = EngineConfig::from_args(args)?;
    let mut cfg = cfg;
    cfg.policy = crate::config::PolicyKind::Dense;
    let mut engine = Engine::new(cfg)?;
    let spec = engine.meta.corpus.clone();
    let mut rng = Rng::new(args.u64_or("seed", 1));

    let mut rows = Vec::new();
    let mut display = Vec::new();
    for frac in [1, 2, 3, 4, 5, 6] {
        let decode = total * frac / 8;
        let prefill_target = total - decode;
        // synth a prompt of the right length: repeat problem prompts
        let mut prompt = Vec::new();
        while prompt.len() < prefill_target {
            let p = Problem::sample(&mut rng, &spec, None);
            prompt.extend(p.encode_prompt(&spec));
        }
        prompt.truncate(prefill_target);
        let out = engine.generate(
            &prompt,
            &GenOptions { max_new: decode, force_len: Some(decode), ..Default::default() },
        )?;
        rows.push(vec![
            prefill_target.to_string(),
            decode.to_string(),
            format!("{:.3}", out.prefill_secs),
            format!("{:.3}", out.decode_secs),
        ]);
        display.push(vec![
            format!("{prefill_target}+{decode}"),
            format!("{:.2}s", out.prefill_secs),
            format!("{:.2}s", out.decode_secs),
            format!("{:.0}%", 100.0 * out.decode_secs / (out.decode_secs + out.prefill_secs)),
        ]);
    }
    let path = dir.join("fig1c.csv");
    write_csv(&path, &["prefill_tokens", "decode_tokens", "prefill_secs", "decode_secs"], &rows)?;
    println!("wrote {path:?}");
    println!("Figure 1(c): time breakdown at fixed total = {total} tokens (dense)");
    print_table(&["prefill+decode", "prefill time", "decode time", "decode share"], &display);
    println!("paper shape check: decode share rises sharply with decode fraction.");
    Ok(())
}

/// Expose profiles for tests.
pub fn all_profiles() -> Vec<LengthProfile> {
    LONGBENCH.iter().chain(MATH.iter()).copied().collect()
}
