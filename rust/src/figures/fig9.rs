//! Figure 9: RaaS accuracy across alpha ∈ {1e-2 … 1e-5} × cache budgets —
//! the timestamp threshold sweet spot (paper: alpha ≈ 1e-4).

use anyhow::Result;

use crate::config::{EngineConfig, PolicyKind};
use crate::kvcache::policy::make_policy;
use crate::sim::reasoning::{run_trials, SimParams};
use crate::sim::{DATASETS, MODELS};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::ascii_plot;

use super::common::{print_table, results_dir, write_csv, DEFAULT_BUDGETS};

/// Run the Figure-9 command (`raas fig9`): see the module docs.
pub fn run(args: &Args) -> Result<()> {
    let dir = results_dir(args.str_opt("out"))?;
    let trials = args.usize_or("trials", 200);
    let budgets = args.usize_list_or("budgets", &DEFAULT_BUDGETS);
    let seed = args.u64_or("seed", 9);
    let alphas = [1e-2, 1e-3, 1e-4, 1e-5];
    let dp = DATASETS[1]; // math500
    let mp = MODELS[1];

    let mut rows = Vec::new();
    let mut series_store: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    let mut tbl = Vec::new();
    for alpha in alphas {
        let mut pts = Vec::new();
        for &budget in &budgets {
            let cfg = EngineConfig {
                policy: PolicyKind::Raas,
                budget,
                alpha,
                ..Default::default()
            };
            let policy = make_policy(&cfg);
            let params =
                SimParams { budget_tokens: budget, max_decode: 4096, ..Default::default() };
            let mut rng = Rng::new(seed ^ (budget as u64) ^ alpha.to_bits());
            let agg = run_trials(policy.as_ref(), &params, &mp, &dp, trials, &mut rng);
            rows.push(vec![
                format!("{alpha:e}"),
                budget.to_string(),
                format!("{:.3}", agg.accuracy),
                format!("{:.2}", agg.milestone_miss_rate),
            ]);
            pts.push((budget as f64, agg.accuracy));
        }
        tbl.push({
            let mut row = vec![format!("{alpha:e}")];
            row.extend(pts.iter().map(|(_, a)| format!("{a:.3}")));
            row
        });
        series_store.push((format!("a={alpha:e}"), pts));
    }
    let path = dir.join("fig9.csv");
    write_csv(&path, &["alpha", "budget", "accuracy", "milestone_miss_rate"], &rows)?;
    println!("wrote {path:?}");
    println!("Figure 9: RaaS accuracy vs alpha (math500 persona)");
    let mut headers = vec!["alpha"];
    let budget_strs: Vec<String> = budgets.iter().map(|b| b.to_string()).collect();
    headers.extend(budget_strs.iter().map(|s| s.as_str()));
    print_table(&headers, &tbl);
    let series: Vec<(&str, &[(f64, f64)])> =
        series_store.iter().map(|(n, p)| (n.as_str(), p.as_slice())).collect();
    println!("{}", ascii_plot("RaaS accuracy vs budget per alpha", &series, 64, 12));
    println!("paper shape check: mid-range alpha (≈1e-4 … 1e-3) dominates; very large");
    println!("alpha unstamps live milestones, very small alpha stamps everything (FIFO).");
    Ok(())
}
