//! Deterministic PRNG + distributions (the `rand` crate is unavailable).
//!
//! PCG32 (Melissa O'Neill's `pcg32_srandom_r`/`pcg32_random_r`), seeded via
//! SplitMix64.  Everything in the repo that needs randomness (workload
//! generation, trace simulation, property tests) goes through this so runs
//! are reproducible from a single `--seed`.

/// PCG32 generator with SplitMix64 seeding (see module docs).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator (any `u64` is a valid seed).
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state: 0, inc: init_inc };
        rng.state = init_state.wrapping_add(init_inc);
        rng.next_u32();
        rng
    }

    /// Independent stream derived from this one (for per-request RNGs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 32-bit draw (the core PCG32 step).
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit draw (two 32-bit draws).
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + (self.f64() * (hi - lo) as f64) as usize
    }

    /// Uniform integer in [lo, hi) over `i64` — panics if lo >= hi.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + (self.f64() * (hi - lo) as f64) as i64
    }

    /// Bernoulli draw: true with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with given underlying mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate lambda (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Zipf-like draw over ranks 1..=n with exponent s (approximate, via
    /// rejection-free inverse CDF on the harmonic weights).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n >= 1);
        let target = self.f64() * harmonic(n, s);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            if acc >= target {
                return k;
            }
        }
        n
    }

    /// Index draw from unnormalised weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.range(0, i + 1);
            v.swap(i, j);
        }
    }

    /// Uniform element draw — panics on an empty slice.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.range(0, v.len())]
    }
}

fn harmonic(n: usize, s: f64) -> f64 {
    (1..=n).map(|k| 1.0 / (k as f64).powf(s)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.range(5, 9);
            assert!((5..9).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn zipf_rank1_most_common() {
        let mut r = Rng::new(17);
        let mut counts = [0usize; 10];
        for _ in 0..5_000 {
            counts[r.zipf(10, 1.1) - 1] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[0] > counts[9]);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(19);
        let mut hits = [0usize; 3];
        for _ in 0..9_000 {
            hits[r.categorical(&[1.0, 0.0, 2.0])] += 1;
        }
        assert_eq!(hits[1], 0);
        assert!(hits[2] > hits[0]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
