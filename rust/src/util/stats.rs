//! Summary statistics, histograms, CDFs and log-log fits.
//!
//! The log-log slope fit is what turns the Figure-7 latency/memory series
//! into the *measured complexity exponents* reported in the Figure-2 table
//! (O(L) ⇒ slope ≈ 0 in N; O(N) ⇒ slope ≈ 1; O(N²) total ⇒ slope ≈ 2).

/// Streaming summary over f64 samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    /// An empty summary.
    pub fn new() -> Self {
        Self::default()
    }
    /// Record one sample.
    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }
    /// Record every sample from an iterator.
    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        self.samples.extend(xs);
    }
    /// Number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples.len()
    }
    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }
    /// Sample standard deviation (Bessel-corrected; 0 below 2 samples).
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64).sqrt()
    }
    /// Smallest sample (+inf when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }
    /// Largest sample (-inf when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
    /// p in [0, 100]; nearest-rank on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }
    /// Raw samples in insertion order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Empirical CDF as (x, fraction <= x) points, for the Figure-1 plots.
pub fn cdf_points(samples: &[f64]) -> Vec<(f64, f64)> {
    let mut sorted: Vec<f64> = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    sorted
        .into_iter()
        .enumerate()
        .map(|(i, x)| (x, (i + 1) as f64 / n as f64))
        .collect()
}

/// Least-squares fit of y = a + b*x.  Returns (a, b, r2).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    let b = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let a = my - b * mx;
    let r2 = if sxx > 0.0 && syy > 0.0 { (sxy * sxy) / (sxx * syy) } else { 1.0 };
    (a, b, r2)
}

/// Slope of log(y) vs log(x): the empirical complexity exponent.
/// Points with non-positive x or y are skipped.
pub fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let pts: Vec<(f64, f64)> = xs
        .iter()
        .zip(ys)
        .filter(|(x, y)| **x > 0.0 && **y > 0.0)
        .map(|(x, y)| (x.ln(), y.ln()))
        .collect();
    if pts.len() < 2 {
        return 0.0;
    }
    let lx: Vec<f64> = pts.iter().map(|p| p.0).collect();
    let ly: Vec<f64> = pts.iter().map(|p| p.1).collect();
    linear_fit(&lx, &ly).1
}

/// Fixed-width histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Inclusive lower bound of the binned range.
    pub lo: f64,
    /// Exclusive upper bound of the binned range.
    pub hi: f64,
    /// Per-bin counts over `[lo, hi)`, equal width.
    pub bins: Vec<usize>,
    /// Samples below `lo`.
    pub underflow: usize,
    /// Samples at or above `hi`.
    pub overflow: usize,
}

impl Histogram {
    /// `n_bins` equal-width bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Histogram { lo, hi, bins: vec![0; n_bins], underflow: 0, overflow: 0 }
    }
    /// Count one sample into its bin (or under/overflow).
    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }
    /// Total samples counted, including under/overflow.
    pub fn total(&self) -> usize {
        self.bins.iter().sum::<usize>() + self.underflow + self.overflow
    }
}

/// Render an ASCII line plot (one series) — used for terminal figure output.
pub fn ascii_plot(
    title: &str,
    series: &[(&str, &[(f64, f64)])],
    width: usize,
    height: usize,
) -> String {
    let mut out = String::new();
    let all: Vec<(f64, f64)> = series.iter().flat_map(|(_, pts)| pts.iter().copied()).collect();
    if all.is_empty() {
        return format!("{title}: (no data)\n");
    }
    let (xmin, xmax) = all.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), p| {
        (lo.min(p.0), hi.max(p.0))
    });
    let (ymin, ymax) = all.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), p| {
        (lo.min(p.1), hi.max(p.1))
    });
    let xspan = (xmax - xmin).max(1e-12);
    let yspan = (ymax - ymin).max(1e-12);
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        for (x, y) in pts.iter() {
            let cx = (((x - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let cy = (((y - ymin) / yspan) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("  y: [{ymin:.3} .. {ymax:.3}]\n"));
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("  +{}\n", "-".repeat(width)));
    out.push_str(&format!("  x: [{xmin:.1} .. {xmax:.1}]   legend: "));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("{}={} ", marks[si % marks.len()], name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        s.extend([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
    }

    #[test]
    fn summary_std() {
        let mut s = Summary::new();
        s.extend([2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((s.std() - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn cdf_monotone() {
        let pts = cdf_points(&[3.0, 1.0, 2.0]);
        assert_eq!(pts[0], (1.0, 1.0 / 3.0));
        assert_eq!(pts[2], (3.0, 1.0));
    }

    #[test]
    fn fit_exact_line() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [3.0, 5.0, 7.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn loglog_exponent() {
        // y = x^2
        let xs: Vec<f64> = (1..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| x * x).collect();
        assert!((loglog_slope(&xs, &ys) - 2.0).abs() < 1e-9);
        // constant ⇒ slope 0
        let ys0 = vec![5.0; xs.len()];
        assert!(loglog_slope(&xs, &ys0).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(100.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn ascii_plot_renders() {
        let pts = [(0.0, 0.0), (1.0, 1.0)];
        let s = ascii_plot("t", &[("a", &pts)], 20, 5);
        assert!(s.contains('*'));
        assert!(s.contains("legend"));
    }
}
