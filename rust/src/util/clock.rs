//! Injectable serving clock (DESIGN.md §6): deadline expiry, breaker
//! backoff and watchdog heartbeats all read time through [`Clock`], so the
//! robustness tests drive a [`SimClock`] deterministically while `main`
//! serves on the real [`WallClock`].  Durations are plain milliseconds —
//! a monotonic `u64` is atomically publishable (heartbeat stamps cross
//! threads lock-free) where `std::time::Instant` is not.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Monotonic millisecond clock.  Implementations must be cheap — the
/// batcher reads it on every admission tick.
pub trait Clock: Send + Sync {
    /// Milliseconds since this clock's origin (process start for
    /// [`WallClock`], zero for a fresh [`SimClock`]).
    fn now_ms(&self) -> u64;
}

/// Shared handle to a clock; replicas, router and supervisor must read the
/// same one or deadline/heartbeat comparisons are meaningless.
pub type SharedClock = Arc<dyn Clock>;

/// Real time, measured from a process-wide origin so every `WallClock`
/// reads the same timeline.
#[derive(Debug, Default, Clone, Copy)]
pub struct WallClock;

static WALL_ORIGIN: OnceLock<Instant> = OnceLock::new();

impl WallClock {
    /// The process-wide shared wall clock.
    pub fn shared() -> SharedClock {
        WALL_ORIGIN.get_or_init(Instant::now);
        Arc::new(WallClock)
    }
}

impl Clock for WallClock {
    fn now_ms(&self) -> u64 {
        WALL_ORIGIN.get_or_init(Instant::now).elapsed().as_millis() as u64
    }
}

/// Manually-advanced test clock: time moves only when the test says so,
/// making deadline expiry, breaker reopen and hang detection exact.
#[derive(Debug, Default)]
pub struct SimClock {
    ms: AtomicU64,
}

impl SimClock {
    /// Fresh sim clock at t = 0, ready to share across threads.
    pub fn new() -> Arc<SimClock> {
        Arc::new(SimClock { ms: AtomicU64::new(0) })
    }

    /// Advance by `ms` milliseconds.
    pub fn advance(&self, ms: u64) {
        self.ms.fetch_add(ms, Ordering::SeqCst);
    }

    /// Jump to an absolute time (must not move backwards in tests that
    /// care about monotonicity; the clock itself does not enforce it).
    pub fn set(&self, ms: u64) {
        self.ms.store(ms, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now_ms(&self) -> u64 {
        self.ms.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_moves_only_on_demand() {
        let c = SimClock::new();
        assert_eq!(c.now_ms(), 0);
        c.advance(250);
        assert_eq!(c.now_ms(), 250);
        c.set(1000);
        assert_eq!(c.now_ms(), 1000);
        let shared: SharedClock = c.clone();
        assert_eq!(shared.now_ms(), 1000);
    }

    #[test]
    fn wall_clock_is_monotonic_and_shared() {
        let a = WallClock::shared();
        let b = WallClock::shared();
        let t0 = a.now_ms();
        let t1 = b.now_ms();
        assert!(t1 >= t0, "two WallClock handles must share one origin");
    }
}
