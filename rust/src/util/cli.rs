//! Tiny argument parser (clap is unavailable offline).
//!
//! Grammar: `raas <subcommand> [--flag value | --switch] ...`
//! Values are typed on access; unknown flags are rejected by `finish()`.

use std::collections::BTreeMap;

/// Parsed command line: an optional subcommand plus `--flag value` pairs
/// and bare `--switch`es, with access tracking for the typo guard.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First argument when it does not start with `--`.
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    accessed: std::cell::RefCell<Vec<String>>,
}

/// Parse/validation failure with a human-readable message.
#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cli error: {}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse an argv iterator (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(name) = tok.strip_prefix("--") else {
                return Err(CliError(format!("unexpected positional argument '{tok}'")));
            };
            if let Some((k, v)) = name.split_once('=') {
                args.flags.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                args.flags.insert(name.to_string(), it.next().unwrap());
            } else {
                args.switches.push(name.to_string());
            }
        }
        Ok(args)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    fn note(&self, key: &str) {
        self.accessed.borrow_mut().push(key.to_string());
    }

    /// String flag, `None` when absent.
    pub fn str_opt(&self, key: &str) -> Option<String> {
        self.note(key);
        self.flags.get(key).cloned()
    }
    /// String flag with a default.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or_else(|| default.to_string())
    }
    /// `usize` flag with a default (unparseable values fall back too).
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.note(key);
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    /// `usize` flag, `None` when absent or unparseable.
    pub fn usize_opt(&self, key: &str) -> Option<usize> {
        self.note(key);
        self.flags.get(key).and_then(|v| v.parse().ok())
    }
    /// `f64` flag with a default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.note(key);
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    /// `u64` flag with a default.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.note(key);
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    /// Whether a bare `--switch` was passed.
    pub fn switch(&self, key: &str) -> bool {
        self.note(key);
        self.switches.iter().any(|s| s == key)
    }
    /// Comma-separated list flag: `--budgets 64,128,256`.
    pub fn usize_list_or(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.note(key);
        match self.flags.get(key) {
            Some(v) => v.split(',').filter_map(|t| t.trim().parse().ok()).collect(),
            None => default.to_vec(),
        }
    }
    /// Comma-separated string list flag with a default.
    pub fn str_list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        self.note(key);
        match self.flags.get(key) {
            Some(v) => v.split(',').map(|t| t.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Reject flags that were provided but never accessed (typo guard).
    pub fn finish(&self) -> Result<(), CliError> {
        let seen = self.accessed.borrow();
        for k in self.flags.keys().chain(self.switches.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(CliError(format!("unknown flag '--{k}'")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["fig7", "--budget", "256", "--policy=raas", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig7"));
        assert_eq!(a.usize_or("budget", 0), 256);
        assert_eq!(a.str_or("policy", ""), "raas");
        assert!(a.switch("verbose"));
        assert!(!a.switch("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn lists() {
        let a = parse(&["x", "--budgets", "64,128, 256"]);
        assert_eq!(a.usize_list_or("budgets", &[]), vec![64, 128, 256]);
        assert_eq!(a.usize_list_or("other", &[1]), vec![1]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert!(a.subcommand.is_none());
        assert_eq!(a.f64_or("alpha", 1e-4), 1e-4);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["x", "--oops", "1"]);
        let _ = a.usize_or("fine", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn positional_rejected() {
        assert!(Args::parse(["x".to_string(), "stray".to_string()]).is_err());
    }
}
