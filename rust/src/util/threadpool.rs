//! Fixed-size worker pool over std::sync::mpsc (tokio is unavailable; the
//! coordinator's replicas and the router run on these threads instead —
//! DESIGN.md §3 documents the substitution).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Spawn a single named thread (replica threads carry their name into
/// panic messages and debugger output).
pub fn spawn_named(name: String, f: impl FnOnce() + Send + 'static) -> JoinHandle<()> {
    std::thread::Builder::new().name(name).spawn(f).expect("spawn thread")
}

/// Fixed-size worker pool; dropping it joins every worker.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (panics if `n == 0`).
    pub fn new(n: usize) -> Self {
        assert!(n > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("raas-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Queue a job for the next free worker (fire-and-forget).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().expect("pool closed").send(Box::new(f)).expect("workers alive");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        let n = items.len();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }
}
