//! Minimal JSON value, parser and serializer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! stored as `f64` (adequate for configs, metadata and result files).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value (numbers are `f64`; object keys are sorted).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// A string (escapes resolved).
    Str(String),
    /// An ordered array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` makes serialization deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------
    /// Object member by key (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Array element by index (`None` for non-arrays / out of range).
    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(idx),
            _ => None,
        }
    }
    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The number truncated to `i64`, if this is a number.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    /// The number truncated to `usize`, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// The member map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")`
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------
    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    /// Build an array from any value iterator.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    /// Build a number from anything convertible to `f64`.
    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }
    /// Build a string value.
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}

/// Parse failure: what went wrong and where.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Description of the failure.
    pub msg: String,
    /// Byte offset into the input where parsing stopped.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }
    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }
    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }
    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let s = std::str::from_utf8(&self.b[start..self.i])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }
    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }
    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().at(2).unwrap().get("b").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":{"layers":4,"dims":[1,2,3]},"name":"raas \"q\"","ok":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
