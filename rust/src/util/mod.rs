//! In-tree substrates for facilities that would normally come from crates
//! (serde, clap, rand, criterion, …) — this environment is offline and only
//! the `xla` crate's dependency closure is available (see DESIGN.md §3).

pub mod cli;
pub mod clock;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
