//! Per-sequence cache state: one page table (+ representative bounds) per
//! layer, backed by the shared pool.
//!
//! The page table is the logical→physical mapping (DESIGN.md §2): a
//! sequence owns its `PageMeta` entries, never the physical pages they
//! point at.  Several sequences may map the same physical page
//! ([`SeqCache::fork`], prefix-cache attachment); the first divergent
//! append to a shared page copy-on-writes it through
//! [`super::pool::KvPool::cow_page`] and swaps the mapping in place.

use anyhow::{bail, Result};

use super::page::{page_probs, PageId, PageMeta, PageView, RepBounds};
use super::pool::KvPool;

/// One layer's view of a sequence's cache.
#[derive(Debug, Default)]
pub struct LayerCache {
    /// Resident pages in position order.  The final page is the active one.
    pub table: Vec<PageMeta>,
    /// Quest-style representative bounds, aligned with `table`.
    pub reps: Vec<RepBounds>,
}

impl LayerCache {
    /// Tokens held across this layer's resident pages.
    pub fn resident_tokens(&self) -> usize {
        self.table.iter().map(|p| p.len).sum()
    }

    /// Raw upper-bound scores for every resident page given this step's q.
    pub fn rep_scores(&self, q: &[f32], n_heads: usize, n_kv: usize, head_dim: usize,
                      out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.reps.iter().map(|r| r.score(q, n_heads, n_kv, head_dim)));
    }

    /// Page-major per-head upper-bound scores for every resident page
    /// (`[table.len() * n_heads]`) — the unified-selection feed
    /// ([`super::policy::SparsityPolicy::select_unified_into`]).  Reducing
    /// with [`super::page::reduce_head_scores_max`] recovers
    /// [`LayerCache::rep_scores`] bitwise.
    pub fn rep_scores_heads(&self, q: &[f32], n_heads: usize, n_kv: usize, head_dim: usize,
                            out: &mut Vec<f32>) {
        out.clear();
        for r in &self.reps {
            r.score_heads_into(q, n_heads, n_kv, head_dim, out);
        }
    }

    /// Softmaxed pseudo-probabilities (what RaaS thresholds against alpha).
    pub fn rep_probs(&self, scores: &[f32], head_dim: usize, out: &mut Vec<f32>) {
        page_probs(scores, head_dim, out);
    }
}

/// All layers of one sequence.
#[derive(Debug)]
pub struct SeqCache {
    /// One page table (+ rep bounds) per layer, position order.
    pub layers: Vec<LayerCache>,
    /// Tokens appended so far (= next absolute position).
    pub n_tokens: usize,
    /// Prompt length, stamped when prefill completes (0 before).
    pub prompt_len: usize,
    /// Prompt tokens attached from the pool's prefix cache at sequence
    /// start (0 when the sequence prefilled cold).  The admission layer
    /// reads this to avoid charging cached tokens against the prefill
    /// budget — the prefix-cache TTFT win.
    pub prefix_cached_tokens: usize,
    page_size: usize,
    kv_dim: usize,
}

impl SeqCache {
    /// Empty cache for an `n_layers` model over `page_size`-token pages.
    pub fn new(n_layers: usize, page_size: usize, kv_dim: usize) -> Self {
        SeqCache {
            layers: (0..n_layers).map(|_| LayerCache::default()).collect(),
            n_tokens: 0,
            prompt_len: 0,
            prefix_cached_tokens: 0,
            page_size,
            kv_dim,
        }
    }

    /// Slots per page, in tokens.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Fork this sequence: copy the logical→physical page tables (and rep
    /// bounds) only, retaining every mapped physical page — no slab bytes
    /// move.  Both sequences then share pages until one appends into a
    /// shared page, which copy-on-writes just that page
    /// ([`SeqCache::append_slots`]).  The fork decodes bit-identically to
    /// an independently prefilled sequence (tokens, score logs, slab
    /// contents — pool ids excepted, pinned by the bit-identity suites).
    pub fn fork(&self, pool: &mut KvPool) -> SeqCache {
        let layers = self
            .layers
            .iter()
            .map(|lc| {
                for p in &lc.table {
                    pool.retain(p.pool_id);
                }
                LayerCache { table: lc.table.clone(), reps: lc.reps.clone() }
            })
            .collect();
        SeqCache {
            layers,
            n_tokens: self.n_tokens,
            prompt_len: self.prompt_len,
            prefix_cached_tokens: self.prefix_cached_tokens,
            page_size: self.page_size,
            kv_dim: self.kv_dim,
        }
    }

    /// Map one already-resident physical page (a prefix-cache hit) into
    /// `layer` at the current append position: retain the page, push a
    /// full pinned-or-not `PageMeta` (stamp 0, exactly what a fresh
    /// prefill append would have produced) plus the cached rep bounds.
    /// The caller advances `n_tokens` once every layer attached.
    pub fn attach_shared_page(&mut self, layer: usize, pool: &mut KvPool, id: PageId,
                              rep: &RepBounds, pinned: bool) -> Result<()> {
        let lc = &mut self.layers[layer];
        let start_pos = lc.table.last().map_or(0, |p| p.end_pos());
        if start_pos % self.page_size != 0 {
            bail!("prefix attach at layer {layer}: position {start_pos} is not page-aligned");
        }
        pool.retain(id);
        let mut meta = PageMeta::new(id, start_pos, pinned, 0);
        meta.len = self.page_size;
        lc.table.push(meta);
        lc.reps.push(rep.clone());
        Ok(())
    }

    /// Append one token's K/V to `layer` at absolute position `pos`.
    /// A new page is opened when the active page is full, or at the
    /// prefill/decode boundary (so pinning stays page-aligned).
    pub fn append(&mut self, layer: usize, pool: &mut KvPool, pos: usize,
                  k: &[f32], v: &[f32], pinned: bool, now: u64) -> Result<()> {
        self.append_slots(layer, pool, pos, 1, k, v, pinned, now)
    }

    /// Bulk append of `n` contiguous tokens' K/V (`k`/`v` of
    /// `[n * kv_dim]`, absolute positions `pos..pos+n`) to `layer` —
    /// page-granular: one pool slab copy, one `RepBounds` fold pass and
    /// one page-meta touch per page run instead of per token (the
    /// pool-direct prefill path, DESIGN.md §2).  Bit-identical to `n`
    /// sequential [`SeqCache::append`] calls for any run split — page
    /// opening, pinning boundaries and the min/max rep fold all follow the
    /// same per-slot order (pinned by `prop_append_slots_matches_appends`).
    #[allow(clippy::too_many_arguments)]
    pub fn append_slots(&mut self, layer: usize, pool: &mut KvPool, pos: usize, n: usize,
                        k: &[f32], v: &[f32], pinned: bool, now: u64) -> Result<()> {
        debug_assert_eq!(k.len(), n * self.kv_dim);
        debug_assert_eq!(v.len(), n * self.kv_dim);
        let kv = self.kv_dim;
        let mut done = 0usize;
        while done < n {
            let lc = &mut self.layers[layer];
            let need_new = match lc.table.last() {
                None => true,
                Some(p) => p.len >= self.page_size || p.pinned != pinned,
            };
            if need_new {
                let id = pool.alloc()?;
                lc.table.push(PageMeta::new(id, pos + done, pinned, now));
                lc.reps.push(RepBounds::empty(kv));
            }
            let page = lc.table.last_mut().unwrap();
            // Hard check, not a debug_assert: retrying after a mid-chunk
            // append failure (or any position desync) must error cleanly
            // in release builds too, never write misaligned slots — one
            // predictable branch per page run.
            if page.end_pos() != pos + done {
                bail!("non-contiguous append at layer {layer}: active page ends at {}, \
                       appending position {}", page.end_pos(), pos + done);
            }
            // Copy-on-write at the first divergent append: a forked (or
            // prefix-shared) active page is detached before any slot is
            // written, so sharers never observe each other's tokens.  On
            // the exclusive fast path `cow_page` is a refcount compare.
            if pool.is_shared(page.pool_id) {
                page.pool_id = pool.cow_page(page.pool_id, page.len)?;
            }
            let take = (self.page_size - page.len).min(n - done);
            pool.write_slots(page.pool_id, page.len, take, &k[done * kv..(done + take) * kv],
                             &v[done * kv..(done + take) * kv]);
            page.len += take;
            let reps = lc.reps.last_mut().unwrap();
            for t in done..done + take {
                reps.update(&k[t * kv..(t + 1) * kv]);
            }
            done += take;
        }
        Ok(())
    }

    /// Append one prefill chunk's worth of K/V for absolute positions
    /// `start..end`, page-run-major: per page-aligned run (outer), per
    /// layer (inner), one [`SeqCache::append_slots`] call each — so pool
    /// pages are allocated in `(page, layer)` lexicographic order for ANY
    /// chunk boundaries, mid-page ones included.  That ordering is what
    /// makes chunked, monolithic and concurrent-batched prefill
    /// bit-identical down to the pool ids (DESIGN.md §2, prefill
    /// dataflow); both the sequential and the batched engine prefill
    /// drivers route through this single helper so they cannot drift.
    ///
    /// `kv(layer, pos, len)` returns the K/V slices (`[len * kv_dim]`
    /// each) for positions `pos..pos+len` of `layer`.  Prefill appends
    /// carry stamp 0, matching the engine's monolithic path.
    ///
    /// On `Err` (pool exhaustion mid-run) the sequence holds a
    /// partially-appended chunk and must be released, not retried — the
    /// contiguity check in [`SeqCache::append_slots`] makes a retry a
    /// clean error instead of cache corruption.
    pub fn append_prefill_runs<'a>(
        &mut self, pool: &mut KvPool, start: usize, end: usize, pinned: bool,
        kv: impl Fn(usize, usize, usize) -> (&'a [f32], &'a [f32]),
    ) -> Result<()> {
        let page = self.page_size;
        let n_layers = self.layers.len();
        let mut pos = start;
        while pos < end {
            let run_end = end.min((pos / page + 1) * page);
            let len = run_end - pos;
            for layer in 0..n_layers {
                let (k, v) = kv(layer, pos, len);
                self.append_slots(layer, pool, pos, len, k, v, pinned, 0)?;
            }
            pos = run_end;
        }
        Ok(())
    }

    /// Evict page `idx` of `layer`, releasing its pool page.
    pub fn evict(&mut self, layer: usize, idx: usize, pool: &mut KvPool) {
        let lc = &mut self.layers[layer];
        let meta = lc.table.remove(idx);
        lc.reps.remove(idx);
        pool.release(meta.pool_id);
    }

    /// Gather the selected pages' slots into contiguous buffers padded to
    /// `capacity` slots.  Returns the number of valid slots.
    pub fn gather(&self, layer: usize, pool: &KvPool, sel: &[usize], capacity: usize,
                  k_out: &mut Vec<f32>, v_out: &mut Vec<f32>, valid_out: &mut Vec<f32>)
                  -> usize {
        let kv = self.kv_dim;
        k_out.clear();
        v_out.clear();
        valid_out.clear();
        k_out.resize(capacity * kv, 0.0);
        v_out.resize(capacity * kv, 0.0);
        valid_out.resize(capacity, 0.0);
        let lc = &self.layers[layer];
        let mut used = 0usize;
        for &i in sel {
            let page = &lc.table[i];
            debug_assert!(used + page.len <= capacity, "capacity too small for selection");
            pool.read_page(
                page.pool_id,
                page.len,
                &mut k_out[used * kv..(used + page.len) * kv],
                &mut v_out[used * kv..(used + page.len) * kv],
            );
            for s in 0..page.len {
                valid_out[used + s] = 1.0;
            }
            used += page.len;
        }
        used
    }

    /// Iterate dtype-tagged [`PageView`]s of the selected pages, in
    /// selection order — the shared core of [`SeqCache::page_views`],
    /// [`SeqCache::page_views_into`] and the batched flat-view assembly in
    /// `Engine::decode_batch`.  The views alias the pool slabs (`f32`
    /// master for the reference dtype, quantized bytes + per-page params
    /// otherwise), so the pool cannot be mutated while they live.
    pub fn page_view_iter<'s, 'p: 's>(&'s self, layer: usize, pool: &'p KvPool,
                                      sel: &'s [usize])
                                      -> impl Iterator<Item = PageView<'p>> + 's {
        let lc = &self.layers[layer];
        sel.iter().map(move |&i| {
            let page = &lc.table[i];
            pool.page_view(page.pool_id, page.len)
        })
    }

    /// Zero-copy twin of [`SeqCache::gather`]: collect [`PageView`]s of
    /// the selected pages, in selection order, into `out` — no copy, no
    /// capacity padding, no `valid` mask.
    pub fn page_views<'p>(&self, layer: usize, pool: &'p KvPool, sel: &[usize],
                          out: &mut Vec<PageView<'p>>) {
        out.clear();
        out.extend(self.page_view_iter(layer, pool, sel));
    }

    /// [`SeqCache::page_views`] into an inline [`PageViewBuf`]: the decode
    /// hot path's variant — selections up to [`PAGE_VIEW_INLINE`] pages
    /// (any realistic budget/page_size ratio) stay entirely on the stack,
    /// deleting the per-layer view-`Vec` allocation.
    pub fn page_views_into<'p>(&self, layer: usize, pool: &'p KvPool, sel: &[usize],
                               out: &mut PageViewBuf<'p>) {
        out.clear();
        for view in self.page_view_iter(layer, pool, sel) {
            out.push(view);
        }
    }

    /// Pages the next single-token *decode* append would allocate across
    /// all layers: a layer takes one page when its table is empty, its
    /// active page is full, or its active page is pinned (decode appends
    /// are unpinned, so the prefill/decode boundary forces a fresh page —
    /// the same predicate [`SeqCache::append_slots`] applies per layer),
    /// or when the active page is shared (the COW detach transiently
    /// allocates one page before dropping the shared reference).  The
    /// engine checks this against the pool's free-page headroom *before*
    /// mutating any layer, so a pool-exhausted decode step fails
    /// pre-append and the sequence stays intact and retryable once
    /// preemption frees pages (DESIGN.md §6).
    pub fn pages_needed_for_next_token(&self, pool: &KvPool) -> usize {
        self.layers
            .iter()
            .filter(|lc| match lc.table.last() {
                None => true,
                Some(p) => p.len >= self.page_size || p.pinned || pool.is_shared(p.pool_id),
            })
            .count()
    }

    /// Resident tokens in one layer's table.
    pub fn resident_tokens(&self, layer: usize) -> usize {
        self.layers[layer].resident_tokens()
    }

    /// Resident pages summed across all layers.
    pub fn resident_pages_total(&self) -> usize {
        self.layers.iter().map(|l| l.table.len()).sum()
    }

    /// Resident bytes against the pool (the Figure-7 memory axis).
    pub fn resident_bytes(&self, pool: &KvPool) -> usize {
        self.resident_pages_total() * pool.bytes_per_page()
    }

    /// Release every page back to the pool (sequence finished).
    pub fn release_all(&mut self, pool: &mut KvPool) {
        for lc in &mut self.layers {
            for page in lc.table.drain(..) {
                pool.release(page.pool_id);
            }
            lc.reps.clear();
        }
        self.n_tokens = 0;
    }
}

/// Inline capacity of [`PageViewBuf`]: selections of at most this many
/// pages assemble their views with zero heap allocation.  32 pages covers
/// budget-bounded selections at the in-tree defaults (budget/page_size
/// ≤ 16 for the 96–256-token budgets); selections over the full resident
/// table (Dense at long context, a pinned long prompt under RaaS) exceed
/// it and spill to a heap `Vec` transparently — matching the old
/// always-allocate behavior, never worse.
pub const PAGE_VIEW_INLINE: usize = 32;

/// Smallvec-style buffer of page views for the paged attention route: the
/// per-layer view list lives on the stack up to [`PAGE_VIEW_INLINE`]
/// entries and spills to a `Vec` beyond.  The views borrow the pool slabs,
/// so a buffer cannot outlive the next pool mutation — which is exactly
/// why the engine re-fills a fresh stack-local per layer instead of
/// holding engine-lifetime scratch.
pub struct PageViewBuf<'p> {
    len: usize,
    inline: [PageView<'p>; PAGE_VIEW_INLINE],
    spill: Vec<PageView<'p>>,
}

impl<'p> PageViewBuf<'p> {
    /// Empty buffer (all-inline until [`PAGE_VIEW_INLINE`] views).
    pub fn new() -> Self {
        PageViewBuf { len: 0, inline: [PageView::EMPTY; PAGE_VIEW_INLINE], spill: Vec::new() }
    }

    /// Drop every view (keeps the spill allocation for reuse).
    pub fn clear(&mut self) {
        self.len = 0;
        self.spill.clear();
    }

    /// Append one page view, spilling to the heap past the inline
    /// capacity.
    pub fn push(&mut self, view: PageView<'p>) {
        if self.spill.is_empty() && self.len < PAGE_VIEW_INLINE {
            self.inline[self.len] = view;
        } else {
            if self.spill.is_empty() {
                // first spill: move the inline prefix so views() stays one
                // contiguous slice
                self.spill.reserve(self.len + 1);
                self.spill.extend_from_slice(&self.inline[..self.len]);
            }
            self.spill.push(view);
        }
        self.len += 1;
    }

    /// Number of collected views.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no views were collected.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The collected views as one contiguous slice, in push order.
    pub fn views(&self) -> &[PageView<'p>] {
        if self.spill.is_empty() {
            &self.inline[..self.len]
        } else {
            &self.spill
        }
    }
}

impl Default for PageViewBuf<'_> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::super::page::PageData;
    use super::*;

    fn f32_view(len: usize, k: &[f32], v: &[f32]) -> PageView<'_> {
        PageView { len, data: PageData::F32 { k, v } }
    }

    fn view_k<'p>(view: &PageView<'p>) -> &'p [f32] {
        match view.data {
            PageData::F32 { k, .. } => k,
            PageData::Quant { .. } => panic!("expected an f32 view"),
        }
    }

    fn view_v<'p>(view: &PageView<'p>) -> &'p [f32] {
        match view.data {
            PageData::F32 { v, .. } => v,
            PageData::Quant { .. } => panic!("expected an f32 view"),
        }
    }

    fn mk() -> (SeqCache, KvPool) {
        (SeqCache::new(2, 4, 3), KvPool::new(64, 4, 3))
    }

    #[test]
    fn append_opens_pages_as_needed() {
        let (mut sc, mut pool) = mk();
        for pos in 0..6 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[0.0; 3], true, 0).unwrap();
        }
        assert_eq!(sc.layers[0].table.len(), 2); // 4 + 2
        assert_eq!(sc.layers[0].table[0].len, 4);
        assert_eq!(sc.layers[0].table[1].len, 2);
        assert_eq!(sc.resident_tokens(0), 6);
    }

    #[test]
    fn append_slots_matches_sequential_appends() {
        // 11 tokens in one bulk run vs 11 appends: identical tables, reps,
        // and slab bytes (multi-page run, partial tail page).
        let (mut sa, mut pa) = mk();
        let (mut sb, mut pb) = mk();
        let n = 11usize;
        let k: Vec<f32> = (0..n * 3).map(|x| x as f32 * 0.5 - 2.0).collect();
        let v: Vec<f32> = (0..n * 3).map(|x| 30.0 - x as f32).collect();
        sa.append_slots(0, &mut pa, 0, n, &k, &v, false, 3).unwrap();
        for pos in 0..n {
            sb.append(0, &mut pb, pos, &k[pos * 3..(pos + 1) * 3], &v[pos * 3..(pos + 1) * 3],
                      false, 3)
                .unwrap();
        }
        assert_eq!(sa.layers[0].table.len(), sb.layers[0].table.len());
        for (a, b) in sa.layers[0].table.iter().zip(&sb.layers[0].table) {
            assert_eq!((a.pool_id, a.start_pos, a.len, a.pinned, a.last_stamp),
                       (b.pool_id, b.start_pos, b.len, b.pinned, b.last_stamp));
            assert_eq!(pa.page_k(a.pool_id, a.len), pb.page_k(b.pool_id, b.len));
            assert_eq!(pa.page_v(a.pool_id, a.len), pb.page_v(b.pool_id, b.len));
        }
        for (ra, rb) in sa.layers[0].reps.iter().zip(&sb.layers[0].reps) {
            assert_eq!(ra.kmin, rb.kmin);
            assert_eq!(ra.kmax, rb.kmax);
        }
    }

    #[test]
    fn non_contiguous_append_is_a_clean_error() {
        // Position desync (e.g. a retry after a failed chunk) must error in
        // release builds, never write misaligned slots.
        let (mut sc, mut pool) = mk();
        sc.append(0, &mut pool, 0, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
        assert!(sc.append(0, &mut pool, 2, &[0.0; 3], &[0.0; 3], false, 0).is_err());
        // the failed call must not have grown the page
        assert_eq!(sc.resident_tokens(0), 1);
    }

    #[test]
    fn append_slots_respects_pinned_boundary() {
        // A bulk unpinned run after a pinned prefix must open a new page at
        // the boundary even mid-page, exactly like `append`.
        let (mut sc, mut pool) = mk();
        let k = [0.25f32; 6];
        sc.append_slots(0, &mut pool, 0, 2, &k, &k, true, 0).unwrap();
        sc.append_slots(0, &mut pool, 2, 2, &k, &k, false, 1).unwrap();
        assert_eq!(sc.layers[0].table.len(), 2);
        assert!(sc.layers[0].table[0].pinned);
        assert_eq!(sc.layers[0].table[0].len, 2);
        assert!(!sc.layers[0].table[1].pinned);
        assert_eq!(sc.layers[0].table[1].start_pos, 2);
    }

    #[test]
    fn page_view_buf_inline_and_spill() {
        let backing: Vec<f32> = (0..4).map(|x| x as f32).collect();
        let mut buf = PageViewBuf::new();
        assert!(buf.is_empty());
        for i in 0..PAGE_VIEW_INLINE {
            buf.push(f32_view(i, &backing[..2], &backing[2..]));
        }
        assert_eq!(buf.len(), PAGE_VIEW_INLINE);
        assert_eq!(buf.views().len(), PAGE_VIEW_INLINE);
        // one past the inline capacity: spills, stays contiguous, keeps order
        buf.push(f32_view(99, &backing[..1], &backing[..1]));
        assert_eq!(buf.len(), PAGE_VIEW_INLINE + 1);
        let views = buf.views();
        assert_eq!(views.len(), PAGE_VIEW_INLINE + 1);
        assert_eq!(views[0].len, 0);
        assert_eq!(views[PAGE_VIEW_INLINE].len, 99);
        buf.clear();
        assert!(buf.is_empty());
        assert!(buf.views().is_empty());
    }

    #[test]
    fn page_views_into_matches_page_views() {
        let (mut sc, mut pool) = mk();
        for pos in 0..7 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[9.0; 3], false, 0).unwrap();
        }
        let sel = [0usize, 1];
        let mut vec_views = Vec::new();
        sc.page_views(0, &pool, &sel, &mut vec_views);
        let mut buf = PageViewBuf::new();
        sc.page_views_into(0, &pool, &sel, &mut buf);
        assert_eq!(buf.views(), &vec_views[..]);
    }

    #[test]
    fn quantized_pool_views_dequantize_like_gather() {
        // An int8 pool: `page_views` must hand out Quant-tagged views whose
        // `copy_*_into` bridge reproduces `gather`'s dequantized bytes.
        use super::super::quant::KvDtype;
        let mut sc = SeqCache::new(1, 4, 3);
        let mut pool = KvPool::new_with_dtype(8, 4, 3, KvDtype::Int8);
        for pos in 0..6 {
            let x = pos as f32 * 1.5 - 3.0;
            sc.append(0, &mut pool, pos, &[x; 3], &[-x; 3], false, 0).unwrap();
        }
        let sel = [0usize, 1];
        let (mut k, mut v, mut valid) = (Vec::new(), Vec::new(), Vec::new());
        let used = sc.gather(0, &pool, &sel, 8, &mut k, &mut v, &mut valid);
        let mut views = Vec::new();
        sc.page_views(0, &pool, &sel, &mut views);
        let mut off = 0usize;
        for w in &views {
            assert!(matches!(w.data, PageData::Quant { .. }), "int8 pool must tag views Quant");
            let (mut dk, mut dv) = (vec![0.0f32; w.len * 3], vec![0.0f32; w.len * 3]);
            w.copy_k_into(&mut dk);
            w.copy_v_into(&mut dv);
            assert_eq!(dk[..], k[off * 3..(off + w.len) * 3]);
            assert_eq!(dv[..], v[off * 3..(off + w.len) * 3]);
            off += w.len;
        }
        assert_eq!(off, used);
    }

    #[test]
    fn pages_needed_for_next_token_tracks_the_append_predicate() {
        let (mut sc, mut pool) = mk();
        // empty tables: every layer opens a page
        assert_eq!(sc.pages_needed_for_next_token(&pool), 2);
        // pinned (prefill) active page: the decode boundary still forces
        // a fresh page per layer
        for layer in 0..2 {
            sc.append(layer, &mut pool, 0, &[0.0; 3], &[0.0; 3], true, 0).unwrap();
        }
        assert_eq!(sc.pages_needed_for_next_token(&pool), 2);
        // an unpinned active page with free slots needs nothing
        for layer in 0..2 {
            sc.append(layer, &mut pool, 1, &[0.0; 3], &[0.0; 3], false, 1).unwrap();
        }
        assert_eq!(sc.pages_needed_for_next_token(&pool), 0);
        // fill layer 0's active page (it opened at position 1): that layer
        // needs a fresh one for the next token
        for pos in 2..5 {
            sc.append(0, &mut pool, pos, &[0.0; 3], &[0.0; 3], false, 1).unwrap();
        }
        assert_eq!(sc.pages_needed_for_next_token(&pool), 1);
        // a shared active page counts: the COW detach allocates
        let mut fork = sc.fork(&mut pool);
        assert_eq!(sc.pages_needed_for_next_token(&pool), 2);
        fork.release_all(&mut pool);
        sc.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn prefill_decode_boundary_starts_new_page() {
        let (mut sc, mut pool) = mk();
        sc.append(0, &mut pool, 0, &[0.0; 3], &[0.0; 3], true, 0).unwrap();
        sc.append(0, &mut pool, 1, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
        assert_eq!(sc.layers[0].table.len(), 2);
        assert!(sc.layers[0].table[0].pinned);
        assert!(!sc.layers[0].table[1].pinned);
    }

    #[test]
    fn page_views_match_gather() {
        let (mut sc, mut pool) = mk();
        for pos in 0..7 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[20.0 + pos as f32; 3], false, 0)
                .unwrap();
        }
        // pages: [0..4), [4..7); select both
        let sel = [0usize, 1];
        let (mut k, mut v, mut valid) = (Vec::new(), Vec::new(), Vec::new());
        let used = sc.gather(0, &pool, &sel, 8, &mut k, &mut v, &mut valid);
        let mut views = Vec::new();
        sc.page_views(0, &pool, &sel, &mut views);
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].len, 4);
        assert_eq!(views[1].len, 3);
        let flat_k: Vec<f32> = views.iter().flat_map(|w| view_k(w).iter().copied()).collect();
        let flat_v: Vec<f32> = views.iter().flat_map(|w| view_v(w).iter().copied()).collect();
        assert_eq!(flat_k, k[..used * 3]);
        assert_eq!(flat_v, v[..used * 3]);
    }

    #[test]
    fn gather_concatenates_selected_pages() {
        let (mut sc, mut pool) = mk();
        for pos in 0..8 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[10.0 + pos as f32; 3], false, 0)
                .unwrap();
        }
        let (mut k, mut v, mut valid) = (Vec::new(), Vec::new(), Vec::new());
        // select page 1 only (positions 4..8)
        let used = sc.gather(0, &pool, &[1], 8, &mut k, &mut v, &mut valid);
        assert_eq!(used, 4);
        assert_eq!(k[0], 4.0);
        assert_eq!(v[0], 14.0);
        assert_eq!(valid[3], 1.0);
        assert_eq!(valid[4], 0.0, "padding invalid");
    }

    #[test]
    fn evict_releases_pool_page() {
        let (mut sc, mut pool) = mk();
        for pos in 0..8 {
            sc.append(0, &mut pool, pos, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
        }
        let before = pool.allocated_pages();
        sc.evict(0, 0, &mut pool);
        assert_eq!(pool.allocated_pages(), before - 1);
        assert_eq!(sc.layers[0].table[0].start_pos, 4);
    }

    #[test]
    fn release_all_returns_everything() {
        let (mut sc, mut pool) = mk();
        for layer in 0..2 {
            for pos in 0..5 {
                sc.append(layer, &mut pool, pos, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
            }
        }
        assert!(pool.allocated_pages() > 0);
        sc.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn fork_copies_page_tables_only_and_cow_detaches_on_append() {
        let (mut sc, mut pool) = mk();
        for pos in 0..6 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[10.0 + pos as f32; 3], false, 0)
                .unwrap();
        }
        let pages_before = pool.allocated_pages();
        let mut fork = sc.fork(&mut pool);
        assert_eq!(pool.allocated_pages(), pages_before, "fork must not allocate pages");
        assert_eq!(fork.n_tokens, sc.n_tokens);
        for (a, b) in sc.layers[0].table.iter().zip(&fork.layers[0].table) {
            assert_eq!(a.pool_id, b.pool_id, "fork maps the same physical pages");
            assert!(pool.is_shared(a.pool_id));
        }
        // divergent append: the fork's active page detaches, the parent's
        // bytes stay untouched; the full page stays shared
        fork.append(0, &mut pool, 6, &[99.0; 3], &[99.0; 3], false, 1).unwrap();
        assert_eq!(pool.allocated_pages(), pages_before + 1, "COW allocated exactly one page");
        let (pt, ft) = (&sc.layers[0].table, &fork.layers[0].table);
        assert_eq!(pt[0].pool_id, ft[0].pool_id, "untouched full page still shared");
        assert_ne!(pt[1].pool_id, ft[1].pool_id, "active page detached");
        assert_eq!(pool.page_k(pt[1].pool_id, 2), &[4.0, 4.0, 4.0, 5.0, 5.0, 5.0]);
        assert_eq!(pool.page_k(ft[1].pool_id, 3)[..6], *pool.page_k(pt[1].pool_id, 2));
        assert_eq!(pool.page_k(ft[1].pool_id, 3)[6..], [99.0, 99.0, 99.0]);
        // both releases drain the pool completely
        sc.release_all(&mut pool);
        fork.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn cow_races_eviction_in_the_same_tick() {
        // Satellite edge case: sequence A evicts a shared page in the same
        // tick sequence B copy-on-writes its own mapping of it.  Order:
        // B's COW drops one ref, then A's evict drops the last — the slab
        // range must free exactly once and B's detached copy must survive.
        let (mut sa, mut pool) = mk();
        for pos in 0..4 {
            sa.append(0, &mut pool, pos, &[pos as f32; 3], &[0.5; 3], false, 0).unwrap();
        }
        let mut sb = sa.fork(&mut pool);
        let shared = sa.layers[0].table[0].pool_id;
        assert_eq!(pool.ref_count(shared), 2);
        // the page is full (4 slots), so drive COW directly through
        // `cow_page` on B's mapping — the same call `append_slots` makes
        let nb = pool.cow_page(shared, 4).unwrap();
        sb.layers[0].table[0].pool_id = nb;
        assert_eq!(pool.ref_count(shared), 1);
        // A evicts the (now exclusively owned) original in the same tick
        sa.evict(0, 0, &mut pool);
        assert_eq!(pool.ref_count(shared), 0, "slab range freed exactly once");
        assert_eq!(pool.page_k(nb, 4)[..3], [0.0, 0.0, 0.0], "B's copy intact");
        assert_eq!(pool.page_k(nb, 4)[9..], [3.0, 3.0, 3.0]);
        sb.release_all(&mut pool);
        sa.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn evicting_a_shared_page_keeps_the_survivors_view() {
        // Satellite edge case: evicting a refcount-2 page from one table
        // must not free the slab range the other sequence still reads.
        let (mut sa, mut pool) = mk();
        for pos in 0..8 {
            sa.append(0, &mut pool, pos, &[pos as f32; 3], &[1.0; 3], false, 0).unwrap();
        }
        let mut sb = sa.fork(&mut pool);
        let victim = sa.layers[0].table[0].pool_id;
        let before = pool.allocated_pages();
        sa.evict(0, 0, &mut pool);
        assert_eq!(pool.allocated_pages(), before, "shared eviction frees no pages");
        assert_eq!(pool.ref_count(victim), 1);
        assert_eq!(pool.page_k(sb.layers[0].table[0].pool_id, 4)[..3], [0.0, 0.0, 0.0]);
        sb.release_all(&mut pool);
        sa.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn attach_shared_page_maps_and_pins() {
        let (mut donor, mut pool) = mk();
        for pos in 0..4 {
            donor.append(0, &mut pool, pos, &[pos as f32; 3], &[2.0; 3], true, 0).unwrap();
        }
        let id = donor.layers[0].table[0].pool_id;
        let rep = donor.layers[0].reps[0].clone();
        let mut sc = SeqCache::new(2, 4, 3);
        sc.attach_shared_page(0, &mut pool, id, &rep, true).unwrap();
        assert_eq!(pool.ref_count(id), 2);
        let p = &sc.layers[0].table[0];
        assert_eq!((p.pool_id, p.start_pos, p.len, p.pinned, p.last_stamp), (id, 0, 4, true, 0));
        assert_eq!(sc.layers[0].reps[0].kmin, rep.kmin);
        // a second attach lands page-aligned at position 4; a mid-page
        // attach is rejected before any retain
        let mut mid = SeqCache::new(1, 4, 3);
        mid.append(0, &mut pool, 0, &[0.0; 3], &[0.0; 3], true, 0).unwrap();
        assert!(mid.attach_shared_page(0, &mut pool, id, &rep, true).is_err());
        assert_eq!(pool.ref_count(id), 2, "failed attach must not retain");
        sc.release_all(&mut pool);
        mid.release_all(&mut pool);
        donor.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn rep_scores_align_with_pages() {
        let (mut sc, mut pool) = mk();
        // kv_dim 3 => treat as 1 kv head, head_dim 3, 1 q head
        sc.append(0, &mut pool, 0, &[1.0, 0.0, 0.0], &[0.0; 3], false, 0).unwrap();
        for pos in 1..5 {
            sc.append(0, &mut pool, pos, &[0.0, 1.0, 0.0], &[0.0; 3], false, 0).unwrap();
        }
        let mut scores = Vec::new();
        sc.layers[0].rep_scores(&[2.0, 0.0, 0.0], 1, 1, 3, &mut scores);
        assert_eq!(scores.len(), 2);
        assert!(scores[0] >= 2.0 - 1e-6, "page 0 contains the aligned key");
    }

    #[test]
    fn head_scores_reduce_to_rep_scores() {
        let (mut sc, mut pool) = mk();
        for pos in 0..7 {
            let k = [pos as f32 * 0.1, 1.0 - pos as f32 * 0.05, 0.3];
            sc.append(0, &mut pool, pos, &k, &[0.0; 3], false, 0).unwrap();
        }
        let q = [0.4f32, -0.7, 0.9];
        let (mut heads, mut reduced, mut classic) = (Vec::new(), Vec::new(), Vec::new());
        sc.layers[0].rep_scores_heads(&q, 1, 1, 3, &mut heads);
        assert_eq!(heads.len(), sc.layers[0].table.len());
        crate::kvcache::page::reduce_head_scores_max(&heads, 1, &mut reduced);
        sc.layers[0].rep_scores(&q, 1, 1, 3, &mut classic);
        let a: Vec<u32> = reduced.iter().map(|s| s.to_bits()).collect();
        let b: Vec<u32> = classic.iter().map(|s| s.to_bits()).collect();
        assert_eq!(a, b, "head-major reduction must be bitwise the classic fold");
    }
}
