//! Per-sequence cache state: one page table (+ representative bounds) per
//! layer, backed by the shared pool.

use anyhow::Result;

use super::page::{page_probs, PageMeta, RepBounds};
use super::pool::KvPool;

/// One layer's view of a sequence's cache.
#[derive(Debug, Default)]
pub struct LayerCache {
    /// Resident pages in position order.  The final page is the active one.
    pub table: Vec<PageMeta>,
    /// Quest-style representative bounds, aligned with `table`.
    pub reps: Vec<RepBounds>,
}

impl LayerCache {
    pub fn resident_tokens(&self) -> usize {
        self.table.iter().map(|p| p.len).sum()
    }

    /// Raw upper-bound scores for every resident page given this step's q.
    pub fn rep_scores(&self, q: &[f32], n_heads: usize, n_kv: usize, head_dim: usize,
                      out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.reps.iter().map(|r| r.score(q, n_heads, n_kv, head_dim)));
    }

    /// Softmaxed pseudo-probabilities (what RaaS thresholds against alpha).
    pub fn rep_probs(&self, scores: &[f32], head_dim: usize, out: &mut Vec<f32>) {
        page_probs(scores, head_dim, out);
    }
}

/// All layers of one sequence.
#[derive(Debug)]
pub struct SeqCache {
    pub layers: Vec<LayerCache>,
    /// Tokens appended so far (= next absolute position).
    pub n_tokens: usize,
    pub prompt_len: usize,
    page_size: usize,
    kv_dim: usize,
}

impl SeqCache {
    pub fn new(n_layers: usize, page_size: usize, kv_dim: usize) -> Self {
        SeqCache {
            layers: (0..n_layers).map(|_| LayerCache::default()).collect(),
            n_tokens: 0,
            prompt_len: 0,
            page_size,
            kv_dim,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Append one token's K/V to `layer` at absolute position `pos`.
    /// A new page is opened when the active page is full, or at the
    /// prefill/decode boundary (so pinning stays page-aligned).
    pub fn append(&mut self, layer: usize, pool: &mut KvPool, pos: usize,
                  k: &[f32], v: &[f32], pinned: bool, now: u64) -> Result<()> {
        debug_assert_eq!(k.len(), self.kv_dim);
        let lc = &mut self.layers[layer];
        let need_new = match lc.table.last() {
            None => true,
            Some(p) => p.len >= self.page_size || p.pinned != pinned,
        };
        if need_new {
            let id = pool.alloc()?;
            lc.table.push(PageMeta::new(id, pos, pinned, now));
            lc.reps.push(RepBounds::empty(self.kv_dim));
        }
        let page = lc.table.last_mut().unwrap();
        debug_assert_eq!(page.end_pos(), pos, "non-contiguous append");
        pool.write_slot(page.pool_id, page.len, k, v);
        page.len += 1;
        lc.reps.last_mut().unwrap().update(k);
        Ok(())
    }

    /// Evict page `idx` of `layer`, releasing its pool page.
    pub fn evict(&mut self, layer: usize, idx: usize, pool: &mut KvPool) {
        let lc = &mut self.layers[layer];
        let meta = lc.table.remove(idx);
        lc.reps.remove(idx);
        pool.release(meta.pool_id);
    }

    /// Gather the selected pages' slots into contiguous buffers padded to
    /// `capacity` slots.  Returns the number of valid slots.
    pub fn gather(&self, layer: usize, pool: &KvPool, sel: &[usize], capacity: usize,
                  k_out: &mut Vec<f32>, v_out: &mut Vec<f32>, valid_out: &mut Vec<f32>)
                  -> usize {
        let kv = self.kv_dim;
        k_out.clear();
        v_out.clear();
        valid_out.clear();
        k_out.resize(capacity * kv, 0.0);
        v_out.resize(capacity * kv, 0.0);
        valid_out.resize(capacity, 0.0);
        let lc = &self.layers[layer];
        let mut used = 0usize;
        for &i in sel {
            let page = &lc.table[i];
            debug_assert!(used + page.len <= capacity, "capacity too small for selection");
            pool.read_page(
                page.pool_id,
                page.len,
                &mut k_out[used * kv..(used + page.len) * kv],
                &mut v_out[used * kv..(used + page.len) * kv],
            );
            for s in 0..page.len {
                valid_out[used + s] = 1.0;
            }
            used += page.len;
        }
        used
    }

    /// Zero-copy twin of [`SeqCache::gather`]: collect `(k, v, len)` slab
    /// views of the selected pages, in selection order, into `out` — no
    /// copy, no capacity padding, no `valid` mask.  The views alias the
    /// pool slabs, so the pool cannot be mutated while they live.
    pub fn page_views<'p>(&self, layer: usize, pool: &'p KvPool, sel: &[usize],
                          out: &mut Vec<(&'p [f32], &'p [f32], usize)>) {
        out.clear();
        let lc = &self.layers[layer];
        for &i in sel {
            let page = &lc.table[i];
            out.push((pool.page_k(page.pool_id, page.len), pool.page_v(page.pool_id, page.len),
                      page.len));
        }
    }

    pub fn resident_tokens(&self, layer: usize) -> usize {
        self.layers[layer].resident_tokens()
    }

    pub fn resident_pages_total(&self) -> usize {
        self.layers.iter().map(|l| l.table.len()).sum()
    }

    pub fn resident_bytes(&self, pool: &KvPool) -> usize {
        self.resident_pages_total() * pool.bytes_per_page()
    }

    /// Release every page back to the pool (sequence finished).
    pub fn release_all(&mut self, pool: &mut KvPool) {
        for lc in &mut self.layers {
            for page in lc.table.drain(..) {
                pool.release(page.pool_id);
            }
            lc.reps.clear();
        }
        self.n_tokens = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> (SeqCache, KvPool) {
        (SeqCache::new(2, 4, 3), KvPool::new(64, 4, 3))
    }

    #[test]
    fn append_opens_pages_as_needed() {
        let (mut sc, mut pool) = mk();
        for pos in 0..6 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[0.0; 3], true, 0).unwrap();
        }
        assert_eq!(sc.layers[0].table.len(), 2); // 4 + 2
        assert_eq!(sc.layers[0].table[0].len, 4);
        assert_eq!(sc.layers[0].table[1].len, 2);
        assert_eq!(sc.resident_tokens(0), 6);
    }

    #[test]
    fn prefill_decode_boundary_starts_new_page() {
        let (mut sc, mut pool) = mk();
        sc.append(0, &mut pool, 0, &[0.0; 3], &[0.0; 3], true, 0).unwrap();
        sc.append(0, &mut pool, 1, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
        assert_eq!(sc.layers[0].table.len(), 2);
        assert!(sc.layers[0].table[0].pinned);
        assert!(!sc.layers[0].table[1].pinned);
    }

    #[test]
    fn page_views_match_gather() {
        let (mut sc, mut pool) = mk();
        for pos in 0..7 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[20.0 + pos as f32; 3], false, 0)
                .unwrap();
        }
        // pages: [0..4), [4..7); select both
        let sel = [0usize, 1];
        let (mut k, mut v, mut valid) = (Vec::new(), Vec::new(), Vec::new());
        let used = sc.gather(0, &pool, &sel, 8, &mut k, &mut v, &mut valid);
        let mut views = Vec::new();
        sc.page_views(0, &pool, &sel, &mut views);
        assert_eq!(views.len(), 2);
        assert_eq!(views[0].2, 4);
        assert_eq!(views[1].2, 3);
        let flat_k: Vec<f32> = views.iter().flat_map(|&(k, _, _)| k.iter().copied()).collect();
        let flat_v: Vec<f32> = views.iter().flat_map(|&(_, v, _)| v.iter().copied()).collect();
        assert_eq!(flat_k, k[..used * 3]);
        assert_eq!(flat_v, v[..used * 3]);
    }

    #[test]
    fn gather_concatenates_selected_pages() {
        let (mut sc, mut pool) = mk();
        for pos in 0..8 {
            sc.append(0, &mut pool, pos, &[pos as f32; 3], &[10.0 + pos as f32; 3], false, 0)
                .unwrap();
        }
        let (mut k, mut v, mut valid) = (Vec::new(), Vec::new(), Vec::new());
        // select page 1 only (positions 4..8)
        let used = sc.gather(0, &pool, &[1], 8, &mut k, &mut v, &mut valid);
        assert_eq!(used, 4);
        assert_eq!(k[0], 4.0);
        assert_eq!(v[0], 14.0);
        assert_eq!(valid[3], 1.0);
        assert_eq!(valid[4], 0.0, "padding invalid");
    }

    #[test]
    fn evict_releases_pool_page() {
        let (mut sc, mut pool) = mk();
        for pos in 0..8 {
            sc.append(0, &mut pool, pos, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
        }
        let before = pool.allocated_pages();
        sc.evict(0, 0, &mut pool);
        assert_eq!(pool.allocated_pages(), before - 1);
        assert_eq!(sc.layers[0].table[0].start_pos, 4);
    }

    #[test]
    fn release_all_returns_everything() {
        let (mut sc, mut pool) = mk();
        for layer in 0..2 {
            for pos in 0..5 {
                sc.append(layer, &mut pool, pos, &[0.0; 3], &[0.0; 3], false, 0).unwrap();
            }
        }
        assert!(pool.allocated_pages() > 0);
        sc.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn rep_scores_align_with_pages() {
        let (mut sc, mut pool) = mk();
        // kv_dim 3 => treat as 1 kv head, head_dim 3, 1 q head
        sc.append(0, &mut pool, 0, &[1.0, 0.0, 0.0], &[0.0; 3], false, 0).unwrap();
        for pos in 1..5 {
            sc.append(0, &mut pool, pos, &[0.0, 1.0, 0.0], &[0.0; 3], false, 0).unwrap();
        }
        let mut scores = Vec::new();
        sc.layers[0].rep_scores(&[2.0, 0.0, 0.0], 1, 1, 3, &mut scores);
        assert_eq!(scores.len(), 2);
        assert!(scores[0] >= 2.0 - 1e-6, "page 0 contains the aligned key");
    }
}
