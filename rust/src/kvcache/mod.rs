//! Paged KV-cache manager: the memory substrate the sparsity policies act on.
//!
//! Layout follows vLLM-style paged attention adapted to this stack: the pool
//! owns fixed-size pages of post-RoPE keys and raw values for **one layer**
//! each; a sequence holds one page table per layer.  All memory accounting
//! (the paper's Figure-7 memory axis) is byte-accurate against the pool.

pub mod page;
pub mod policy;
pub mod pool;
pub mod prefix;
pub mod quant;
pub mod seq;

pub use page::{PageData, PageId, PageMeta, PageView, RepBounds};
pub use pool::{KvPool, PoolExhausted, SwapHandle};
pub use prefix::{prefix_hashes, PrefixIndex};
pub use quant::{KvDtype, QuantParams};
pub use seq::{PageViewBuf, SeqCache, PAGE_VIEW_INLINE};
