//! KV-slab element dtypes and per-page quantization codecs (DESIGN.md §2,
//! slab layout).
//!
//! The pool stores K/V either as raw `f32` (the reference dtype) or as one
//! byte per element under a per-page affine code:
//!
//! * [`KvDtype::Int8`] — asymmetric affine `u8`: `x ≈ zero + scale * q`
//!   with `zero = lo` and `scale = hi/255 - lo/255` derived from the
//!   page's running value range (the overflow-safe form of
//!   `(hi - lo)/255`).  Worst-case absolute error ≈ `range / 510`.
//! * [`KvDtype::Fp8E4M3`] — symmetric FP8 E4M3FN: `x ≈ scale * e4m3(q)`
//!   with `scale = amax / 448` (448 is the format's largest finite value;
//!   E4M3FN spends the infinity encodings on more range).  Relative error
//!   ≤ 2⁻⁴ for normals plus a `scale · 2⁻¹⁰` subnormal floor.
//!
//! Parameters are a pure function of a page's running `(lo, hi)` range
//! ([`KvDtype::params`]), and pages re-encode from the master slab whenever
//! the range grows — so the quantized bytes depend only on a page's final
//! contents, never on chunking, batching, or fork order.  That is what
//! keeps every bit-identity suite green under `KV_DTYPE=fp8|int8`.

use anyhow::{bail, Result};

/// Element dtype of the pool's K/V slabs, selected at pool construction
/// (`--kv-dtype`, [`crate::config::EngineConfig::kv_dtype`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvDtype {
    /// Raw `f32` — the reference dtype; bit-identical to the pre-quant pool.
    #[default]
    F32,
    /// FP8 E4M3FN with a symmetric per-page scale (`amax / 448`).
    Fp8E4M3,
    /// Asymmetric affine `u8` with per-page `(scale, zero)`.
    Int8,
}

impl KvDtype {
    /// Every dtype, in reference-first order (bench/CI matrix order).
    pub fn all() -> [KvDtype; 3] {
        [KvDtype::F32, KvDtype::Fp8E4M3, KvDtype::Int8]
    }

    /// Parse a CLI/env name (`f32`, `fp8` / `fp8e4m3`, `int8`).
    pub fn parse(s: &str) -> Result<KvDtype> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Ok(KvDtype::F32),
            "fp8" | "fp8e4m3" | "e4m3" => Ok(KvDtype::Fp8E4M3),
            "int8" | "i8" | "u8" => Ok(KvDtype::Int8),
            other => bail!("unknown kv dtype '{other}' (expected f32|fp8|int8)"),
        }
    }

    /// Canonical name (round-trips through [`KvDtype::parse`]).
    pub fn name(&self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Fp8E4M3 => "fp8",
            KvDtype::Int8 => "int8",
        }
    }

    /// Dtype from the `KV_DTYPE` environment variable (the CI bit-identity
    /// matrix hook), defaulting to `F32` when unset.  An unparseable value
    /// panics: a typo in a CI matrix leg must fail loudly, not silently
    /// re-run the `f32` leg.
    pub fn from_env() -> KvDtype {
        match std::env::var("KV_DTYPE") {
            Ok(s) => KvDtype::parse(&s).expect("invalid KV_DTYPE env var"),
            Err(_) => KvDtype::F32,
        }
    }

    /// Slab bytes per stored K or V element.
    pub fn bytes_per_elem(&self) -> usize {
        match self {
            KvDtype::F32 => 4,
            KvDtype::Fp8E4M3 | KvDtype::Int8 => 1,
        }
    }

    /// Whether this dtype carries per-page quantization parameters.
    pub fn is_quantized(&self) -> bool {
        !matches!(self, KvDtype::F32)
    }

    /// Accounting bytes of per-page quantization metadata: `(scale, zero)`
    /// per K and per V stream, 4 bytes each.  0 for `F32`.
    pub fn page_param_bytes(&self) -> usize {
        if self.is_quantized() {
            16
        } else {
            0
        }
    }

    /// Derive this dtype's per-page parameters from a page's running value
    /// range.  Deterministic and total: called with the same `(lo, hi)` it
    /// always yields the same params, including on empty pages
    /// (`lo = +inf, hi = -inf` ⇒ the zero code).
    pub fn params(&self, lo: f32, hi: f32) -> QuantParams {
        match self {
            KvDtype::F32 => QuantParams { scale: 1.0, zero: 0.0 },
            KvDtype::Int8 => {
                if !(lo <= hi) {
                    return QuantParams { scale: 0.0, zero: 0.0 };
                }
                // hi/255 - lo/255 rather than (hi-lo)/255: the subtraction
                // cannot overflow even at lo = -f32::MAX, hi = f32::MAX
                let scale = hi / 255.0 - lo / 255.0;
                QuantParams { scale: scale.max(0.0), zero: lo }
            }
            KvDtype::Fp8E4M3 => {
                if !(lo <= hi) {
                    return QuantParams { scale: 0.0, zero: 0.0 };
                }
                let amax = lo.abs().max(hi.abs());
                QuantParams { scale: amax / 448.0, zero: 0.0 }
            }
        }
    }

    /// Quantize `src` into `dst` under `params` (one byte per element).
    /// No-op for `F32` (the master slab is the storage).
    pub fn encode_slice(&self, src: &[f32], params: QuantParams, dst: &mut [u8]) {
        debug_assert_eq!(src.len(), dst.len());
        match self {
            KvDtype::F32 => {}
            KvDtype::Int8 => {
                if params.scale <= 0.0 {
                    dst.fill(0);
                    return;
                }
                // (x - zero)/scale computed as x/scale - zero/scale: both
                // quotients are ≤ ~255 in magnitude for in-range x, so the
                // subtraction cannot overflow the way (x - zero) can when
                // x and zero sit at opposite float extremes
                let inv = 1.0 / params.scale;
                let zq = params.zero * inv;
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = (x * inv - zq).round().clamp(0.0, 255.0) as u8;
                }
            }
            KvDtype::Fp8E4M3 => {
                if params.scale <= 0.0 {
                    dst.fill(0);
                    return;
                }
                for (d, &x) in dst.iter_mut().zip(src) {
                    *d = f32_to_e4m3(x / params.scale);
                }
            }
        }
    }

    /// Dequantize `src` into `dst` under `params`.  Exact inverse of the
    /// code points: `Int8`'s `q = 0` decodes to `zero` exactly, `Fp8`'s
    /// codes decode through the closed-form E4M3FN value.
    pub fn decode_slice(&self, src: &[u8], params: QuantParams, dst: &mut [f32]) {
        debug_assert_eq!(src.len(), dst.len());
        match self {
            KvDtype::F32 => {}
            KvDtype::Int8 => {
                for (d, &q) in dst.iter_mut().zip(src) {
                    *d = params.zero + params.scale * q as f32;
                }
            }
            KvDtype::Fp8E4M3 => {
                for (d, &q) in dst.iter_mut().zip(src) {
                    *d = params.scale * e4m3_to_f32(q);
                }
            }
        }
    }

    /// Worst-case absolute reconstruction error for one value `x` encoded
    /// under `params` (used by the round-trip property tests; includes
    /// small slack for the f32 arithmetic of the codec itself).
    pub fn error_bound(&self, x: f32, params: QuantParams) -> f32 {
        match self {
            KvDtype::F32 => 0.0,
            // half a code step, plus slack for the inv-scale multiply
            KvDtype::Int8 => params.scale * 0.501 + x.abs() * 1e-5 + 1e-30,
            // 2⁻⁴ relative for normals, scale·2⁻¹⁰ subnormal floor
            KvDtype::Fp8E4M3 => {
                (x.abs() * (1.0 / 16.0)).max(params.scale * (1.0 / 512.0)) * 1.001
                    + x.abs() * 1e-5
                    + 1e-30
            }
        }
    }
}

impl std::fmt::Display for KvDtype {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-page affine dequantization parameters: `x ≈ zero + scale * code(q)`.
/// `F32` pages carry the identity `(1, 0)`; `Fp8E4M3` pages always have
/// `zero = 0` (symmetric).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Multiplier applied to the decoded code point.
    pub scale: f32,
    /// Additive offset (the page minimum for `Int8`).
    pub zero: f32,
}

impl QuantParams {
    /// The identity parameters (`scale = 1, zero = 0`).
    pub const IDENTITY: QuantParams = QuantParams { scale: 1.0, zero: 0.0 };
}

/// Round a non-negative finite `f32` to the nearest integer, ties to even
/// (the IEEE default the E4M3FN codec needs; `f32::round` ties away).
fn round_even(x: f32) -> u32 {
    let f = x.floor();
    let d = x - f;
    let mut n = f as u32;
    if d > 0.5 || (d == 0.5 && n % 2 == 1) {
        n += 1;
    }
    n
}

/// Encode one `f32` as an FP8 E4M3FN byte: 1 sign, 4 exponent (bias 7),
/// 3 mantissa; no infinities, NaN = `0x7F`, largest finite = ±448
/// (`0x7E`), subnormal ULP = 2⁻⁹.  Round-to-nearest-even, saturating.
pub fn f32_to_e4m3(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7F;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0x00 };
    let a = x.abs();
    if a == 0.0 {
        return sign;
    }
    // floor(log2(a)) for normal f32 inputs; f32 subnormals (< 2^-126) are
    // far below the E4M3 subnormal range and round to zero below
    let e0 = ((a.to_bits() >> 23) & 0xFF) as i32 - 127;
    let e = e0.max(-6);
    // scale so one unit = one mantissa ULP at exponent e: normals land in
    // [8, 16), E4M3-subnormals (e == -6) in [0, 8)
    let scaled = a * exp2i(3 - e);
    let mut m = round_even(scaled);
    let mut exp = e;
    if m >= 16 {
        // rounding carried into the next binade (15.5+ -> 16 = 2 * 8)
        m /= 2;
        exp += 1;
    }
    if exp > 8 || (exp == 8 && m > 14) {
        return sign | 0x7E; // saturate at 448
    }
    if m < 8 {
        // E4M3 subnormal: biased exponent 0, value = m * 2^-9
        sign | m as u8
    } else {
        let biased = (exp + 7) as u8;
        sign | (biased << 3) | (m - 8) as u8
    }
}

/// Decode one FP8 E4M3FN byte (see [`f32_to_e4m3`] for the format).
pub fn e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 3) & 0x0F) as i32;
    let man = (b & 0x07) as f32;
    if exp == 0x0F && (b & 0x07) == 0x07 {
        return f32::NAN.copysign(sign);
    }
    let v = if exp == 0 { man * exp2i(-9) } else { (8.0 + man) * exp2i(exp - 10) };
    sign * v
}

/// `2^e` as f32 for the small exponents the codec needs.
fn exp2i(e: i32) -> f32 {
    if (-126..=127).contains(&e) {
        f32::from_bits(((e + 127) as u32) << 23)
    } else if e > 127 {
        f32::INFINITY
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        for d in KvDtype::all() {
            assert_eq!(KvDtype::parse(d.name()).unwrap(), d);
            assert_eq!(format!("{d}"), d.name());
        }
        assert_eq!(KvDtype::parse("FP8E4M3").unwrap(), KvDtype::Fp8E4M3);
        assert_eq!(KvDtype::parse("I8").unwrap(), KvDtype::Int8);
        assert!(KvDtype::parse("f16").is_err());
        assert_eq!(KvDtype::default(), KvDtype::F32);
    }

    #[test]
    fn e4m3_exact_code_points() {
        // spot-check the format's anchor values both directions
        assert_eq!(e4m3_to_f32(0x00), 0.0);
        assert_eq!(e4m3_to_f32(0x01), 2f32.powi(-9)); // smallest subnormal
        assert_eq!(e4m3_to_f32(0x08), 2f32.powi(-6)); // smallest normal
        assert_eq!(e4m3_to_f32(0x7E), 448.0); // largest finite
        assert_eq!(e4m3_to_f32(0xFE), -448.0);
        assert!(e4m3_to_f32(0x7F).is_nan());
        assert_eq!(f32_to_e4m3(448.0), 0x7E);
        assert_eq!(f32_to_e4m3(-448.0), 0xFE);
        assert_eq!(f32_to_e4m3(1.0), 0x38); // biased exp 7, mantissa 0
        assert_eq!(f32_to_e4m3(1.75), 0x3E);
        assert_eq!(f32_to_e4m3(0.0), 0x00);
        assert!(e4m3_to_f32(f32_to_e4m3(f32::NAN)).is_nan());
    }

    #[test]
    fn e4m3_roundtrip_is_identity_on_all_finite_codes() {
        for b in 0u16..=255 {
            let b = b as u8;
            if b & 0x7F == 0x7F {
                continue; // NaN codes
            }
            let x = e4m3_to_f32(b);
            let b2 = f32_to_e4m3(x);
            // -0.0 encodes back to 0x80, +0.0 to 0x00; both decode equal
            assert_eq!(e4m3_to_f32(b2).to_bits(), x.to_bits(), "code {b:#04x}");
        }
    }

    #[test]
    fn e4m3_saturates_and_rounds_to_even() {
        assert_eq!(f32_to_e4m3(1e30), 0x7E);
        assert_eq!(f32_to_e4m3(-1e30), 0xFE);
        assert_eq!(f32_to_e4m3(464.0), 0x7E); // tie at 448/480 midpoint -> even 14
        assert_eq!(f32_to_e4m3(465.0), 0x7E); // above the tie: saturates too
        // 1.0625 is the midpoint of 1.0 (m=8) and 1.125 (m=9): ties to 8
        assert_eq!(f32_to_e4m3(1.0625), 0x38);
        // 1.1875 is the midpoint of 1.125 (m=9) and 1.25 (m=10): ties to 10
        assert_eq!(f32_to_e4m3(1.1875), 0x3A);
        // below half the smallest subnormal: rounds to zero
        assert_eq!(f32_to_e4m3(2f32.powi(-11)), 0x00);
        assert_eq!(f32_to_e4m3(-2f32.powi(-11)), 0x80);
    }

    #[test]
    fn int8_params_edges() {
        let d = KvDtype::Int8;
        // empty range (fresh page) yields the zero code
        let p = d.params(f32::INFINITY, f32::NEG_INFINITY);
        assert_eq!(p.scale, 0.0);
        // degenerate single-value range: scale 0, zero reproduces exactly
        let p = d.params(3.5, 3.5);
        assert_eq!(p.scale, 0.0);
        assert_eq!(p.zero, 3.5);
        let (src, mut enc, mut dec) = (vec![3.5f32; 4], vec![0u8; 4], vec![0f32; 4]);
        d.encode_slice(&src, p, &mut enc);
        d.decode_slice(&enc, p, &mut dec);
        assert_eq!(dec, src);
        // full-extreme range must not overflow
        let p = d.params(-f32::MAX, f32::MAX);
        assert!(p.scale.is_finite() && p.scale > 0.0);
    }

    #[test]
    fn roundtrip_error_within_bound() {
        let vals = [
            0.0f32, 1.0, -1.0, 0.37, -250.0, 1e-8, 3e4, -3e4, 1e-30, f32::MAX / 2.0,
        ];
        for d in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let lo = vals.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = vals.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let p = d.params(lo, hi);
            let mut enc = vec![0u8; vals.len()];
            let mut dec = vec![0f32; vals.len()];
            d.encode_slice(&vals, p, &mut enc);
            d.decode_slice(&enc, p, &mut dec);
            for (i, (&x, &y)) in vals.iter().zip(&dec).enumerate() {
                let bound = d.error_bound(x, p);
                assert!(
                    (x - y).abs() <= bound,
                    "{d} val[{i}]={x} decoded {y} err {} > bound {bound}",
                    (x - y).abs()
                );
            }
        }
    }

    #[test]
    fn int8_lo_hi_decode_near_exact() {
        let d = KvDtype::Int8;
        let (lo, hi) = (-7.25f32, 19.5f32);
        let p = d.params(lo, hi);
        let src = [lo, hi];
        let mut enc = [0u8; 2];
        let mut dec = [0f32; 2];
        d.encode_slice(&src, p, &mut enc);
        assert_eq!(enc[0], 0);
        assert_eq!(enc[1], 255);
        d.decode_slice(&enc, p, &mut dec);
        assert_eq!(dec[0], lo, "q=0 must decode to the page minimum exactly");
        assert!((dec[1] - hi).abs() <= p.scale * 0.501);
    }
}
