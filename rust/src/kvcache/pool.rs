//! The physical page pool: two contiguous K/V slabs carved into fixed-size
//! pages, with a free list, per-page reference counts and byte-accurate
//! accounting (drives the Figure-7 memory axis and the coordinator's
//! admission control).
//!
//! Slab layout (the zero-copy paged-attention substrate, DESIGN.md §2):
//! page `id` owns `[id * page_size * kv_dim .. (id+1) * page_size * kv_dim]`
//! of both slabs, so a resident page's K/V is a plain `&[f32]` slice
//! ([`KvPool::page_k`] / [`KvPool::page_v`]) that backends read in place —
//! no per-page allocations, no gather copy, real cache locality.
//!
//! Sharing (DESIGN.md §2, prefix sharing): pages are refcounted, so several
//! sequences' page tables — and the pool-level prefix index — can map the
//! same physical page.  [`KvPool::retain`] adds an owner,
//! [`KvPool::release`] drops one (the slab range is freed only when the
//! last owner leaves), and [`KvPool::cow_page`] is the copy-on-write step a
//! sequence takes before mutating a page it no longer owns exclusively.

use anyhow::{bail, Result};

use super::page::PageId;

/// The shared physical KV page pool (one per engine).
///
/// # Example — alloc → bulk write → zero-copy view
///
/// The paged-attention dataflow in miniature: allocate a page, write two
/// tokens' K/V in one bulk call, read them back as in-place slab views
/// (what [`crate::runtime::Backend::layer_attn_mlp_paged`] consumes):
///
/// ```
/// use raas::kvcache::KvPool;
///
/// // 4 pages × 4 slots, kv_dim 2 (floats per slot for K and for V)
/// let mut pool = KvPool::new(4, 4, 2);
/// let page = pool.alloc().unwrap();
/// let k = [1.0f32, 2.0, 3.0, 4.0]; // two slots of keys
/// let v = [5.0f32, 6.0, 7.0, 8.0]; // two slots of values
/// pool.write_slots(page, 0, 2, &k, &v);
/// assert_eq!(pool.page_k(page, 2), &k[..]); // zero-copy slab view
/// assert_eq!(pool.page_v(page, 2), &v[..]);
/// assert_eq!(pool.allocated_pages(), 1);
/// pool.release(page);
/// assert_eq!(pool.allocated_pages(), 0);
/// ```
#[derive(Debug)]
pub struct KvPool {
    page_size: usize,
    kv_dim: usize,
    /// Contiguous key slab, `[capacity_pages * page_size * kv_dim]`; each
    /// slot holds `kv_dim = n_kv_heads * head_dim` post-RoPE key floats.
    k: Vec<f32>,
    /// Contiguous value slab, same geometry as `k`.
    v: Vec<f32>,
    capacity_pages: usize,
    free: Vec<PageId>,
    /// Bit `id` set ⇔ page `id` is on the free list — O(1) double-free
    /// detection (the old `free.contains` scan was O(free) per release).
    free_bits: Vec<u64>,
    /// Owners per page (sequences + the prefix index).  1 on alloc;
    /// [`KvPool::release`] frees the slab range only at the last owner.
    refs: Vec<u32>,
    /// Max RaaS stamp ever observed for the page while allocated
    /// (reset on alloc).  A shared page's effective eviction stamp is the
    /// max over its sharers; the pool aggregates it here because sharers
    /// cannot see each other's tables.
    stamp_max: Vec<u64>,
    /// Pages with more than one owner, maintained by retain/release/cow —
    /// the O(1) "is any sharing active" gate the engine's eviction and
    /// stamp-aggregation fast paths check before paying per-page work.
    shared_pages: usize,
    allocated: usize,
    high_water: usize,
}

impl KvPool {
    /// `capacity_pages` pages of `page_size` tokens, `kv_dim` floats per
    /// token for K and V each.
    pub fn new(capacity_pages: usize, page_size: usize, kv_dim: usize) -> Self {
        let stride = page_size * kv_dim;
        KvPool {
            page_size,
            kv_dim,
            k: vec![0.0; capacity_pages * stride],
            v: vec![0.0; capacity_pages * stride],
            capacity_pages,
            free: (0..capacity_pages as u32).rev().collect(),
            free_bits: vec![u64::MAX; (capacity_pages + 63) / 64],
            refs: vec![0; capacity_pages],
            stamp_max: vec![0; capacity_pages],
            shared_pages: 0,
            allocated: 0,
            high_water: 0,
        }
    }

    /// Slots per page, in tokens.
    pub fn page_size(&self) -> usize {
        self.page_size
    }
    /// Floats per slot for K (and, separately, for V).
    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }
    /// Total pages the slabs were sized for.
    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }
    /// Pages currently allocated.
    pub fn allocated_pages(&self) -> usize {
        self.allocated
    }
    /// Pages on the free list (the admission-control headroom signal).
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }
    /// Highest simultaneous allocation seen since the last reset.
    pub fn high_water_pages(&self) -> usize {
        self.high_water
    }
    /// Bytes one page occupies (K + V slab shares, f32).
    pub fn bytes_per_page(&self) -> usize {
        2 * self.page_size * self.kv_dim * 4
    }
    /// Bytes currently allocated.
    pub fn allocated_bytes(&self) -> usize {
        self.allocated * self.bytes_per_page()
    }
    /// High-water allocation in bytes (the Figure-7 memory axis).
    pub fn high_water_bytes(&self) -> usize {
        self.high_water * self.bytes_per_page()
    }
    /// Restart high-water tracking from the current allocation.
    pub fn reset_high_water(&mut self) {
        self.high_water = self.allocated;
    }

    /// Slab offset of page `id`'s first float.
    fn page_off(&self, id: PageId) -> usize {
        id as usize * self.page_size * self.kv_dim
    }

    fn is_free(&self, id: PageId) -> bool {
        (self.free_bits[id as usize / 64] >> (id as usize % 64)) & 1 == 1
    }

    fn set_free(&mut self, id: PageId, free: bool) {
        let (word, bit) = (id as usize / 64, id as usize % 64);
        if free {
            self.free_bits[word] |= 1u64 << bit;
        } else {
            self.free_bits[word] &= !(1u64 << bit);
        }
    }

    /// Allocate one page off the free list; errors when the pool is
    /// exhausted (the serving layer's backpressure signal).  The caller is
    /// the sole owner (refcount 1).
    pub fn alloc(&mut self) -> Result<PageId> {
        let Some(id) = self.free.pop() else {
            bail!("kv pool exhausted ({} pages)", self.capacity_pages);
        };
        self.set_free(id, false);
        self.refs[id as usize] = 1;
        self.stamp_max[id as usize] = 0;
        self.allocated += 1;
        self.high_water = self.high_water.max(self.allocated);
        Ok(id)
    }

    /// Add one owner to an allocated page (forking copies a page table by
    /// retaining every mapped page; the prefix index retains the pages it
    /// caches).  Retaining a free page is a hard panic — it would resurrect
    /// a slab range another allocation is about to reuse.
    pub fn retain(&mut self, id: PageId) {
        assert!((id as usize) < self.capacity_pages, "retain of invalid page {id}");
        assert!(!self.is_free(id), "retain of free page {id}");
        self.refs[id as usize] += 1;
        if self.refs[id as usize] == 2 {
            self.shared_pages += 1;
        }
    }

    /// Drop one owner of a page; the slab range returns to the free list
    /// only when the last owner leaves.  Releasing a page that has already
    /// hit zero owners is a hard panic (O(1) `free_bits` check) — the
    /// double-decref twin of the PR 3 double-free guard: a freed-but-
    /// aliased page would silently corrupt another sequence's zero-copy
    /// views.
    pub fn release(&mut self, id: PageId) {
        assert!((id as usize) < self.capacity_pages, "release of invalid page {id}");
        assert!(!self.is_free(id), "double free of page {id}");
        let refs = &mut self.refs[id as usize];
        *refs -= 1;
        match *refs {
            0 => {
                self.set_free(id, true);
                self.allocated -= 1;
                self.free.push(id);
            }
            1 => self.shared_pages -= 1,
            _ => {}
        }
    }

    /// Owners of page `id` (0 for a free page).
    pub fn ref_count(&self, id: PageId) -> u32 {
        self.refs[id as usize]
    }

    /// Whether page `id` has more than one owner (a write requires
    /// [`KvPool::cow_page`] first).
    pub fn is_shared(&self, id: PageId) -> bool {
        self.refs[id as usize] > 1
    }

    /// Whether ANY page currently has more than one owner — the O(1) gate
    /// the engine checks before paying per-page sharing costs (stamp
    /// aggregation, shared-aware eviction) on the exclusive fast path.
    pub fn any_shared(&self) -> bool {
        self.shared_pages > 0
    }

    /// Copy-on-write: make page `id` exclusively owned by the caller,
    /// given `len` filled slots.  Exclusive pages are returned unchanged
    /// (the common case — zero cost).  A shared page is detached: allocate
    /// a fresh page, memcpy the first `len` slots of both slabs (the
    /// existing slab ranges, no staging buffer), drop the caller's
    /// reference on the original, and return the new id for the caller to
    /// swap into its page table.  The new page inherits the original's
    /// stamp-max (its content is the same tokens).
    pub fn cow_page(&mut self, id: PageId, len: usize) -> Result<PageId> {
        if !self.is_shared(id) {
            return Ok(id);
        }
        let new = self.alloc()?;
        let n = len * self.kv_dim;
        let src = self.page_off(id);
        let dst = self.page_off(new);
        self.k.copy_within(src..src + n, dst);
        self.v.copy_within(src..src + n, dst);
        self.stamp_max[new as usize] = self.stamp_max[id as usize];
        self.release(id);
        Ok(new)
    }

    /// Fold a sharer's observed RaaS stamp into the page's pool-level
    /// aggregate (monotone max).  Exclusive pages never consult this —
    /// their own `last_stamp` is authoritative — so feeding it is only
    /// required while [`KvPool::any_shared`] holds.
    pub fn note_stamp(&mut self, id: PageId, stamp: u64) {
        let s = &mut self.stamp_max[id as usize];
        if stamp > *s {
            *s = stamp;
        }
    }

    /// Max RaaS stamp observed for page `id` by any sharer since
    /// allocation — the shared page's effective eviction stamp
    /// (conservative: stamps from departed sharers persist, erring toward
    /// retention, and RaaS stamps are monotone in `now` so an exclusive
    /// page's aggregate equals its own stamp).
    pub fn stamp_max(&self, id: PageId) -> u64 {
        self.stamp_max[id as usize]
    }

    /// Write one token's K and V into `slot` of page `id`.
    pub fn write_slot(&mut self, id: PageId, slot: usize, k: &[f32], v: &[f32]) {
        self.write_slots(id, slot, 1, k, v);
    }

    /// Bulk write `n` consecutive tokens' K/V (`k`/`v` of `[n * kv_dim]`)
    /// into slots `slot..slot+n` of page `id` — one slab memcpy for K and
    /// one for V, the pool-direct prefill path (vs one `write_slot` call
    /// per token).
    pub fn write_slots(&mut self, id: PageId, slot: usize, n: usize, k: &[f32], v: &[f32]) {
        debug_assert!(slot + n <= self.page_size);
        debug_assert_eq!(k.len(), n * self.kv_dim);
        debug_assert_eq!(v.len(), n * self.kv_dim);
        debug_assert!(!self.is_free(id), "write to free page {id}");
        debug_assert!(!self.is_shared(id), "write to shared page {id} without copy-on-write");
        let off = self.page_off(id) + slot * self.kv_dim;
        self.k[off..off + n * self.kv_dim].copy_from_slice(k);
        self.v[off..off + n * self.kv_dim].copy_from_slice(v);
    }

    /// Copy `len` slots of page `id` into the destination slices (gather).
    pub fn read_page(&self, id: PageId, len: usize, dst_k: &mut [f32], dst_v: &mut [f32]) {
        debug_assert!(len <= self.page_size);
        let n = len * self.kv_dim;
        let off = self.page_off(id);
        dst_k[..n].copy_from_slice(&self.k[off..off + n]);
        dst_v[..n].copy_from_slice(&self.v[off..off + n]);
    }

    /// Zero-copy view of the first `len` slots of page `id`'s keys,
    /// `[len * kv_dim]` — what the paged attention path reads in place.
    pub fn page_k(&self, id: PageId, len: usize) -> &[f32] {
        debug_assert!(len <= self.page_size);
        let off = self.page_off(id);
        &self.k[off..off + len * self.kv_dim]
    }

    /// Zero-copy view of the first `len` slots of page `id`'s values.
    pub fn page_v(&self, id: PageId, len: usize) -> &[f32] {
        debug_assert!(len <= self.page_size);
        let off = self.page_off(id);
        &self.v[off..off + len * self.kv_dim]
    }

    /// Zero-copy view of one slot's key vector, `[kv_dim]`.
    pub fn slot_k(&self, id: PageId, slot: usize) -> &[f32] {
        let off = self.page_off(id) + slot * self.kv_dim;
        &self.k[off..off + self.kv_dim]
    }
    /// Zero-copy view of one slot's value vector, `[kv_dim]`.
    pub fn slot_v(&self, id: PageId, slot: usize) -> &[f32] {
        let off = self.page_off(id) + slot * self.kv_dim;
        &self.v[off..off + self.kv_dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut pool = KvPool::new(3, 16, 8);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert!(pool.alloc().is_err(), "pool should be exhausted");
        assert_eq!(pool.allocated_pages(), 3);
        pool.release(b);
        assert_eq!(pool.allocated_pages(), 2);
        let d = pool.alloc().unwrap();
        assert_eq!(d, b, "free list reuses released page");
        pool.release(a);
        pool.release(c);
        pool.release(d);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.high_water_pages(), 3);
    }

    #[test]
    #[should_panic(expected = "double free of page")]
    fn double_free_panics() {
        // Regression for the O(free)->O(1) free_bits check: releasing the
        // same page twice must still be caught (and now always, not only
        // with debug assertions).
        let mut pool = KvPool::new(4, 16, 8);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut pool = KvPool::new(1, 4, 3);
        let id = pool.alloc().unwrap();
        pool.write_slot(id, 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        pool.write_slot(id, 2, &[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        let mut k = vec![0.0; 3 * 3];
        let mut v = vec![0.0; 3 * 3];
        pool.read_page(id, 3, &mut k, &mut v);
        assert_eq!(&k[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&k[6..9], &[7.0, 8.0, 9.0]);
        assert_eq!(&v[6..9], &[10.0, 11.0, 12.0]);
        assert_eq!(pool.slot_k(id, 2), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn page_views_alias_slab_contents() {
        let mut pool = KvPool::new(3, 4, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.write_slot(a, 0, &[1.0, 2.0], &[3.0, 4.0]);
        pool.write_slot(a, 1, &[5.0, 6.0], &[7.0, 8.0]);
        pool.write_slot(b, 0, &[-1.0, -2.0], &[-3.0, -4.0]);
        assert_eq!(pool.page_k(a, 2), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(pool.page_v(a, 2), &[3.0, 4.0, 7.0, 8.0]);
        assert_eq!(pool.page_k(b, 1), &[-1.0, -2.0]);
        // views match the gather copy exactly
        let mut k = vec![0.0; 2 * 2];
        let mut v = vec![0.0; 2 * 2];
        pool.read_page(a, 2, &mut k, &mut v);
        assert_eq!(pool.page_k(a, 2), &k[..]);
        assert_eq!(pool.page_v(a, 2), &v[..]);
    }

    #[test]
    fn write_slots_matches_per_slot_writes() {
        let mut a = KvPool::new(1, 4, 3);
        let mut b = KvPool::new(1, 4, 3);
        let ia = a.alloc().unwrap();
        let ib = b.alloc().unwrap();
        let k: Vec<f32> = (0..9).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..9).map(|x| 100.0 + x as f32).collect();
        a.write_slots(ia, 1, 3, &k, &v);
        for s in 0..3 {
            b.write_slot(ib, 1 + s, &k[s * 3..(s + 1) * 3], &v[s * 3..(s + 1) * 3]);
        }
        assert_eq!(a.page_k(ia, 4), b.page_k(ib, 4));
        assert_eq!(a.page_v(ia, 4), b.page_v(ib, 4));
        assert_eq!(a.slot_k(ia, 2), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn byte_accounting() {
        let mut pool = KvPool::new(4, 16, 64);
        assert_eq!(pool.bytes_per_page(), 2 * 16 * 64 * 4);
        let _a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert_eq!(pool.allocated_bytes(), 2 * pool.bytes_per_page());
    }

    #[test]
    fn retain_release_refcount_lifecycle() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.ref_count(a), 1);
        assert!(!pool.is_shared(a));
        assert!(!pool.any_shared());
        pool.retain(a);
        assert_eq!(pool.ref_count(a), 2);
        assert!(pool.is_shared(a));
        assert!(pool.any_shared());
        // first release drops one owner; the slab range stays allocated
        pool.release(a);
        assert_eq!(pool.ref_count(a), 1);
        assert!(!pool.any_shared());
        assert_eq!(pool.allocated_pages(), 1, "shared release must not free the page");
        // last owner frees for real
        pool.release(a);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.ref_count(a), 0);
    }

    #[test]
    fn releasing_a_shared_page_does_not_recycle_its_slab_range() {
        // Eviction of a refcount-2 page from one sequence must not hand the
        // range to the next alloc: the other owner still reads it in place.
        let mut pool = KvPool::new(2, 2, 2);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        pool.retain(a);
        pool.release(a); // one owner evicts
        let b = pool.alloc().unwrap();
        assert_ne!(b, a, "shared page's range must not be reallocated");
        assert_eq!(pool.page_k(a, 2), &[1.0, 2.0, 3.0, 4.0], "survivor's bytes intact");
    }

    #[test]
    #[should_panic(expected = "double free of page")]
    fn double_decref_past_zero_panics() {
        // Satellite regression mirroring the PR 3 double-free guard: once
        // the last owner released, another release must hard-panic, not
        // wrap the refcount.
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.release(a);
        pool.release(a); // refcount hits zero: page freed
        pool.release(a); // decref past zero
    }

    #[test]
    #[should_panic(expected = "retain of free page")]
    fn retain_of_free_page_panics() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.retain(a);
    }

    #[test]
    fn cow_page_is_identity_when_exclusive() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.cow_page(a, 3).unwrap(), a);
        assert_eq!(pool.allocated_pages(), 1);
    }

    #[test]
    fn cow_page_detaches_shared_bytes() {
        let mut pool = KvPool::new(3, 3, 2);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[-1.0, -2.0, -3.0, -4.0]);
        pool.retain(a);
        let b = pool.cow_page(a, 2).unwrap();
        assert_ne!(b, a);
        assert_eq!(pool.ref_count(a), 1, "cow dropped the caller's reference");
        assert_eq!(pool.ref_count(b), 1);
        assert!(!pool.any_shared());
        // bytes copied, then divergence stays private
        assert_eq!(pool.page_k(b, 2), pool.page_k(a, 2).to_vec());
        assert_eq!(pool.page_v(b, 2), pool.page_v(a, 2).to_vec());
        pool.write_slot(b, 2, &[9.0, 9.0], &[8.0, 8.0]);
        assert_eq!(pool.page_k(a, 2), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.page_k(b, 3)[4..], [9.0, 9.0]);
    }

    #[test]
    fn stamp_max_aggregates_and_resets_on_alloc() {
        let mut pool = KvPool::new(1, 4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.stamp_max(a), 0);
        pool.note_stamp(a, 7);
        pool.note_stamp(a, 3);
        assert_eq!(pool.stamp_max(a), 7, "monotone max");
        pool.release(a);
        let b = pool.alloc().unwrap();
        assert_eq!(b, a);
        assert_eq!(pool.stamp_max(b), 0, "stale stamps cleared on realloc");
    }
}
