//! The physical page pool: a slab of fixed-size KV pages with a free list
//! and byte-accurate accounting (drives the Figure-7 memory axis and the
//! coordinator's admission control).

use anyhow::{bail, Result};

use super::page::PageId;

/// KV data for one page of one layer: `page_size` slots of post-RoPE keys
/// and raw values, each `kv_dim = n_kv_heads * head_dim` floats.
#[derive(Debug)]
struct PageData {
    k: Vec<f32>, // [page_size * kv_dim]
    v: Vec<f32>,
}

#[derive(Debug)]
pub struct KvPool {
    page_size: usize,
    kv_dim: usize,
    pages: Vec<PageData>,
    free: Vec<PageId>,
    allocated: usize,
    high_water: usize,
}

impl KvPool {
    /// `capacity_pages` pages of `page_size` tokens, `kv_dim` floats per
    /// token for K and V each.
    pub fn new(capacity_pages: usize, page_size: usize, kv_dim: usize) -> Self {
        let pages = (0..capacity_pages)
            .map(|_| PageData {
                k: vec![0.0; page_size * kv_dim],
                v: vec![0.0; page_size * kv_dim],
            })
            .collect();
        let free = (0..capacity_pages as u32).rev().collect();
        KvPool { page_size, kv_dim, pages, free, allocated: 0, high_water: 0 }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }
    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }
    pub fn capacity_pages(&self) -> usize {
        self.pages.len()
    }
    pub fn allocated_pages(&self) -> usize {
        self.allocated
    }
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }
    pub fn high_water_pages(&self) -> usize {
        self.high_water
    }
    pub fn bytes_per_page(&self) -> usize {
        2 * self.page_size * self.kv_dim * 4
    }
    pub fn allocated_bytes(&self) -> usize {
        self.allocated * self.bytes_per_page()
    }
    pub fn high_water_bytes(&self) -> usize {
        self.high_water * self.bytes_per_page()
    }
    pub fn reset_high_water(&mut self) {
        self.high_water = self.allocated;
    }

    pub fn alloc(&mut self) -> Result<PageId> {
        let Some(id) = self.free.pop() else {
            bail!("kv pool exhausted ({} pages)", self.pages.len());
        };
        self.allocated += 1;
        self.high_water = self.high_water.max(self.allocated);
        Ok(id)
    }

    pub fn release(&mut self, id: PageId) {
        debug_assert!((id as usize) < self.pages.len());
        debug_assert!(!self.free.contains(&id), "double free of page {id}");
        self.allocated -= 1;
        self.free.push(id);
    }

    /// Write one token's K and V into `slot` of page `id`.
    pub fn write_slot(&mut self, id: PageId, slot: usize, k: &[f32], v: &[f32]) {
        debug_assert!(slot < self.page_size);
        debug_assert_eq!(k.len(), self.kv_dim);
        let off = slot * self.kv_dim;
        let page = &mut self.pages[id as usize];
        page.k[off..off + self.kv_dim].copy_from_slice(k);
        page.v[off..off + self.kv_dim].copy_from_slice(v);
    }

    /// Copy `len` slots of page `id` into the destination slices (gather).
    pub fn read_page(&self, id: PageId, len: usize, dst_k: &mut [f32], dst_v: &mut [f32]) {
        debug_assert!(len <= self.page_size);
        let n = len * self.kv_dim;
        let page = &self.pages[id as usize];
        dst_k[..n].copy_from_slice(&page.k[..n]);
        dst_v[..n].copy_from_slice(&page.v[..n]);
    }

    pub fn slot_k(&self, id: PageId, slot: usize) -> &[f32] {
        let off = slot * self.kv_dim;
        &self.pages[id as usize].k[off..off + self.kv_dim]
    }
    pub fn slot_v(&self, id: PageId, slot: usize) -> &[f32] {
        let off = slot * self.kv_dim;
        &self.pages[id as usize].v[off..off + self.kv_dim]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut pool = KvPool::new(3, 16, 8);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert!(pool.alloc().is_err(), "pool should be exhausted");
        assert_eq!(pool.allocated_pages(), 3);
        pool.release(b);
        assert_eq!(pool.allocated_pages(), 2);
        let d = pool.alloc().unwrap();
        assert_eq!(d, b, "free list reuses released page");
        pool.release(a);
        pool.release(c);
        pool.release(d);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.high_water_pages(), 3);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut pool = KvPool::new(1, 4, 3);
        let id = pool.alloc().unwrap();
        pool.write_slot(id, 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        pool.write_slot(id, 2, &[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        let mut k = vec![0.0; 3 * 3];
        let mut v = vec![0.0; 3 * 3];
        pool.read_page(id, 3, &mut k, &mut v);
        assert_eq!(&k[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&k[6..9], &[7.0, 8.0, 9.0]);
        assert_eq!(&v[6..9], &[10.0, 11.0, 12.0]);
        assert_eq!(pool.slot_k(id, 2), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn byte_accounting() {
        let mut pool = KvPool::new(4, 16, 64);
        assert_eq!(pool.bytes_per_page(), 2 * 16 * 64 * 4);
        let _a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert_eq!(pool.allocated_bytes(), 2 * pool.bytes_per_page());
    }
}
