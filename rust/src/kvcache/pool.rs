//! The physical page pool: two contiguous K/V slabs carved into fixed-size
//! pages, with a free list, per-page reference counts and byte-accurate
//! accounting (drives the Figure-7 memory axis and the coordinator's
//! admission control).
//!
//! Slab layout (the zero-copy paged-attention substrate, DESIGN.md §2):
//! page `id` owns `[id * page_size * kv_dim .. (id+1) * page_size * kv_dim]`
//! of both slabs, so a resident page's K/V is a plain `&[f32]` slice
//! ([`KvPool::page_k`] / [`KvPool::page_v`]) that backends read in place —
//! no per-page allocations, no gather copy, real cache locality.
//!
//! Sharing (DESIGN.md §2, prefix sharing): pages are refcounted, so several
//! sequences' page tables — and the pool-level prefix index — can map the
//! same physical page.  [`KvPool::retain`] adds an owner,
//! [`KvPool::release`] drops one (the slab range is freed only when the
//! last owner leaves), and [`KvPool::cow_page`] is the copy-on-write step a
//! sequence takes before mutating a page it no longer owns exclusively.
//!
//! Dtypes (DESIGN.md §2, quantized slab layout): the pool is dtype-generic
//! at runtime via [`KvDtype`].  `F32` is the reference layout above.  Under
//! `Fp8E4M3`/`Int8` the pool additionally carries one-byte-per-element
//! quantized slabs plus a per-page running value range; every write updates
//! the range and re-encodes the page's filled prefix from the master `f32`
//! slab, so the quantized bytes are a pure function of the page's final
//! contents (chunking/fork/COW invariant — the bit-identity suites hold
//! under every dtype).  Attention consumes the quantized bytes through
//! [`KvPool::page_view`] / [`KvPool::read_page`]; the `f32` master doubles
//! as the simulator's reference instrumentation and is excluded from the
//! byte accounting, which reflects the quantized layout a device slab
//! would carry ([`KvPool::bytes_per_page`]).

use anyhow::Result;

use super::page::{PageData, PageId, PageView};
use super::quant::{KvDtype, QuantParams};

/// Typed allocation-failure error: the pool's free list is empty.
///
/// This is the serving layer's backpressure signal — the batcher
/// downcasts step/prefill errors to it (`err.downcast_ref::<PoolExhausted>()`)
/// to distinguish "preempt a victim and retry" from a genuine execution
/// fault (DESIGN.md §6).  Fault injectors construct it directly so
/// injected exhaustion takes the same recovery path as the real thing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    /// Total pages the pool was sized for.
    pub capacity_pages: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv pool exhausted ({} pages)", self.capacity_pages)
    }
}

impl std::error::Error for PoolExhausted {}

/// One page's bytes parked in host memory by [`KvPool::swap_out`].
#[derive(Debug, Clone)]
struct SwappedPage {
    /// Original pool id (so page tables can be remapped on swap-in).
    id: PageId,
    /// Master `f32` key slots, full page stride.
    k: Vec<f32>,
    /// Master `f32` value slots, full page stride.
    v: Vec<f32>,
    /// Quantized key bytes (empty for `F32` pools).
    qk: Vec<u8>,
    /// Quantized value bytes (empty for `F32` pools).
    qv: Vec<u8>,
    /// Running quant ranges `(k_lo, k_hi, v_lo, v_hi)`.
    ranges: (f32, f32, f32, f32),
    /// Pool-level stamp aggregate at swap-out.
    stamp_max: u64,
}

/// A set of pages held in the host-side swap buffer (restore-mode
/// preemption, DESIGN.md §6): [`KvPool::swap_out`] copies the slab
/// bytes + quant params out and frees the slab ranges;
/// [`KvPool::swap_in`] re-allocates and writes them back bit-identically.
/// The handle owns the bytes — dropping it discards the swapped state.
#[derive(Debug)]
pub struct SwapHandle {
    pages: Vec<SwappedPage>,
    /// Accounted bytes (quantized layout) the swapped pages occupied —
    /// feeds the `preempt.restore_bytes` metric.
    bytes: usize,
}

impl SwapHandle {
    /// Number of pages parked in this handle.
    pub fn pages(&self) -> usize {
        self.pages.len()
    }

    /// Accounted bytes of the parked pages (what a device slab freed).
    pub fn bytes(&self) -> usize {
        self.bytes
    }
}

/// The shared physical KV page pool (one per engine).
///
/// # Example — alloc → bulk write → zero-copy view
///
/// The paged-attention dataflow in miniature: allocate a page, write two
/// tokens' K/V in one bulk call, read them back as in-place slab views
/// (what [`crate::runtime::Backend::layer_attn_mlp_paged`] consumes):
///
/// ```
/// use raas::kvcache::KvPool;
///
/// // 4 pages × 4 slots, kv_dim 2 (floats per slot for K and for V)
/// let mut pool = KvPool::new(4, 4, 2);
/// let page = pool.alloc().unwrap();
/// let k = [1.0f32, 2.0, 3.0, 4.0]; // two slots of keys
/// let v = [5.0f32, 6.0, 7.0, 8.0]; // two slots of values
/// pool.write_slots(page, 0, 2, &k, &v);
/// assert_eq!(pool.page_k(page, 2), &k[..]); // zero-copy slab view
/// assert_eq!(pool.page_v(page, 2), &v[..]);
/// assert_eq!(pool.allocated_pages(), 1);
/// pool.release(page);
/// assert_eq!(pool.allocated_pages(), 0);
/// ```
#[derive(Debug)]
pub struct KvPool {
    page_size: usize,
    kv_dim: usize,
    /// Element dtype of the attention-visible storage.
    dtype: KvDtype,
    /// Contiguous key slab, `[capacity_pages * page_size * kv_dim]`; each
    /// slot holds `kv_dim = n_kv_heads * head_dim` post-RoPE key floats.
    /// Under a quantized dtype this is the *master* copy the quantized
    /// bytes re-encode from (reference instrumentation, not accounted).
    k: Vec<f32>,
    /// Contiguous value slab, same geometry as `k`.
    v: Vec<f32>,
    /// Quantized key slab, `[capacity_pages * page_size * kv_dim]` bytes —
    /// empty for `F32`.
    qk: Vec<u8>,
    /// Quantized value slab, same geometry as `qk`.
    qv: Vec<u8>,
    /// Per-page running key minimum/maximum (quantized dtypes only; reset
    /// on alloc).  Quant params derive from these deterministically.
    k_lo: Vec<f32>,
    /// See `k_lo`.
    k_hi: Vec<f32>,
    /// Per-page running value minimum/maximum.
    v_lo: Vec<f32>,
    /// See `v_lo`.
    v_hi: Vec<f32>,
    capacity_pages: usize,
    free: Vec<PageId>,
    /// Bit `id` set ⇔ page `id` is on the free list — O(1) double-free
    /// detection (the old `free.contains` scan was O(free) per release).
    free_bits: Vec<u64>,
    /// Owners per page (sequences + the prefix index).  1 on alloc;
    /// [`KvPool::release`] frees the slab range only at the last owner.
    refs: Vec<u32>,
    /// Max RaaS stamp ever observed for the page while allocated
    /// (reset on alloc).  A shared page's effective eviction stamp is the
    /// max over its sharers; the pool aggregates it here because sharers
    /// cannot see each other's tables.
    stamp_max: Vec<u64>,
    /// Pages with more than one owner, maintained by retain/release/cow —
    /// the O(1) "is any sharing active" gate the engine's eviction and
    /// stamp-aggregation fast paths check before paying per-page work.
    shared_pages: usize,
    allocated: usize,
    high_water: usize,
}

impl KvPool {
    /// `capacity_pages` pages of `page_size` tokens, `kv_dim` floats per
    /// token for K and V each, stored as reference `f32`
    /// (= [`KvPool::new_with_dtype`] with [`KvDtype::F32`]).
    pub fn new(capacity_pages: usize, page_size: usize, kv_dim: usize) -> Self {
        Self::new_with_dtype(capacity_pages, page_size, kv_dim, KvDtype::F32)
    }

    /// Pool with an explicit storage dtype (`--kv-dtype`); quantized
    /// dtypes add the byte slabs + per-page range metadata.
    pub fn new_with_dtype(capacity_pages: usize, page_size: usize, kv_dim: usize,
                          dtype: KvDtype) -> Self {
        let stride = page_size * kv_dim;
        let qlen = if dtype.is_quantized() { capacity_pages * stride } else { 0 };
        let plen = if dtype.is_quantized() { capacity_pages } else { 0 };
        KvPool {
            page_size,
            kv_dim,
            dtype,
            k: vec![0.0; capacity_pages * stride],
            v: vec![0.0; capacity_pages * stride],
            qk: vec![0; qlen],
            qv: vec![0; qlen],
            k_lo: vec![f32::INFINITY; plen],
            k_hi: vec![f32::NEG_INFINITY; plen],
            v_lo: vec![f32::INFINITY; plen],
            v_hi: vec![f32::NEG_INFINITY; plen],
            capacity_pages,
            free: (0..capacity_pages as u32).rev().collect(),
            free_bits: vec![u64::MAX; (capacity_pages + 63) / 64],
            refs: vec![0; capacity_pages],
            stamp_max: vec![0; capacity_pages],
            shared_pages: 0,
            allocated: 0,
            high_water: 0,
        }
    }

    /// Slots per page, in tokens.
    pub fn page_size(&self) -> usize {
        self.page_size
    }
    /// Element dtype of the attention-visible K/V storage.
    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }
    /// Floats per slot for K (and, separately, for V).
    pub fn kv_dim(&self) -> usize {
        self.kv_dim
    }
    /// Total pages the slabs were sized for.
    pub fn capacity_pages(&self) -> usize {
        self.capacity_pages
    }
    /// Pages currently allocated.
    pub fn allocated_pages(&self) -> usize {
        self.allocated
    }
    /// Pages on the free list (the admission-control headroom signal).
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }
    /// Highest simultaneous allocation seen since the last reset.
    pub fn high_water_pages(&self) -> usize {
        self.high_water
    }
    /// Bytes one page occupies in the attention-visible layout: K + V slab
    /// shares at the storage dtype's width, plus per-page quant metadata
    /// (`(scale, zero)` × K/V for quantized dtypes).  The `f32` master
    /// slab kept under quantized dtypes is sim-side reference
    /// instrumentation and deliberately not counted — this figure is what
    /// a device-resident slab of the same dtype would occupy.
    pub fn bytes_per_page(&self) -> usize {
        2 * self.page_size * self.kv_dim * self.dtype.bytes_per_elem()
            + self.dtype.page_param_bytes()
    }
    /// Bytes currently allocated.
    pub fn allocated_bytes(&self) -> usize {
        self.allocated * self.bytes_per_page()
    }
    /// High-water allocation in bytes (the Figure-7 memory axis).
    pub fn high_water_bytes(&self) -> usize {
        self.high_water * self.bytes_per_page()
    }
    /// Restart high-water tracking from the current allocation.
    pub fn reset_high_water(&mut self) {
        self.high_water = self.allocated;
    }

    /// Slab offset of page `id`'s first float.
    fn page_off(&self, id: PageId) -> usize {
        id as usize * self.page_size * self.kv_dim
    }

    fn is_free(&self, id: PageId) -> bool {
        (self.free_bits[id as usize / 64] >> (id as usize % 64)) & 1 == 1
    }

    fn set_free(&mut self, id: PageId, free: bool) {
        let (word, bit) = (id as usize / 64, id as usize % 64);
        if free {
            self.free_bits[word] |= 1u64 << bit;
        } else {
            self.free_bits[word] &= !(1u64 << bit);
        }
    }

    /// Allocate one page off the free list; errors when the pool is
    /// exhausted (the serving layer's backpressure signal).  The caller is
    /// the sole owner (refcount 1).
    pub fn alloc(&mut self) -> Result<PageId> {
        let Some(id) = self.free.pop() else {
            return Err(PoolExhausted { capacity_pages: self.capacity_pages }.into());
        };
        self.set_free(id, false);
        self.refs[id as usize] = 1;
        self.stamp_max[id as usize] = 0;
        if self.dtype.is_quantized() {
            // fresh range: the first write's fold wins
            self.k_lo[id as usize] = f32::INFINITY;
            self.k_hi[id as usize] = f32::NEG_INFINITY;
            self.v_lo[id as usize] = f32::INFINITY;
            self.v_hi[id as usize] = f32::NEG_INFINITY;
        }
        self.allocated += 1;
        self.high_water = self.high_water.max(self.allocated);
        Ok(id)
    }

    /// Add one owner to an allocated page (forking copies a page table by
    /// retaining every mapped page; the prefix index retains the pages it
    /// caches).  Retaining a free page is a hard panic — it would resurrect
    /// a slab range another allocation is about to reuse.
    pub fn retain(&mut self, id: PageId) {
        assert!((id as usize) < self.capacity_pages, "retain of invalid page {id}");
        assert!(!self.is_free(id), "retain of free page {id}");
        self.refs[id as usize] += 1;
        if self.refs[id as usize] == 2 {
            self.shared_pages += 1;
        }
    }

    /// Drop one owner of a page; the slab range returns to the free list
    /// only when the last owner leaves.  Releasing a page that has already
    /// hit zero owners is a hard panic (O(1) `free_bits` check) — the
    /// double-decref twin of the PR 3 double-free guard: a freed-but-
    /// aliased page would silently corrupt another sequence's zero-copy
    /// views.
    pub fn release(&mut self, id: PageId) {
        assert!((id as usize) < self.capacity_pages, "release of invalid page {id}");
        assert!(!self.is_free(id), "double free of page {id}");
        let refs = &mut self.refs[id as usize];
        *refs -= 1;
        match *refs {
            0 => {
                self.set_free(id, true);
                self.allocated -= 1;
                self.free.push(id);
            }
            1 => self.shared_pages -= 1,
            _ => {}
        }
    }

    /// Owners of page `id` (0 for a free page).
    pub fn ref_count(&self, id: PageId) -> u32 {
        self.refs[id as usize]
    }

    /// Whether page `id` has more than one owner (a write requires
    /// [`KvPool::cow_page`] first).
    pub fn is_shared(&self, id: PageId) -> bool {
        self.refs[id as usize] > 1
    }

    /// Whether ANY page currently has more than one owner — the O(1) gate
    /// the engine checks before paying per-page sharing costs (stamp
    /// aggregation, shared-aware eviction) on the exclusive fast path.
    pub fn any_shared(&self) -> bool {
        self.shared_pages > 0
    }

    /// Copy-on-write: make page `id` exclusively owned by the caller,
    /// given `len` filled slots.  Exclusive pages are returned unchanged
    /// (the common case — zero cost).  A shared page is detached: allocate
    /// a fresh page, memcpy the first `len` slots of both slabs (the
    /// existing slab ranges, no staging buffer), drop the caller's
    /// reference on the original, and return the new id for the caller to
    /// swap into its page table.  The new page inherits the original's
    /// stamp-max (its content is the same tokens).
    pub fn cow_page(&mut self, id: PageId, len: usize) -> Result<PageId> {
        if !self.is_shared(id) {
            return Ok(id);
        }
        let new = self.alloc()?;
        let n = len * self.kv_dim;
        let src = self.page_off(id);
        let dst = self.page_off(new);
        self.k.copy_within(src..src + n, dst);
        self.v.copy_within(src..src + n, dst);
        if self.dtype.is_quantized() {
            // scales travel with the bytes: the detached copy inherits the
            // original's running range (same tokens ⇒ same params), so its
            // quantized prefix is byte-identical until it diverges
            self.qk.copy_within(src..src + n, dst);
            self.qv.copy_within(src..src + n, dst);
            let (s, d) = (id as usize, new as usize);
            self.k_lo[d] = self.k_lo[s];
            self.k_hi[d] = self.k_hi[s];
            self.v_lo[d] = self.v_lo[s];
            self.v_hi[d] = self.v_hi[s];
        }
        self.stamp_max[new as usize] = self.stamp_max[id as usize];
        self.release(id);
        Ok(new)
    }

    /// Fold a sharer's observed RaaS stamp into the page's pool-level
    /// aggregate (monotone max).  Exclusive pages never consult this —
    /// their own `last_stamp` is authoritative — so feeding it is only
    /// required while [`KvPool::any_shared`] holds.
    pub fn note_stamp(&mut self, id: PageId, stamp: u64) {
        let s = &mut self.stamp_max[id as usize];
        if stamp > *s {
            *s = stamp;
        }
    }

    /// Max RaaS stamp observed for page `id` by any sharer since
    /// allocation — the shared page's effective eviction stamp
    /// (conservative: stamps from departed sharers persist, erring toward
    /// retention, and RaaS stamps are monotone in `now` so an exclusive
    /// page's aggregate equals its own stamp).
    pub fn stamp_max(&self, id: PageId) -> u64 {
        self.stamp_max[id as usize]
    }

    /// Write one token's K and V into `slot` of page `id`.
    pub fn write_slot(&mut self, id: PageId, slot: usize, k: &[f32], v: &[f32]) {
        self.write_slots(id, slot, 1, k, v);
    }

    /// Bulk write `n` consecutive tokens' K/V (`k`/`v` of `[n * kv_dim]`)
    /// into slots `slot..slot+n` of page `id` — one slab memcpy for K and
    /// one for V, the pool-direct prefill path (vs one `write_slot` call
    /// per token).
    ///
    /// Under a quantized dtype this is the quantize-on-append hook: the
    /// write folds into the page's running value range and re-encodes the
    /// page's filled prefix from the master slab under the updated params,
    /// making the quantized bytes a pure function of (contents, range) —
    /// independent of how writes were chunked.
    pub fn write_slots(&mut self, id: PageId, slot: usize, n: usize, k: &[f32], v: &[f32]) {
        debug_assert!(slot + n <= self.page_size);
        debug_assert_eq!(k.len(), n * self.kv_dim);
        debug_assert_eq!(v.len(), n * self.kv_dim);
        debug_assert!(!self.is_free(id), "write to free page {id}");
        debug_assert!(!self.is_shared(id), "write to shared page {id} without copy-on-write");
        let off = self.page_off(id) + slot * self.kv_dim;
        self.k[off..off + n * self.kv_dim].copy_from_slice(k);
        self.v[off..off + n * self.kv_dim].copy_from_slice(v);
        if self.dtype.is_quantized() {
            let i = id as usize;
            for &x in k {
                self.k_lo[i] = self.k_lo[i].min(x);
                self.k_hi[i] = self.k_hi[i].max(x);
            }
            for &x in v {
                self.v_lo[i] = self.v_lo[i].min(x);
                self.v_hi[i] = self.v_hi[i].max(x);
            }
            self.requantize_page(id, slot + n);
        }
    }

    /// Re-encode the first `filled` slots of page `id` from the master
    /// slab under the page's current range params.
    fn requantize_page(&mut self, id: PageId, filled: usize) {
        let (kp, vp) = self.page_params(id);
        let n = filled * self.kv_dim;
        let off = self.page_off(id);
        let dt = self.dtype;
        dt.encode_slice(&self.k[off..off + n], kp, &mut self.qk[off..off + n]);
        dt.encode_slice(&self.v[off..off + n], vp, &mut self.qv[off..off + n]);
    }

    /// Copy `len` slots of page `id` into the destination slices (gather).
    /// Under a quantized dtype the destination receives the *dequantized*
    /// stored bytes, so the gather route attends exactly what the paged
    /// route sees.
    pub fn read_page(&self, id: PageId, len: usize, dst_k: &mut [f32], dst_v: &mut [f32]) {
        debug_assert!(len <= self.page_size);
        let n = len * self.kv_dim;
        let off = self.page_off(id);
        if self.dtype.is_quantized() {
            let (kp, vp) = self.page_params(id);
            self.dtype.decode_slice(&self.qk[off..off + n], kp, &mut dst_k[..n]);
            self.dtype.decode_slice(&self.qv[off..off + n], vp, &mut dst_v[..n]);
        } else {
            dst_k[..n].copy_from_slice(&self.k[off..off + n]);
            dst_v[..n].copy_from_slice(&self.v[off..off + n]);
        }
    }

    /// Dtype-tagged zero-copy view of the first `len` slots of page `id` —
    /// what the paged attention entry points consume
    /// ([`crate::runtime::PagedAttnInput`]).  `F32` pools hand out the
    /// master slab ranges directly; quantized pools hand out the byte
    /// slabs plus the page's derived `(scale, zero)` params.
    pub fn page_view(&self, id: PageId, len: usize) -> PageView<'_> {
        debug_assert!(len <= self.page_size);
        let n = len * self.kv_dim;
        let off = self.page_off(id);
        let data = if self.dtype.is_quantized() {
            let (k_params, v_params) = self.page_params(id);
            PageData::Quant {
                dtype: self.dtype,
                k: &self.qk[off..off + n],
                v: &self.qv[off..off + n],
                k_params,
                v_params,
            }
        } else {
            PageData::F32 { k: &self.k[off..off + n], v: &self.v[off..off + n] }
        };
        PageView { len, data }
    }

    /// The `(K, V)` quantization params of page `id`, derived from its
    /// running value range (identity params for `F32`).  Deterministic:
    /// same range ⇒ same params, on every pool.
    pub fn page_params(&self, id: PageId) -> (QuantParams, QuantParams) {
        if !self.dtype.is_quantized() {
            return (QuantParams::IDENTITY, QuantParams::IDENTITY);
        }
        let i = id as usize;
        (
            self.dtype.params(self.k_lo[i], self.k_hi[i]),
            self.dtype.params(self.v_lo[i], self.v_hi[i]),
        )
    }

    /// Zero-copy view of the first `len` slots of page `id`'s *master*
    /// (`f32`) keys, `[len * kv_dim]`.  Under `F32` this is exactly what
    /// attention reads; under a quantized dtype it is the unquantized
    /// reference copy (bit-identity oracles, RepBounds folds) — attention
    /// goes through [`KvPool::page_view`] / [`KvPool::read_page`] instead.
    pub fn page_k(&self, id: PageId, len: usize) -> &[f32] {
        debug_assert!(len <= self.page_size);
        let off = self.page_off(id);
        &self.k[off..off + len * self.kv_dim]
    }

    /// Zero-copy view of the first `len` slots of page `id`'s *master*
    /// (`f32`) values (see [`KvPool::page_k`] for the dtype caveat).
    pub fn page_v(&self, id: PageId, len: usize) -> &[f32] {
        debug_assert!(len <= self.page_size);
        let off = self.page_off(id);
        &self.v[off..off + len * self.kv_dim]
    }

    /// Zero-copy view of one slot's key vector, `[kv_dim]`.
    pub fn slot_k(&self, id: PageId, slot: usize) -> &[f32] {
        let off = self.page_off(id) + slot * self.kv_dim;
        &self.k[off..off + self.kv_dim]
    }
    /// Zero-copy view of one slot's value vector, `[kv_dim]`.
    pub fn slot_v(&self, id: PageId, slot: usize) -> &[f32] {
        let off = self.page_off(id) + slot * self.kv_dim;
        &self.v[off..off + self.kv_dim]
    }

    /// Swap the given pages out to a host-side buffer (restore-mode
    /// preemption): copy each page's full slab stride (master `f32`,
    /// quantized bytes, running ranges, stamp aggregate) into the returned
    /// [`SwapHandle`] and release the slab range.  The pages must be
    /// exclusively owned by the caller — swapping a shared page out from
    /// under another sharer's zero-copy views is a hard panic, exactly
    /// like a shared write without COW.
    pub fn swap_out(&mut self, ids: &[PageId]) -> SwapHandle {
        let stride = self.page_size * self.kv_dim;
        let mut pages = Vec::with_capacity(ids.len());
        for &id in ids {
            assert!(!self.is_free(id), "swap_out of free page {id}");
            assert!(!self.is_shared(id), "swap_out of shared page {id}");
            let off = self.page_off(id);
            let i = id as usize;
            let quant = self.dtype.is_quantized();
            pages.push(SwappedPage {
                id,
                k: self.k[off..off + stride].to_vec(),
                v: self.v[off..off + stride].to_vec(),
                qk: if quant { self.qk[off..off + stride].to_vec() } else { Vec::new() },
                qv: if quant { self.qv[off..off + stride].to_vec() } else { Vec::new() },
                ranges: if quant {
                    (self.k_lo[i], self.k_hi[i], self.v_lo[i], self.v_hi[i])
                } else {
                    (0.0, 0.0, 0.0, 0.0)
                },
                stamp_max: self.stamp_max[i],
            });
            self.release(id);
        }
        let bytes = ids.len() * self.bytes_per_page();
        SwapHandle { pages, bytes }
    }

    /// Swap a parked page set back in: allocate one fresh page per entry,
    /// restore the bytes/ranges/stamps verbatim, and return the
    /// `(old_id, new_id)` remapping for the owning sequence's page tables.
    /// All-or-nothing: if the pool cannot hold the whole set the call
    /// fails with [`PoolExhausted`] *before* any allocation, leaving both
    /// the pool and the handle untouched (retryable after more pages
    /// free up).  The restored quantized bytes are the swapped-out bytes
    /// verbatim — no re-encode — so restore-mode resume is bit-identical.
    pub fn swap_in(&mut self, handle: &SwapHandle) -> Result<Vec<(PageId, PageId)>> {
        if self.free.len() < handle.pages.len() {
            return Err(PoolExhausted { capacity_pages: self.capacity_pages }.into());
        }
        let stride = self.page_size * self.kv_dim;
        let mut map = Vec::with_capacity(handle.pages.len());
        for page in &handle.pages {
            let id = self.alloc().expect("headroom checked above");
            let off = self.page_off(id);
            self.k[off..off + stride].copy_from_slice(&page.k);
            self.v[off..off + stride].copy_from_slice(&page.v);
            if self.dtype.is_quantized() {
                self.qk[off..off + stride].copy_from_slice(&page.qk);
                self.qv[off..off + stride].copy_from_slice(&page.qv);
                let i = id as usize;
                (self.k_lo[i], self.k_hi[i], self.v_lo[i], self.v_hi[i]) = page.ranges;
            }
            self.stamp_max[id as usize] = page.stamp_max;
            map.push((page.id, id));
        }
        Ok(map)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut pool = KvPool::new(3, 16, 8);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        let c = pool.alloc().unwrap();
        assert!(pool.alloc().is_err(), "pool should be exhausted");
        assert_eq!(pool.allocated_pages(), 3);
        pool.release(b);
        assert_eq!(pool.allocated_pages(), 2);
        let d = pool.alloc().unwrap();
        assert_eq!(d, b, "free list reuses released page");
        pool.release(a);
        pool.release(c);
        pool.release(d);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.high_water_pages(), 3);
    }

    #[test]
    #[should_panic(expected = "double free of page")]
    fn double_free_panics() {
        // Regression for the O(free)->O(1) free_bits check: releasing the
        // same page twice must still be caught (and now always, not only
        // with debug assertions).
        let mut pool = KvPool::new(4, 16, 8);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn write_read_roundtrip() {
        let mut pool = KvPool::new(1, 4, 3);
        let id = pool.alloc().unwrap();
        pool.write_slot(id, 0, &[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]);
        pool.write_slot(id, 2, &[7.0, 8.0, 9.0], &[10.0, 11.0, 12.0]);
        let mut k = vec![0.0; 3 * 3];
        let mut v = vec![0.0; 3 * 3];
        pool.read_page(id, 3, &mut k, &mut v);
        assert_eq!(&k[0..3], &[1.0, 2.0, 3.0]);
        assert_eq!(&k[6..9], &[7.0, 8.0, 9.0]);
        assert_eq!(&v[6..9], &[10.0, 11.0, 12.0]);
        assert_eq!(pool.slot_k(id, 2), &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn page_views_alias_slab_contents() {
        let mut pool = KvPool::new(3, 4, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.write_slot(a, 0, &[1.0, 2.0], &[3.0, 4.0]);
        pool.write_slot(a, 1, &[5.0, 6.0], &[7.0, 8.0]);
        pool.write_slot(b, 0, &[-1.0, -2.0], &[-3.0, -4.0]);
        assert_eq!(pool.page_k(a, 2), &[1.0, 2.0, 5.0, 6.0]);
        assert_eq!(pool.page_v(a, 2), &[3.0, 4.0, 7.0, 8.0]);
        assert_eq!(pool.page_k(b, 1), &[-1.0, -2.0]);
        // views match the gather copy exactly
        let mut k = vec![0.0; 2 * 2];
        let mut v = vec![0.0; 2 * 2];
        pool.read_page(a, 2, &mut k, &mut v);
        assert_eq!(pool.page_k(a, 2), &k[..]);
        assert_eq!(pool.page_v(a, 2), &v[..]);
    }

    #[test]
    fn write_slots_matches_per_slot_writes() {
        let mut a = KvPool::new(1, 4, 3);
        let mut b = KvPool::new(1, 4, 3);
        let ia = a.alloc().unwrap();
        let ib = b.alloc().unwrap();
        let k: Vec<f32> = (0..9).map(|x| x as f32).collect();
        let v: Vec<f32> = (0..9).map(|x| 100.0 + x as f32).collect();
        a.write_slots(ia, 1, 3, &k, &v);
        for s in 0..3 {
            b.write_slot(ib, 1 + s, &k[s * 3..(s + 1) * 3], &v[s * 3..(s + 1) * 3]);
        }
        assert_eq!(a.page_k(ia, 4), b.page_k(ib, 4));
        assert_eq!(a.page_v(ia, 4), b.page_v(ib, 4));
        assert_eq!(a.slot_k(ia, 2), &[3.0, 4.0, 5.0]);
    }

    #[test]
    fn byte_accounting() {
        let mut pool = KvPool::new(4, 16, 64);
        assert_eq!(pool.bytes_per_page(), 2 * 16 * 64 * 4);
        let _a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        assert_eq!(pool.allocated_bytes(), 2 * pool.bytes_per_page());
    }

    #[test]
    fn retain_release_refcount_lifecycle() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.ref_count(a), 1);
        assert!(!pool.is_shared(a));
        assert!(!pool.any_shared());
        pool.retain(a);
        assert_eq!(pool.ref_count(a), 2);
        assert!(pool.is_shared(a));
        assert!(pool.any_shared());
        // first release drops one owner; the slab range stays allocated
        pool.release(a);
        assert_eq!(pool.ref_count(a), 1);
        assert!(!pool.any_shared());
        assert_eq!(pool.allocated_pages(), 1, "shared release must not free the page");
        // last owner frees for real
        pool.release(a);
        assert_eq!(pool.allocated_pages(), 0);
        assert_eq!(pool.ref_count(a), 0);
    }

    #[test]
    fn releasing_a_shared_page_does_not_recycle_its_slab_range() {
        // Eviction of a refcount-2 page from one sequence must not hand the
        // range to the next alloc: the other owner still reads it in place.
        let mut pool = KvPool::new(2, 2, 2);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        pool.retain(a);
        pool.release(a); // one owner evicts
        let b = pool.alloc().unwrap();
        assert_ne!(b, a, "shared page's range must not be reallocated");
        assert_eq!(pool.page_k(a, 2), &[1.0, 2.0, 3.0, 4.0], "survivor's bytes intact");
    }

    #[test]
    #[should_panic(expected = "double free of page")]
    fn double_decref_past_zero_panics() {
        // Satellite regression mirroring the PR 3 double-free guard: once
        // the last owner released, another release must hard-panic, not
        // wrap the refcount.
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.release(a);
        pool.release(a); // refcount hits zero: page freed
        pool.release(a); // decref past zero
    }

    #[test]
    #[should_panic(expected = "retain of free page")]
    fn retain_of_free_page_panics() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.retain(a);
    }

    #[test]
    fn cow_page_is_identity_when_exclusive() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.cow_page(a, 3).unwrap(), a);
        assert_eq!(pool.allocated_pages(), 1);
    }

    #[test]
    fn cow_page_detaches_shared_bytes() {
        let mut pool = KvPool::new(3, 3, 2);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[-1.0, -2.0, -3.0, -4.0]);
        pool.retain(a);
        let b = pool.cow_page(a, 2).unwrap();
        assert_ne!(b, a);
        assert_eq!(pool.ref_count(a), 1, "cow dropped the caller's reference");
        assert_eq!(pool.ref_count(b), 1);
        assert!(!pool.any_shared());
        // bytes copied, then divergence stays private
        assert_eq!(pool.page_k(b, 2), pool.page_k(a, 2).to_vec());
        assert_eq!(pool.page_v(b, 2), pool.page_v(a, 2).to_vec());
        pool.write_slot(b, 2, &[9.0, 9.0], &[8.0, 8.0]);
        assert_eq!(pool.page_k(a, 2), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.page_k(b, 3)[4..], [9.0, 9.0]);
    }

    #[test]
    fn quant_bytes_per_page_accounting() {
        // sim-default geometry: 16 slots × kv_dim 64
        let f32_pool = KvPool::new(4, 16, 64);
        assert_eq!(f32_pool.bytes_per_page(), 2 * 16 * 64 * 4);
        for d in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let q = KvPool::new_with_dtype(4, 16, 64, d);
            assert_eq!(q.dtype(), d);
            assert_eq!(q.bytes_per_page(), 2 * 16 * 64 + 16);
            assert!(
                f32_pool.bytes_per_page() >= 2 * q.bytes_per_page(),
                "quantized page must be at least 2x smaller"
            );
        }
    }

    #[test]
    fn quant_roundtrip_within_bound() {
        for d in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let mut pool = KvPool::new_with_dtype(2, 4, 3, d);
            let id = pool.alloc().unwrap();
            let k = [0.5f32, -2.0, 7.25, 0.0, 3.5, -0.125];
            let v = [10.0f32, -10.0, 0.25, 4.0, -1.0, 2.0];
            pool.write_slots(id, 0, 2, &k, &v);
            let (kp, vp) = pool.page_params(id);
            let mut dk = vec![0.0f32; 6];
            let mut dv = vec![0.0f32; 6];
            pool.read_page(id, 2, &mut dk, &mut dv);
            for i in 0..6 {
                assert!((dk[i] - k[i]).abs() <= d.error_bound(k[i], kp), "{d} k[{i}]");
                assert!((dv[i] - v[i]).abs() <= d.error_bound(v[i], vp), "{d} v[{i}]");
            }
            // master stays exact; the view exposes the quantized bytes
            assert_eq!(pool.page_k(id, 2), &k[..]);
            match pool.page_view(id, 2).data {
                PageData::Quant { dtype, k: qb, .. } => {
                    assert_eq!(dtype, d);
                    assert_eq!(qb.len(), 6);
                }
                PageData::F32 { .. } => panic!("quant pool must hand out quant views"),
            }
        }
    }

    #[test]
    fn quant_bytes_are_chunking_invariant() {
        // the same slot contents written as one run vs slot-by-slot must
        // produce byte-identical quantized slabs AND identical params —
        // the property that keeps chunked/monolithic prefill bit-identical
        // under quantized dtypes
        for d in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let mut a = KvPool::new_with_dtype(1, 4, 3, d);
            let mut b = KvPool::new_with_dtype(1, 4, 3, d);
            let ia = a.alloc().unwrap();
            let ib = b.alloc().unwrap();
            let k: Vec<f32> = (0..12).map(|x| (x as f32 - 6.0) * 1.7).collect();
            let v: Vec<f32> = (0..12).map(|x| (x as f32).sin() * 40.0).collect();
            a.write_slots(ia, 0, 4, &k, &v);
            for s in 0..4 {
                b.write_slots(ib, s, 1, &k[s * 3..(s + 1) * 3], &v[s * 3..(s + 1) * 3]);
            }
            assert_eq!(a.page_params(ia), b.page_params(ib), "{d}: params must match");
            let (mut ka, mut va) = (vec![0.0; 12], vec![0.0; 12]);
            let (mut kb, mut vb) = (vec![0.0; 12], vec![0.0; 12]);
            a.read_page(ia, 4, &mut ka, &mut va);
            b.read_page(ib, 4, &mut kb, &mut vb);
            let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&ka), bits(&kb), "{d}: dequantized keys must be bit-identical");
            assert_eq!(bits(&va), bits(&vb), "{d}: dequantized values must be bit-identical");
        }
    }

    #[test]
    fn cow_preserves_quant_params_until_divergence() {
        // COW divergence with scales: the detached copy must carry the
        // original's bytes AND params; post-divergence writes must only
        // change the copy
        let d = KvDtype::Int8;
        let mut pool = KvPool::new_with_dtype(3, 3, 2, d);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[-1.0, -2.0, -3.0, -4.0]);
        let params_a = pool.page_params(a);
        pool.retain(a);
        let b = pool.cow_page(a, 2).unwrap();
        assert_ne!(b, a);
        assert_eq!(pool.page_params(b), params_a, "detached copy inherits params");
        let (mut ka, mut va) = (vec![0.0; 4], vec![0.0; 4]);
        let (mut kb, mut vb) = (vec![0.0; 4], vec![0.0; 4]);
        pool.read_page(a, 2, &mut ka, &mut va);
        pool.read_page(b, 2, &mut kb, &mut vb);
        assert_eq!(ka, kb, "copied prefix dequantizes identically");
        assert_eq!(va, vb);
        // divergent write widens only the copy's range
        pool.write_slots(b, 2, 1, &[100.0, -50.0], &[7.0, 7.0]);
        assert_eq!(pool.page_params(a), params_a, "original's params untouched");
        assert_ne!(pool.page_params(b), params_a, "copy re-derives params");
        let (mut ka2, mut va2) = (vec![0.0; 4], vec![0.0; 4]);
        pool.read_page(a, 2, &mut ka2, &mut va2);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&ka), bits(&ka2), "original's dequant bytes untouched");
    }

    #[test]
    fn f32_pool_views_stay_master_backed() {
        // the F32 tag must keep today's zero-copy semantics exactly
        let mut pool = KvPool::new(2, 4, 2);
        assert_eq!(pool.dtype(), KvDtype::F32);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        match pool.page_view(a, 2).data {
            PageData::F32 { k, v } => {
                assert!(std::ptr::eq(k.as_ptr(), pool.page_k(a, 2).as_ptr()));
                assert_eq!(k, pool.page_k(a, 2));
                assert_eq!(v, pool.page_v(a, 2));
            }
            PageData::Quant { .. } => panic!("F32 pool must hand out f32 views"),
        }
        let (kp, vp) = pool.page_params(a);
        assert_eq!((kp.scale, kp.zero, vp.scale, vp.zero), (1.0, 0.0, 1.0, 0.0));
    }

    #[test]
    fn exhaustion_error_is_typed_and_non_mutating() {
        // Satellite: pin pool-exhaustion-during-decode behavior at the
        // pool layer — a failed alloc is the typed `PoolExhausted` signal,
        // mutates nothing (no phantom allocation, no free_bits drift), and
        // the pool stays fully usable after pages are released.
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        let _b = pool.alloc().unwrap();
        let err = pool.alloc().unwrap_err();
        let typed = err.downcast_ref::<PoolExhausted>().expect("typed exhaustion error");
        assert_eq!(typed.capacity_pages, 2);
        assert_eq!(pool.allocated_pages(), 2, "failed alloc must not count");
        assert_eq!(pool.free_pages(), 0);
        // recovery: release → the exact same page comes back, refcounted 1
        pool.release(a);
        assert_eq!(pool.free_pages(), 1);
        let c = pool.alloc().unwrap();
        assert_eq!(c, a);
        assert_eq!(pool.ref_count(c), 1);
    }

    #[test]
    fn mid_decode_exhaustion_releases_cleanly_without_leak() {
        // Satellite: the decode-shaped exhaustion scenario — a sequence
        // holds pages, the next alloc fails, the sequence is torn down.
        // Every held page must return to the free list exactly once
        // (the free_bits double-free guard stays armed throughout).
        let mut pool = KvPool::new(3, 4, 2);
        let held: Vec<_> = (0..3).map(|_| pool.alloc().unwrap()).collect();
        assert!(pool.alloc().unwrap_err().downcast_ref::<PoolExhausted>().is_some());
        for &id in &held {
            pool.release(id);
        }
        assert_eq!(pool.allocated_pages(), 0, "no leaked pages after teardown");
        assert_eq!(pool.free_pages(), 3);
        // and the guard still fires on a second release
        let a = pool.alloc().unwrap();
        pool.release(a);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.release(a);
        }));
        assert!(result.is_err(), "double free must still panic after exhaustion recovery");
    }

    #[test]
    fn swap_roundtrip_restores_bytes_and_frees_while_parked() {
        let mut pool = KvPool::new(2, 2, 2);
        let a = pool.alloc().unwrap();
        pool.write_slots(a, 0, 2, &[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0]);
        pool.note_stamp(a, 9);
        let handle = pool.swap_out(&[a]);
        assert_eq!(handle.pages(), 1);
        assert_eq!(handle.bytes(), pool.bytes_per_page());
        assert_eq!(pool.allocated_pages(), 0, "swap_out frees the slab range");
        // the freed range is reusable while the page is parked
        let filler = pool.alloc().unwrap();
        pool.write_slots(filler, 0, 1, &[-9.0, -9.0], &[-9.0, -9.0]);
        let map = pool.swap_in(&handle).unwrap();
        assert_eq!(map.len(), 1);
        assert_eq!(map[0].0, a, "mapping keys on the original id");
        let new = map[0].1;
        assert_eq!(pool.page_k(new, 2), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(pool.page_v(new, 2), &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(pool.stamp_max(new), 9, "stamp aggregate survives the roundtrip");
        pool.release(new);
        pool.release(filler);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn swap_in_is_all_or_nothing_under_pressure() {
        let mut pool = KvPool::new(2, 2, 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        pool.write_slots(a, 0, 1, &[1.0, 1.0], &[1.0, 1.0]);
        pool.write_slots(b, 0, 1, &[2.0, 2.0], &[2.0, 2.0]);
        let handle = pool.swap_out(&[a, b]);
        // occupy one page: swap-in of two must fail before allocating any
        let filler = pool.alloc().unwrap();
        let err = pool.swap_in(&handle).unwrap_err();
        assert!(err.downcast_ref::<PoolExhausted>().is_some());
        assert_eq!(pool.allocated_pages(), 1, "failed swap_in must not half-allocate");
        // retryable: free the filler and the same handle swaps in whole
        pool.release(filler);
        let map = pool.swap_in(&handle).unwrap();
        assert_eq!(map.len(), 2);
        assert_eq!(pool.page_k(map[0].1, 1), &[1.0, 1.0]);
        assert_eq!(pool.page_k(map[1].1, 1), &[2.0, 2.0]);
    }

    #[test]
    fn swap_roundtrip_preserves_quantized_bytes_verbatim() {
        // restore-mode bit-identity depends on the quantized bytes and
        // params surviving the roundtrip without a re-encode
        for d in [KvDtype::Int8, KvDtype::Fp8E4M3] {
            let mut pool = KvPool::new_with_dtype(2, 4, 3, d);
            let a = pool.alloc().unwrap();
            let k = [0.5f32, -2.0, 7.25, 0.0, 3.5, -0.125];
            let v = [10.0f32, -10.0, 0.25, 4.0, -1.0, 2.0];
            pool.write_slots(a, 0, 2, &k, &v);
            let params = pool.page_params(a);
            let (mut k0, mut v0) = (vec![0.0f32; 6], vec![0.0f32; 6]);
            pool.read_page(a, 2, &mut k0, &mut v0);
            let handle = pool.swap_out(&[a]);
            let map = pool.swap_in(&handle).unwrap();
            let new = map[0].1;
            assert_eq!(pool.page_params(new), params, "{d}: params survive");
            let (mut k1, mut v1) = (vec![0.0f32; 6], vec![0.0f32; 6]);
            pool.read_page(new, 2, &mut k1, &mut v1);
            let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
            assert_eq!(bits(&k0), bits(&k1), "{d}: dequant keys bit-identical");
            assert_eq!(bits(&v0), bits(&v1), "{d}: dequant values bit-identical");
        }
    }

    #[test]
    #[should_panic(expected = "swap_out of shared page")]
    fn swap_out_of_shared_page_panics() {
        let mut pool = KvPool::new(2, 4, 2);
        let a = pool.alloc().unwrap();
        pool.retain(a);
        pool.swap_out(&[a]);
    }

    #[test]
    fn stamp_max_aggregates_and_resets_on_alloc() {
        let mut pool = KvPool::new(1, 4, 2);
        let a = pool.alloc().unwrap();
        assert_eq!(pool.stamp_max(a), 0);
        pool.note_stamp(a, 7);
        pool.note_stamp(a, 3);
        assert_eq!(pool.stamp_max(a), 7, "monotone max");
        pool.release(a);
        let b = pool.alloc().unwrap();
        assert_eq!(b, a);
        assert_eq!(pool.stamp_max(b), 0, "stale stamps cleared on realloc");
    }
}
