//! Pool-level prefix index: maps page-granular token-prefix hashes to
//! already-resident physical pages so a new sequence with a shared prefix
//! (system prompt, few-shot header, multi-turn history) attaches those
//! pages instead of re-running `prefill_chunk` over them — the prefix-cache
//! TTFT win (DESIGN.md §2).
//!
//! Keys are chained FNV-1a hashes over the little-endian token bytes at
//! page boundaries: page `n`'s key hashes tokens `0..(n+1)*page_size`, so a
//! key identifies the whole prefix up to and including that page, not just
//! the page's own tokens.  Because causal attention makes a page's K/V a
//! pure function of the tokens at and before it, the cached slab bytes are
//! exactly what a fresh prefill would have written — which is what the
//! bit-identity suites pin.  Each entry additionally stores its final
//! page's raw tokens as a collision guard: a lookup only hits when the
//! tokens match, so a 64-bit hash collision degrades to a miss, never to
//! wrong KV state.
//!
//! The index is an owner like any sequence: it retains pages on insert and
//! releases them on reclaim, so a cached page survives the sequence that
//! produced it.  `BTreeMap` keeps iteration (and therefore LRU tie-breaks)
//! deterministic.
//!
//! Dtype-generic by construction: entries store only `(PageId, RepBounds)`,
//! and under a quantized pool ([`super::quant::KvDtype`]) the quantized
//! bytes and per-page `(scale, zero)` params are pool-resident state keyed
//! by that id — so a warm attach shares them automatically, and a sharer
//! dequantizes bit-identically to the donor.

use std::collections::BTreeMap;

use super::page::{PageId, RepBounds};
use super::pool::KvPool;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `tokens` (little-endian byte order) into a running FNV-1a state.
/// Chaining page hashes — `h1 = fnv1a_chain(FNV-offset, page0)`,
/// `h2 = fnv1a_chain(h1, page1)`, … — makes each page's key cover the
/// entire prefix before it.
pub fn fnv1a_chain(seed: u64, tokens: &[u32]) -> u64 {
    let mut h = seed;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Chained prefix hash per FULL page of `tokens`: entry `n` keys pages
/// `0..=n`, i.e. tokens `0..(n+1)*page_size`.  A trailing partial page
/// produces no hash — only full pages are cacheable.
pub fn prefix_hashes(tokens: &[u32], page_size: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() / page_size);
    let mut h = FNV_OFFSET;
    for page in tokens.chunks_exact(page_size) {
        h = fnv1a_chain(h, page);
        out.push(h);
    }
    out
}

/// One cached prefix page: the physical page (+ rep bounds) per layer,
/// the page's raw tokens (collision guard) and an LRU tick.
#[derive(Debug)]
struct PrefixEntry {
    /// This page's own tokens (`page_size` of them) — verified on lookup.
    tokens: Vec<u32>,
    /// `(physical page, rep bounds)` per layer, index = layer.
    pages: Vec<(PageId, RepBounds)>,
    /// Monotone tick of the last hit or insert (LRU victim = minimum).
    last_hit: u64,
}

/// The pool-level prefix cache: chained-hash → per-layer resident pages,
/// capacity-capped with deterministic LRU reclaim.
#[derive(Debug)]
pub struct PrefixIndex {
    entries: BTreeMap<u64, PrefixEntry>,
    /// Max entries held; one entry retains `n_layers` physical pages.
    cap_entries: usize,
    tick: u64,
}

impl PrefixIndex {
    /// Empty index holding at most `cap_entries` cached pages (each entry
    /// retains one physical page per layer).
    pub fn new(cap_entries: usize) -> Self {
        PrefixIndex { entries: BTreeMap::new(), cap_entries, tick: 0 }
    }

    /// Cached entries currently held.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the index holds nothing.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entry capacity this index reclaims down to.
    pub fn cap_entries(&self) -> usize {
        self.cap_entries
    }

    /// Look up the prefix page keyed by `hash`, verifying the page's own
    /// tokens against `page_tokens` (hash collisions degrade to a miss).
    /// A hit refreshes the entry's LRU tick and returns the per-layer
    /// `(page, rep bounds)` list; the caller attaches via
    /// [`super::seq::SeqCache::attach_shared_page`], which retains.
    pub fn lookup(&mut self, hash: u64, page_tokens: &[u32]) -> Option<&[(PageId, RepBounds)]> {
        self.tick += 1;
        let e = self.entries.get_mut(&hash)?;
        if e.tokens != page_tokens {
            return None;
        }
        e.last_hit = self.tick;
        Some(&e.pages)
    }

    /// Cache one full prefill page under `hash`: the index retains every
    /// physical page in `pages` and becomes a co-owner.  Returns `false`
    /// (retaining nothing) if the key is already present.  Call
    /// [`PrefixIndex::reclaim`] afterwards to enforce the capacity cap.
    pub fn insert(&mut self, hash: u64, page_tokens: &[u32], pages: Vec<(PageId, RepBounds)>,
                  pool: &mut KvPool) -> bool {
        if self.cap_entries == 0 || self.entries.contains_key(&hash) {
            return false;
        }
        self.tick += 1;
        for &(id, _) in &pages {
            pool.retain(id);
        }
        self.entries
            .insert(hash, PrefixEntry { tokens: page_tokens.to_vec(), pages, last_hit: self.tick });
        true
    }

    /// Evict least-recently-hit entries until at most `cap_entries` remain
    /// (ties broken by smallest hash — `BTreeMap` order — for determinism),
    /// releasing their pages.  Returns the number of entries evicted.
    pub fn reclaim(&mut self, pool: &mut KvPool) -> usize {
        let mut evicted = 0usize;
        while self.entries.len() > self.cap_entries {
            let victim = self
                .entries
                .iter()
                .min_by_key(|(hash, e)| (e.last_hit, **hash))
                .map(|(hash, _)| *hash)
                .expect("non-empty index over capacity");
            let e = self.entries.remove(&victim).expect("victim present");
            for (id, _) in e.pages {
                pool.release(id);
            }
            evicted += 1;
        }
        evicted
    }

    /// Drop every entry, releasing all retained pages (engine teardown, or
    /// tests asserting pool drain).
    pub fn release_all(&mut self, pool: &mut KvPool) {
        for (_, e) in std::mem::take(&mut self.entries) {
            for (id, _) in e.pages {
                pool.release(id);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_pool() -> KvPool {
        KvPool::new(16, 4, 2)
    }

    fn mk_pages(pool: &mut KvPool, n_layers: usize) -> Vec<(PageId, RepBounds)> {
        (0..n_layers)
            .map(|_| (pool.alloc().unwrap(), RepBounds::empty(pool.kv_dim())))
            .collect()
    }

    #[test]
    fn chained_hashes_cover_full_pages_only() {
        let toks: Vec<u32> = (0..11).collect(); // page_size 4 -> 2 full pages
        let hs = prefix_hashes(&toks, 4);
        assert_eq!(hs.len(), 2);
        // chaining: page 1's key depends on page 0's tokens
        let direct = fnv1a_chain(fnv1a_chain(FNV_OFFSET, &toks[..4]), &toks[4..8]);
        assert_eq!(hs[1], direct);
        // a different first page changes the second key too
        let mut other = toks.clone();
        other[0] = 99;
        assert_ne!(prefix_hashes(&other, 4)[1], hs[1]);
        // same prefix, longer prompt: identical leading keys
        let longer: Vec<u32> = (0..40).collect();
        assert_eq!(prefix_hashes(&longer, 4)[..2], hs[..]);
    }

    #[test]
    fn insert_retains_and_lookup_hits_with_matching_tokens() {
        let mut pool = mk_pool();
        let mut idx = PrefixIndex::new(8);
        let pages = mk_pages(&mut pool, 2);
        let ids: Vec<PageId> = pages.iter().map(|&(id, _)| id).collect();
        let toks = [1u32, 2, 3, 4];
        assert!(idx.insert(42, &toks, pages, &mut pool));
        for &id in &ids {
            assert_eq!(pool.ref_count(id), 2, "index co-owns the page");
        }
        let hit = idx.lookup(42, &toks).expect("hit");
        assert_eq!(hit.len(), 2);
        assert_eq!(hit[0].0, ids[0]);
        // wrong tokens under the same hash: collision guard forces a miss
        assert!(idx.lookup(42, &[9, 9, 9, 9]).is_none());
        assert!(idx.lookup(7, &toks).is_none(), "unknown key misses");
        // duplicate insert is a no-op that retains nothing
        let dup = mk_pages(&mut pool, 2);
        assert!(!idx.insert(42, &toks, dup.clone(), &mut pool));
        for &(id, _) in &dup {
            assert_eq!(pool.ref_count(id), 1);
            pool.release(id);
        }
        idx.release_all(&mut pool);
        for &id in &ids {
            assert_eq!(pool.ref_count(id), 1, "release_all drops the index's ref only");
            pool.release(id);
        }
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn reclaim_evicts_lru_and_releases_pages() {
        let mut pool = mk_pool();
        let mut idx = PrefixIndex::new(2);
        let mut ids = Vec::new();
        for h in [10u64, 20, 30] {
            let pages = mk_pages(&mut pool, 1);
            ids.push(pages[0].0);
            idx.insert(h, &[h as u32; 4], pages, &mut pool);
        }
        // refresh 10 so 20 becomes the LRU victim
        assert!(idx.lookup(10, &[10u32; 4]).is_some());
        assert_eq!(idx.reclaim(&mut pool), 1);
        assert_eq!(idx.len(), 2);
        assert!(idx.lookup(20, &[20u32; 4]).is_none(), "LRU entry evicted");
        assert!(idx.lookup(10, &[10u32; 4]).is_some());
        assert!(idx.lookup(30, &[30u32; 4]).is_some());
        assert_eq!(pool.ref_count(ids[1]), 1, "evicted entry released its page");
        idx.release_all(&mut pool);
        assert!(idx.is_empty());
        for id in ids {
            pool.release(id);
        }
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn quantized_pages_attach_with_pool_resident_params() {
        // Entries store only (PageId, RepBounds); under a quantized pool
        // the bytes and per-page (scale, zero) live in the pool keyed by
        // that id, so a warm attach inherits them automatically and reads
        // bit-identically to the donor — even after the donor departs.
        use super::super::quant::KvDtype;
        let mut pool = KvPool::new_with_dtype(4, 4, 2, KvDtype::Int8);
        let mut idx = PrefixIndex::new(4);
        let id = pool.alloc().unwrap();
        let k: Vec<f32> = (0..8).map(|i| (i as f32) * 1.25 - 3.0).collect();
        let v: Vec<f32> = (0..8).map(|i| 5.0 - (i as f32) * 0.75).collect();
        pool.write_slots(id, 0, 4, &k, &v);
        let params = pool.page_params(id);
        let (mut dk, mut dv) = (vec![0.0; 8], vec![0.0; 8]);
        pool.read_page(id, 4, &mut dk, &mut dv);
        assert!(idx.insert(9, &[1, 2, 3, 4], vec![(id, RepBounds::empty(2))], &mut pool));
        assert_eq!(pool.ref_count(id), 2, "index co-owns the quantized page");
        let attached = idx.lookup(9, &[1, 2, 3, 4]).expect("warm hit")[0].0;
        assert_eq!(attached, id, "a hit attaches the resident physical page");
        assert_eq!(pool.page_params(attached), params);
        // donor departs; the index keeps the page, its bytes AND its params
        pool.release(id);
        assert_eq!(pool.allocated_pages(), 1);
        assert_eq!(pool.page_params(attached), params);
        let (mut ak, mut av) = (vec![0.0; 8], vec![0.0; 8]);
        pool.read_page(attached, 4, &mut ak, &mut av);
        let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&ak), bits(&dk), "attached keys dequantize like the donor's");
        assert_eq!(bits(&av), bits(&dv), "attached values dequantize like the donor's");
        idx.release_all(&mut pool);
        assert_eq!(pool.allocated_pages(), 0);
    }

    #[test]
    fn zero_capacity_index_caches_nothing() {
        let mut pool = mk_pool();
        let mut idx = PrefixIndex::new(0);
        let pages = mk_pages(&mut pool, 1);
        let id = pages[0].0;
        assert!(!idx.insert(1, &[0; 4], pages, &mut pool));
        assert_eq!(pool.ref_count(id), 1, "disabled index must not retain");
        pool.release(id);
    }
}
