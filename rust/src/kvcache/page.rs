//! Page metadata: what the sparsity policies reason about, plus the
//! dtype-tagged [`PageView`] the paged attention route consumes.

use super::quant::{KvDtype, QuantParams};

/// Index into the pool's contiguous K/V slabs: page `id` owns slab range
/// `[id * page_size * kv_dim .. (id+1) * page_size * kv_dim]`
/// (`KvPool::page_k`/`page_v` hand out that range as a zero-copy view).
pub type PageId = u32;

/// Per-page bookkeeping.  One `PageMeta` per (sequence, layer, page).
#[derive(Debug, Clone)]
pub struct PageMeta {
    /// Pool slab index holding this page's KV data (u32::MAX in simulation,
    /// where no real KV bytes exist).
    pub pool_id: PageId,
    /// Absolute position of the first token in this page.
    pub start_pos: usize,
    /// Number of filled slots (≤ page_size).
    pub len: usize,
    /// Prefill pages are pinned: RaaS never evicts them (phoenix protection).
    pub pinned: bool,
    /// RaaS: last step at which this page's estimated attention score
    /// exceeded alpha (or placed in the top-r fraction).
    pub last_stamp: u64,
    /// Policy accumulator: H2O's lifetime attention mass, or RPC's frozen
    /// importance snapshot (copied from `win_score` at each compression).
    pub acc_score: f64,
    /// RPC: exponentially-decayed recent-window attention mass — the
    /// running selector score `acc_score` is frozen from every
    /// `rpc_period` steps.
    pub win_score: f64,
}

/// Sentinel pool id for simulator-only pages that hold no real KV bytes.
pub const NO_POOL: PageId = u32::MAX;

impl PageMeta {
    /// Fresh empty page starting at `start_pos`, stamped `now`.
    pub fn new(pool_id: PageId, start_pos: usize, pinned: bool, now: u64) -> Self {
        PageMeta {
            pool_id,
            start_pos,
            len: 0,
            pinned,
            last_stamp: now,
            acc_score: 0.0,
            win_score: 0.0,
        }
    }
    /// One past the absolute position of the last filled slot.
    pub fn end_pos(&self) -> usize {
        self.start_pos + self.len
    }
}

/// One page's K/V storage as the paged attention route sees it: either
/// zero-copy `f32` slab ranges (the reference dtype) or quantized bytes
/// plus the page's affine dequantization params.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PageData<'p> {
    /// Reference storage: in-place `f32` slab views, `[len * kv_dim]`.
    F32 {
        /// Keys.
        k: &'p [f32],
        /// Values.
        v: &'p [f32],
    },
    /// Quantized storage: one byte per element, `[len * kv_dim]`, decoded
    /// as `zero + scale * code(q)` per stream.
    Quant {
        /// Element encoding.
        dtype: KvDtype,
        /// Quantized keys.
        k: &'p [u8],
        /// Quantized values.
        v: &'p [u8],
        /// Key-stream dequantization params.
        k_params: QuantParams,
        /// Value-stream dequantization params.
        v_params: QuantParams,
    },
}

/// A dtype-tagged, zero-copy view of one resident page's filled slots —
/// the element type of [`crate::runtime::PagedAttnInput::pages`].  `F32`
/// views alias the pool's master slab; quantized views alias the byte
/// slabs and carry the page's `(scale, zero)` params so backends can
/// dequantize into scratch (or fuse the dequant into their kernels).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PageView<'p> {
    /// Number of live slots in the view.
    pub len: usize,
    /// The page's storage at its pool dtype.
    pub data: PageData<'p>,
}

impl PageView<'_> {
    /// The empty `f32` view — inline-buffer filler
    /// ([`crate::kvcache::PageViewBuf`]).
    pub const EMPTY: PageView<'static> = PageView { len: 0, data: PageData::F32 { k: &[], v: &[] } };

    /// Dequantize (or copy) this view's keys into `dst`
    /// (`[len * kv_dim]`) — the gather-route bridge for backends that
    /// want contiguous `f32` regardless of the pool dtype.
    pub fn copy_k_into(&self, dst: &mut [f32]) {
        match self.data {
            PageData::F32 { k, .. } => dst.copy_from_slice(k),
            PageData::Quant { dtype, k, k_params, .. } => dtype.decode_slice(k, k_params, dst),
        }
    }

    /// Dequantize (or copy) this view's values into `dst` (`[len * kv_dim]`).
    pub fn copy_v_into(&self, dst: &mut [f32]) {
        match self.data {
            PageData::F32 { v, .. } => dst.copy_from_slice(v),
            PageData::Quant { dtype, v, v_params, .. } => dtype.decode_slice(v, v_params, dst),
        }
    }

    /// Whether two views alias the same storage bytes (same slab range of
    /// the same pool) — the O(1) identity check behind cross-item work
    /// reuse in batched paged attention.
    pub fn same_storage(&self, other: &PageView<'_>) -> bool {
        if self.len != other.len {
            return false;
        }
        match (&self.data, &other.data) {
            (PageData::F32 { k: a, .. }, PageData::F32 { k: b, .. }) => {
                std::ptr::eq(a.as_ptr(), b.as_ptr())
            }
            (PageData::Quant { k: a, .. }, PageData::Quant { k: b, .. }) => {
                std::ptr::eq(a.as_ptr(), b.as_ptr())
            }
            _ => false,
        }
    }
}

/// Quest-style representative key bounds for one page (one layer):
/// channelwise min/max over the page's post-RoPE keys, per kv head.
#[derive(Debug, Clone)]
pub struct RepBounds {
    /// Channelwise minimum, `[n_kv_heads * head_dim]`.
    pub kmin: Vec<f32>,
    /// Channelwise maximum, `[n_kv_heads * head_dim]`.
    pub kmax: Vec<f32>,
}

impl RepBounds {
    /// Bounds over zero keys (+inf/-inf, so the first fold wins).
    pub fn empty(kv_dim: usize) -> Self {
        RepBounds { kmin: vec![f32::INFINITY; kv_dim], kmax: vec![f32::NEG_INFINITY; kv_dim] }
    }

    /// Fold one token's key vector (length kv_dim) into the bounds.
    pub fn update(&mut self, key: &[f32]) {
        debug_assert_eq!(key.len(), self.kmin.len());
        for (i, &x) in key.iter().enumerate() {
            if x < self.kmin[i] {
                self.kmin[i] = x;
            }
            if x > self.kmax[i] {
                self.kmax[i] = x;
            }
        }
    }

    /// Quest upper bound: max over query heads in the kv group of
    /// sum_c max(q_c*kmin_c, q_c*kmax_c).
    ///
    /// `q` is [n_heads * head_dim]; heads h map to kv head h / group.
    pub fn score(&self, q: &[f32], n_heads: usize, n_kv: usize, head_dim: usize) -> f32 {
        let group = n_heads / n_kv;
        let mut best = f32::NEG_INFINITY;
        for h in 0..n_heads {
            let g = h / group;
            let qh = &q[h * head_dim..(h + 1) * head_dim];
            let kmin = &self.kmin[g * head_dim..(g + 1) * head_dim];
            let kmax = &self.kmax[g * head_dim..(g + 1) * head_dim];
            let mut s = 0.0f32;
            for c in 0..head_dim {
                s += (qh[c] * kmin[c]).max(qh[c] * kmax[c]);
            }
            if s > best {
                best = s;
            }
        }
        best
    }

    /// Per-query-head Quest upper bounds, appended to `out` (`n_heads`
    /// values).  Same arithmetic as [`RepBounds::score`] minus the final
    /// max over heads — the unified-selection hook
    /// ([`crate::kvcache::policy::SparsityPolicy::select_unified_into`])
    /// consumes the full head profile instead of the per-page reduction.
    pub fn score_heads_into(&self, q: &[f32], n_heads: usize, n_kv: usize, head_dim: usize,
                            out: &mut Vec<f32>) {
        let group = n_heads / n_kv;
        for h in 0..n_heads {
            let g = h / group;
            let qh = &q[h * head_dim..(h + 1) * head_dim];
            let kmin = &self.kmin[g * head_dim..(g + 1) * head_dim];
            let kmax = &self.kmax[g * head_dim..(g + 1) * head_dim];
            let mut s = 0.0f32;
            for c in 0..head_dim {
                s += (qh[c] * kmin[c]).max(qh[c] * kmax[c]);
            }
            out.push(s);
        }
    }
}

/// Collapse page-major per-head scores (`[n_pages * n_heads]`, from
/// [`crate::kvcache::seq::LayerCache::rep_scores_heads`]) to the per-page
/// max over heads — bitwise the reduction [`RepBounds::score`] bakes in,
/// so the classic `page_probs`/`observe` feed is identical whichever
/// scoring route produced it.
pub fn reduce_head_scores_max(head_scores: &[f32], n_heads: usize, out: &mut Vec<f32>) {
    out.clear();
    let nh = n_heads.max(1);
    debug_assert_eq!(head_scores.len() % nh, 0);
    for page in head_scores.chunks_exact(nh) {
        let mut best = f32::NEG_INFINITY;
        for &s in page {
            if s > best {
                best = s;
            }
        }
        out.push(best);
    }
}

/// Softmax the per-page upper-bound scores into pseudo-probabilities —
/// the quantity RaaS thresholds against alpha (mirrors page_probs_ref).
pub fn page_probs(scores: &[f32], head_dim: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(scores.len(), 0.0);
    if scores.is_empty() {
        return;
    }
    let scale = 1.0 / (head_dim as f32).sqrt();
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max) * scale;
    let mut denom = 0.0f32;
    for (i, &s) in scores.iter().enumerate() {
        let e = (s * scale - m).exp();
        out[i] = e;
        denom += e;
    }
    if denom > 0.0 {
        for p in out.iter_mut() {
            *p /= denom;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_contain_keys() {
        let mut b = RepBounds::empty(4);
        b.update(&[1.0, -2.0, 0.5, 0.0]);
        b.update(&[0.0, 3.0, 0.5, -1.0]);
        assert_eq!(b.kmin, vec![0.0, -2.0, 0.5, -1.0]);
        assert_eq!(b.kmax, vec![1.0, 3.0, 0.5, 0.0]);
    }

    #[test]
    fn score_upper_bounds_true_dot() {
        // 1 head, 1 kv head, dim 4
        let keys = [[0.3f32, -0.5, 1.0, 0.2], [-0.1, 0.4, -0.2, 0.8]];
        let mut b = RepBounds::empty(4);
        for k in &keys {
            b.update(k);
        }
        let q = [0.7f32, -0.3, 0.5, 1.1];
        let bound = b.score(&q, 1, 1, 4);
        for k in &keys {
            let dot: f32 = q.iter().zip(k).map(|(a, b)| a * b).sum();
            assert!(bound >= dot - 1e-6, "bound {bound} < dot {dot}");
        }
    }

    #[test]
    fn gqa_group_max() {
        // 2 q heads sharing 1 kv head: score = max over heads
        let mut b = RepBounds::empty(2);
        b.update(&[1.0, 1.0]);
        let q = [1.0f32, 0.0, /* head 1: */ 5.0, 5.0];
        let s = b.score(&q, 2, 1, 2);
        assert!((s - 10.0).abs() < 1e-6);
    }

    #[test]
    fn head_scores_reduce_to_classic_score() {
        // 4 q heads over 2 kv heads: the max over the per-head profile must
        // be bitwise the scalar `score` fold.
        let mut b = RepBounds::empty(4);
        b.update(&[0.3, -0.5, 1.0, 0.2]);
        b.update(&[-0.1, 0.4, -0.2, 0.8]);
        let q = [0.7f32, -0.3, 0.5, 1.1, -0.2, 0.9, 0.1, -0.6];
        let mut heads = Vec::new();
        b.score_heads_into(&q, 4, 2, 2, &mut heads);
        assert_eq!(heads.len(), 4);
        let mut reduced = Vec::new();
        reduce_head_scores_max(&heads, 4, &mut reduced);
        assert_eq!(reduced.len(), 1);
        assert_eq!(reduced[0].to_bits(), b.score(&q, 4, 2, 2).to_bits());
    }

    #[test]
    fn reduce_handles_multiple_pages() {
        // page-major [2 pages * 3 heads]
        let hs = [1.0f32, 5.0, 2.0, -1.0, -3.0, -2.0];
        let mut out = vec![9.0];
        reduce_head_scores_max(&hs, 3, &mut out);
        assert_eq!(out, vec![5.0, -1.0]);
    }

    #[test]
    fn probs_sum_to_one() {
        let mut out = Vec::new();
        page_probs(&[1.0, 2.0, 3.0], 16, &mut out);
        let sum: f32 = out.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
        assert!(out[2] > out[0]);
    }

    #[test]
    fn probs_empty_ok() {
        let mut out = vec![1.0];
        page_probs(&[], 16, &mut out);
        assert!(out.is_empty());
    }
}
