//! Dense (standard attention): attend everything, evict nothing.
//! O(N) time, O(N) memory, reference accuracy (paper Figure 2, col 1).

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// Dense attention: select every resident page, evict none.
pub struct DensePolicy;

impl SparsityPolicy for DensePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Dense
    }

    fn observe(&self, _table: &mut [PageMeta], _probs: &[f32], _now: u64) {}

    fn select_into(&self, table: &[PageMeta], _scores: &[f32], _budget_tokens: usize,
                   _page_size: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend(0..table.len());
    }

    fn evict_candidate(&self, _table: &[PageMeta]) -> Option<usize> {
        None
    }

    fn bounds_memory(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    #[test]
    fn selects_everything_evicts_nothing() {
        let p = DensePolicy;
        let t = mk_table(&[(16, false), (16, false), (3, false)]);
        assert_eq!(p.select(&t, &[0.0; 3], 32, 16), vec![0, 1, 2]);
        assert_eq!(p.evict_candidate(&t), None);
        assert!(!p.bounds_memory());
    }
}
