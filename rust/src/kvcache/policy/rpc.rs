//! RPC — Reasoning Path Compression (arXiv:2505.13866): periodically
//! compress the generated trajectory, keeping the pages a recent window of
//! queries found important.
//!
//! Two pieces, mirroring the paper's design:
//!
//! 1. **Recent-window selector.**  Each page carries an exponentially
//!    decayed recent-attention mass (`PageMeta::win_score`, e-folding
//!    length `window` steps) — the paper's importance score computed from
//!    a sliding window of recent queries, maintained in O(1) per page per
//!    step (no per-query history is stored).
//!
//! 2. **Periodic compression.**  Every `period` steps the running window
//!    is *frozen* into the page's importance snapshot
//!    (`PageMeta::acc_score`).  Eviction always ranks by the snapshot, so
//!    the retained set changes only at compression boundaries — unlike
//!    H2O's per-step lifetime accumulator or RaaS's per-step stamps.  The
//!    trailing ~one-period of trajectory is exempt (the paper's
//!    uncompressed recent segment), as is the prompt (pinned pages are
//!    skipped: RPC compresses only the *generated* path and keeps the
//!    input intact).
//!
//! Like RaaS/H2O it is eviction-sparse: O(L) attention time because the
//! resident set is budget-bounded, O(L) memory.

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// RPC: periodic trajectory compression from a recent-window selector.
pub struct RpcPolicy {
    /// Compression cadence in decode steps (the paper's R).
    pub period: u64,
    /// Selector window in decode steps: the e-folding length of the
    /// recent-window attention mass.
    pub window: f64,
}

impl RpcPolicy {
    /// Pages of trailing trajectory exempt from compression — the
    /// uncompressed recent segment, ~one period of decode (page size is
    /// inferred from the table like H2O's recent window, so the policy
    /// needs no engine plumbing).
    fn protected_pages(&self, table: &[PageMeta]) -> usize {
        let page_size = table.iter().map(|p| p.len).max().unwrap_or(16).max(1);
        (self.period as usize / page_size + 1).min(table.len().saturating_sub(1))
    }
}

impl SparsityPolicy for RpcPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Rpc
    }

    fn observe(&self, table: &mut [PageMeta], probs: &[f32], now: u64) {
        if table.is_empty() {
            return;
        }
        // O(1) per page: decay the recent window, fold in this step's
        // estimated mass.  mu = 1 - 1/W gives an e-folding length of ~W
        // steps without storing any query history.
        let mu = 1.0 - 1.0 / self.window.max(1.0);
        for (page, &p) in table.iter_mut().zip(probs) {
            page.win_score = page.win_score * mu + p as f64;
        }
        // Compression boundary: freeze the window into the snapshot the
        // eviction ranking reads.  A NaN window freezes as NaN, which
        // `total_cmp` orders above +inf — never the minimum, so a
        // degenerate score errs towards retention (H2O's convention).
        if now % self.period.max(1) == 0 {
            for page in table.iter_mut() {
                page.acc_score = page.win_score;
            }
        }
    }

    fn select_into(&self, table: &[PageMeta], _scores: &[f32], _budget_tokens: usize,
                   _page_size: usize, out: &mut Vec<usize>) {
        // RPC attends the full (budget-bounded) resident set; sparsity
        // comes from compression-driven eviction, like RaaS.
        out.clear();
        out.extend(0..table.len());
    }

    fn evict_candidate(&self, table: &[PageMeta]) -> Option<usize> {
        if table.len() <= 1 {
            return None;
        }
        let protected = self.protected_pages(table);
        table[..table.len() - protected]
            .iter()
            .enumerate()
            .filter(|(_, p)| !p.pinned)
            .min_by(|(_, a), (_, b)| {
                a.acc_score
                    .total_cmp(&b.acc_score)
                    .then(a.start_pos.cmp(&b.start_pos))
            })
            .map(|(i, _)| i)
    }

    fn bounds_memory(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    fn policy() -> RpcPolicy {
        RpcPolicy { period: 4, window: 2.0 }
    }

    #[test]
    fn window_decays_and_accumulates() {
        let p = policy();
        let mut t = mk_table(&[(16, false), (16, false)]);
        p.observe(&mut t, &[0.8, 0.2], 1);
        assert!((t[0].win_score - 0.8).abs() < 1e-9);
        p.observe(&mut t, &[0.0, 0.2], 2);
        // mu = 1 - 1/2 = 0.5: 0.8 * 0.5 + 0.0
        assert!((t[0].win_score - 0.4).abs() < 1e-9);
        assert!((t[1].win_score - 0.3).abs() < 1e-9);
    }

    #[test]
    fn snapshot_freezes_only_at_period_boundaries() {
        let p = policy();
        let mut t = mk_table(&[(16, false), (16, false)]);
        for now in 1..=3 {
            p.observe(&mut t, &[0.9, 0.1], now);
            assert_eq!(t[0].acc_score, 0.0, "no compression before the boundary");
        }
        p.observe(&mut t, &[0.9, 0.1], 4);
        assert!(t[0].acc_score > 1.0, "boundary freezes the accumulated window");
    }

    #[test]
    fn ranking_is_frozen_between_compressions() {
        let p = policy();
        // period 4 / page size 16 -> 1 protected trailing page; 0..5 evictable
        let mut t = mk_table(&[(16, false); 6]);
        // boundary at step 4: page 0 cold, pages 1..5 warm
        for now in 1..=4 {
            p.observe(&mut t, &[0.0, 0.3, 0.3, 0.3, 0.3, 0.3], now);
        }
        assert_eq!(p.evict_candidate(&t), Some(0));
        // page 0 heats up AFTER the boundary; the frozen snapshot still
        // ranks it coldest until the next compression
        for now in 5..=7 {
            p.observe(&mut t, &[0.9, 0.0, 0.3, 0.3, 0.3, 0.3], now);
        }
        assert_eq!(p.evict_candidate(&t), Some(0), "ranking constant mid-period");
        p.observe(&mut t, &[0.9, 0.0, 0.3, 0.3, 0.3, 0.3], 8);
        assert_eq!(p.evict_candidate(&t), Some(1), "next boundary re-ranks");
    }

    #[test]
    fn recent_tail_is_protected() {
        // period 20 / page size 16 -> 20/16 + 1 = 2 protected trailing pages
        let p = RpcPolicy { period: 20, window: 2.0 };
        let t = mk_table(&[(16, false); 5]);
        // all snapshots are 0 (tied); the victim must still come from the
        // compressible prefix, tie-breaking towards the older position
        assert_eq!(p.evict_candidate(&t), Some(0));
        let mut t = mk_table(&[(16, false); 5]);
        t[0].acc_score = 1.0;
        t[1].acc_score = 1.0;
        t[2].acc_score = 1.0;
        // pages 3,4 (the recent segment) are never candidates even though
        // their snapshots are colder than the compressible prefix
        assert_eq!(p.evict_candidate(&t), Some(0), "cold tail exempt from compression");
    }

    #[test]
    fn pinned_prompt_is_never_compressed() {
        let p = policy();
        let mut t = mk_table(&[(16, true), (16, true), (16, false), (16, false), (16, false)]);
        t[2].acc_score = 0.5;
        t[3].acc_score = 0.9;
        assert_eq!(p.evict_candidate(&t), Some(2), "pins skipped even when coldest");
        let t = mk_table(&[(16, true), (16, true), (16, false)]);
        // protected tail (1 page) + pins cover everything -> unevictable
        assert_eq!(p.evict_candidate(&t), None);
    }

    #[test]
    fn bounds_memory_and_full_selection() {
        let p = policy();
        let t = mk_table(&[(16, false); 3]);
        assert!(p.bounds_memory());
        assert_eq!(p.select(&t, &[0.0; 3], 16, 16), vec![0, 1, 2]);
    }
}
