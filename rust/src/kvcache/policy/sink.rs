//! StreamingLLM / attention-sink: keep the first `sink_tokens` plus a recent
//! window; evict everything in the middle.  O(L) time and memory, but it
//! indiscriminately discards milestone tokens — the paper's Figure 6 shows
//! the resulting accuracy collapse on reasoning tasks.

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// StreamingLLM-style sink + recent-window retention.
pub struct SinkPolicy {
    /// Tokens at the sequence start that are never evicted.
    pub sink_tokens: usize,
}

impl SinkPolicy {
    fn is_sink(&self, page: &PageMeta) -> bool {
        page.start_pos < self.sink_tokens
    }
}

impl SparsityPolicy for SinkPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Sink
    }

    fn observe(&self, _table: &mut [PageMeta], _probs: &[f32], _now: u64) {}

    fn select_into(&self, table: &[PageMeta], _scores: &[f32], _budget_tokens: usize,
                   _page_size: usize, out: &mut Vec<usize>) {
        // Attend the whole resident set: eviction already enforces the
        // sink+window structure.
        out.clear();
        out.extend(0..table.len());
    }

    fn evict_candidate(&self, table: &[PageMeta]) -> Option<usize> {
        if table.len() <= 1 {
            return None;
        }
        // Oldest page that is not a sink page; never the final (active) page.
        table[..table.len() - 1]
            .iter()
            .position(|p| !self.is_sink(p))
    }

    fn bounds_memory(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    #[test]
    fn evicts_oldest_middle_page() {
        let p = SinkPolicy { sink_tokens: 16 };
        // page 0: positions 0..16 (sink); pages 1..3 decode
        let t = mk_table(&[(16, false), (16, false), (16, false), (4, false)]);
        assert_eq!(p.evict_candidate(&t), Some(1));
    }

    #[test]
    fn never_evicts_active_page() {
        let p = SinkPolicy { sink_tokens: 16 };
        let t = mk_table(&[(16, false), (4, false)]);
        // only non-sink page is the last (active) one -> nothing evictable
        assert_eq!(p.evict_candidate(&t), None);
        let t2 = mk_table(&[(16, false)]);
        assert_eq!(p.evict_candidate(&t2), None);
    }

    #[test]
    fn sink_window_structure_emerges() {
        // Simulate: pages stream in; evict whenever above 3 pages.
        let p = SinkPolicy { sink_tokens: 16 };
        let mut table = mk_table(&[(16, false)]);
        for i in 1..10 {
            let mut m = PageMeta::new(i as u32, i * 16, false, 0);
            m.len = 16;
            table.push(m);
            while table.len() > 3 {
                let victim = p.evict_candidate(&table).expect("evictable");
                table.remove(victim);
            }
        }
        // sink page survives; remaining pages are the most recent ones
        assert_eq!(table[0].start_pos, 0);
        assert_eq!(table.len(), 3);
        assert_eq!(table[2].start_pos, 9 * 16);
        assert_eq!(table[1].start_pos, 8 * 16);
    }
}
