//! The sparsity-policy zoo: the paper's five algorithms (Figure 2) plus
//! the post-paper follow-ons, RPC (arXiv:2505.13866) and LessIsMore
//! (arXiv:2508.07101) — seven in all (`PolicyKind::all`).
//!
//! A policy sees, per decode step and per layer, the resident page table and
//! the per-page estimated attention probabilities (softmaxed Quest-style
//! representative scores — `page::page_probs`).  It decides
//!
//!  * which resident pages the Pallas kernel attends this step (`select`,
//!    or `select_unified_into` for cross-head unified policies),
//!  * how per-page statistics evolve (`observe` — RaaS timestamps, H2O
//!    accumulators, RPC recent windows), and
//!  * which page to evict when the resident set exceeds the budget
//!    (`evict_candidate`).
//!
//! The same implementations serve both the real engine and the trace
//! simulator, so the accuracy grids (Figures 6/8/9, the accuracy-cliff
//! bench) exercise exactly the code that runs on the serving path.  The
//! cross-policy trait contract is pinned by
//! `rust/tests/policy_conformance.rs`.

mod dense;
mod h2o;
mod lessismore;
mod quest;
mod raas;
mod rpc;
mod sink;

pub use dense::DensePolicy;
pub use h2o::H2oPolicy;
pub use lessismore::LessIsMorePolicy;
pub use quest::QuestPolicy;
pub use raas::RaasPolicy;
pub use rpc::RpcPolicy;
pub use sink::SinkPolicy;

use super::page::PageMeta;
use crate::config::{EngineConfig, PolicyKind};

/// A KV-cache sparsity algorithm (one of the zoo's seven).
///
/// Policies are driven per decode step, per layer, with the resident page
/// table and per-page estimated attention probabilities; the same
/// implementations serve the engine and the trace simulator, so the
/// accuracy grids exercise exactly the serving-path code.
pub trait SparsityPolicy: Send {
    /// Which of the zoo's algorithms this is.
    fn kind(&self) -> PolicyKind;

    /// Update per-page statistics after this step's estimated probabilities
    /// are known.  `now` is the decode-step counter.
    fn observe(&self, table: &mut [PageMeta], probs: &[f32], now: u64);

    /// Indices (into `table`) of pages to attend this step, written into
    /// `out` (cleared first).  `scores` are the raw representative upper
    /// bounds (pre-softmax), aligned with `table`.  Must always include the
    /// final page (the one receiving new tokens) when the table is
    /// non-empty.  The out-param form is the hot-path entry point: the
    /// engine hands in per-sequence scratch so steady-state decode
    /// allocates nothing (one fresh `Vec` per layer per step adds up).
    fn select_into(&self, table: &[PageMeta], scores: &[f32], budget_tokens: usize,
                   page_size: usize, out: &mut Vec<usize>);

    /// Allocating convenience wrapper around
    /// [`SparsityPolicy::select_into`] (tests only — every production
    /// caller, including the trace simulator and the benches, carries
    /// reusable scratch through `select_into`).
    fn select(&self, table: &[PageMeta], scores: &[f32], budget_tokens: usize,
              page_size: usize) -> Vec<usize> {
        let mut out = Vec::new();
        self.select_into(table, scores, budget_tokens, page_size, &mut out);
        out
    }

    /// Whether this policy selects one *unified* page set from the full
    /// per-head score profile (LessIsMore) instead of the per-page reduced
    /// scores.  The engine only pays for head-major scoring
    /// (`LayerCache::rep_scores_heads`) when this returns true; every
    /// per-head-oblivious policy keeps the classic reduced-score path
    /// bit-for-bit.
    fn unified_selection(&self) -> bool {
        false
    }

    /// Unified cross-head selection: like [`SparsityPolicy::select_into`]
    /// but over page-major per-head scores (`[table.len() * n_heads]`,
    /// from `LayerCache::rep_scores_heads`).  The default reduces each
    /// page's head profile to its max — exactly the aggregation
    /// `RepBounds::score` bakes into the classic scores — and defers to
    /// `select_into`, so per-head-oblivious policies behave identically
    /// through either entry point.  (The default allocates; the engine
    /// only routes here when [`SparsityPolicy::unified_selection`] is
    /// true, and unified policies override with scratch-backed impls.)
    fn select_unified_into(&self, table: &[PageMeta], head_scores: &[f32], n_heads: usize,
                           budget_tokens: usize, page_size: usize, out: &mut Vec<usize>) {
        let nh = n_heads.max(1);
        debug_assert_eq!(head_scores.len(), table.len() * nh);
        let mut reduced = Vec::new();
        super::page::reduce_head_scores_max(head_scores, nh, &mut reduced);
        self.select_into(table, &reduced, budget_tokens, page_size, out);
    }

    /// Page to evict while the resident set exceeds the budget.  `None`
    /// means nothing is evictable (Dense/Quest/LessIsMore always; RaaS
    /// when only pinned prefill pages remain — the paper retains prefill
    /// regardless; RPC when pins cover everything older than its
    /// uncompressed recent window).
    ///
    /// Shared pages (refcount > 1 in the pool: forked sequences, prefix
    /// cache hits) are handled above the policy: the engine feeds this
    /// method a table whose `last_stamp` is boosted to the pool-level
    /// maximum over all sharers (`KvPool::stamp_max`), so a page still hot
    /// in *any* co-owning sequence is never the stalest candidate here.
    /// Policies stay sharing-oblivious — they only ever see per-page stats.
    fn evict_candidate(&self, table: &[PageMeta]) -> Option<usize>;

    /// Whether resident memory is bounded by the budget (O(L) memory).
    fn bounds_memory(&self) -> bool;
}

/// Instantiate the policy named by the config.
pub fn make_policy(cfg: &EngineConfig) -> Box<dyn SparsityPolicy> {
    match cfg.policy {
        PolicyKind::Dense => Box::new(DensePolicy),
        PolicyKind::Sink => Box::new(SinkPolicy { sink_tokens: cfg.sink_tokens }),
        PolicyKind::H2o => Box::new(H2oPolicy {
            recent_fraction: cfg.h2o_recent_fraction,
            budget_tokens: cfg.budget,
        }),
        PolicyKind::Quest => Box::new(QuestPolicy),
        PolicyKind::Raas => Box::new(RaasPolicy::new(cfg.alpha, cfg.stamp_fraction)),
        PolicyKind::Rpc => Box::new(RpcPolicy { period: cfg.rpc_period, window: cfg.rpc_window }),
        PolicyKind::LessIsMore => Box::new(LessIsMorePolicy::default()),
    }
}

/// Total resident tokens in a table.
pub fn resident_tokens(table: &[PageMeta]) -> usize {
    table.iter().map(|p| p.len).sum()
}

#[cfg(test)]
pub(crate) fn mk_table(lens: &[(usize, bool)]) -> Vec<PageMeta> {
    // (len, pinned) pages laid out contiguously from position 0
    let mut pos = 0;
    lens.iter()
        .enumerate()
        .map(|(i, &(len, pinned))| {
            let mut m = PageMeta::new(i as u32, pos, pinned, 0);
            m.len = len;
            pos += len;
            m
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_matches_kind() {
        for kind in PolicyKind::all() {
            let cfg = EngineConfig { policy: kind, ..Default::default() };
            assert_eq!(make_policy(&cfg).kind(), kind);
        }
    }

    #[test]
    fn resident_token_count() {
        let t = mk_table(&[(16, true), (16, false), (5, false)]);
        assert_eq!(resident_tokens(&t), 37);
    }

    #[test]
    fn unified_default_matches_classic_reduction() {
        // Per-head-oblivious policies select identically through either
        // entry point: the default hook max-reduces the head profile into
        // exactly the classic scores, then delegates.
        let t = mk_table(&[(16, false); 6]);
        #[rustfmt::skip]
        let hs = [
            0.9f32, 0.1, // page 0
            0.2, 0.8,    // page 1
            0.5, 0.5,    // page 2
            0.0, 0.3,    // page 3
            0.7, 0.6,    // page 4
            0.1, 0.0,    // page 5 (active)
        ];
        let mut reduced = Vec::new();
        crate::kvcache::page::reduce_head_scores_max(&hs, 2, &mut reduced);
        for kind in PolicyKind::all() {
            let cfg = EngineConfig { policy: kind, ..Default::default() };
            let p = make_policy(&cfg);
            if p.unified_selection() {
                continue; // unified policies override the hook outright
            }
            let mut via_hook = Vec::new();
            let mut classic = Vec::new();
            p.select_unified_into(&t, &hs, 2, 48, 16, &mut via_hook);
            p.select_into(&t, &reduced, 48, 16, &mut classic);
            assert_eq!(via_hook, classic, "{kind:?}");
        }
    }
}
