//! H2O (Heavy-Hitter Oracle): accumulate attention mass per page; evict the
//! lightest non-recent page.  O(L) time/memory in theory, but — as the paper
//! observes — the *accumulated* statistic overweights stale milestones: an
//! old lemma that once drew heavy attention outlives the newer lemma the
//! chain actually needs (Figures 6 and 8).

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// H2O: evict the page with the least accumulated attention mass.
pub struct H2oPolicy {
    /// Fraction of the budget protected as a recent window.
    pub recent_fraction: f64,
    /// Cache budget in tokens (sizes the recent window).
    pub budget_tokens: usize,
}

impl H2oPolicy {
    fn recent_pages(&self, page_size: usize) -> usize {
        (((self.budget_tokens as f64 * self.recent_fraction) / page_size as f64).ceil()
            as usize)
            .max(1)
    }
}

impl SparsityPolicy for H2oPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::H2o
    }

    fn observe(&self, table: &mut [PageMeta], probs: &[f32], _now: u64) {
        for (page, &p) in table.iter_mut().zip(probs) {
            page.acc_score += p as f64;
        }
    }

    fn select_into(&self, table: &[PageMeta], _scores: &[f32], _budget_tokens: usize,
                   _page_size: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend(0..table.len());
    }

    fn evict_candidate(&self, table: &[PageMeta]) -> Option<usize> {
        if table.len() <= 1 {
            return None;
        }
        let page_size = table.iter().map(|p| p.len).max().unwrap_or(16).max(1);
        let protected = self.recent_pages(page_size).min(table.len() - 1);
        let evictable = &table[..table.len() - protected];
        // `total_cmp`: accumulators go NaN if a NaN prob was ever observed;
        // eviction must keep working (NaN orders above +inf, so poisoned
        // pages are treated as heavy and survive — never a panic).
        evictable
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| a.acc_score.total_cmp(&b.acc_score))
            .map(|(i, _)| i)
    }

    fn bounds_memory(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    fn policy() -> H2oPolicy {
        H2oPolicy { recent_fraction: 0.25, budget_tokens: 64 }
    }

    #[test]
    fn accumulates_scores() {
        let p = policy();
        let mut t = mk_table(&[(16, false), (16, false)]);
        p.observe(&mut t, &[0.7, 0.3], 1);
        p.observe(&mut t, &[0.2, 0.8], 2);
        assert!((t[0].acc_score - 0.9).abs() < 1e-6);
        assert!((t[1].acc_score - 1.1).abs() < 1e-6);
    }

    #[test]
    fn evicts_lightest_outside_recent_window() {
        let p = policy(); // recent window = 64*0.25/16 = 1 page
        let mut t = mk_table(&[(16, false), (16, false), (16, false), (8, false)]);
        p.observe(&mut t, &[0.5, 0.05, 0.3, 0.15], 1);
        // lightest is page 1; last page protected
        assert_eq!(p.evict_candidate(&t), Some(1));
    }

    #[test]
    fn stale_heavy_hitter_outlives_new_milestone() {
        // The failure mode the paper describes: page 0 accumulated a lot of
        // mass long ago; the newer milestone page 1 has less *accumulated*
        // mass even though it is what the chain needs next — H2O evicts it.
        let p = policy();
        let mut t = mk_table(&[(16, false), (16, false), (8, false)]);
        for _ in 0..50 {
            p.observe(&mut t, &[0.9, 0.0, 0.1], 0); // old milestone era
        }
        for _ in 0..3 {
            p.observe(&mut t, &[0.0, 0.8, 0.2], 0); // new milestone era
        }
        assert_eq!(p.evict_candidate(&t), Some(1), "H2O drops the new milestone");
    }

    #[test]
    fn singleton_table_not_evictable() {
        assert_eq!(policy().evict_candidate(&mk_table(&[(4, false)])), None);
    }
}
