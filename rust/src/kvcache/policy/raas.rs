//! RaaS — the paper's contribution.
//!
//! Two ideas (paper §3.2):
//!
//! 1. **Milestone tracking via timestamps.**  Each page carries the last
//!    step at which its estimated attention probability exceeded `alpha`
//!    (default 1e-4).  A milestone page keeps receiving fresh stamps while
//!    the chain consumes it, then its stamp freezes as the waterfall fades.
//!    On overflow, evict the page with the *oldest* stamp — exactly the
//!    lemma the reasoning no longer needs.  (`alpha <= 0` switches to the
//!    equivalent top-`stamp_fraction` formulation, paper's r = 50%.)
//!
//! 2. **Pinned prefill.**  Phoenix tokens live almost exclusively in the
//!    short prompt of reasoning tasks; prefill pages are exempt from
//!    eviction, so they are retained even when the budget is tight (which
//!    also reproduces the paper's small-budget pathology in Figure 6).
//!
//! Result: O(L) time **and** O(L) memory at Quest-level accuracy.

use std::cell::RefCell;

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// RaaS: milestone timestamps + pinned prefill (the paper's policy).
pub struct RaasPolicy {
    /// Timestamp-refresh threshold on estimated attention probability.
    pub alpha: f64,
    /// Used instead when `alpha <= 0`: stamp the top fraction each step.
    pub stamp_fraction: f64,
    /// Reusable index scratch for the top-r formulation (`observe` takes
    /// `&self`, hence the cell); steady-state observation allocates
    /// nothing.  `RefCell`, not a lock: policies live on one replica
    /// thread, like the backend feature memo.
    topr_scratch: RefCell<Vec<usize>>,
}

impl RaasPolicy {
    /// Policy with refresh threshold `alpha` (`<= 0` selects the
    /// top-`stamp_fraction` formulation instead).
    pub fn new(alpha: f64, stamp_fraction: f64) -> Self {
        RaasPolicy { alpha, stamp_fraction, topr_scratch: RefCell::new(Vec::new()) }
    }
}

impl SparsityPolicy for RaasPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Raas
    }

    fn observe(&self, table: &mut [PageMeta], probs: &[f32], now: u64) {
        if table.is_empty() {
            return;
        }
        if self.alpha > 0.0 {
            for (page, &p) in table.iter_mut().zip(probs) {
                if p as f64 >= self.alpha {
                    page.last_stamp = now;
                }
            }
        } else {
            // top-r formulation: stamp the ceil(r * n) highest-probability
            // pages.  `total_cmp`: a NaN prob must not panic mid-decode;
            // NaNs rank highest and get stamped, erring towards retention.
            //
            // Partial selection (O(n) expected vs the old full-sort
            // O(n log n), per layer per step): only the top-k *set* is
            // stamped, never its internal order.  The index tie-break makes
            // the comparator a total order, so the stamped set is exactly
            // what the old stable descending sort produced on tied probs
            // (earlier pages win) — mirroring Quest's `select_into`.
            let n = table.len();
            let k = ((self.stamp_fraction * n as f64).ceil() as usize).clamp(1, n);
            let mut order = self.topr_scratch.borrow_mut();
            order.clear();
            order.extend(0..n);
            if k < n {
                order.select_nth_unstable_by(k, |&a, &b| {
                    probs[b].total_cmp(&probs[a]).then(a.cmp(&b))
                });
                order.truncate(k);
            }
            for &i in order.iter() {
                table[i].last_stamp = now;
            }
        }
        // The active page always carries the latest stamp: its tokens are
        // the current reasoning frontier.
        if let Some(last) = table.last_mut() {
            last.last_stamp = now;
        }
    }

    fn select_into(&self, table: &[PageMeta], _scores: &[f32], _budget_tokens: usize,
                   _page_size: usize, out: &mut Vec<usize>) {
        // RaaS attends the full (budget-bounded) resident set; sparsity comes
        // from eviction, which is what keeps memory at O(L).
        out.clear();
        out.extend(0..table.len());
    }

    fn evict_candidate(&self, table: &[PageMeta]) -> Option<usize> {
        if table.len() <= 1 {
            return None;
        }
        table[..table.len() - 1]
            .iter()
            .enumerate()
            .filter(|(_, p)| !p.pinned)
            .min_by(|(_, a), (_, b)| {
                a.last_stamp
                    .cmp(&b.last_stamp)
                    .then(a.start_pos.cmp(&b.start_pos))
            })
            .map(|(i, _)| i)
    }

    fn bounds_memory(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    fn policy() -> RaasPolicy {
        RaasPolicy::new(0.01, 0.5)
    }

    #[test]
    fn stamps_pages_above_alpha() {
        let p = policy();
        let mut t = mk_table(&[(16, false), (16, false), (16, false)]);
        p.observe(&mut t, &[0.5, 0.001, 0.3], 7);
        assert_eq!(t[0].last_stamp, 7);
        assert_eq!(t[1].last_stamp, 0, "below alpha keeps old stamp");
        assert_eq!(t[2].last_stamp, 7);
    }

    #[test]
    fn active_page_always_stamped() {
        let p = policy();
        let mut t = mk_table(&[(16, false), (4, false)]);
        p.observe(&mut t, &[0.9, 0.0], 3);
        assert_eq!(t[1].last_stamp, 3);
    }

    #[test]
    fn top_r_formulation() {
        let p = RaasPolicy::new(0.0, 0.5);
        let mut t = mk_table(&[(16, false), (16, false), (16, false), (16, false)]);
        p.observe(&mut t, &[0.4, 0.1, 0.45, 0.05], 9);
        assert_eq!(t[0].last_stamp, 9);
        assert_eq!(t[2].last_stamp, 9);
        assert_eq!(t[1].last_stamp, 0);
        assert_eq!(t[3].last_stamp, 9, "active page stamped regardless");
    }

    #[test]
    fn top_r_tied_probs_stamp_earlier_pages() {
        // The partial selection must reproduce the old stable descending
        // sort's deterministic tie handling: probs tied across the k
        // boundary resolve to the earlier page indices.
        let p = RaasPolicy::new(0.0, 0.4);
        let mut t = mk_table(&[(16, false); 6]);
        // k = ceil(0.4 * 6) = 3; pages 0,2,3,4 tie at 0.2 — only the two
        // earliest tied pages join top scorer 1
        p.observe(&mut t, &[0.2, 0.9, 0.2, 0.2, 0.2, 0.0], 5);
        assert_eq!(t[0].last_stamp, 5, "earliest tied page stamped");
        assert_eq!(t[1].last_stamp, 5, "top page stamped");
        assert_eq!(t[2].last_stamp, 5, "second tied page stamped");
        assert_eq!(t[3].last_stamp, 0, "tie past the boundary not stamped");
        assert_eq!(t[4].last_stamp, 0);
        assert_eq!(t[5].last_stamp, 5, "active page stamped regardless");
        // repeated observation reuses the scratch and stays deterministic
        p.observe(&mut t, &[0.2, 0.9, 0.2, 0.2, 0.2, 0.0], 6);
        assert_eq!(t[3].last_stamp, 0);
        assert_eq!(t[0].last_stamp, 6);
    }

    #[test]
    fn evicts_oldest_stamp_skipping_pinned() {
        let p = policy();
        let mut t = mk_table(&[(16, true), (16, false), (16, false), (8, false)]);
        t[1].last_stamp = 2;
        t[2].last_stamp = 10;
        assert_eq!(p.evict_candidate(&t), Some(1));
        // even if the pinned prefill page is the oldest:
        t[1].last_stamp = 50;
        assert_eq!(p.evict_candidate(&t), Some(2));
    }

    #[test]
    fn all_pinned_is_unevictable() {
        let p = policy();
        let t = mk_table(&[(16, true), (16, true), (8, false)]);
        // only unpinned page is the active one -> None (paper: prefill is
        // retained even when it exceeds the budget)
        assert_eq!(p.evict_candidate(&t), None);
    }

    #[test]
    fn milestone_lifecycle() {
        // A milestone page keeps its stamp fresh while consumed, then goes
        // cold and becomes the eviction victim — the waterfall in miniature.
        let p = policy();
        let mut t = mk_table(&[(16, true), (16, false), (16, false), (16, false)]);
        // steps 1..5: page 1 is the hot milestone
        for now in 1..=5 {
            p.observe(&mut t, &[0.02, 0.9, 0.02, 0.06], now);
        }
        // steps 6..9: reasoning moved on; page 2 is the new milestone
        for now in 6..=9 {
            p.observe(&mut t, &[0.02, 0.001, 0.9, 0.08], now);
        }
        assert_eq!(t[1].last_stamp, 5);
        assert_eq!(t[2].last_stamp, 9);
        assert_eq!(p.evict_candidate(&t), Some(1), "faded milestone evicted first");
    }

    #[test]
    fn ties_break_towards_older_position() {
        let p = policy();
        let mut t = mk_table(&[(16, false), (16, false), (8, false)]);
        t[0].last_stamp = 4;
        t[1].last_stamp = 4;
        assert_eq!(p.evict_candidate(&t), Some(0));
    }
}
