//! Quest: query-aware top-L page selection per decode step, but the full KV
//! cache stays resident — O(L) attention time, **O(N) memory** (the corner
//! of the impossible trinity RaaS removes; paper Figures 2 and 7).

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// Quest: query-aware top-L page selection over a fully resident cache.
pub struct QuestPolicy;

impl SparsityPolicy for QuestPolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Quest
    }

    fn observe(&self, _table: &mut [PageMeta], _probs: &[f32], _now: u64) {}

    fn select_into(&self, table: &[PageMeta], scores: &[f32], budget_tokens: usize,
                   page_size: usize, out: &mut Vec<usize>) {
        out.clear();
        let budget_pages = (budget_tokens / page_size.max(1)).max(1);
        if table.len() <= budget_pages {
            out.extend(0..table.len());
            return;
        }
        // Rank by representative score; the active (last) page is always
        // included, as in Quest's implementation.  `total_cmp`: a NaN score
        // (e.g. degenerate rep bounds) must not panic the engine — NaNs
        // order above +inf and get selected, which is the conservative
        // failure mode for a *selection* policy.
        //
        // Partial selection (O(n) expected vs the old full-sort O(n log n),
        // per layer per step): only the top-k set is needed, not its
        // internal order.  The index tie-break makes the comparator a total
        // order, so the selected *set* is exactly what the old stable
        // descending sort produced on tied scores (earlier pages win).
        let last = table.len() - 1;
        let k = budget_pages - 1;
        out.extend(0..last);
        if k < out.len() {
            out.select_nth_unstable_by(k, |&a, &b| {
                scores[b].total_cmp(&scores[a]).then(a.cmp(&b))
            });
            out.truncate(k);
        }
        out.push(last);
        out.sort_unstable();
    }

    fn evict_candidate(&self, _table: &[PageMeta]) -> Option<usize> {
        None // retains everything
    }

    fn bounds_memory(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    #[test]
    fn selects_top_scoring_pages_plus_active() {
        let p = QuestPolicy;
        let t = mk_table(&[(16, false); 6]);
        // 6 pages, budget 3 pages = 48 tokens
        let sel = p.select(&t, &[0.1, 0.9, 0.2, 0.8, 0.05, 0.0], 48, 16);
        assert_eq!(sel, vec![1, 3, 5]);
    }

    #[test]
    fn small_table_selected_fully() {
        let p = QuestPolicy;
        let t = mk_table(&[(16, false), (8, false)]);
        assert_eq!(p.select(&t, &[0.0, 0.0], 1024, 16), vec![0, 1]);
    }

    #[test]
    fn tied_scores_select_earlier_pages() {
        // The partial selection must reproduce the old stable sort's
        // deterministic tie handling: equal scores resolve to the earlier
        // page index.
        let p = QuestPolicy;
        let t = mk_table(&[(16, false); 6]);
        // pages 0,2,3 tie at 0.5; budget 3 pages -> two tied picks + active
        let sel = p.select(&t, &[0.5, 0.1, 0.5, 0.5, 0.2, 0.0], 48, 16);
        assert_eq!(sel, vec![0, 2, 5]);
        // one-page budget degenerates to the active page alone
        let sel = p.select(&t, &[0.9, 0.9, 0.9, 0.9, 0.9, 0.0], 16, 16);
        assert_eq!(sel, vec![5]);
    }

    #[test]
    fn never_evicts() {
        let p = QuestPolicy;
        let t = mk_table(&[(16, false); 8]);
        assert_eq!(p.evict_candidate(&t), None);
        assert!(!p.bounds_memory());
    }
}
