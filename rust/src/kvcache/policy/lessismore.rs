//! LessIsMore (arXiv:2508.07101): cross-head *unified* page selection.
//!
//! Per-head top-L selection (Quest) lets every head vote for a different
//! page set: the union inflates the pages actually touched, and heads in
//! the minority are starved of the pages the majority agrees matter.
//! LessIsMore instead normalizes each head's scores into attention shares
//! over pages, sums the shares *across heads*, and selects ONE unified
//! page set every head attends — same budget, fewer distinct pages, and
//! cross-head agreement on milestones is preserved.  A slice of the
//! budget is always spent on the most recent pages (the paper's local
//! window), which also guarantees the active page is selected.
//!
//! This is the policy the `select_unified_into` trait hook exists for:
//! the engine feeds it the page-major per-head score profile
//! (`LayerCache::rep_scores_heads`) instead of the max-reduced classic
//! scores.  Like Quest it is selection-sparse: everything stays resident
//! (O(N) memory), sparsity is in which pages the kernel touches (O(L)
//! time).

use std::cell::RefCell;

use super::{PageMeta, SparsityPolicy};
use crate::config::PolicyKind;

/// LessIsMore: head-aggregated unified top-L page selection over a fully
/// resident cache.
#[derive(Default)]
pub struct LessIsMorePolicy {
    /// Reusable per-page aggregated-share scratch (`select_*` takes
    /// `&self`, hence the cell); steady-state selection allocates nothing.
    /// `RefCell`, not a lock: policies live on one replica thread.
    agg_scratch: RefCell<Vec<f32>>,
}

/// Sum each head's softmax-normalized attention share into one unified
/// per-page importance.  Normalizing per head first means a loud head
/// (large score scale) cannot drown a quiet one — each head contributes
/// exactly one unit of share mass.  A head whose profile is non-finite
/// (NaN/±inf anywhere that poisons its partition sum) abstains rather
/// than panicking or dominating; if every head abstains the aggregate is
/// all-zero and the deterministic index tie-break takes over.
fn aggregate_shares(head_scores: &[f32], n_heads: usize, agg: &mut Vec<f32>) {
    let n_pages = head_scores.len() / n_heads;
    agg.clear();
    agg.resize(n_pages, 0.0);
    for h in 0..n_heads {
        let mut m = f32::NEG_INFINITY;
        for page in head_scores.chunks_exact(n_heads) {
            let s = page[h];
            if s > m {
                m = s;
            }
        }
        let mut denom = 0.0f32;
        for page in head_scores.chunks_exact(n_heads) {
            denom += (page[h] - m).exp();
        }
        if denom > 0.0 && denom.is_finite() {
            for (a, page) in agg.iter_mut().zip(head_scores.chunks_exact(n_heads)) {
                *a += (page[h] - m).exp() / denom;
            }
        }
    }
}

impl SparsityPolicy for LessIsMorePolicy {
    fn kind(&self) -> PolicyKind {
        PolicyKind::LessIsMore
    }

    fn observe(&self, _table: &mut [PageMeta], _probs: &[f32], _now: u64) {}

    fn unified_selection(&self) -> bool {
        true
    }

    fn select_unified_into(&self, table: &[PageMeta], head_scores: &[f32], n_heads: usize,
                           budget_tokens: usize, page_size: usize, out: &mut Vec<usize>) {
        out.clear();
        let n = table.len();
        if n == 0 {
            return;
        }
        let nh = n_heads.max(1);
        debug_assert_eq!(head_scores.len(), n * nh);
        let budget_pages = (budget_tokens / page_size.max(1)).max(1);
        if n <= budget_pages {
            out.extend(0..n);
            return;
        }
        // Unified recent window: 1/8 of the page budget (at least the
        // active page) is always spent on the most recent pages, shared by
        // every head.
        let recent = (budget_pages / 8).max(1);
        let cut = n - recent;
        let k = budget_pages - recent;
        let mut agg = self.agg_scratch.borrow_mut();
        aggregate_shares(head_scores, nh, &mut agg);
        // Top-k of the non-recent prefix by aggregated share.  Partial
        // selection + index tie-break, mirroring Quest: `total_cmp` keeps
        // degenerate scores deterministic and panic-free, ties resolve to
        // the earlier page.
        out.extend(0..cut);
        if k < out.len() {
            out.select_nth_unstable_by(k, |&a, &b| agg[b].total_cmp(&agg[a]).then(a.cmp(&b)));
            out.truncate(k);
        }
        out.extend(cut..n);
        out.sort_unstable();
    }

    fn select_into(&self, table: &[PageMeta], scores: &[f32], budget_tokens: usize,
                   page_size: usize, out: &mut Vec<usize>) {
        // Classic entry point (trace simulator, conformance suite): the
        // reduced per-page scores are a one-head profile, under which
        // unified selection degenerates to softmax-monotone top-L with the
        // same recent window.
        self.select_unified_into(table, scores, 1, budget_tokens, page_size, out);
    }

    fn evict_candidate(&self, _table: &[PageMeta]) -> Option<usize> {
        None // retains everything, like Quest
    }

    fn bounds_memory(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::super::mk_table;
    use super::*;

    #[test]
    fn unified_set_covers_disagreeing_heads() {
        // head 0 cares about page 0, head 1 about page 2; the unified set
        // must include BOTH (plus the recent window) — per-head top-1
        // would have starved one of them.
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 5]);
        #[rustfmt::skip]
        let hs = [
            5.0f32, 0.0, // page 0
            0.0, 0.0,    // page 1
            0.0, 5.0,    // page 2
            0.0, 0.0,    // page 3
            0.0, 0.0,    // page 4 (active)
        ];
        let mut sel = Vec::new();
        p.select_unified_into(&t, &hs, 2, 48, 16, &mut sel);
        assert_eq!(sel, vec![0, 2, 4]);
    }

    #[test]
    fn loud_head_cannot_drown_quiet_head() {
        // Head 0's scores are 100x head 1's scale; per-head share
        // normalization makes both contribute one unit of mass, so head
        // 1's favorite page still wins a slot over head 0's runner-up.
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 5]);
        #[rustfmt::skip]
        let hs = [
            300.0f32, 0.0, // page 0: head 0's favorite
            250.0, 0.0,    // page 1: head 0's runner-up
            0.0, 3.0,      // page 2: head 1's favorite
            0.0, 0.0,      // page 3
            0.0, 0.0,      // page 4 (active)
        ];
        let mut sel = Vec::new();
        p.select_unified_into(&t, &hs, 2, 48, 16, &mut sel);
        assert_eq!(sel, vec![0, 2, 4], "raw-magnitude ranking would pick pages 0,1");
    }

    #[test]
    fn classic_entry_point_is_single_head_top_l() {
        // Through `select_into`, softmax over one head is score-monotone:
        // same shape as Quest's test, with the recent window at the end.
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 6]);
        let sel = p.select(&t, &[0.1, 0.9, 0.2, 0.8, 0.05, 0.0], 48, 16);
        assert_eq!(sel, vec![1, 3, 5]);
    }

    #[test]
    fn tied_scores_select_earlier_pages() {
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 6]);
        let sel = p.select(&t, &[0.5; 6], 48, 16);
        assert_eq!(sel, vec![0, 1, 5]);
        // one-page budget degenerates to the active page alone
        let sel = p.select(&t, &[0.5; 6], 16, 16);
        assert_eq!(sel, vec![5]);
    }

    #[test]
    fn recent_window_scales_with_budget() {
        // 16-page budget -> 2 recent pages; the two most recent pages are
        // always in, even with zero aggregated share.
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 20]);
        let mut scores = vec![1.0f32; 20];
        scores[18] = -50.0;
        scores[19] = -50.0;
        let sel = p.select(&t, &scores, 256, 16);
        assert_eq!(sel.len(), 16);
        assert!(sel.contains(&18) && sel.contains(&19), "recent window always selected");
        assert_eq!(&sel[..14], &(0..14).collect::<Vec<_>>()[..], "ties pick earliest prefix");
    }

    #[test]
    fn small_table_selected_fully() {
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false), (8, false)]);
        let mut sel = Vec::new();
        p.select_unified_into(&t, &[0.0; 4], 2, 1024, 16, &mut sel);
        assert_eq!(sel, vec![0, 1]);
    }

    #[test]
    fn non_finite_heads_abstain_deterministically() {
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 5]);
        #[rustfmt::skip]
        let hs = [
            f32::NAN, 0.0,          // NaN poisons head 0 everywhere
            f32::NAN, 9.0,          // head 1's favorite: page 1
            f32::NAN, 0.0,
            f32::NAN, f32::NEG_INFINITY,
            f32::NAN, 0.0,
        ];
        let mut sel = Vec::new();
        p.select_unified_into(&t, &hs, 2, 48, 16, &mut sel);
        assert_eq!(sel, vec![0, 1, 4], "head 0 abstains; head 1 still ranks");
        // every head poisoned: all-zero aggregate, earliest-index ties
        let all_nan = [f32::NAN; 10];
        p.select_unified_into(&t, &all_nan, 2, 48, 16, &mut sel);
        assert_eq!(sel, vec![0, 1, 4]);
    }

    #[test]
    fn scratch_reuse_is_pure() {
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 8]);
        let scores = [0.3f32, 0.9, 0.1, 0.7, 0.2, 0.8, 0.4, 0.0];
        let mut a = vec![99usize; 5];
        let mut b = Vec::new();
        p.select_into(&t, &scores, 64, 16, &mut a);
        p.select_into(&t, &scores, 64, 16, &mut b);
        assert_eq!(a, b, "dirty out + warm scratch must not change the selection");
    }

    #[test]
    fn never_evicts() {
        let p = LessIsMorePolicy::default();
        let t = mk_table(&[(16, false); 8]);
        assert_eq!(p.evict_candidate(&t), None);
        assert!(!p.bounds_memory());
        assert!(p.unified_selection());
    }
}
